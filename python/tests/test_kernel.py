# pytest: Pallas kernels vs pure-jnp oracle — the CORE L1 correctness
# signal.  Hypothesis sweeps shapes (and the f32/bf16 dtypes the serving
# stack uses); every kernel must match its ref to float tolerance.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sparse_attn, full_attn, fused_attn

jax.config.update("jax_platform_name", "cpu")


def rand_inputs(rng, S, Q, Hq, Hkv, D, T, W, dtype=np.float32):
    q = rng.normal(size=(S, Q, Hq, D)).astype(dtype)
    k = rng.normal(size=(S, T, Hkv, D)).astype(dtype)
    v = rng.normal(size=(S, T, Hkv, D)).astype(dtype)
    pos = rng.integers(0, T - Q, size=(S,)).astype(np.int32)
    idx = rng.integers(-1, T, size=(S, Hkv, W)).astype(np.int32)
    qv = rng.integers(1, Q + 1, size=(S,)).astype(np.int32)
    kind = rng.integers(0, 2, size=(S,)).astype(np.int32)
    return map(jnp.asarray, (q, k, v, pos, idx, qv, kind))


shape_strategy = st.tuples(
    st.integers(1, 4),            # S
    st.integers(1, 6),            # Q
    st.sampled_from([2, 4, 6]),   # Hkv candidates -> Hq = Hkv * G
    st.sampled_from([1, 2, 3]),   # G
    st.sampled_from([8, 16, 32]), # D
    st.sampled_from([128, 256]),  # T (multiple of kernel TILE)
    st.integers(1, 48),           # W
    st.integers(0, 2**31 - 1),    # seed
)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_sparse_attn_matches_ref(params):
    S, Q, Hkv, G, D, T, W, seed = params
    Hq = Hkv * G
    rng = np.random.default_rng(seed)
    q, k, v, pos, idx, _, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, W)
    out_ref = ref.sparse_attn_ref(q, k, v, idx, pos)
    out_pl = sparse_attn(q, k, v, idx, pos)
    np.testing.assert_allclose(out_pl, out_ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(shape_strategy)
def test_full_attn_matches_ref(params):
    S, Q, Hkv, G, D, T, _, seed = params
    Hq = Hkv * G
    rng = np.random.default_rng(seed)
    q, k, v, pos, _, qv, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, 4)
    o_r, d_r, l_r = ref.full_attn_ref(q, k, v, pos, qv)
    o_p, d_p, l_p = full_attn(q, k, v, pos, qv)
    np.testing.assert_allclose(o_p, o_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(d_p, d_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(l_p, l_r, rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(shape_strategy)
def test_fused_attn_matches_ref(params):
    S, Q, Hkv, G, D, T, W, seed = params
    Hq = Hkv * G
    rng = np.random.default_rng(seed)
    q, k, v, pos, idx, qv, kind = rand_inputs(rng, S, Q, Hq, Hkv, D, T, W)
    o_r, d_r = ref.fused_attn_ref(q, k, v, idx, pos, qv, kind)
    o_p, d_p = fused_attn(q, k, v, idx, pos, qv, kind)
    np.testing.assert_allclose(o_p, o_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(d_p, d_r, rtol=2e-5, atol=2e-5)


def test_bf16_path():
    """The TPU-native dtype must flow through both kernels."""
    rng = np.random.default_rng(0)
    S, Q, Hq, Hkv, D, T, W = 2, 3, 4, 2, 16, 128, 16
    q, k, v, pos, idx, qv, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, W)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    o_r = ref.sparse_attn_ref(qb, kb, vb, idx, pos)
    o_p = sparse_attn(qb, kb, vb, idx, pos)
    np.testing.assert_allclose(
        np.asarray(o_p, np.float32), np.asarray(o_r, np.float32), rtol=3e-2, atol=3e-2
    )


def test_full_attn_dump_is_probability():
    rng = np.random.default_rng(1)
    q, k, v, pos, _, qv, _ = rand_inputs(rng, 3, 4, 4, 2, 16, 256, 4)
    _, dump, _ = full_attn(q, k, v, pos, qv)
    sums = np.asarray(dump).sum(-1)
    np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-4)
    assert (np.asarray(dump) >= 0).all()


def test_lse_rematerialisation_identity():
    """exp(logits - lse) must reproduce the softmax the kernel used —
    the identity PillarAttn's zero-overhead identification relies on."""
    rng = np.random.default_rng(2)
    S, Q, Hq, Hkv, D, T = 2, 3, 2, 2, 8, 128
    q, k, v, pos, _, qv, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, 4)
    out, dump, lse = full_attn(q, k, v, pos, qv)
    # rematerialise probabilities for slot 0, head 0, query 0
    scale = 1.0 / np.sqrt(D)
    kx = np.repeat(np.asarray(k), Hq // Hkv, axis=2)
    logits = np.einsum("qhd,thd->qht", np.asarray(q)[0], kx[0]) * scale
    t = np.arange(T)
    mask = t[None, None, :] <= (np.asarray(pos)[0] + np.arange(Q))[:, None, None]
    logits = np.where(mask, logits, -1e30)
    p = np.exp(logits - np.asarray(lse)[0][:, :, None])
    np.testing.assert_allclose(p.sum(-1), np.ones((Q, Hq)), rtol=1e-4)


def test_sparse_idx_holes_are_ignored():
    """-1 entries must not contribute attention mass."""
    rng = np.random.default_rng(3)
    S, Q, Hq, Hkv, D, T, W = 1, 1, 2, 2, 8, 128, 8
    q, k, v, pos, idx, _, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, W)
    idx = np.asarray(idx).copy()
    idx[:, :, 1:] = -1
    idx[:, :, 0] = 5
    pos = jnp.asarray(np.array([100], np.int32))
    out = sparse_attn(q, k, v, jnp.asarray(idx), pos)
    # attending exactly one token => each q head outputs its kv head's value
    g = Hq // Hkv
    expect = np.asarray(v)[0, 5][np.repeat(np.arange(Hkv), g)]  # [Hq, D]
    np.testing.assert_allclose(np.asarray(out)[0, 0], expect, rtol=1e-5)


def test_sparse_causality():
    """Future entries in idx (beyond pos+q) must be masked."""
    rng = np.random.default_rng(4)
    S, Q, Hq, Hkv, D, T, W = 1, 2, 2, 2, 8, 128, 6
    q, k, v, _, _, _, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, W)
    pos = jnp.asarray(np.array([10], np.int32))
    # idx contains only past (3) and future (50) tokens
    idx = np.full((1, Hkv, W), -1, np.int32)
    idx[:, :, 0] = 3
    idx[:, :, 1] = 50
    out = sparse_attn(q, k, v, jnp.asarray(idx), pos)
    idx2 = np.full((1, Hkv, W), -1, np.int32)
    idx2[:, :, 0] = 3
    out2 = sparse_attn(q, k, v, jnp.asarray(idx2), pos)
    np.testing.assert_allclose(out, out2, rtol=1e-6)


def test_full_matches_sparse_with_complete_index():
    """Page-size-1 unified abstraction: full attention == sparse attention
    with the complete index set (the §4.2 uniform abstraction)."""
    rng = np.random.default_rng(5)
    S, Q, Hq, Hkv, D, T = 2, 2, 4, 2, 16, 128
    q, k, v, pos, _, qv, _ = rand_inputs(rng, S, Q, Hq, Hkv, D, T, 4)
    full_idx = np.broadcast_to(np.arange(T, dtype=np.int32), (S, Hkv, T)).copy()
    o_sparse = sparse_attn(q, k, v, jnp.asarray(full_idx), pos)
    o_full, _, _ = full_attn(q, k, v, pos, qv)
    np.testing.assert_allclose(o_sparse, o_full, rtol=2e-5, atol=2e-5)
