//! `drafter_dispatch` — trait-dispatch overhead vs the enum interpreter.
//!
//! PR motivation guard: replacing the engine's `match cfg.drafter` sites
//! with `Box<dyn Drafter>` calls must not put measurable cost on the
//! per-step path.  Two measurements:
//!
//! 1. **Micro**: per-call latency of `Drafter::plan` through a rotating
//!    `Vec<Box<dyn Drafter>>` (defeats devirtualisation, exercises the
//!    real vtable) vs the equivalent enum-match sizing decision the old
//!    engine hardwired.  The difference is the dispatch overhead.
//! 2. **End-to-end**: a real engine run (PillarAttn, default workload) to
//!    put that overhead in per-iteration context — the engine makes at
//!    most ~(slots + drafter-count) trait calls per iteration.
//!
//! Gate (enforced, like `pillar_select`): dispatch overhead extrapolated
//! to a full iteration must stay under 1% of the measured per-iteration
//! wallclock.  Emits `reports/BENCH_drafter_dispatch.json`.

use super::BenchCtx;
use crate::engine::{Engine, EngineConfig};
use crate::spec::{DraftCtx, Drafter, DrafterKind, DrafterRegistry};
use crate::util::json::{arr, num, obj, s as jstr, Json};
use crate::workload::{Dataset, WorkloadGen};
use anyhow::Result;
use std::hint::black_box;
use std::time::Instant;

/// The pre-trait engine's per-round sizing decision (`first_round_target`
/// in the enum-interpreter core), kept as the dispatch baseline.
fn enum_plan_target(kind: &DrafterKind, k: usize) -> usize {
    if kind.is_self_spec() {
        k
    } else {
        0
    }
}

pub fn drafter_dispatch(ctx: &mut BenchCtx) -> Result<()> {
    println!("drafter_dispatch: Box<dyn Drafter> vs enum-interpreter per-step cost");
    let rt = ctx.rt()?;
    let m = rt.cfg.model.clone();
    let kinds = [
        DrafterKind::Vanilla,
        DrafterKind::Pillar { w: 64 },
        DrafterKind::Window { w: 64 },
        DrafterKind::OracleTopK { w: 64 },
        DrafterKind::NGram { n: 3 },
        DrafterKind::Eagle,
        DrafterKind::TriForce { w: 64 },
    ];
    let reg = DrafterRegistry::with_builtins();
    let mut drafters: Vec<Box<dyn Drafter>> = kinds
        .iter()
        .map(|k| reg.create(k, &m))
        .collect::<Result<_>>()?;

    let mk_ctx = |i: usize| DraftCtx {
        req_id: i as u64,
        slot_idx: i % m.slots,
        k: 8,
        sched_cap: 8,
        len: 64 + i % 128,
        remaining: 100,
        pending: (i % m.vocab) as i32,
        first_round: false,
        ngram: None,
    };

    // Warm both paths, then measure.
    let reps = 200_000 * ctx.n_requests.max(1);
    for i in 0..1_000 {
        let d = &mut drafters[i % kinds.len()];
        black_box(d.plan(&mk_ctx(i)).target);
        black_box(enum_plan_target(&kinds[i % kinds.len()], 8));
    }
    let t0 = Instant::now();
    let mut acc = 0usize;
    for i in 0..reps {
        let d = &mut drafters[black_box(i % kinds.len())];
        acc = acc.wrapping_add(d.plan(black_box(&mk_ctx(i))).target);
    }
    black_box(acc);
    let dyn_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;

    let t0 = Instant::now();
    let mut acc = 0usize;
    for i in 0..reps {
        let kind = &kinds[black_box(i % kinds.len())];
        acc = acc.wrapping_add(enum_plan_target(kind, black_box(8)));
    }
    black_box(acc);
    let enum_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
    let overhead_ns = (dyn_ns - enum_ns).max(0.0);
    println!(
        "  plan() per call: dyn {dyn_ns:.1}ns, enum {enum_ns:.1}ns \
         (dispatch overhead {overhead_ns:.1}ns)"
    );

    // End-to-end context: one engine run, per-iteration wallclock.
    let reqs = WorkloadGen::new(rt.cfg.grammar.clone(), m.clone(), Dataset::Aime, ctx.seed)
        .offline_batch(ctx.n_requests.max(2));
    let mut eng = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )?;
    let r = eng.run(reqs)?;
    println!("  {}", r.summary());
    let iter_us = r.wall_s * 1e6 / r.iterations.max(1) as f64;
    // Upper bound on trait calls an iteration makes: per verified slot
    // one `plan` (round restart) + one `on_verify`, per drafter one
    // `propose_batch`/`after_draft` hook pair, plus per-slot capability
    // reads at admission (bounded by slots) — ~2·(slots + drafters).
    let calls_per_iter = (2 * (m.slots + kinds.len())) as f64;
    let overhead_us_per_iter = overhead_ns * calls_per_iter / 1e3;
    let ratio = overhead_us_per_iter / iter_us.max(1e-9);
    println!(
        "  per-iteration: engine {iter_us:.1}us, dispatch bound {overhead_us_per_iter:.4}us \
         ({:.4}% — gate < 1%)",
        ratio * 100.0
    );

    let json = obj(vec![
        ("experiment", jstr("drafter_dispatch")),
        ("harness", jstr("cargo bench -- drafter_dispatch")),
        ("plan_dyn_ns", num(dyn_ns)),
        ("plan_enum_ns", num(enum_ns)),
        ("dispatch_overhead_ns", num(overhead_ns)),
        ("engine_iter_us", num(iter_us)),
        ("calls_per_iter_bound", num(calls_per_iter)),
        ("overhead_ratio", num(ratio)),
        (
            "drafters",
            arr(kinds.iter().map(|k| jstr(&k.name())).collect::<Vec<Json>>()),
        ),
    ]);
    ctx.save("BENCH_drafter_dispatch.json", &json.to_string())?;
    // Enforced after saving, so a regression still leaves evidence.
    anyhow::ensure!(
        ratio < 0.01,
        "drafter_dispatch gate failed: dispatch overhead is {:.3}% of an \
         engine iteration (need < 1%)",
        ratio * 100.0
    );
    Ok(())
}
