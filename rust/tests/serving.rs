//! End-to-end serving-front-end tests over real TCP sockets (loopback,
//! ephemeral ports): the acceptance criteria of the network subsystem.
//!
//! * Two concurrent tenants stream tokens over the wire **bit-identical**
//!   to an in-process `Engine::run` of the same requests — the serving
//!   layer adds transport, admission and fairness, never different math.
//! * A slow reader exhausts its credit window, trips the stall clock, and
//!   is drop-to-cancelled with a typed `SlowReader` error — while a
//!   healthy connection's sessions finish undisturbed.
//! * Above the KV watermark new submissions are shed with a typed
//!   `KvShed` error while every admitted session runs to completion.
//! * `/metrics` serves a parseable Prometheus exposition with per-tenant
//!   labelled series; graceful drain leaves an accurate summary.

use std::collections::BTreeMap;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::time::{Duration, Instant};

use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::runtime::Runtime;
use sparsespec::serving::{
    run_load, wire, ClientConfig, ErrorCode, Frame, Server, ServerConfig, TenantLoad,
};
use sparsespec::spec::DrafterKind;
use sparsespec::workload::{Dataset, Request, WorkloadGen};

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, seed)
            .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

/// In-process greedy reference for a request set (outputs are schedule-
/// independent at temperature 0, pinned by tests/sessions.rs).
fn reference_outputs(
    rt: &Rc<Runtime>,
    cfg: EngineConfig,
    reqs: Vec<Request>,
) -> BTreeMap<u64, Vec<i32>> {
    let mut eng = Engine::new(rt.clone(), cfg).expect("reference engine");
    eng.run(reqs).expect("reference run").outputs
}

/// Read frames off a raw socket until `done` says stop (or panic at the
/// deadline); returns everything read.
fn read_frames_until(
    r: &mut BufReader<TcpStream>,
    deadline: Instant,
    mut done: impl FnMut(&Frame) -> bool,
) -> Vec<Frame> {
    let mut out = Vec::new();
    loop {
        assert!(Instant::now() < deadline, "deadline waiting for frames; got {out:?}");
        match wire::read_frame(r) {
            Ok(Some(f)) => {
                let stop = done(&f);
                out.push(f);
                if stop {
                    return out;
                }
            }
            Ok(None) => panic!("server hung up early; got {out:?}"),
            Err(e) => panic!("wire error {e}; got {out:?}"),
        }
    }
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let reader = BufReader::new(stream.try_clone().expect("clone"));
    (stream, reader)
}

fn http_get_metrics(addr: std::net::SocketAddr) -> String {
    let mut s = TcpStream::connect(addr).expect("metrics connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n")
        .expect("metrics GET");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("metrics body");
    resp
}

/// Acceptance pin: two concurrent tenants over real TCP, streamed tokens
/// bit-identical to `Engine::run`, `/metrics` parseable with per-tenant
/// series, graceful drain with an accurate summary.
#[test]
fn two_tenants_stream_bit_identical_to_in_process_run() {
    let rt = runtime();
    let mk_cfg = || {
        let mut c = EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8);
        c.max_iterations = u64::MAX;
        c
    };
    // unique ids across tenants so one reference run covers both
    let mut acme = small_requests(&rt, 4, 32, 11);
    let mut hobby = small_requests(&rt, 4, 32, 22);
    for (i, r) in acme.iter_mut().enumerate() {
        r.id = 1000 + i as u64;
    }
    for (i, r) in hobby.iter_mut().enumerate() {
        r.id = 2000 + i as u64;
    }
    let mut union = acme.clone();
    union.extend(hobby.iter().cloned());
    let reference = reference_outputs(&rt, mk_cfg(), union);

    let mut scfg = ServerConfig::new(&artifacts_dir(), mk_cfg());
    scfg.addr = "127.0.0.1:0".into();
    scfg.metrics_addr = Some("127.0.0.1:0".into());
    let server = Server::spawn(scfg).expect("server spawns");
    let metrics_addr = server.metrics_addr().expect("metrics listener");

    let mut ccfg = ClientConfig::new(&server.addr().to_string());
    ccfg.timeout_s = 60.0;
    ccfg.tenants.push(TenantLoad { name: "acme".into(), requests: acme.clone(), drafter: String::new() });
    ccfg.tenants.push(TenantLoad { name: "hobby".into(), requests: hobby.clone(), drafter: String::new() });
    let report = run_load(ccfg).expect("client run");

    assert_eq!(report.completed, 8, "all sessions complete: {}", report.render());
    assert_eq!(report.failed, 0);
    assert_eq!(report.refused_total(), 0);
    for (tenant, reqs) in [("acme", &acme), ("hobby", &hobby)] {
        for r in reqs.iter() {
            let got = report
                .outputs
                .get(&(tenant.to_string(), r.id))
                .unwrap_or_else(|| panic!("missing output for {tenant}/{}", r.id));
            assert_eq!(
                got,
                &reference[&r.id],
                "tenant {tenant} req {} streamed tokens differ from Engine::run",
                r.id
            );
        }
        assert_eq!(
            report.metrics.counter("sessions_completed", &[("tenant", tenant)]),
            4.0
        );
    }

    // /metrics: poll until the post-completion publish lands, then check
    // it parses as a Prometheus exposition with per-tenant series.
    let deadline = Instant::now() + Duration::from_secs(20);
    let body = loop {
        let resp = http_get_metrics(metrics_addr);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        if body.contains("tenant=\"acme\"") && body.contains("tenant=\"hobby\"") {
            break body;
        }
        assert!(Instant::now() < deadline, "per-tenant series never published:\n{body}");
        std::thread::sleep(Duration::from_millis(100));
    };
    let mut series = 0;
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("unparseable: {line}"));
        assert!(name.starts_with("sparsespec_"), "unprefixed series: {line}");
        value.parse::<f64>().unwrap_or_else(|_| panic!("non-numeric sample: {line}"));
        series += 1;
    }
    assert!(series > 10, "suspiciously few series:\n{body}");
    assert!(
        body.contains("sparsespec_sessions_completed{tenant=\"acme\"} 4"),
        "labelled completion counter missing:\n{body}"
    );

    server.shutdown(false);
    let summary = server.join().expect("drain");
    assert_eq!(summary.sessions_completed, 8);
    assert_eq!(summary.sessions_cancelled, 0);
    assert_eq!(summary.sessions_refused, 0);
    assert!(summary.exposition.contains("tenant=\"hobby\""));
    // engine-side report merged into the final exposition on drain
    assert_eq!(summary.report.outputs.len(), 8);
}

/// Acceptance pin: a reader that never returns credit stalls, is dropped
/// with a typed SlowReader error and a cancelled Finished — and a healthy
/// concurrent connection's sessions stream to completion bit-identically.
#[test]
fn slow_reader_is_cancelled_without_disturbing_others() {
    let rt = runtime();
    let mk_cfg = || {
        let mut c = EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(4);
        c.max_iterations = u64::MAX;
        c
    };
    let mut scfg = ServerConfig::new(&artifacts_dir(), mk_cfg());
    scfg.addr = "127.0.0.1:0".into();
    scfg.send_window = 4; // tiny credit window: backpressure bites fast
    scfg.send_queue_cap = 4 + 64;
    scfg.stall_ticks = 40;
    let server = Server::spawn(scfg).expect("server spawns");
    let deadline = Instant::now() + Duration::from_secs(30);

    // Slow connection: one long request, then silence — no reads, no
    // credit. 450 tokens at k=4 is ~90 engine iterations, far past the
    // 40-tick stall allowance, so the drop lands mid-generation.
    let (mut slow_w, mut slow_r) = connect(server.addr());
    let mut long_req = small_requests(&rt, 1, usize::MAX, 33).remove(0);
    long_req.max_new = 450;
    wire::write_frame(
        &mut slow_w,
        &Frame::Submit {
            req_id: 77,
            seed: long_req.seed,
            max_new: long_req.max_new as u32,
            tenant: "victim".into(),
            drafter: String::new(),
            prompt: long_req.prompt.clone(),
        },
    )
    .expect("slow submit");

    // Healthy connection: pre-grant a huge credit window so the tiny
    // server default never gates it, then stream two sessions fully.
    let healthy_reqs = small_requests(&rt, 2, 32, 44);
    let reference = reference_outputs(&rt, mk_cfg(), healthy_reqs.clone());
    let (mut h_w, mut h_r) = connect(server.addr());
    wire::write_frame(&mut h_w, &Frame::Credit { n: 1 << 20 }).expect("credit");
    for r in &healthy_reqs {
        wire::write_frame(
            &mut h_w,
            &Frame::Submit {
                req_id: r.id,
                seed: r.seed,
                max_new: r.max_new as u32,
                tenant: "healthy".into(),
                drafter: String::new(),
                prompt: r.prompt.clone(),
            },
        )
        .expect("healthy submit");
    }
    let mut by_req: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut session_to_req: BTreeMap<u64, u64> = BTreeMap::new();
    let mut finished = 0usize;
    read_frames_until(&mut h_r, deadline, |f| {
        match f {
            Frame::Accepted { req_id, session, .. } => {
                session_to_req.insert(*session, *req_id);
            }
            Frame::Token { session, token, .. } => {
                by_req.entry(session_to_req[session]).or_default().push(*token);
            }
            Frame::Finished { reason, .. } => {
                assert_eq!(*reason, 0, "healthy session must complete");
                finished += 1;
            }
            Frame::Error { detail, .. } => panic!("healthy conn got error: {detail}"),
            _ => {}
        }
        finished == 2
    });
    for r in &healthy_reqs {
        assert_eq!(
            by_req.get(&r.id),
            reference.get(&r.id),
            "slow-reader drop disturbed healthy request {}",
            r.id
        );
    }

    // The slow connection's backlog is in the kernel buffer: exactly the
    // credit window of tokens, then the typed drop and the cancel.
    let mut tokens = 0u32;
    let mut saw_error: Option<ErrorCode> = None;
    let frames = read_frames_until(&mut slow_r, deadline, |f| {
        match f {
            Frame::Token { .. } => tokens += 1,
            Frame::Error { code, .. } => saw_error = Some(*code),
            _ => {}
        }
        matches!(f, Frame::Finished { .. })
    });
    assert_eq!(tokens, 4, "exactly the credit window leaks out: {frames:?}");
    assert_eq!(saw_error, Some(ErrorCode::SlowReader), "{frames:?}");
    match frames.last() {
        Some(Frame::Finished { reason, tokens, .. }) => {
            assert_eq!(*reason, 1, "slow session ends cancelled");
            assert_eq!(*tokens, 4);
        }
        other => panic!("expected Finished, got {other:?}"),
    }

    server.shutdown(false);
    let summary = server.join().expect("drain");
    assert_eq!(summary.sessions_completed, 2);
    assert_eq!(summary.sessions_cancelled, 1);
    assert!(summary.exposition.contains("sparsespec_slow_reader_drops 1"));
}

/// Acceptance pin: above the KV watermark new submissions get a typed
/// KvShed refusal; everything admitted still runs to completion with
/// outputs bit-identical to the in-process reference.
#[test]
fn kv_watermark_sheds_new_submissions_while_admitted_work_completes() {
    let rt = runtime();
    let pad = rt.cfg.model.prompt_pad;
    let k = 4usize;
    let long_new = 450usize;
    // budget fits the long request (worst-case pad + max_new + k + 2,
    // plus headroom) — and the near-zero watermark sheds any submission
    // arriving while KV is occupied at all.
    let budget = pad + long_new + k + 2 + 32;
    let mk_cfg = || {
        let mut c = EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(k)
            .with_kv(KvPolicy::parse("dynamic").unwrap(), budget);
        c.max_iterations = u64::MAX;
        c
    };
    let mut scfg = ServerConfig::new(&artifacts_dir(), mk_cfg());
    scfg.addr = "127.0.0.1:0".into();
    scfg.kv_shed_watermark = 1e-6;
    let server = Server::spawn(scfg).expect("server spawns");
    let deadline = Instant::now() + Duration::from_secs(30);

    let mut long_req = small_requests(&rt, 1, usize::MAX, 5).remove(0);
    long_req.max_new = long_new;
    long_req.id = 1;
    let reference = reference_outputs(&rt, mk_cfg(), vec![long_req.clone()]);

    // Conn 1: the long-running session; credit granted up front so the
    // server streams freely into the kernel buffer.
    let (mut a_w, mut a_r) = connect(server.addr());
    wire::write_frame(&mut a_w, &Frame::Credit { n: 1 << 20 }).expect("credit");
    wire::write_frame(
        &mut a_w,
        &Frame::Submit {
            req_id: long_req.id,
            seed: long_req.seed,
            max_new: long_req.max_new as u32,
            tenant: "hog".into(),
            drafter: String::new(),
            prompt: long_req.prompt.clone(),
        },
    )
    .expect("submit long");
    // wait until it is visibly generating — KV is in use from here on
    read_frames_until(&mut a_r, deadline, |f| matches!(f, Frame::Token { .. }));

    // Conn 2: probe submissions. While any session holds KV the watermark
    // sheds the probe; an admitted probe (possible once everything else
    // finished) must itself complete — then the next probe sheds on it.
    let (mut b_w, mut b_r) = connect(server.addr());
    wire::write_frame(&mut b_w, &Frame::Credit { n: 1 << 20 }).expect("credit");
    let small = small_requests(&rt, 1, 8, 6).remove(0);
    let mut shed: Option<String> = None;
    for attempt in 0..40u64 {
        let req_id = 500 + attempt;
        wire::write_frame(
            &mut b_w,
            &Frame::Submit {
                req_id,
                seed: small.seed,
                max_new: small.max_new as u32,
                tenant: "probe".into(),
                drafter: String::new(),
                prompt: small.prompt.clone(),
            },
        )
        .expect("submit probe");
        let mut refusal: Option<(ErrorCode, String)> = None;
        read_frames_until(&mut b_r, deadline, |f| match f {
            Frame::Error { code, detail, .. } => {
                refusal = Some((*code, detail.clone()));
                true
            }
            Frame::Finished { reason, .. } => {
                assert_eq!(*reason, 0, "admitted probe must complete");
                true
            }
            _ => false,
        });
        if let Some((code, detail)) = refusal {
            assert_eq!(code, ErrorCode::KvShed, "typed shed expected, got {code:?}: {detail}");
            shed = Some(detail);
            break;
        }
    }
    let detail = shed.expect("no probe was ever shed above the watermark");
    assert!(detail.contains("watermark"), "{detail}");

    // The admitted long session runs to completion, bit-identical.
    let mut tokens: Vec<i32> = Vec::new();
    read_frames_until(&mut a_r, deadline, |f| {
        if let Frame::Token { token, .. } = f {
            tokens.push(*token);
        }
        matches!(f, Frame::Finished { .. })
    });
    assert_eq!(&tokens, &reference[&long_req.id], "shedding disturbed the admitted session");

    server.shutdown(false);
    let summary = server.join().expect("drain");
    assert!(summary.sessions_refused >= 1);
    assert!(
        summary.exposition.contains("sessions_refused{code=\"kv_shed\""),
        "{}",
        summary.exposition
    );
}

/// Draining: while a drain is in progress, new connections are turned
/// away with a typed refusal; in-flight work still finishes and the
/// summary's engine report carries its output.
///
/// The in-flight session is held open deterministically by credit
/// starvation (window 4, astronomically large stall allowance), so the
/// drain window is as wide as the test needs it to be.
#[test]
fn graceful_drain_refuses_new_connections() {
    let rt = runtime();
    let mut cfg = EngineConfig::new(DrafterKind::Vanilla).with_k(4);
    cfg.max_iterations = u64::MAX;
    let mut scfg = ServerConfig::new(&artifacts_dir(), cfg);
    scfg.addr = "127.0.0.1:0".into();
    scfg.send_window = 4;
    scfg.send_queue_cap = 4 + 64;
    scfg.stall_ticks = u64::MAX / 2; // never slow-reader-drop in this test
    let server = Server::spawn(scfg).expect("server spawns");
    let deadline = Instant::now() + Duration::from_secs(30);

    // a session larger than the credit window: 4 tokens stream, the rest
    // stay undeliverable until we grant credit — the drain must wait
    let mut req = small_requests(&rt, 1, usize::MAX, 3).remove(0);
    req.max_new = 64;
    let (mut w, mut r) = connect(server.addr());
    wire::write_frame(
        &mut w,
        &Frame::Submit {
            req_id: req.id,
            seed: req.seed,
            max_new: req.max_new as u32,
            tenant: "t".into(),
            drafter: String::new(),
            prompt: req.prompt.clone(),
        },
    )
    .unwrap();
    let mut seen = 0;
    read_frames_until(&mut r, deadline, |f| {
        if matches!(f, Frame::Token { .. }) {
            seen += 1;
        }
        seen == 4
    });

    server.shutdown(false);
    // a late connection is refused typed (polling: the engine thread has
    // to observe the drain first)
    let mut refused = false;
    while Instant::now() < deadline {
        let stream = TcpStream::connect(server.addr()).expect("listener stays up during drain");
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut lr = BufReader::new(stream);
        let mut saw_drain = false;
        loop {
            match wire::read_frame(&mut lr) {
                Ok(Some(Frame::Error { code: ErrorCode::Draining, .. })) => saw_drain = true,
                Ok(Some(_)) => continue,
                Ok(None) | Err(_) => break,
            }
        }
        if saw_drain {
            refused = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(refused, "late connection never saw a typed Draining refusal");

    // release the hostage: credit lets the session finish, the drain ends
    wire::write_frame(&mut w, &Frame::Credit { n: 1 << 20 }).unwrap();
    read_frames_until(&mut r, deadline, |f| matches!(f, Frame::Finished { .. }));
    let summary = server.join().expect("drain");
    assert_eq!(summary.sessions_completed, 1);
    assert_eq!(summary.report.outputs.len(), 1);
}
