# Cross-check of the rust/src/spec/pillar.rs selection rewrite (PR 1).
#
# Two 1:1 Python ports of the Rust code are fuzzed against each other:
#   * `legacy_*`  — the seed pipeline (full sort + set dedup, per-call
#     lists), identical to `spec::pillar::reference` on the Rust side and
#     to `ref.py::topk_ids_ref`'s semantics;
#   * `new_*`     — the rewritten pipeline (contiguous-range candidate
#     pool, partial-select top-k, range-check dedup in compose).
#
# This is the committed form of the 200k-case fuzz cited in
# EXPERIMENTS.md §Perf; it checks algorithm semantics (set equality,
# ordering, -1 padding, tie rule) — compiling the Rust is the tier-1
# gate's job.  Case count scales via PILLAR_PORT_CASES (default 5000).
import os
import random


def legacy_topk(scores, length, budget, sinks, recent):
    chosen = list(range(min(sinks, length)))
    lo = max(length - recent, 0)
    chosen += [t for t in range(lo, length) if t >= sinks]
    chosen = chosen[:budget]
    rest = budget - len(chosen)
    if rest > 0 and length > 0:
        taken = set(chosen)
        cand = [t for t in range(length) if t not in taken]
        cand.sort(key=lambda t: (-scores[t], t))
        chosen += cand[:rest]
    chosen.sort()
    return chosen + [-1] * (budget - len(chosen))


def new_select(scores, length, budget, sinks, recent):
    # mirrors select_into: sinks [0, s_eff) and recent [lo, len) are
    # contiguous, the top-k pool is exactly the gap [s_eff, lo)
    out = []
    s_eff = min(sinks, length)
    lo = max(max(length - recent, 0), s_eff)
    n_fixed = s_eff + (length - lo)
    out += list(range(min(s_eff, budget)))
    if n_fixed >= budget:
        for t in range(lo, length):
            if len(out) >= budget:
                break
            out.append(t)
        return out + [-1] * (budget - len(out))
    rest = budget - n_fixed
    pool = lo - s_eff
    if rest > 0 and pool > 0:
        k = min(rest, pool)
        cand = sorted(range(s_eff, lo), key=lambda t: (-scores[t], t))
        out += cand[:k]  # partial select picks the same set: total order
    out += list(range(lo, length))
    out.sort()
    return out + [-1] * (budget - len(out))


def legacy_compose_row(crit, length, budget, sinks, recent):
    s = list(range(min(sinks, length)))
    lo = max(length - recent, 0)
    s += [t for t in range(lo, length) if t >= sinks]
    have = set(s)
    for c in crit:
        if len(s) >= budget:
            break
        if 0 <= c < length and c not in have:
            s.append(c)
    s = s[:budget]
    s.sort()
    return s + [-1] * (budget - len(s))


def new_compose_row(crit_row, length, budget, sinks, recent):
    # mirrors compose_into: membership == two range checks
    s_eff = min(sinks, length)
    lo = max(max(length - recent, 0), s_eff)
    out = list(range(min(s_eff, budget)))
    for t in range(lo, length):
        if len(out) >= budget:
            break
        out.append(t)
    for c in crit_row:
        if len(out) >= budget or c < 0:
            break
        if s_eff <= c < lo:
            out.append(c)
    out.sort()
    return out + [-1] * (budget - len(out))


def test_rewrite_matches_seed_semantics_fuzz():
    cases = int(os.environ.get("PILLAR_PORT_CASES", "5000"))
    rng = random.Random(0x5EED)
    for case in range(cases):
        budget = rng.randint(1, 40)
        sinks = rng.randint(0, budget)          # beyond pillar() invariants
        recent = rng.randint(0, budget + 4)     # sinks+recent may exceed budget
        t_dim = rng.randint(1, 120)
        length = rng.randint(0, t_dim)
        tie_levels = rng.choice([1, 2, 4, 1000])
        scores = [rng.randint(0, tie_levels) / tie_levels for _ in range(t_dim)]

        a = legacy_topk(scores, length, budget, sinks, recent)
        b = new_select(scores, length, budget, sinks, recent)
        assert a == b, f"select mismatch case={case}: {(budget, sinks, recent, length)}"

        # refresh stores the selection; compose at a grown context
        crit_legacy = [x for x in a if x >= 0]
        len2 = length + rng.randint(0, 6)
        ca = legacy_compose_row(crit_legacy, len2, budget, sinks, recent)
        cb = new_compose_row(b, len2, budget, sinks, recent)
        assert ca == cb, f"compose mismatch case={case}: {(budget, sinks, recent, length, len2)}"


def test_tie_rule_is_lowest_index_wins():
    # all-equal scores: top-k must be the lowest candidate indices
    budget, sinks, recent, length = 12, 2, 3, 40
    scores = [0.5] * length
    ids = new_select(scores, length, budget, sinks, recent)
    valid = [x for x in ids if x >= 0]
    lo = length - recent
    expected = list(range(sinks)) + list(range(sinks, sinks + budget - sinks - recent)) + list(range(lo, length))
    assert valid == sorted(expected)
    assert ids == legacy_topk(scores, length, budget, sinks, recent)
