//! Fig. 15 — fused vs sequential vs naive-batch attention.
//!
//! Two data sources, combined (DESIGN.md §1 fused-kernel substitution):
//!
//! 1. **Measured**: wallclock of the real artifacts on this CPU —
//!    `draft_w64` (sparse template), `verify_q9` (dense template) — giving
//!    the per-launch costs of the *Sequential* strategy, and the
//!    `draft_w256`-as-dense cost standing in for the one-size-fits-all
//!    *Naive Batch* template (every row pays the widest gather).
//! 2. **Modelled**: the `DeviceModel` launch-overhead + bandwidth account
//!    of the three strategies at paper scale, which is where the 1.3x /
//!    1.8x shape comes from on a real accelerator.
//!
//! The Pallas fused kernel itself (python/compile/kernels/fused_attn.py)
//! is numerics-verified against both paths in pytest; interpret-mode
//! wallclock is not a TPU proxy, hence the split here.

use super::BenchCtx;
use crate::perfmodel::DeviceModel;
use crate::runtime::ModelRunner;
use anyhow::Result;
use std::fmt::Write as _;
use std::time::Instant;

pub fn fig15_fused_kernel(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 15: fused vs sequential vs naive-batch attention");
    let m = ctx.rt.cfg.model.clone();
    let mut runner = ModelRunner::new(ctx.rt.clone())?;
    let s = m.slots;
    let k = m.spec_k;
    let q = k + 1;

    // Warm both artifacts, then measure steady-state call time.
    let token = vec![5i32; s];
    let pos = vec![64i32; s];
    let active = vec![1i32; s];
    let w = m.draft_budget;
    let idx: Vec<i32> = (0..s * m.layers * m.kv_heads * w)
        .map(|i| (i % 64) as i32)
        .collect();
    let vt = vec![5i32; s * q];
    let qv = vec![q as i32; s];

    let reps = 5;
    runner.draft(w, &token, &pos, &idx, &active)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        runner.draft(w, &token, &pos, &idx, &active)?;
    }
    let t_draft = t0.elapsed().as_secs_f64() / reps as f64;

    runner.verify(q, &vt, &pos, &qv, &active)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        runner.verify(q, &vt, &pos, &qv, &active)?;
    }
    let t_verify = t0.elapsed().as_secs_f64() / reps as f64;

    // Naive batch: every row pays the dense/widest template.  Measured
    // stand-in: the W=256 gather draft (widest sparse tile) + dense call.
    let w_wide = 256;
    let idx_wide: Vec<i32> = (0..s * m.layers * m.kv_heads * w_wide)
        .map(|i| (i % 64) as i32)
        .collect();
    runner.draft(w_wide, &token, &pos, &idx_wide, &active)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        runner.draft(w_wide, &token, &pos, &idx_wide, &active)?;
    }
    let t_wide = t0.elapsed().as_secs_f64() / reps as f64;

    println!(
        "  measured artifact costs: draft(sparse W=64) {:.1}ms, verify(dense) {:.1}ms, widest-template draft {:.1}ms",
        t_draft * 1e3,
        t_verify * 1e3,
        t_wide * 1e3
    );

    // Modelled comparison at paper scale: a mixed batch of B rows, 1/(k+1)
    // of them dense (verify) and the rest sparse.
    let dev = DeviceModel::default();
    let b = 128.0;
    let n_verify = b / (k as f64 + 1.0);
    let n_draft = b - n_verify;
    let bpt = m.kv_bytes_per_token() as f64 * 50.0; // unscale lengths
    let ctx_len = 300.0;
    let sparse_bytes = n_draft * (w as f64) * bpt;
    let dense_bytes = n_verify * ctx_len * bpt;

    // Sequential: two launches, each at its best template (full BW each,
    // but pays two launch latencies + loses inter-kernel pipelining on the
    // small sparse kernel: model that as a fixed efficiency of 50% BW for
    // the sparse launch, per the paper's FlashInfer profile).
    let t_seq = dev.t_attn(dense_bytes) / 0.85
        + dev.t_attn(sparse_bytes) / 0.50
        + 2.0 * dev.t_launch;
    // Naive batch: one launch, one-size-fits-all template: dense rows fine,
    // sparse rows read at dense-template efficiency AND pad to the dense
    // tile (extra bytes), per the paper's "degrade to 50%" profile.
    let t_naive = (dev.t_attn(dense_bytes) + dev.t_attn(n_draft * ctx_len * bpt)) / 0.85
        + dev.t_launch;
    // Fused: one launch, on-chip dispatch to the best template per row:
    // both classes near their peak efficiency (85% / 80%).
    let t_fused = dev.t_attn(dense_bytes) / 0.85
        + dev.t_attn(sparse_bytes) / 0.80
        + dev.t_launch;

    println!(
        "  modelled (paper-scale): sequential {:.2}ms, naive-batch {:.2}ms, fused {:.2}ms",
        t_seq * 1e3,
        t_naive * 1e3,
        t_fused * 1e3
    );
    println!(
        "  fused speedup: {:.2}x vs sequential (paper 1.3x), {:.2}x vs naive batch (paper 1.8x)",
        t_seq / t_fused,
        t_naive / t_fused
    );

    // Kernel-level pallas microbench results, if the python side produced
    // them (make kernel-bench).
    let kb = std::path::Path::new(&ctx.rt.cfg.dir).join("kernel_bench.json");
    if let Ok(txt) = std::fs::read_to_string(&kb) {
        if let Ok(j) = crate::util::json::Json::parse(&txt) {
            println!("  pallas interpret-mode microbench (numerics-path, not TPU-time):");
            for key in j.keys() {
                if let Some(v) = j.get(key).and_then(|x| x.as_f64()) {
                    println!("    {key}: {:.2} ms", v * 1e3);
                }
            }
        }
    }

    let mut csv = String::from("strategy,modelled_ms,measured_component_ms\n");
    let _ = writeln!(csv, "sequential,{:.4},{:.4}", t_seq * 1e3, (t_draft + t_verify) * 1e3);
    let _ = writeln!(csv, "naive_batch,{:.4},{:.4}", t_naive * 1e3, (t_wide + t_verify) * 1e3);
    let _ = writeln!(csv, "fused,{:.4},", t_fused * 1e3);
    ctx.save("fig15.csv", &csv)
}
