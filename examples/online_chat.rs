//! Latency-oriented online serving (§2.2): Poisson arrivals, per-request
//! latency percentiles, with the unified scheduler + delayed verification.
//!
//!   cargo run --release --example online_chat [-- --rate 1.5 --horizon 20]

use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::runtime::Runtime;
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rt = Rc::new(Runtime::load(&args.str("artifacts", "artifacts"))?);
    let rate = args.f64("rate", 1.5);
    let horizon = args.f64("horizon", 20.0);

    for (name, drafter, sched, delayed) in [
        ("vanilla", DrafterKind::Vanilla, Schedule::Lockstep, false),
        (
            "sparsespec(unified+delayed)",
            DrafterKind::Pillar { w: 128 },
            Schedule::Unified,
            true,
        ),
    ] {
        let mut gen = WorkloadGen::new(
            rt.cfg.grammar.clone(),
            rt.cfg.model.clone(),
            Dataset::LiveCodeBench,
            17,
        );
        let reqs = gen.online_trace(rate, horizon);
        println!("{name}: {} arrivals over {horizon}s at {rate}/s", reqs.len());
        let cfg = EngineConfig::new(drafter).with_k(8).with_schedule(sched, delayed);
        let mut eng = Engine::new(rt.clone(), cfg)?;
        let r = eng.run(reqs)?;
        println!("  {}", r.summary());
        let mut lat = r.request_latency_s.clone();
        if lat.len() > 0 {
            println!(
                "  latency: p50={:.2}s p99={:.2}s max={:.2}s",
                lat.percentile(50.0),
                lat.percentile(99.0),
                lat.max()
            );
        }
    }
    Ok(())
}
