//! PillarAttn critical-token selection (§4.1).
//!
//! The verification kernel dumps, per (layer, kv-head), the attention mass
//! each cache position received from the verified queries (averaged over
//! the query-head group) — at zero extra memory passes, since the dense
//! kernel computes those probabilities anyway.  This module turns one dump
//! into the index sets the next k draft steps attend to:
//!
//!   indices(l, h) = sinks ∪ recent-window ∪ Top-K(dump[l, h], rest)
//!
//! mirroring `python/compile/kernels/ref.py::topk_ids_ref` exactly (the
//! cross-language golden test lives in python/tests/test_pillar.py).

/// How a drafter composes its per-(layer, head) index set.
#[derive(Clone, Copy, Debug)]
pub struct IndexPolicy {
    /// Total entries per (layer, head) — must equal the artifact's W.
    pub budget: usize,
    /// Leading positions always kept (attention sinks).
    pub sinks: usize,
    /// Trailing window always kept (needed so freshly drafted tokens are
    /// attendable; also the entire mechanism of the MagicDec baseline).
    pub recent: usize,
}

impl IndexPolicy {
    pub fn pillar(budget: usize) -> Self {
        // Paper-style split: a few sinks, a modest local window, the bulk
        // of the budget to dump-selected critical tokens.  (recent=W/2 was
        // tried during the perf pass and measured *worse* — α 0.45 → 0.33
        // — the dump top-k carries more predictive mass than extra window;
        // see EXPERIMENTS.md §Perf.)
        let sinks = 4.min(budget / 8);
        let recent = (budget / 4).max(8).min(budget - sinks);
        IndexPolicy { budget, sinks, recent }
    }

    /// Sliding-window policy (MagicDec / StreamingLLM): no score-selected
    /// tokens at all — everything after the sinks is the recent window.
    pub fn window(budget: usize) -> Self {
        let sinks = 4.min(budget / 8);
        IndexPolicy { budget, sinks, recent: budget - sinks }
    }
}

/// Build one (layer, head) index set.  `scores[t]` is the dumped attention
/// mass for position t (ignored for the slots covered by sinks/recent);
/// `len` is the current valid context length.  Returns exactly
/// `policy.budget` entries, ascending, -1-padded.
pub fn topk_indices(scores: &[f32], len: usize, policy: &IndexPolicy) -> Vec<i32> {
    let budget = policy.budget;
    let mut chosen: Vec<i32> = Vec::with_capacity(budget);
    // sinks
    for t in 0..policy.sinks.min(len) {
        chosen.push(t as i32);
    }
    // recent window
    let lo = len.saturating_sub(policy.recent);
    for t in lo..len {
        if (t as i32) >= policy.sinks as i32 {
            chosen.push(t as i32);
        }
    }
    chosen.truncate(budget);
    // top-k among the rest
    let rest = budget - chosen.len();
    if rest > 0 && len > 0 {
        let taken: std::collections::HashSet<i32> = chosen.iter().copied().collect();
        let mut cand: Vec<i32> = (0..len as i32).filter(|t| !taken.contains(t)).collect();
        cand.sort_by(|&a, &b| {
            let (sa, sb) = (scores[a as usize], scores[b as usize]);
            sb.partial_cmp(&sa)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        chosen.extend(cand.into_iter().take(rest));
    }
    chosen.sort_unstable();
    chosen.resize(budget, -1); // -1 padding sits at the tail
    chosen
}

/// Per-request PillarAttn state: the frozen critical sets from the last
/// verification, refreshed every stride (= every verify).
#[derive(Clone, Debug)]
pub struct PillarState {
    pub layers: usize,
    pub kv_heads: usize,
    pub policy: IndexPolicy,
    /// Frozen critical tokens per (layer, head) — only the Top-K part;
    /// sinks+recent are recomputed per step so new tokens enter the window.
    critical: Vec<Vec<i32>>,
}

impl PillarState {
    pub fn new(layers: usize, kv_heads: usize, policy: IndexPolicy) -> Self {
        PillarState {
            layers,
            kv_heads,
            policy,
            critical: vec![Vec::new(); layers * kv_heads],
        }
    }

    /// Refresh from a verification dump slice for this request:
    /// `dump` is [L, Hkv, T] flattened; positions >= `len` are stale
    /// (rejected drafts / old garbage) and are excluded.
    pub fn refresh(&mut self, dump: &[f32], t_dim: usize, len: usize) {
        let rest_budget = self.policy.budget;
        for l in 0..self.layers {
            for h in 0..self.kv_heads {
                let off = (l * self.kv_heads + h) * t_dim;
                let scores = &dump[off..off + t_dim];
                // Keep a full budget's worth of candidates; composition at
                // draft time fills sinks/recent first.
                let ids = topk_indices(scores, len.min(t_dim), &self.policy);
                let slot = &mut self.critical[l * self.kv_heads + h];
                slot.clear();
                slot.extend(ids.iter().copied().filter(|&x| x >= 0));
                let _ = rest_budget;
            }
        }
    }

    /// Compose the index set for a draft step at current length `len`
    /// (the drafted token sits at position len-1 after its KV write; the
    /// engine passes pos = len-1 and we must include it).
    /// Output: [L, Hkv, W] flattened, -1 padded, each ascending.
    pub fn compose(&self, len: usize) -> Vec<i32> {
        let w = self.policy.budget;
        let mut out = Vec::with_capacity(self.layers * self.kv_heads * w);
        for l in 0..self.layers {
            for h in 0..self.kv_heads {
                let crit = &self.critical[l * self.kv_heads + h];
                let mut set: Vec<i32> = Vec::with_capacity(w);
                // sinks
                for t in 0..self.policy.sinks.min(len) {
                    set.push(t as i32);
                }
                // recent window (always includes the newest positions, so
                // tokens drafted since the last verification are visible)
                let lo = len.saturating_sub(self.policy.recent);
                for t in lo..len {
                    if t >= self.policy.sinks {
                        set.push(t as i32);
                    }
                }
                // frozen critical tokens (dedup, in-range)
                let have: std::collections::HashSet<i32> = set.iter().copied().collect();
                for &c in crit {
                    if set.len() >= w {
                        break;
                    }
                    if (c as usize) < len && !have.contains(&c) {
                        set.push(c);
                    }
                }
                set.truncate(w);
                set.sort_unstable();
                set.resize(w, -1); // -1 padding at the tail
                out.extend(set);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest;

    fn policy() -> IndexPolicy {
        IndexPolicy { budget: 16, sinks: 2, recent: 4 }
    }

    #[test]
    fn topk_selects_highest_scores() {
        let mut scores = vec![0.0f32; 64];
        scores[30] = 0.9;
        scores[45] = 0.8;
        scores[10] = 0.7;
        let ids = topk_indices(&scores, 64, &policy());
        assert_eq!(ids.len(), 16);
        // sinks 0,1; recent 60..64; top includes 30, 45, 10
        assert!(ids.contains(&0) && ids.contains(&1));
        for t in 60..64 {
            assert!(ids.contains(&(t as i32)), "recent {t} missing");
        }
        for t in [30, 45, 10] {
            assert!(ids.contains(&(t as i32)), "critical {t} missing");
        }
    }

    #[test]
    fn short_context_pads_with_holes() {
        let scores = vec![0.1f32; 8];
        let ids = topk_indices(&scores, 5, &policy());
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        assert_eq!(valid, vec![0, 1, 2, 3, 4]);
        assert!(ids[5..].iter().all(|&x| x == -1));
    }

    ptest!(topk_invariants, |g| {
        let len = g.usize(0, 256);
        let budget = g.usize(4, 64);
        let sinks = g.usize(0, budget / 4);
        let recent = g.usize(1, budget - sinks);
        let policy = IndexPolicy { budget, sinks, recent };
        let scores: Vec<f32> = (0..256).map(|_| g.f64(0.0, 1.0) as f32).collect();
        let ids = topk_indices(&scores, len, &policy);
        assert_eq!(ids.len(), budget);
        // valid prefix, -1 suffix
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        let n_valid = valid.len();
        assert!(ids[..n_valid].iter().all(|&x| x >= 0));
        assert!(ids[n_valid..].iter().all(|&x| x == -1));
        // ascending, unique, in range
        for w in valid.windows(2) {
            assert!(w[0] < w[1], "not strictly ascending: {ids:?}");
        }
        assert!(valid.iter().all(|&x| (x as usize) < len.max(1)));
        // count = min(budget, len)
        assert_eq!(n_valid, budget.min(len));
        // newest token always present when len > 0
        if len > 0 && budget > 0 {
            assert!(valid.contains(&(len as i32 - 1)));
        }
    });

    #[test]
    fn state_refresh_and_compose() {
        let mut st = PillarState::new(2, 2, policy());
        let t = 64;
        let mut dump = vec![0.0f32; 2 * 2 * t];
        // layer 0 head 0: position 33 is critical
        dump[33] = 1.0;
        // layer 1 head 1: position 7 is critical
        dump[(1 * 2 + 1) * t + 7] = 1.0;
        st.refresh(&dump, t, 50);
        let idx = st.compose(50);
        assert_eq!(idx.len(), 2 * 2 * 16);
        let l0h0 = &idx[0..16];
        assert!(l0h0.contains(&33), "l0h0={l0h0:?}");
        let l1h1 = &idx[3 * 16..4 * 16];
        assert!(l1h1.contains(&7), "l1h1={l1h1:?}");
        // stale positions beyond len excluded
        assert!(idx.iter().all(|&x| x < 50));
    }

    #[test]
    fn compose_includes_new_positions_between_refreshes() {
        let mut st = PillarState::new(1, 1, policy());
        let t = 64;
        let dump = vec![0.0f32; t];
        st.refresh(&dump, t, 20);
        // context grew to 24 since the refresh (4 drafted tokens)
        let idx = st.compose(24);
        for p in 20..24 {
            assert!(idx.contains(&(p as i32)), "drafted position {p} missing");
        }
    }

    #[test]
    fn window_policy_is_pure_window() {
        let p = IndexPolicy::window(16);
        let mut scores = vec![0.0f32; 128];
        scores[50] = 100.0; // huge score must be IGNORED by window policy
        let ids = topk_indices(&scores, 100, &p);
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        assert_eq!(valid.len(), 16);
        // sinks + last 12: position 50 not included
        assert!(!valid.contains(&50));
        assert!(valid.contains(&99));
    }
}
