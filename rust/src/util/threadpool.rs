//! Minimal thread pool + channel-based async executor (no `tokio` in this
//! environment — see DESIGN.md §1).
//!
//! The engine uses this for everything that the paper overlaps with GPU
//! execution: the delayed-verification CPU metadata preparation, the
//! KV-offload copier, and the workload's request arrival process.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    jobs: std::collections::VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// Fixed-size worker pool with `spawn` + `wait_idle` semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    idle_cv: Arc<(Mutex<()>, Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                jobs: Default::default(),
                shutdown: false,
                in_flight: 0,
            }),
            cv: Condvar::new(),
        });
        let idle_cv = Arc::new((Mutex::new(()), Condvar::new()));
        let workers = (0..n.max(1))
            .map(|_| {
                let sh = shared.clone();
                let idle = idle_cv.clone();
                thread::spawn(move || loop {
                    let job = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(j) = q.jobs.pop_front() {
                                q.in_flight += 1;
                                break Some(j);
                            }
                            if q.shutdown {
                                break None;
                            }
                            q = sh.cv.wait(q).unwrap();
                        }
                    };
                    match job {
                        None => return,
                        Some(j) => {
                            // A panicking job must not kill the worker or
                            // leak in_flight (wait_idle would hang);
                            // scoped jobs re-raise at the scope barrier,
                            // Promise consumers see a dropped producer.
                            // (The default panic hook has already printed
                            // the payload + location; add pool context.)
                            if std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(j),
                            )
                            .is_err()
                            {
                                eprintln!(
                                    "threadpool: worker job panicked (see panic message above); pool continues"
                                );
                            }
                            let mut q = sh.queue.lock().unwrap();
                            q.in_flight -= 1;
                            let idle_now = q.in_flight == 0 && q.jobs.is_empty();
                            drop(q);
                            if idle_now {
                                idle.1.notify_all();
                            }
                        }
                    }
                })
            })
            .collect();
        Self { shared, workers, idle_cv }
    }

    /// Number of worker threads (sizing hint for scoped fan-out).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `jobs` on the pool and block until every one has finished.
    /// Unlike `spawn`, the closures may borrow from the caller's stack
    /// frame: the borrow is sound because this function does not return
    /// until all jobs have completed (the latch counts down even if a job
    /// panics, via the drop guard).
    ///
    /// Must NOT be called from inside a pool worker: with every worker
    /// blocked in a nested `scope`, the queued jobs could never run.
    pub fn scope<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        use std::sync::atomic::{AtomicBool, Ordering};
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new((Mutex::new(jobs.len()), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in jobs {
            // SAFETY: the latch wait below keeps this stack frame — and
            // every borrow captured by `job` — alive until the job has
            // run, so widening the closure's lifetime cannot be observed.
            let job: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(job) };
            let guard = ScopeGuard { latch: latch.clone(), panicked: panicked.clone() };
            self.spawn(move || {
                let _guard = guard;
                job();
            });
        }
        let (m, cv) = &*latch;
        let mut left = m.lock().unwrap();
        while *left > 0 {
            left = cv.wait(left).unwrap();
        }
        drop(left);
        // Re-raise on the caller's thread so a failed scoped job is as
        // loud as its serial equivalent would have been.
        if panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool::scope: a scoped job panicked");
        }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push_back(Box::new(f));
        drop(q);
        self.shared.cv.notify_one();
    }

    /// Block until the queue is drained and no job is running.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.idle_cv;
        let mut g = lock.lock().unwrap();
        loop {
            {
                let q = self.shared.queue.lock().unwrap();
                if q.jobs.is_empty() && q.in_flight == 0 {
                    return;
                }
            }
            let (g2, _timeout) = cv
                .wait_timeout(g, std::time::Duration::from_millis(50))
                .unwrap();
            g = g2;
        }
    }
}

/// Counts a scoped job as finished on drop, so a panicking job still
/// releases the `scope` barrier instead of deadlocking the caller — and
/// records the panic so `scope` can re-raise it.
struct ScopeGuard {
    latch: Arc<(Mutex<usize>, Condvar)>,
    panicked: Arc<std::sync::atomic::AtomicBool>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        if thread::panicking() {
            self.panicked
                .store(true, std::sync::atomic::Ordering::SeqCst);
        }
        let (m, cv) = &*self.latch;
        let mut left = m.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A one-shot future-like cell: spawn work, fetch the result later.
/// This is the overlap primitive used by delayed verification (§4.3): the
/// consumer calls `get()` only one iteration later, so the producer runs
/// concurrently with the current GPU step.
pub struct Promise<T> {
    rx: mpsc::Receiver<T>,
}

impl<T: Send + 'static> Promise<T> {
    pub fn spawn_on<F: FnOnce() -> T + Send + 'static>(pool: &ThreadPool, f: F) -> Self {
        let (tx, rx) = mpsc::channel();
        pool.spawn(move || {
            let _ = tx.send(f());
        });
        Promise { rx }
    }

    /// Blocks until the value is ready.
    pub fn get(self) -> T {
        self.rx.recv().expect("promise producer dropped")
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_runs_borrowing_jobs() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u64; 64];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(16)
                .enumerate()
                .map(|(ci, chunk)| {
                    Box::new(move || {
                        for (i, x) in chunk.iter_mut().enumerate() {
                            *x = (ci * 16 + i) as u64;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(jobs);
        }
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
        // pool stays usable afterwards
        let p = Promise::spawn_on(&pool, || 7);
        assert_eq!(p.get(), 7);
    }

    #[test]
    fn scope_empty_is_noop() {
        let pool = ThreadPool::new(2);
        pool.scope(Vec::new());
    }

    #[test]
    fn scope_propagates_job_panic_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![Box::new(|| panic!("scoped job boom")), Box::new(|| {})];
            pool.scope(jobs);
        }));
        assert!(res.is_err(), "scope must re-raise a scoped job panic");
        // the worker survived (catch_unwind in the worker loop), in_flight
        // did not leak, and the pool keeps serving
        let p = Promise::spawn_on(&pool, || 5);
        assert_eq!(p.get(), 5);
        pool.wait_idle();
    }

    #[test]
    fn promise_roundtrip() {
        let pool = ThreadPool::new(2);
        let p = Promise::spawn_on(&pool, || 21 * 2);
        assert_eq!(p.get(), 42);
    }

    #[test]
    fn promises_overlap() {
        let pool = ThreadPool::new(2);
        let t0 = std::time::Instant::now();
        let a = Promise::spawn_on(&pool, || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            1
        });
        let b = Promise::spawn_on(&pool, || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            2
        });
        assert_eq!(a.get() + b.get(), 3);
        assert!(t0.elapsed() < std::time::Duration::from_millis(95));
    }
}
