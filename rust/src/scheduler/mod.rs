//! Unified batch scheduler (§4.2).
//!
//! Self-speculation lets draft and verify phases share one pipeline (the
//! uniform page-size-1 abstraction lives in the kernels); what remains on
//! the coordinator is *when* each request drafts vs verifies:
//!
//! * `Lockstep` — all requests share a global phase: k draft iterations,
//!   then one verification iteration (the "naive" schedule of §3.3 and
//!   what MagicDec/TriForce-style systems do).  Workload per iteration
//!   fluctuates: GEMM rows spike by (k+1)× at verification.
//! * `Unified` — requests are staggered across k+1 *buckets* by greedy
//!   least-loaded bin-packing at admission (Fig. 8); every iteration mixes
//!   ~B/(k+1) verifying requests with drafting ones, so GEMM rows stay
//!   flat (Fig. 14) and delayed verification (§4.3) has something to
//!   overlap every iteration.
//!
//! The scheduler is pure bookkeeping (no device calls) so its invariants
//! are property-tested heavily; the engine consumes `phase_of` + the
//! per-iteration composition.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    Lockstep,
    Unified,
}

impl Schedule {
    pub fn parse(s: &str) -> Option<Schedule> {
        match s.to_ascii_lowercase().as_str() {
            "lockstep" | "naive" | "sync" => Some(Schedule::Lockstep),
            "unified" | "staggered" => Some(Schedule::Unified),
            _ => None,
        }
    }

    /// Delayed verification (§4.3) overlaps verify CPU work with the
    /// *next* iteration's draft launches — only Unified guarantees every
    /// iteration carries draft work, so only it supports the overlap.
    /// (`EngineConfig::builder` enforces this at construction.)
    pub fn supports_delayed_verify(&self) -> bool {
        matches!(self, Schedule::Unified)
    }
}

/// Greedy least-loaded bucket assignment (Fig. 8): bucket b means "this
/// request verifies when `iter ≡ b (mod k+1)`".  A request admitted
/// mid-cycle gets a shortened first draft run so it lands in its bucket.
#[derive(Clone, Debug)]
pub struct BucketScheduler {
    pub k: usize,
    counts: Vec<usize>,
}

impl BucketScheduler {
    pub fn new(k: usize) -> Self {
        BucketScheduler { k, counts: vec![0; k + 1] }
    }

    pub fn n_buckets(&self) -> usize {
        self.k + 1
    }

    /// Assign a new request to the least-loaded bucket (ties → lowest id).
    pub fn assign(&mut self) -> usize {
        let mut best = 0;
        for b in 1..self.counts.len() {
            if self.counts[b] < self.counts[best] {
                best = b;
            }
        }
        self.counts[best] += 1;
        best
    }

    /// Assign a request to a *specific* bucket (the Lockstep schedule puts
    /// everyone in bucket 0).  Keeps the count it increments and the count
    /// `release()` later decrements on the same bucket — assigning via
    /// least-loaded `assign()` and then storing a different bucket id
    /// would underflow the release accounting.
    pub fn assign_to(&mut self, bucket: usize) -> usize {
        self.counts[bucket] += 1;
        bucket
    }

    pub fn release(&mut self, bucket: usize) {
        debug_assert!(self.counts[bucket] > 0, "release of empty bucket");
        self.counts[bucket] -= 1;
    }

    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    pub fn imbalance(&self) -> usize {
        let mx = self.counts.iter().max().copied().unwrap_or(0);
        let mn = self.counts.iter().min().copied().unwrap_or(0);
        mx - mn
    }

    /// Number of draft steps a request admitted at global iteration `iter`
    /// into bucket `b` should run before its first verification, so that
    /// its verification lands on an iteration ≡ b (mod k+1).
    pub fn first_draft_len(&self, iter: u64, bucket: usize) -> usize {
        let phase_now = (iter % (self.k as u64 + 1)) as usize;
        // We verify at the iteration where phase == bucket; draft until then.
        (bucket + self.k + 1 - phase_now) % (self.k + 1)
    }
}

/// Per-iteration batch composition — the Fig. 14 trace record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IterComposition {
    pub drafting: usize,
    pub verifying: usize,
    pub prefilling: usize,
    /// GEMM input rows this iteration: drafting×1 + verifying×(k+1) +
    /// prefilling×P.
    pub gemm_rows: usize,
    /// KV bytes attention must touch this iteration.
    pub attn_bytes: usize,
}

impl IterComposition {
    /// The composition as trace-span args (attached to every `iteration`
    /// span so Perfetto shows the Fig. 14 batch mix per slice).
    pub fn trace_args(&self) -> crate::trace::Args {
        vec![
            ("drafting", self.drafting.into()),
            ("verifying", self.verifying.into()),
            ("prefilling", self.prefilling.into()),
            ("gemm_rows", self.gemm_rows.into()),
            ("attn_bytes", self.attn_bytes.into()),
        ]
    }
}

/// Trace of compositions over a run; feeds Fig. 14 and the simulated-time
/// accounting of Fig. 13.
#[derive(Clone, Debug, Default)]
pub struct ScheduleTrace {
    pub iters: Vec<IterComposition>,
}

impl ScheduleTrace {
    pub fn push(&mut self, c: IterComposition) {
        self.iters.push(c);
    }

    pub fn gemm_rows_stddev(&self) -> f64 {
        let n = self.iters.len() as f64;
        if n < 2.0 {
            return 0.0;
        }
        let mean = self.iters.iter().map(|c| c.gemm_rows as f64).sum::<f64>() / n;
        (self
            .iters
            .iter()
            .map(|c| (c.gemm_rows as f64 - mean).powi(2))
            .sum::<f64>()
            / (n - 1.0))
            .sqrt()
    }

    pub fn csv(&self) -> String {
        let mut s = String::from("iter,drafting,verifying,prefilling,gemm_rows,attn_bytes\n");
        for (i, c) in self.iters.iter().enumerate() {
            s.push_str(&format!(
                "{i},{},{},{},{},{}\n",
                c.drafting, c.verifying, c.prefilling, c.gemm_rows, c.attn_bytes
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest;

    #[test]
    fn assignment_balances() {
        let mut s = BucketScheduler::new(8);
        for _ in 0..27 {
            s.assign();
        }
        assert!(s.imbalance() <= 1, "counts={:?}", s.counts());
    }

    #[test]
    fn assign_to_keeps_release_balanced() {
        // The Lockstep engine path: everyone assigned to bucket 0, every
        // release on bucket 0 — no underflow no matter how many retire.
        let mut s = BucketScheduler::new(8);
        for _ in 0..20 {
            assert_eq!(s.assign_to(0), 0);
        }
        assert_eq!(s.counts()[0], 20);
        for _ in 0..20 {
            s.release(0);
        }
        assert_eq!(s.counts().iter().sum::<usize>(), 0);
    }

    #[test]
    fn first_draft_len_aligns_verification() {
        let s = {
            let mut s = BucketScheduler::new(8);
            s.assign();
            s
        };
        // Admitted at iter 0 into bucket 3: draft 3 steps, verify at iter 3.
        assert_eq!(s.first_draft_len(0, 3), 3);
        // Admitted at iter 5 into bucket 3: verify at iter 12 (3 mod 9).
        assert_eq!(s.first_draft_len(5, 3), 7);
        // Admitted exactly on its bucket: verify immediately next cycle.
        assert_eq!(s.first_draft_len(3, 3), 0);
    }

    ptest!(greedy_always_picks_least_loaded, |g| {
        let k = g.usize(1, 16);
        let mut s = BucketScheduler::new(k);
        let mut live: Vec<usize> = Vec::new();
        for _ in 0..g.usize(1, 300) {
            if !live.is_empty() && g.bool(0.4) {
                let i = g.usize(0, live.len() - 1);
                let b = live.swap_remove(i);
                s.release(b);
            } else {
                let min_before = *s.counts().iter().min().unwrap();
                let b = s.assign();
                // invariant: the chosen bucket had the minimum count
                assert_eq!(s.counts()[b] - 1, min_before);
                live.push(b);
            }
            let total: usize = s.counts().iter().sum();
            assert_eq!(total, live.len(), "count conservation");
        }
    });

    ptest!(pure_arrivals_keep_imbalance_at_most_one, |g| {
        let k = g.usize(1, 12);
        let mut s = BucketScheduler::new(k);
        for _ in 0..g.usize(1, 200) {
            s.assign();
        }
        assert!(s.imbalance() <= 1);
    });

    #[test]
    fn trace_stddev_flat_vs_spiky() {
        let mut flat = ScheduleTrace::default();
        let mut spiky = ScheduleTrace::default();
        for i in 0..90 {
            flat.push(IterComposition { gemm_rows: 24, ..Default::default() });
            spiky.push(IterComposition {
                gemm_rows: if i % 9 == 8 { 108 } else { 12 },
                ..Default::default()
            });
        }
        assert!(flat.gemm_rows_stddev() < 1e-9);
        assert!(spiky.gemm_rows_stddev() > 20.0);
    }
}
