# Cross-check of the PR-5 Drafter-trait redesign (rust/src/spec/drafter.rs,
# rust/src/spec/adaptive.rs, engine/core.rs trait dispatch), per the
# no-Rust-toolchain verify flow: 1:1 Python ports of the dispatch and
# AdaptiveK logic are driven through the miniature engine from
# test_sim_runtime_port.py (the committed port of runtime/sim.rs).
#
# Pins, mirroring rust/tests/drafter_trait.rs:
#   1. dispatch equivalence — resolving a drafter through a name->ctor
#      registry (the DrafterRegistry shape) produces bit-identical outputs
#      to calling the policy directly (trait dispatch == enum interpreter);
#   2. per-session mixed dispatch stays lossless — sessions with different
#      policies each reproduce the dense chain token-for-token;
#   3. AdaptiveK (AIMD over a windowed acceptance estimate; start k_max,
#      widen +1 at alpha >= 0.8, halve below 0.4, bounds [1, k_max]) —
#      convergence both directions, in-engine losslessness at any k trace,
#      and the scheduling claim: on a low-acceptance drafter the adaptive
#      controller wastes fewer rejected draft steps per accepted token
#      than static k.
#
# Constants MUST stay in lockstep with spec/adaptive.rs (AdaptiveKCfg).

from test_sim_runtime_port import (
    compose,
    dense_next,
    pillar_policy,
    prompt_for,
    refresh,
    sparse_next,
    speculative,
    vanilla,
    window_policy,
)

K_MIN = 1
WINDOW = 8
WIDEN_AT = 0.8
NARROW_AT = 0.4


class AdaptiveK:
    """1:1 port of spec::adaptive::AdaptiveK (AIMD controller)."""

    def __init__(self, k_max):
        self.k_max = max(k_max, 1)
        self.k = self.k_max
        self.hist = []

    def rate(self):
        drafted = sum(d for d, _ in self.hist)
        accepted = sum(a for _, a in self.hist)
        return None if drafted == 0 else accepted / drafted

    def observe(self, drafted, accepted):
        self.hist.append((drafted, accepted))
        if len(self.hist) > WINDOW:
            self.hist = self.hist[-WINDOW:]
        r = self.rate()
        if r is None:
            return
        if r >= WIDEN_AT:
            self.k = min(self.k + 1, self.k_max)
        elif r < NARROW_AT:
            self.k = max(self.k // 2, K_MIN)


def speculative_stats(prompt, max_new, k, policy, controller=None):
    """The mini engine round loop with an optional AdaptiveK clamp.

    Mirrors engine/core.rs: start_round asks plan() for the round size
    (static drafters: k; adaptive: min(k, controller.k)), drafts, verifies
    densely, rolls back, feeds on_verify.  Returns (out, drafted, accepted,
    rounds, k_trace).
    """
    kv = list(prompt)
    pending = dense_next(kv, len(kv) - 1)
    out = [pending]
    crit = []
    drafted_total, accepted_total, rounds = 0, 0, 0
    k_trace = []
    while len(out) < max_new:
        rsl = len(kv)
        anchor = pending
        cap = k if controller is None else min(k, controller.k)
        k_trace.append(cap)
        kk = min(cap, max(max_new - len(out), 1))
        kv_d = list(kv)
        drafts = []
        cur = anchor
        for _ in range(kk):
            p = len(kv_d)
            kv_d.append(cur)
            idx = compose(crit, p + 1, policy)
            d = sparse_next(kv_d, p, idx)
            drafts.append(d)
            cur = d
        kv_v = list(kv) + [anchor] + drafts
        acc = 0
        next_tok = None
        for j, d in enumerate(drafts):
            tgt = dense_next(kv_v, rsl + j)
            if tgt == d:
                acc += 1
            else:
                next_tok = tgt
                break
        if next_tok is None:
            next_tok = dense_next(kv_v, rsl + len(drafts))
        rounds += 1
        drafted_total += len(drafts)
        accepted_total += acc
        if controller is not None:
            controller.observe(len(drafts), acc)
        take = min(acc, max_new - len(out))
        out += drafts[:take]
        if len(out) < max_new:
            out.append(next_tok)
        kv = list(kv) + [anchor] + drafts[:acc]
        pending = next_tok
        crit = refresh(len(kv), policy)
    return out, drafted_total, accepted_total, rounds, k_trace


# --- registry-shaped dispatch (DrafterRegistry port) --------------------

REGISTRY = {
    "pillar": pillar_policy,
    "window": window_policy,
}


def run_via_registry(name, w, prompt, max_new, k):
    policy = REGISTRY[name](w)
    got, _ = speculative(prompt, max_new, k, policy)
    return got


def test_registry_dispatch_equals_direct_call():
    # trait-dispatch equivalence: name->ctor resolution must be invisible
    # in the outputs, for every registered drafter
    for seed in range(4):
        p = prompt_for(seed)
        for name, w in [("pillar", 64), ("pillar", 16), ("window", 64)]:
            direct, _ = speculative(p, 100, 8, REGISTRY[name](w))
            assert run_via_registry(name, w, p, 100, 8) == direct


def test_mixed_per_session_dispatch_is_lossless():
    # sessions cycling pillar / window / vanilla policies (the engine's
    # per-session override) each reproduce the dense chain exactly
    for seed in range(6):
        p = prompt_for(seed + 50)
        base = vanilla(p, 120)
        policy = [pillar_policy(64), window_policy(64), None][seed % 3]
        if policy is None:
            got = vanilla(p, 120)  # vanilla override: no speculation
        else:
            got, _ = speculative(p, 120, 8, policy)
        assert got == base, f"seed={seed} mixed dispatch diverged"


def test_adaptive_k_converges_both_directions():
    c = AdaptiveK(8)
    assert c.k == 8, "starts optimistic at k_max"
    for _ in range(12):
        c.observe(c.k, 0)
    assert c.k == K_MIN, "zero acceptance must collapse to k_min"
    for _ in range(40):
        c.observe(c.k, c.k)
    assert c.k == 8, "full acceptance must recover k_max"
    # bounds hold on any stream
    c = AdaptiveK(8)
    for i in range(300):
        c.observe(c.k, c.k if i % 3 else 0)
        assert K_MIN <= c.k <= 8


def test_adaptive_k_stays_lossless():
    for seed in range(4):
        p = prompt_for(seed + 200)
        base = vanilla(p, 150)
        for policy in [pillar_policy(64), window_policy(16)]:
            out, _, _, _, ks = speculative_stats(p, 150, 8, policy, AdaptiveK(8))
            assert out == base, f"seed={seed} adaptive diverged"
            assert all(K_MIN <= k <= 8 for k in ks)


def test_adaptive_narrows_on_low_acceptance_drafter():
    # The Vegas claim in miniature: on the weak window drafter over long
    # generations (acceptance well under the widen threshold), AdaptiveK
    # must (a) actually narrow, and (b) waste fewer rejected draft steps
    # per generated token than static k, without losing losslessness.
    waste_static, waste_adapt = 0.0, 0.0
    narrowed = False
    for seed in range(4):
        p = prompt_for(seed + 300)
        base = vanilla(p, 300)
        out_s, drafted_s, accepted_s, _, _ = speculative_stats(
            p, 300, 8, window_policy(16)
        )
        ctl = AdaptiveK(8)
        out_a, drafted_a, accepted_a, _, ks = speculative_stats(
            p, 300, 8, window_policy(16), ctl
        )
        assert out_s == base and out_a == base
        waste_static += (drafted_s - accepted_s) / len(out_s)
        waste_adapt += (drafted_a - accepted_a) / len(out_a)
        narrowed = narrowed or min(ks) < 8
    assert narrowed, "controller never narrowed on a weak drafter"
    assert waste_adapt < waste_static, (
        f"adaptive wasted {waste_adapt:.3f} rejected drafts/token vs "
        f"static {waste_static:.3f}"
    )
