//! The paper's headline experiment in miniature: serve all three reasoning
//! datasets with every training-free system and print the Fig. 10-style
//! comparison table — driven through the session API (submit + drive),
//! with a per-system median TTFT column read off the live session stats.
//!
//! The speedup column divides by the vanilla ("vllm") baseline row; if
//! that row is renamed or reordered away the column prints `n/a` instead
//! of inf/garbage.
//!
//!   cargo run --release --example reasoning_serve [-- --requests 12]
//!   (add `--trace-out trace.json` to export a Perfetto trace of the
//!    sparsespec run on the last dataset; add `--fault-plan runtime:0.02
//!    --fault-seed 7` to re-run the whole table under injected faults)


use std::rc::Rc;

use sparsespec::engine::{EngineConfig, EngineDriver, EngineHandle};
use sparsespec::metrics::p50_cell;
use sparsespec::runtime::Runtime;
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rt = Rc::new(Runtime::load(&args.str("artifacts", "artifacts"))?);
    let n = args.usize("requests", 12);
    let trace_out = args.opt("trace-out").map(|s| s.to_string());
    let systems: Vec<(&str, DrafterKind)> = vec![
        ("vllm", DrafterKind::Vanilla),
        ("vllm-ngram", DrafterKind::NGram { n: 3 }),
        ("magicdec", DrafterKind::Window { w: 128 }),
        ("triforce", DrafterKind::TriForce { w: 64 }),
        ("sparsespec", DrafterKind::Pillar { w: 128 }),
    ];
    println!(
        "{:<14} {:<14} {:>10} {:>12} {:>8} {:>8} {:>12}",
        "dataset", "system", "wall tok/s", "sim tok/s", "alpha", "acc/rnd", "ttft p50(s)"
    );
    for ds in Dataset::all() {
        let mut base: Option<f64> = None;
        for (name, d) in &systems {
            let reqs = WorkloadGen::new(
                rt.cfg.grammar.clone(),
                rt.cfg.model.clone(),
                ds,
                42,
            )
            .offline_batch(n);
            let traced = trace_out.is_some() && *name == "sparsespec";
            let mut cfg = EngineConfig::new(*d).with_k(8);
            if traced {
                cfg.trace = sparsespec::trace::TraceConfig::on();
            }
            // Optional chaos: serve the whole table under a fault plan
            // (greedy outputs are unaffected; the table shows the cost of
            // retries and degraded rounds instead).
            if let Some(spec) = args.opt("fault-plan") {
                cfg = cfg.with_faults(sparsespec::fault::FaultConfig::new(
                    sparsespec::fault::FaultPlan::parse(spec)?,
                    args.u64("fault-seed", 0),
                ));
            }
            let mut driver = EngineDriver::new(EngineHandle::new(rt.clone(), cfg)?);
            for req in reqs {
                driver.submit(req);
            }
            driver.drive()?;
            if traced {
                let path = trace_out.as_deref().unwrap();
                std::fs::write(path, driver.tracer().export_chrome_string())?;
            }
            let r = driver.report();
            if *name == "vllm" {
                base = Some(r.sim_tok_s());
            }
            // Guarded: a reordered/renamed baseline row must not yield
            // inf/garbage speedups.
            let speedup = match base {
                Some(b) if b > 0.0 => format!("{:4.2}x", r.sim_tok_s() / b),
                _ => " n/a".to_string(),
            };
            let ttft = driver.session_metrics();
            let ttft_p50 = p50_cell(&ttft, "ttft_s", &[], 12, 4);
            println!(
                "{:<14} {:<14} {:>10.1} {:>5.1} ({speedup}) {:>8.2} {:>8.2} {ttft_p50}",
                ds.name(),
                name,
                r.wall_tok_s(),
                r.sim_tok_s(),
                r.accept.alpha(),
                r.accept.mean_accepted()
            );
        }
    }
    Ok(())
}
