//! Tiny CLI argument parser (no `clap` in this environment).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Subcommand dispatch is done by the caller on `positional[0]`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("serve --dataset aime --k=8 --verbose --rate 2.5 trailing");
        assert_eq!(a.positional, vec!["serve", "trailing"]);
        assert_eq!(a.str("dataset", ""), "aime");
        assert_eq!(a.usize("k", 0), 8);
        assert!(a.bool("verbose", false));
        assert!((a.f64("rate", 0.0) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.str("missing", "x"), "x");
        assert!(!a.bool("missing", false));
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--a --b 3");
        assert!(a.bool("a", false));
        assert_eq!(a.usize("b", 0), 3);
    }
}
