"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth for the Pallas kernels (pytest
compares kernel vs ref under hypothesis-driven shape/dtype sweeps) and the
implementation that the *CPU-serving* artifacts lower through (see
DESIGN.md §2: CPU PJRT cannot execute Mosaic custom-calls and interpret
mode is a correctness vehicle, so `aot.py` emits both a ref-path artifact
for serving and a pallas-path artifact as the compose proof).

Shape glossary (matches rust/src/model/config.rs):
  S  slots (batch)        T  max_seq (KV positions per slot)
  Q  query tokens/step    W  sparse attention budget per (layer, kv-head)
  Hq q heads   Hkv kv heads   G = Hq/Hkv group   D head_dim
"""

import jax.numpy as jnp

NEG_INF = -1e30


def _expand_gqa(x, group):
    """[S, T, Hkv, D] -> [S, T, Hkv*G, D] by repeating each kv head G times."""
    return jnp.repeat(x, group, axis=2)


def sparse_attn_ref(q, k_cache, v_cache, idx, pos):
    """PillarAttn draft attention (gather form, page size 1).

    Args:
      q:        [S, Q, Hq, D] query vectors (RoPE already applied)
      k_cache:  [S, T, Hkv, D] post-RoPE keys (current tokens already written)
      v_cache:  [S, T, Hkv, D]
      idx:      [S, Hkv, W] int32 token indices to attend; -1 = hole
      pos:      [S] int32 position of query 0 (query qi sits at pos+qi)

    Returns:
      out: [S, Q, Hq, D]

    Causality: entry w is visible to query qi iff 0 <= idx <= pos+qi.
    The Rust coordinator guarantees the current positions pos..pos+qi are
    members of idx (they are part of the recent window), so the token can
    attend itself.
    """
    S, Q, Hq, D = q.shape
    _, T, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.array(D, dtype=q.dtype))

    safe = jnp.clip(idx, 0, T - 1)                                   # [S,Hkv,W]
    s_ix = jnp.arange(S)[:, None, None]
    kg = k_cache[s_ix, safe, jnp.arange(Hkv)[None, :, None]]          # [S,Hkv,W,D]
    vg = v_cache[s_ix, safe, jnp.arange(Hkv)[None, :, None]]

    # queries grouped by kv head: [S, Q, Hkv, G, D]
    qh = q.reshape(S, Q, Hkv, G, D)
    logits = jnp.einsum("sqhgd,shwd->sqhgw", qh, kg) * scale          # [S,Q,Hkv,G,W]

    qpos = pos[:, None] + jnp.arange(Q)[None, :]                      # [S,Q]
    visible = (idx[:, None, :, None, :] >= 0) & (
        idx[:, None, :, None, :] <= qpos[:, :, None, None, None]
    )
    logits = jnp.where(visible, logits, NEG_INF)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("sqhgw,shwd->sqhgd", p, vg)
    return out.reshape(S, Q, Hq, D)


def full_attn_ref(q, k_cache, v_cache, pos, q_valid):
    """Dense verification attention with zero-overhead score dumping.

    Args:
      q:        [S, Q, Hq, D]
      k_cache:  [S, T, Hkv, D] (verify tokens already written at pos..pos+Q-1)
      v_cache:  [S, T, Hkv, D]
      pos:      [S] position of query 0
      q_valid:  [S] number of valid query rows (invalid rows are padding)

    Returns:
      out:   [S, Q, Hq, D]
      dump:  [S, Hkv, T] attention mass per cache position, averaged over the
             valid queries and the G query heads of the group — exactly the
             statistic PillarAttn's Top-K identification consumes (§4.1).
      lse:   [S, Q, Hq] log-sum-exp of the logits (the paper caches logits +
             LSE and rematerialises probabilities; we expose LSE so tests can
             check the rematerialisation identity).
    """
    S, Q, Hq, D = q.shape
    _, T, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.array(D, dtype=q.dtype))

    kx = _expand_gqa(k_cache, G)                                      # [S,T,Hq,D]
    vx = _expand_gqa(v_cache, G)
    logits = jnp.einsum("sqhd,sthd->sqht", q, kx) * scale             # [S,Q,Hq,T]

    qpos = pos[:, None] + jnp.arange(Q)[None, :]                      # [S,Q]
    tpos = jnp.arange(T)[None, None, None, :]
    causal = tpos <= qpos[:, :, None, None]
    logits = jnp.where(causal, logits, NEG_INF)

    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    p = e / denom                                                     # [S,Q,Hq,T]
    out = jnp.einsum("sqht,sthd->sqhd", p, vx)
    lse = (m + jnp.log(denom))[..., 0]                                # [S,Q,Hq]

    # --- score dump: mean prob over valid queries and group heads -------
    valid_q = (jnp.arange(Q)[None, :] < q_valid[:, None]).astype(q.dtype)
    pq = p * valid_q[:, :, None, None]
    nq = jnp.maximum(q_valid.astype(q.dtype), 1.0)[:, None, None]
    dump = pq.reshape(S, Q, Hkv, G, T).sum(axis=(1, 3)) / (nq * G)    # [S,Hkv,T]
    return out, dump, lse


def fused_attn_ref(q, k_cache, v_cache, idx, pos, q_valid, kind):
    """Unified draft+verify batch (Fig. 15 'fused' semantics, reference).

    kind[s] == 0: draft row — sparse attention over idx.
    kind[s] == 1: verify row — dense attention, plus score dump.
    Rows keep one output shape; draft rows produce a zero dump.
    """
    out_s = sparse_attn_ref(q, k_cache, v_cache, idx, pos)
    out_d, dump, _ = full_attn_ref(q, k_cache, v_cache, pos, q_valid)
    kindf = kind.astype(q.dtype)[:, None, None, None]
    out = out_s * (1.0 - kindf) + out_d * kindf
    dump = dump * kind.astype(q.dtype)[:, None, None]
    return out, dump


def topk_ids_ref(dump, length, budget, recent, sinks):
    """Critical-token identification (reference for the Rust implementation).

    Given a score dump [Hkv, T] for one request of current length `length`,
    return per-kv-head index sets of size `budget`:
      sinks      first `sinks` positions (attention sinks),
      recents    last `recent` positions,
      top-k      highest-dump positions among the rest.
    Padding entries are -1, indices ascending.  Mirrors rust/src/spec/pillar.rs.
    """
    import numpy as np

    dump = np.asarray(dump)
    Hkv, T = dump.shape
    out = np.full((Hkv, budget), -1, dtype=np.int32)
    for h in range(Hkv):
        fixed = list(range(min(sinks, length)))
        lo = max(length - recent, 0)
        fixed += [t for t in range(lo, length) if t not in fixed]
        fixed = fixed[:budget]
        rest = budget - len(fixed)
        if rest > 0:
            cand = [t for t in range(length) if t not in set(fixed)]
            cand.sort(key=lambda t: (-dump[h, t], t))
            fixed += cand[:rest]
        fixed.sort()
        out[h, : len(fixed)] = np.array(fixed, dtype=np.int32)
    return out
