//! Property-testing mini-framework (no `proptest` in this environment).
//!
//! Usage:
//! ```ignore
//! ptest!(|g| {
//!     let len = g.usize(1, 100);
//!     let xs = g.vec_u32(len, 0, 1000);
//!     // ... assert invariants; panic (assert!) on violation
//! });
//! ```
//! Runs `PTEST_CASES` (default 256) seeded cases; on failure reports the
//! failing seed so `PTEST_SEED=<n>` reproduces the exact case.  Shrinking is
//! deliberately not implemented — reproducibility via seed is enough at this
//! scale and keeps the harness ~100 lines.

use super::rng::Xoshiro256;

pub struct Gen {
    pub rng: Xoshiro256,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed), seed }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.unit() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.unit() < p_true
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize(lo, hi)).collect()
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }
}

pub fn cases() -> u64 {
    std::env::var("PTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Run `f` across seeded generators; panics with the failing seed embedded.
pub fn run_named(name: &str, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Ok(s) = std::env::var("PTEST_SEED") {
        let seed: u64 = s.parse().expect("PTEST_SEED must be u64");
        let mut g = Gen::new(seed);
        f(&mut g);
        return;
    }
    for i in 0..cases() {
        let seed = 0x5EED_0000 + i;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            f(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at seed {seed} \
                 (reproduce with PTEST_SEED={seed}): {msg}"
            );
        }
    }
}

#[macro_export]
macro_rules! ptest {
    ($name:ident, $body:expr) => {
        #[test]
        fn $name() {
            $crate::util::ptest::run_named(stringify!($name), $body);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges_hold() {
        run_named("ranges", |g| {
            let x = g.usize(3, 9);
            assert!((3..=9).contains(&x));
            let y = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&y));
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with PTEST_SEED=")]
    fn failure_reports_seed() {
        run_named("always_fails", |g| {
            // fails on some seed quickly
            assert!(g.usize(0, 10) != 5, "hit the forbidden value");
        });
    }
}
