//! The SparseSpec serving engine (Layer 3).
//!
//! The engine is an **online, session-based server**: requests are
//! submitted while it runs, tokens stream out the iteration verification
//! accepts them, and sessions can be cancelled mid-generation.  Internally
//! one `Engine` drives one drafter configuration through per-iteration
//! rounds — admission → (draft* → verify) → acceptance/rollback → retire —
//! with the unified batch scheduler (§4.2), delayed verification (§4.3)
//! and the dynamic KV manager (§4.4) wired in.  Draft policies are
//! **plugins**: every baseline of the paper's evaluation implements the
//! object-safe [`crate::spec::Drafter`] trait, resolves through a
//! [`crate::spec::DrafterRegistry`] (out-of-crate drafters register
//! without touching the engine — see `Engine::with_registry`), and can be
//! selected *per session* via `Request::drafter`, so one engine serves a
//! mixed-drafter batch with per-drafter acceptance breakdowns
//! (`RunReport::accept_by`).  `EngineConfig::adaptive_k` layers the
//! feedback-adaptive speculation-length controller (`spec::adaptive`) on
//! any drafter.
//!
//! Two ways to drive it:
//!
//! * **Sessions** (the serving API, [`api`]): build an [`EngineHandle`],
//!   `submit` requests (optionally with a [`TokenSink`]), consume
//!   incremental tokens through each [`SessionHandle`], `cancel` the ones
//!   you no longer need.  Wrap it in an [`EngineDriver`] to feed a live
//!   arrival process (`WorkloadGen::online_arrivals`) on the serving
//!   clock instead of a pre-materialised trace.
//! * **Batch compatibility**: [`Engine::run`] takes a `Vec<Request>` and
//!   returns a [`RunReport`] exactly as before — it is a thin wrapper
//!   over submit + drive, with bit-identical `outputs` on a fixed seed.
//!
//! Configurations come from [`EngineConfig::new`] (permissive, for
//! experiments that know what they are doing) or the validating
//! [`EngineConfig::builder`], which cross-checks the draft length against
//! compiled verify variants, drafter budgets against draft variants, the
//! KV budget against admissibility, and schedule/delayed combinations —
//! at construction time rather than as a mid-run artifact error.
//!
//! Timing is accounted twice (DESIGN.md §1):
//! * **wallclock** — real time on this testbed, and
//! * **simulated** — the calibrated H100 `DeviceModel` applied to the
//!   engine's *real* per-iteration schedule (rows drafted/verified, KV
//!   bytes actually touched).  Scheduling experiments (Figs. 13/14) and
//!   the arrival clock of `EngineDriver` read the simulated clock;
//!   acceptance and correctness are identical.

mod api;
mod core;
mod slot;

pub use self::api::{
    EngineDriver, EngineHandle, FinishReason, SessionHandle, SessionStats, TokenEvent, TokenSink,
};
pub use self::core::Engine;
pub use slot::{Phase, Slot};

use anyhow::{bail, Result};

use crate::kv_cache::KvPolicy;
use crate::model::ModelConfig;
use crate::scheduler::Schedule;
use crate::spec::{validate_drafter, AcceptStats, DrafterKind};

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Default drafter: requests that don't name one resolve here.
    pub drafter: DrafterKind,
    /// Draft length k (verification uses the verify_q{k+1} artifact).
    pub k: usize,
    pub schedule: Schedule,
    /// Overlap verification CPU work with the next iteration (§4.3).
    pub delayed_verify: bool,
    pub kv_policy: KvPolicy,
    /// Device KV capacity in tokens (models HBM; < slots×max_seq so the
    /// §4.4 policies are exercised).
    pub kv_budget: usize,
    /// 0.0 => greedy (deterministic); paper uses 0.65.
    pub temperature: f32,
    pub seed: u64,
    /// Safety valve for tests/benches.
    pub max_iterations: u64,
    pub verbose: bool,
    /// Simulated-clock calibration (None => paper scale; see perfmodel).
    pub sim_scale: Option<crate::perfmodel::SimScale>,
    /// Drafters sessions may select per-request (`Request::drafter`)
    /// beyond the default — declared here so the builder validates their
    /// parameters/artifact budgets up front and the engine precompiles
    /// them at construction.  Overrides not declared here still work:
    /// they are validated at submit time and rejected per-session on
    /// failure.
    pub extra_drafters: Vec<DrafterKind>,
    /// Wrap every resolved drafter in the feedback-adaptive speculation
    /// length controller (`spec::adaptive::AdaptiveK`): each slot
    /// widens/narrows its per-round draft length from windowed
    /// verification feedback, bounded above by `k`.
    pub adaptive_k: bool,
    /// Structured tracing (spans + counters into a bounded journal; see
    /// [`crate::trace`]).  Off by default: disabled tracing costs one
    /// branch per emission point.
    pub trace: crate::trace::TraceConfig,
    /// TTFT target (simulated seconds) for the SLO section of the report:
    /// goodput counts only completions whose first token beat this.
    pub ttft_slo_s: f64,
    /// Deterministic fault injection (chaos testing; see [`crate::fault`]).
    /// Disabled by default: an empty plan costs one branch per check site
    /// and leaves outputs bit-identical (CI-gated by `fault_overhead`).
    pub fault: crate::fault::FaultConfig,
    /// Slot-parallel execution (default on): sim kernels split per-slot
    /// work across the runner's thread pool, and immediate-mode verify
    /// processing fans out across the engine pool.  Off forces the fully
    /// serial path — **bit-identical outputs** either way (the arena
    /// bit-identity suite gates on it); serial is also the reference mode
    /// for the zero-allocation bench gate.
    pub parallel: bool,
}

impl EngineConfig {
    pub fn new(drafter: DrafterKind) -> Self {
        EngineConfig {
            drafter,
            k: 8,
            schedule: Schedule::Lockstep,
            delayed_verify: false,
            kv_policy: KvPolicy::Dynamic,
            kv_budget: usize::MAX / 2, // effectively unbounded by default
            temperature: 0.0,
            seed: 7,
            max_iterations: 1_000_000,
            verbose: false,
            sim_scale: None,
            extra_drafters: Vec::new(),
            adaptive_k: false,
            trace: crate::trace::TraceConfig::default(),
            ttft_slo_s: 1.0,
            fault: crate::fault::FaultConfig::default(),
            parallel: true,
        }
    }

    /// Validating construction: the returned builder checks the assembled
    /// configuration against a `ModelConfig` (artifact variants, KV
    /// admissibility, schedule combinations) in `build`.
    pub fn builder(drafter: DrafterKind) -> EngineConfigBuilder {
        EngineConfigBuilder { cfg: EngineConfig::new(drafter) }
    }

    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn with_schedule(mut self, s: Schedule, delayed: bool) -> Self {
        self.schedule = s;
        self.delayed_verify = delayed;
        self
    }

    pub fn with_kv(mut self, policy: KvPolicy, budget: usize) -> Self {
        self.kv_policy = policy;
        self.kv_budget = budget;
        self
    }

    pub fn with_faults(mut self, f: crate::fault::FaultConfig) -> Self {
        self.fault = f;
        self
    }
}

/// Builder with construction-time validation (`EngineConfig::builder`).
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    pub fn k(mut self, k: usize) -> Self {
        self.cfg.k = k;
        self
    }

    pub fn schedule(mut self, s: Schedule) -> Self {
        self.cfg.schedule = s;
        self
    }

    pub fn delayed_verify(mut self, on: bool) -> Self {
        self.cfg.delayed_verify = on;
        self
    }

    pub fn kv(mut self, policy: KvPolicy, budget: usize) -> Self {
        self.cfg.kv_policy = policy;
        self.cfg.kv_budget = budget;
        self
    }

    pub fn temperature(mut self, t: f32) -> Self {
        self.cfg.temperature = t;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    pub fn max_iterations(mut self, n: u64) -> Self {
        self.cfg.max_iterations = n;
        self
    }

    pub fn sim_scale(mut self, s: crate::perfmodel::SimScale) -> Self {
        self.cfg.sim_scale = Some(s);
        self
    }

    pub fn verbose(mut self, on: bool) -> Self {
        self.cfg.verbose = on;
        self
    }

    /// Declare a drafter that sessions may select per-request
    /// (`Request::drafter`).  Validated in `build` against the same
    /// artifact/parameter rules as the default drafter, and precompiled
    /// at engine construction.
    pub fn allow_drafter(mut self, d: DrafterKind) -> Self {
        self.cfg.extra_drafters.push(d);
        self
    }

    /// Enable the feedback-adaptive speculation-length controller
    /// (`spec::adaptive`): per-slot draft length follows a windowed
    /// acceptance-rate estimate, bounded above by `k`.
    ///
    /// Interaction with [`Schedule::Unified`]: bucket alignment assumes a
    /// round period of `k + 1` iterations, so once the controller narrows
    /// a slot below `k` its verifications drift off the bucket phase and
    /// verify launches fragment across iterations — adaptation trades
    /// batching alignment for less rollback waste.  Use Lockstep (or
    /// accept the fragmentation) when comparing schedules.
    pub fn adaptive_k(mut self, on: bool) -> Self {
        self.cfg.adaptive_k = on;
        self
    }

    /// Enable structured tracing with the given knobs (see
    /// [`crate::trace::TraceConfig`]); export the journal after the run
    /// via `Engine::export_trace_chrome` / `export_trace_jsonl` or the
    /// CLI's `--trace-out`.
    pub fn tracing(mut self, t: crate::trace::TraceConfig) -> Self {
        self.cfg.trace = t;
        self
    }

    /// TTFT target (simulated seconds) for SLO-centric reporting
    /// (`RunReport::slo`).  Goodput counts completions under this target.
    pub fn ttft_slo(mut self, s: f64) -> Self {
        self.cfg.ttft_slo_s = s;
        self
    }

    /// Deterministic fault injection for chaos testing (see
    /// [`crate::fault`] for the plan grammar and the degradation story).
    /// CLI: `--fault-plan "runtime:0.01,kv_reload:0.05" --fault-seed 42`.
    pub fn faults(mut self, f: crate::fault::FaultConfig) -> Self {
        self.cfg.fault = f;
        self
    }

    /// Toggle slot-parallel sim kernels + pooled verify processing.
    /// Outputs are bit-identical either way (gated by `tests/arena.rs`);
    /// `parallel(false)` is the zero-allocation reference mode used by
    /// the `engine_iteration` bench gate.
    pub fn parallel(mut self, on: bool) -> Self {
        self.cfg.parallel = on;
        self
    }

    /// Validate against the model/artifact shape and return the config.
    /// Catches at construction time what would otherwise surface as a
    /// mid-run artifact-lookup error (or silent mis-serving).
    pub fn build(self, m: &ModelConfig) -> Result<EngineConfig> {
        let cfg = self.cfg;
        if !cfg.temperature.is_finite() || cfg.temperature < 0.0 {
            bail!("temperature must be finite and >= 0 (got {})", cfg.temperature);
        }
        if cfg.max_iterations == 0 {
            bail!("max_iterations must be > 0");
        }
        if !cfg.ttft_slo_s.is_finite() || cfg.ttft_slo_s <= 0.0 {
            bail!("ttft_slo_s must be finite and > 0 (got {})", cfg.ttft_slo_s);
        }
        // Vanilla forces k = 0 inside the engine; everything else verifies
        // with the verify_q{k+1} artifact.
        let k_eff = if cfg.drafter == DrafterKind::Vanilla { 0 } else { cfg.k };
        if !m.has_verify_q(k_eff + 1) {
            bail!(
                "k={} needs a verify_q{} artifact; compiled variants {:?} \
                 support k in {:?}",
                k_eff,
                k_eff + 1,
                m.verify_q_variants,
                m.verify_q_variants.iter().map(|q| q - 1).collect::<Vec<_>>()
            );
        }
        // Per-drafter parameter/artifact validation: the default drafter
        // plus every statically declared per-session override, through
        // the same `spec::validate_drafter` the registry constructors
        // use — degenerate parameters (NGram { n: 0 }, zero/tiny budgets)
        // fail here with actionable errors instead of index-underflowing
        // in draft composition mid-run.
        for kind in std::iter::once(&cfg.drafter).chain(cfg.extra_drafters.iter()) {
            validate_drafter(kind, m)?;
            if let DrafterKind::TriForce { .. } = kind {
                // sparse_verify is compiled for exactly (draft_budget,
                // spec_k); the W side is checked by validate_drafter.
                if k_eff != m.spec_k {
                    bail!(
                        "TriForce k={k_eff} must match the sparse_verify artifact's k={}",
                        m.spec_k
                    );
                }
            }
        }
        // KV budget: at least one prompt + a full draft round must fit, or
        // nothing can ever be admitted.
        let min_budget = m.prompt_pad + k_eff + 2;
        if cfg.kv_budget < min_budget {
            bail!(
                "kv_budget={} cannot admit a single request (needs >= {min_budget})",
                cfg.kv_budget
            );
        }
        if cfg.kv_policy == KvPolicy::Conservative && cfg.kv_budget < m.max_seq {
            bail!(
                "Conservative policy reserves worst-case {} tokens per request; \
                 kv_budget={} would never admit anything",
                m.max_seq,
                cfg.kv_budget
            );
        }
        if cfg.delayed_verify && !cfg.schedule.supports_delayed_verify() {
            bail!(
                "delayed verification (§4.3) requires the Unified schedule: under \
                 Lockstep there is no next-iteration draft work to overlap with"
            );
        }
        Ok(cfg)
    }
}

/// SLO-centric view of a run, measured on the **simulated** serving clock
/// (so it is machine-independent and comparable across figures).
#[derive(Clone, Debug, Default)]
pub struct SloReport {
    /// The TTFT target goodput is measured against (`EngineConfig::ttft_slo_s`).
    pub ttft_target_s: f64,
    /// Time to first token per completed-or-cancelled session.
    pub ttft_sim_s: crate::metrics::Histogram,
    /// Inter-token latency (per accepted token, simulated).
    pub itl_sim_s: crate::metrics::Histogram,
    /// Completions whose first token beat the target.
    pub completed_within_ttft: usize,
    /// Total completions.
    pub completed: usize,
    /// Completions-under-target per simulated second.
    pub goodput_rps: f64,
    /// KV-pressure eviction (recompute-path preemption) events.
    pub kv_evictions: u64,
    /// KV offload-to-host events.
    pub kv_offloads: u64,
    /// Host-tier reload events.
    pub kv_reloads: u64,
}

impl SloReport {
    /// Deterministic markdown rendering (sorted, fixed column order).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| slo | value |\n|---|---|\n");
        let p = |h: &crate::metrics::Histogram, q: f64| h.percentile(q);
        let rows: Vec<(&str, String)> = vec![
            ("completed", format!("{}", self.completed)),
            ("completed_within_ttft", format!("{}", self.completed_within_ttft)),
            ("goodput_rps", format!("{:.4}", self.goodput_rps)),
            ("itl_sim_s_p50", format!("{:.6}", p(&self.itl_sim_s, 50.0))),
            ("itl_sim_s_p99", format!("{:.6}", p(&self.itl_sim_s, 99.0))),
            ("kv_evictions", format!("{}", self.kv_evictions)),
            ("kv_offloads", format!("{}", self.kv_offloads)),
            ("kv_reloads", format!("{}", self.kv_reloads)),
            ("ttft_sim_s_p50", format!("{:.6}", p(&self.ttft_sim_s, 50.0))),
            ("ttft_sim_s_p99", format!("{:.6}", p(&self.ttft_sim_s, 99.0))),
            ("ttft_target_s", format!("{:.4}", self.ttft_target_s)),
        ];
        for (k, v) in rows {
            out.push_str(&format!("| {k} | {v} |\n"));
        }
        out
    }
}

/// Everything a run produces (one row of the paper's figures).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub name: String,
    pub iterations: u64,
    pub wall_s: f64,
    /// Simulated H100 time of the same schedule.
    pub sim_s: f64,
    pub sim_cpu_s: f64,
    pub requests_done: usize,
    /// Sessions cancelled mid-run (always 0 for batch `Engine::run` use).
    pub requests_cancelled: usize,
    /// Submissions rejected at resolve time (invalid per-session drafter).
    pub requests_rejected: usize,
    /// Sessions poisoned by a fatal fault (`FinishReason::Failed`).
    /// Blast radius is per-session: co-batched outputs are unaffected.
    pub requests_failed: usize,
    /// Faults the injector actually fired (0 when disabled).
    pub faults_injected: u64,
    /// Transient-fault retries (runtime backoff + skipped KV actions).
    pub fault_retries: u64,
    /// Slots demoted to vanilla (k=1) decoding after repeated drafter
    /// faults or acceptance collapse.
    pub slot_degradations: u64,
    /// Demoted slots re-promoted to speculation after probation.
    pub slot_promotions: u64,
    pub tokens_generated: u64,
    pub accept: AcceptStats,
    /// Acceptance accounting broken down by drafter name — one entry per
    /// drafter the engine resolved (default + per-session overrides), so
    /// mixed-drafter runs compare policies within a single batch.
    pub accept_by: std::collections::BTreeMap<String, AcceptStats>,
    pub kv: crate::kv_cache::KvStats,
    pub offload: crate::kv_cache::OffloadStats,
    pub trace: crate::scheduler::ScheduleTrace,
    pub step_stats: crate::runtime::StepStats,
    /// Mean device-KV utilisation over the run (Fig. 5).
    pub mean_kv_util: f64,
    /// Outputs per request id (for losslessness checks).
    pub outputs: std::collections::BTreeMap<u64, Vec<i32>>,
    pub request_latency_s: crate::metrics::Histogram,
    /// SLO section: TTFT/ITL percentiles, goodput at the latency target,
    /// KV-pressure counts (always populated; simulated clock).
    pub slo: SloReport,
}

impl RunReport {
    pub fn wall_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s.max(1e-9)
    }

    pub fn sim_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.sim_s.max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<14} reqs={:<4} canc={:<3} rej={:<3} fail={:<3} degr={:<3} \
             toks={:<6} iters={:<5} \
             wall={:>7.2}s ({:>7.1} tok/s) \
             sim={:>7.3}s ({:>8.1} tok/s) acc/rnd={:>5.2} α={:>4.2} kv_util={:>4.2} \
             offl={} recomp={}",
            self.name,
            self.requests_done,
            self.requests_cancelled,
            self.requests_rejected,
            self.requests_failed,
            self.slot_degradations,
            self.tokens_generated,
            self.iterations,
            self.wall_s,
            self.wall_tok_s(),
            self.sim_s,
            self.sim_tok_s(),
            self.accept.mean_accepted(),
            self.accept.alpha(),
            self.mean_kv_util,
            self.kv.offload_events,
            self.kv.recomputed_tokens,
        )
    }

    /// The whole report as a typed, labelled [`crate::metrics::MetricsRegistry`]
    /// — the canonical path to Prometheus exposition
    /// (`registry().expose_prometheus("sparsespec")`) and to merging
    /// reports across replicas (`MetricsRegistry::merge_from`).
    pub fn registry(&self) -> crate::metrics::MetricsRegistry {
        let mut r = crate::metrics::MetricsRegistry::default();
        let none: &[(&str, &str)] = &[];
        r.inc("requests_done", none, self.requests_done as f64);
        r.inc("requests_cancelled", none, self.requests_cancelled as f64);
        r.inc("requests_rejected", none, self.requests_rejected as f64);
        r.inc("requests_failed", none, self.requests_failed as f64);
        r.inc("faults_injected", none, self.faults_injected as f64);
        r.inc("fault_retries", none, self.fault_retries as f64);
        r.inc("slot_degradations", none, self.slot_degradations as f64);
        r.inc("slot_promotions", none, self.slot_promotions as f64);
        r.inc("tokens_generated", none, self.tokens_generated as f64);
        r.inc("iterations", none, self.iterations as f64);
        r.inc("kv_offload_events", none, self.kv.offload_events as f64);
        r.inc("kv_reload_events", none, self.kv.reload_events as f64);
        r.inc("kv_recompute_events", none, self.kv.recompute_events as f64);
        r.set_gauge("mean_kv_util", none, self.mean_kv_util);
        r.set_gauge("sim_s", none, self.sim_s);
        r.set_gauge("wall_s", none, self.wall_s);
        r.set_gauge("goodput_rps", none, self.slo.goodput_rps);
        r.hist_mut("request_latency_s", none).merge(&self.request_latency_s);
        r.hist_mut("ttft_sim_s", none).merge(&self.slo.ttft_sim_s);
        r.hist_mut("itl_sim_s", none).merge(&self.slo.itl_sim_s);
        for (name, st) in &self.accept_by {
            let labels: &[(&str, &str)] = &[("drafter", name)];
            r.inc("drafted_tokens", labels, st.drafted as f64);
            r.inc("accepted_tokens", labels, st.accepted as f64);
            r.set_gauge("acceptance_alpha", labels, st.alpha());
        }
        r
    }

    /// Deterministic markdown: counters sorted, then the SLO section, then
    /// per-drafter acceptance — every surface includes
    /// `requests_cancelled`/`requests_rejected`/`requests_failed` and the
    /// degradation counts.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("## run: {}\n\n", self.name));
        out.push_str(&self.registry().to_markdown());
        out.push('\n');
        out.push_str(&self.slo.to_markdown());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelConfig {
        crate::model::SystemConfig::synthetic("artifacts").model
    }

    #[test]
    fn builder_accepts_valid_configs() {
        let m = model();
        let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
            .k(8)
            .schedule(Schedule::Unified)
            .delayed_verify(true)
            .build(&m)
            .unwrap();
        assert_eq!(cfg.k, 8);
        assert!(cfg.delayed_verify);
        // vanilla ignores k (engine forces 0), so any k validates via q=1
        assert!(EngineConfig::builder(DrafterKind::Vanilla).k(999).build(&m).is_ok());
    }

    #[test]
    fn builder_rejects_uncompiled_variants() {
        let m = model();
        // k=7 -> verify_q8 not compiled
        assert!(EngineConfig::builder(DrafterKind::Pillar { w: 64 }).k(7).build(&m).is_err());
        // W=100 not a draft variant
        assert!(EngineConfig::builder(DrafterKind::Pillar { w: 100 }).k(8).build(&m).is_err());
        // TriForce must match the sparse_verify artifact shape
        assert!(EngineConfig::builder(DrafterKind::TriForce { w: 128 }).k(8).build(&m).is_err());
        assert!(EngineConfig::builder(DrafterKind::TriForce { w: 64 }).k(4).build(&m).is_err());
        assert!(EngineConfig::builder(DrafterKind::TriForce { w: 64 }).k(8).build(&m).is_ok());
    }

    #[test]
    fn builder_rejects_bad_kv_and_schedule_combos() {
        let m = model();
        assert!(EngineConfig::builder(DrafterKind::Vanilla)
            .kv(KvPolicy::Dynamic, 8)
            .build(&m)
            .is_err());
        assert!(EngineConfig::builder(DrafterKind::Vanilla)
            .kv(KvPolicy::Conservative, 256)
            .build(&m)
            .is_err());
        assert!(EngineConfig::builder(DrafterKind::Pillar { w: 64 })
            .k(8)
            .schedule(Schedule::Lockstep)
            .delayed_verify(true)
            .build(&m)
            .is_err());
        assert!(EngineConfig::builder(DrafterKind::Vanilla)
            .temperature(-0.5)
            .build(&m)
            .is_err());
    }

    #[test]
    fn builder_rejects_degenerate_drafter_params() {
        let m = model();
        // one case per rejection class (see spec::validate_drafter)
        let e = EngineConfig::builder(DrafterKind::NGram { n: 0 }).build(&m).unwrap_err();
        assert!(e.to_string().contains("n >= 1"), "{e}");
        let e = EngineConfig::builder(DrafterKind::NGram { n: 7 }).build(&m).unwrap_err();
        assert!(e.to_string().contains("<= 4"), "{e}");
        let e = EngineConfig::builder(DrafterKind::Window { w: 0 })
            .k(8)
            .build(&m)
            .unwrap_err();
        assert!(e.to_string().contains("degenerate"), "{e}");
        let e = EngineConfig::builder(DrafterKind::Pillar { w: 4 })
            .k(8)
            .build(&m)
            .unwrap_err();
        assert!(e.to_string().contains("W >= 8"), "{e}");
        let e = EngineConfig::builder(DrafterKind::OracleTopK { w: 0 })
            .k(8)
            .build(&m)
            .unwrap_err();
        assert!(e.to_string().contains("degenerate"), "{e}");
        // valid params still pass
        assert!(EngineConfig::builder(DrafterKind::NGram { n: 3 }).k(8).build(&m).is_ok());
    }

    #[test]
    fn builder_validates_declared_per_session_drafters() {
        let m = model();
        // a bad extra drafter fails the build even with a good default
        assert!(EngineConfig::builder(DrafterKind::Pillar { w: 64 })
            .k(8)
            .allow_drafter(DrafterKind::NGram { n: 0 })
            .build(&m)
            .is_err());
        assert!(EngineConfig::builder(DrafterKind::Pillar { w: 64 })
            .k(8)
            .allow_drafter(DrafterKind::Window { w: 100 })
            .build(&m)
            .is_err());
        // TriForce extras must match the engine k too
        assert!(EngineConfig::builder(DrafterKind::Pillar { w: 64 })
            .k(4)
            .allow_drafter(DrafterKind::TriForce { w: 64 })
            .build(&m)
            .is_err());
        // good extras pass and survive into the config
        let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 64 })
            .k(8)
            .allow_drafter(DrafterKind::NGram { n: 3 })
            .allow_drafter(DrafterKind::Vanilla)
            .adaptive_k(true)
            .build(&m)
            .unwrap();
        assert_eq!(cfg.extra_drafters.len(), 2);
        assert!(cfg.adaptive_k);
    }
}
