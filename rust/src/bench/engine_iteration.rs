//! engine_iteration — the raw-speed proof for the arena hot path
//! (EXPERIMENTS.md §Raw-speed).
//!
//! Three legs over a slots × context sweep of the decode round
//! (draft + dense verify + sparse verify):
//!
//! 1. **reference** — the seed-era kernels kept verbatim in
//!    [`crate::runtime::reference`]: fresh `Vec`s per call, per-row dump
//!    recompute, linear-scan sparse visibility, strictly serial.
//! 2. **serial arena** — the optimised kernels with the slot-parallel
//!    phase off: same bits, zero steady-state allocations (counted when
//!    the bench binary installs [`crate::util::alloc::CountingAlloc`]).
//! 3. **parallel arena** — the shipping configuration.
//!
//! Emits `BENCH_engine_iteration.json` *before* enforcing the gates, so
//! a regression still leaves its evidence on disk.  Gates:
//! * every leg's per-round output checksums are bit-identical;
//! * `Engine::run` produces identical outputs with `parallel` on/off;
//! * best arena leg ≥ 1.5× the reference iterations/s;
//! * zero steady-state allocations on the serial leg (skip, not pass,
//!   when no counting allocator is installed in this binary).

use super::BenchCtx;
use crate::engine::{Engine, EngineConfig};
use crate::spec::DrafterKind;
use crate::util::json::{obj, s as jstr, Json};
use crate::workload::Dataset;
use anyhow::Result;
use std::time::Instant;

/// Engine-level identity leg: one workload, two runs (`parallel` on/off),
/// outputs compared field-by-field.  Runs on every backend.
struct EngineLeg {
    outputs_equal: bool,
    iterations: u64,
    tokens: u64,
    parallel_s: f64,
    serial_s: f64,
}

impl EngineLeg {
    fn to_json(&self) -> Json {
        use crate::util::json::num;
        obj(vec![
            ("outputs_equal", Json::Bool(self.outputs_equal)),
            ("iterations", num(self.iterations as f64)),
            ("tokens_generated", num(self.tokens as f64)),
            ("parallel_s", num(self.parallel_s)),
            ("serial_s", num(self.serial_s)),
        ])
    }
}

fn engine_identity(ctx: &mut BenchCtx) -> Result<EngineLeg> {
    let rt = ctx.rt()?;
    let mut reqs = crate::workload::WorkloadGen::new(
        rt.cfg.grammar.clone(),
        rt.cfg.model.clone(),
        Dataset::Aime,
        ctx.seed,
    )
    .offline_batch(6);
    for r in &mut reqs {
        r.max_new = r.max_new.min(40);
    }
    let mut run = |on: bool| -> Result<(crate::engine::RunReport, f64)> {
        let mut cfg = EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8);
        cfg.parallel = on;
        let mut eng = Engine::new(rt.clone(), cfg)?;
        let t0 = Instant::now();
        let rep = eng.run(reqs.clone())?;
        Ok((rep, t0.elapsed().as_secs_f64()))
    };
    let (rep_par, parallel_s) = run(true)?;
    let (rep_ser, serial_s) = run(false)?;
    let outputs_equal = rep_par.outputs == rep_ser.outputs
        && rep_par.iterations == rep_ser.iterations
        && rep_par.tokens_generated == rep_ser.tokens_generated;
    println!(
        "  engine identity: outputs_equal={} ({} iterations, {} tokens; parallel {:.0}ms, serial {:.0}ms)",
        outputs_equal,
        rep_par.iterations,
        rep_par.tokens_generated,
        parallel_s * 1e3,
        serial_s * 1e3
    );
    Ok(EngineLeg {
        outputs_equal,
        iterations: rep_par.iterations,
        tokens: rep_par.tokens_generated,
        parallel_s,
        serial_s,
    })
}

#[cfg(not(feature = "pjrt"))]
mod kernel {
    use crate::runtime::{reference, ModelRunner, Runtime};
    use crate::util::json::{num, obj, Json};
    use anyhow::Result;
    use std::rc::Rc;
    use std::time::Instant;

    pub struct Sweep {
        pub combos: Vec<Json>,
        pub reference_s: f64,
        pub serial_s: f64,
        pub parallel_s: f64,
        pub total_rounds: usize,
        pub identical: bool,
        /// Steady-state allocations across every serial-leg timed loop;
        /// `None` when no counting allocator is installed.
        pub steady_allocs: Option<u64>,
    }

    /// FNV-style fold of raw f32 bit patterns — exact equality across
    /// legs, allocation-free so it can sit inside the counted loop.
    fn fold(mut h: u64, xs: &[f32]) -> u64 {
        for &x in xs {
            h = (h ^ x.to_bits() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        h
    }

    pub fn sweep(rt: Rc<Runtime>, scale: usize) -> Result<Sweep> {
        let m = rt.cfg.model.clone();
        let (s_max, pad) = (m.slots, m.prompt_pad);
        let q = m.spec_k + 1;
        let w = m.draft_budget;
        let per_head = m.layers * m.kv_heads;
        let rounds = 24 * scale.max(1);
        let warmup = 4usize;

        let mut slot_counts = vec![1usize, (s_max / 2).max(1), s_max];
        slot_counts.dedup();
        let hi = m.max_seq.saturating_sub(q + 1).max(1);
        let mut ctxs = vec![(m.max_seq / 8).max(1).min(hi), (m.max_seq / 2).min(hi), hi];
        ctxs.dedup();

        let mut rr = reference::Runner::new(m.clone(), rt.cfg.eagle.ctx);
        let mut serial = ModelRunner::new(rt.clone())?;
        serial.set_parallel(false);
        let mut par = ModelRunner::new(rt.clone())?;
        par.set_parallel(true);

        let mut combos = Vec::new();
        let (mut ref_tot, mut ser_tot, mut par_tot) = (0.0f64, 0.0f64, 0.0f64);
        let mut identical = true;
        let mut steady_allocs: Option<u64> = None;
        let mut counted_any = false;
        println!(
            "  {:<6} {:>5} {:>10} {:>10} {:>10} {:>8} {:>8}",
            "slots", "ctx", "ref_it/s", "ser_it/s", "par_it/s", "ser_x", "par_x"
        );
        for &sa in &slot_counts {
            for &c0 in &ctxs {
                let active: Vec<i32> = (0..s_max).map(|i| (i < sa) as i32).collect();
                let ptokens: Vec<i32> = (0..s_max * pad).map(|i| (i % 97) as i32 + 1).collect();
                let plen = vec![pad.min(c0).max(1) as i32; s_max];
                let dtok: Vec<i32> = (0..s_max).map(|s| (s as i32 % 31) + 2).collect();
                let pos = vec![c0 as i32; s_max];
                let vtok: Vec<i32> = (0..s_max * q).map(|i| (i % 89) as i32 + 1).collect();
                let qv = vec![q as i32; s_max];
                let idx: Vec<i32> =
                    (0..s_max * per_head * w).map(|i| ((i * 13) % c0) as i32).collect();

                // Reference leg (the in-JSON baseline).
                rr.reset_kv();
                let l = rr.prefill(&ptokens, &plen, &active);
                let mut h_ref = fold(0x5EED, &l);
                for _ in 0..warmup {
                    rr.draft(w, &dtok, &pos, &idx, &active);
                    rr.verify(q, &vtok, &pos, &qv, &active);
                    rr.sparse_verify(&vtok, &pos, &qv, &idx, &active);
                }
                let t0 = Instant::now();
                for _ in 0..rounds {
                    let l = rr.draft(w, &dtok, &pos, &idx, &active);
                    h_ref = fold(h_ref, &l);
                    let (l, d) = rr.verify(q, &vtok, &pos, &qv, &active);
                    h_ref = fold(h_ref, &l);
                    h_ref = fold(h_ref, &d);
                    let l = rr.sparse_verify(&vtok, &pos, &qv, &idx, &active);
                    h_ref = fold(h_ref, &l);
                }
                let ref_s = t0.elapsed().as_secs_f64();

                // Arena legs: the KV writes are deterministic overwrites,
                // so warmup rounds leave the pools exactly where the timed
                // rounds need them and the checksums stay comparable.
                let mut run_arena = |r: &mut ModelRunner, gate: bool| -> Result<(f64, u64)> {
                    r.reset_kv()?;
                    r.prefill(&ptokens, &plen, &active)?;
                    let mut h = fold(0x5EED, r.logits());
                    for _ in 0..warmup {
                        r.draft(w, &dtok, &pos, &idx, &active)?;
                        r.verify(q, &vtok, &pos, &qv, &active)?;
                        r.sparse_verify(&vtok, &pos, &qv, &idx, &active)?;
                    }
                    let base = if gate { crate::util::alloc::allocations() } else { None };
                    let t0 = Instant::now();
                    for _ in 0..rounds {
                        r.draft(w, &dtok, &pos, &idx, &active)?;
                        h = fold(h, r.logits());
                        r.verify(q, &vtok, &pos, &qv, &active)?;
                        h = fold(h, r.logits());
                        h = fold(h, r.dump());
                        r.sparse_verify(&vtok, &pos, &qv, &idx, &active)?;
                        h = fold(h, r.logits());
                    }
                    let dt = t0.elapsed().as_secs_f64();
                    if gate {
                        if let Some(n) = crate::util::alloc::allocations_since(base) {
                            counted_any = true;
                            *steady_allocs.get_or_insert(0) += n;
                        }
                    }
                    Ok((dt, h))
                };
                let (ser_s, h_ser) = run_arena(&mut serial, true)?;
                let (par_s, h_par) = run_arena(&mut par, false)?;
                identical &= h_ref == h_ser && h_ref == h_par;

                let rps = |s: f64| rounds as f64 / s.max(1e-12);
                println!(
                    "  {:<6} {:>5} {:>10.0} {:>10.0} {:>10.0} {:>7.1}x {:>7.1}x",
                    sa,
                    c0,
                    rps(ref_s),
                    rps(ser_s),
                    rps(par_s),
                    ref_s / ser_s.max(1e-12),
                    ref_s / par_s.max(1e-12)
                );
                combos.push(obj(vec![
                    ("slots", num(sa as f64)),
                    ("ctx", num(c0 as f64)),
                    ("reference_iters_per_s", num(rps(ref_s))),
                    ("serial_arena_iters_per_s", num(rps(ser_s))),
                    ("parallel_arena_iters_per_s", num(rps(par_s))),
                    ("speedup_serial", num(ref_s / ser_s.max(1e-12))),
                    ("speedup_parallel", num(ref_s / par_s.max(1e-12))),
                ]));
                ref_tot += ref_s;
                ser_tot += ser_s;
                par_tot += par_s;
            }
        }
        if !counted_any {
            steady_allocs = None;
        }
        Ok(Sweep {
            combos,
            reference_s: ref_tot,
            serial_s: ser_tot,
            parallel_s: par_tot,
            total_rounds: rounds * slot_counts.len() * ctxs.len(),
            identical,
            steady_allocs,
        })
    }
}

#[cfg(not(feature = "pjrt"))]
pub fn engine_iteration(ctx: &mut BenchCtx) -> Result<()> {
    use crate::util::json::{arr, num};
    println!("engine_iteration: arena hot path vs seed-era reference kernels");
    let leg = engine_identity(ctx)?;
    let rt = ctx.rt()?;
    let scale = ctx.n_requests.max(1);
    let sw = kernel::sweep(rt, scale)?;

    let best = sw.serial_s.min(sw.parallel_s);
    let baseline_rps = sw.total_rounds as f64 / sw.reference_s.max(1e-12);
    let arena_rps = sw.total_rounds as f64 / best.max(1e-12);
    let speedup = sw.reference_s / best.max(1e-12);
    println!(
        "  totals: reference {:.0} it/s, arena {:.0} it/s -> {:.2}x (gate: >= 1.5x)",
        baseline_rps, arena_rps, speedup
    );

    let json = obj(vec![
        ("experiment", jstr("engine_iteration")),
        ("harness", jstr("cargo bench -- engine_iteration")),
        ("rounds_total_per_leg", num(sw.total_rounds as f64)),
        ("combos", arr(sw.combos)),
        (
            "totals",
            obj(vec![
                ("reference_s", num(sw.reference_s)),
                ("serial_arena_s", num(sw.serial_s)),
                ("parallel_arena_s", num(sw.parallel_s)),
                ("baseline_iters_per_s", num(baseline_rps)),
                ("arena_iters_per_s", num(arena_rps)),
                ("speedup_vs_baseline", num(speedup)),
            ]),
        ),
        ("kernels_bit_identical", Json::Bool(sw.identical)),
        ("engine", leg.to_json()),
        (
            "alloc_gate",
            obj(vec![
                ("counted", Json::Bool(sw.steady_allocs.is_some())),
                (
                    "steady_state_allocs",
                    sw.steady_allocs.map_or(Json::Null, |n| num(n as f64)),
                ),
            ]),
        ),
        (
            "gates",
            obj(vec![
                ("min_speedup", num(1.5)),
                ("zero_alloc", Json::Bool(true)),
                ("bit_identical", Json::Bool(true)),
            ]),
        ),
    ]);
    ctx.save("BENCH_engine_iteration.json", &json.to_string())?;

    anyhow::ensure!(
        sw.identical,
        "engine_iteration gate failed: arena kernels diverged from the reference kernels"
    );
    anyhow::ensure!(
        leg.outputs_equal,
        "engine_iteration gate failed: Engine::run outputs differ between parallel and serial"
    );
    anyhow::ensure!(
        speedup >= 1.5,
        "engine_iteration gate failed: arena speedup {speedup:.2}x vs reference, need >= 1.5x"
    );
    match sw.steady_allocs {
        Some(0) => println!(
            "  zero-allocation gate: PASS (0 steady-state allocations over {} rounds)",
            sw.total_rounds
        ),
        Some(n) => anyhow::bail!(
            "engine_iteration gate failed: {n} steady-state allocations on the serial arena leg, need 0"
        ),
        None => println!(
            "  zero-allocation gate: SKIPPED (no counting allocator installed in this binary; \
             run via `cargo bench` or `cargo test --test alloc_gate`)"
        ),
    }
    Ok(())
}

/// pjrt builds keep only the engine-level identity gate: the seed-era
/// reference kernels (the throughput baseline) and the allocation count
/// are properties of the sim backend.
#[cfg(feature = "pjrt")]
pub fn engine_iteration(ctx: &mut BenchCtx) -> Result<()> {
    println!("engine_iteration: engine identity only (kernel baseline is sim-only)");
    let leg = engine_identity(ctx)?;
    let json = obj(vec![
        ("experiment", jstr("engine_iteration")),
        ("backend", jstr("pjrt")),
        (
            "note",
            jstr("kernel baseline + alloc gate are sim-only; engine identity gate only"),
        ),
        ("engine", leg.to_json()),
    ]);
    ctx.save("BENCH_engine_iteration.json", &json.to_string())?;
    anyhow::ensure!(
        leg.outputs_equal,
        "engine_iteration gate failed: Engine::run outputs differ between parallel and serial"
    );
    Ok(())
}
