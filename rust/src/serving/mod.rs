//! Network serving front-end: the process boundary around the engine.
//!
//! Three layers, matching the paper's online-serving story (§2.2 —
//! latency-bound decode under continuous arrivals):
//!
//! * [`wire`] — the length-prefixed binary frame protocol
//!   (submit / stream / cancel, credit-based flow control, typed error
//!   codes).  Total codec: malformed bytes return [`wire::WireError`],
//!   never panic.
//! * [`server`] — `sparsespec-server`: one engine thread (the engine is
//!   single-threaded by design), per-connection reader/writer threads,
//!   and the traffic-policing layer — KV-budget admission control,
//!   watermark load-shedding, bounded per-tenant queues under
//!   deficit-weighted round-robin, slow-reader drop-to-cancel, graceful
//!   drain — plus an HTTP `/metrics` endpoint serving the Prometheus
//!   exposition.
//! * [`client`] — `sparsespec-client`: open-loop load generator
//!   replaying `workload` traces per tenant, measuring client-side
//!   TTFT / inter-token latency / goodput and typed refusal counts.
//! * [`router`] — `sparsespec-router`: scale-out front door over N
//!   server replicas — bucket-aware least-loaded routing with tenant
//!   stickiness, health-checked failover (resubmit vs typed fail-fast),
//!   graceful fleet drain, and the one-merge fleet `/metrics` rollup
//!   over each replica's lossless `/snapshot`.
//!
//! Determinism carries over the wire: the engine decodes greedily at
//! `temperature=0`, so each request's streamed token sequence is
//! independent of admission order and bit-identical to `Engine::run` on
//! the same request — pinned by `rust/tests/serving.rs`.

pub mod client;
pub mod router;
pub mod server;
pub mod wire;

pub use client::{run_load, ClientConfig, ClientReport, TenantLoad};
pub use router::{
    failover_action, FailoverAction, ReplicaHealth, ReplicaSpec, RouteDecision, Router,
    RouterConfig, RouterPolicy, RouterSummary,
};
pub use server::{Server, ServerConfig, ServerSummary, WrrQueues};
pub use wire::{ErrorCode, Frame, WireError};
