//! Session-based streaming serving API — the engine's public surface.
//!
//! The paper's system is an *online* server: requests arrive continuously,
//! tokens matter the moment verification accepts them, and a request can
//! be abandoned mid-generation.  This module exposes that shape:
//!
//! * [`EngineHandle::submit`] admits a [`Request`] mid-run and returns a
//!   [`SessionHandle`] — a cheap, clonable view of that request's live
//!   token stream, per-session [`SessionStats`] (TTFT, inter-token
//!   latency, accepted-per-round) and cancellation switch.
//! * Tokens are delivered **incrementally**, the same iteration
//!   verification accepts them: pull them with [`SessionHandle::drain`] /
//!   [`SessionHandle::try_recv`], or push-style by registering a
//!   [`TokenSink`] at submit time — both views observe the same stream.
//! * [`SessionHandle::cancel`] marks the session; the engine applies it at
//!   the next iteration boundary, releasing the slot, its bucket and its
//!   KV pages (device *and* host tier) through the same paths retirement
//!   uses.  Other sessions are unaffected.
//! * [`EngineDriver`] interleaves an **arrival process** (any
//!   `Iterator<Item = Request>`, e.g. `WorkloadGen::online_arrivals`) with
//!   decode iterations on the simulated serving clock, so online traces no
//!   longer need to be materialised up front.
//!
//! `Engine::run` remains as a thin batch-compatibility wrapper over
//! submit + drive: identical queue order, identical iteration loop,
//! bit-identical `RunReport.outputs`.
//!
//! Everything here is single-threaded by design (the engine owns `Rc`
//! runtime state); sessions are `Rc<RefCell<…>>` views, not channels
//! across threads.
//!
//! **Network entry point:** the `sparsespec-server` binary
//! ([`crate::serving`]) wraps exactly this API behind a TCP wire
//! protocol — submit/stream/cancel frames map 1:1 onto
//! [`EngineHandle::submit`] / [`SessionHandle::drain`] /
//! [`SessionHandle::cancel`], with admission control, backpressure and
//! per-tenant fairness layered in front.  Outputs stay bit-identical to
//! in-process serving (greedy decode is schedule-independent).

use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use super::core::Engine;
use super::{EngineConfig, RunReport};
use crate::metrics::{Histogram, MetricsRegistry};
use crate::runtime::Runtime;
use crate::workload::Request;

/// Why a session stopped producing tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generation budget reached; the stream holds the full output.
    Completed,
    /// Cancelled by the consumer; the stream holds a partial output.
    Cancelled,
    /// Rejected at submit time: the request named a drafter the engine
    /// could not resolve (unknown registry name, degenerate parameters,
    /// missing artifact variant).  Nothing was queued; the reason is
    /// readable via [`SessionHandle::reject_reason`].
    Rejected,
    /// Poisoned by a fatal engine fault (e.g. a KV reload whose retry
    /// budget was exhausted).  The stream holds whatever was delivered
    /// before the fault; the typed detail is readable via
    /// [`SessionHandle::failure_reason`].  Blast radius is one session:
    /// its slot, bucket and KV (both tiers) are released through the
    /// regular retirement paths and co-batched sessions are unaffected.
    Failed,
}

impl FinishReason {
    /// Stable lowercase label for trace args and metric labels.
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Completed => "completed",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected => "rejected",
            FinishReason::Failed => "failed",
        }
    }
}

/// One element of a session's event stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TokenEvent {
    /// `index` is the 0-based position in the session's output.
    Token { token: i32, index: usize },
    Finished { reason: FinishReason },
}

/// Push-style consumer of a session's event stream.  Registered at submit
/// time; invoked synchronously inside the engine iteration that produced
/// the event (keep it cheap).  Closures `FnMut(u64, &TokenEvent)` qualify.
pub trait TokenSink {
    fn on_event(&mut self, session: u64, ev: &TokenEvent);
}

impl<F: FnMut(u64, &TokenEvent)> TokenSink for F {
    fn on_event(&mut self, session: u64, ev: &TokenEvent) {
        self(session, ev)
    }
}

/// Per-session serving statistics, updated as the engine runs.
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// Resolved drafter name serving this session (the engine default or
    /// the per-request override) — keys the per-drafter breakdowns in
    /// [`EngineDriver::session_metrics`].
    pub drafter: String,
    /// Simulated-clock submit time.
    pub submitted_sim_s: f64,
    /// Simulated-clock time of the first delivered token.
    pub first_token_sim_s: Option<f64>,
    /// Simulated-clock time the session finished (completed or cancelled).
    pub finished_sim_s: Option<f64>,
    /// Wallclock time-to-first-token.
    pub ttft_s: Option<f64>,
    /// Wallclock inter-token latencies (one sample per token after the
    /// first).
    pub inter_token_s: Histogram,
    /// Tokens delivered so far.
    pub tokens: usize,
    /// Verification rounds this session went through.
    pub rounds: u64,
    /// Drafted tokens accepted across those rounds (bonus not counted).
    pub accepted: u64,
    submitted_at: Instant,
    last_token_at: Option<Instant>,
}

impl SessionStats {
    fn new(sim_s: f64, drafter: String) -> Self {
        SessionStats {
            drafter,
            submitted_sim_s: sim_s,
            first_token_sim_s: None,
            finished_sim_s: None,
            ttft_s: None,
            inter_token_s: Histogram::default(),
            tokens: 0,
            rounds: 0,
            accepted: 0,
            submitted_at: Instant::now(),
            last_token_at: None,
        }
    }

    /// Simulated-clock TTFT (first-token time minus submit time).
    pub fn ttft_sim_s(&self) -> Option<f64> {
        self.first_token_sim_s.map(|t| t - self.submitted_sim_s)
    }

    /// Mean accepted drafts per verification round.
    pub fn mean_accepted_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.accepted as f64 / self.rounds as f64
        }
    }

    fn on_token(&mut self) {
        let now = Instant::now();
        if self.tokens == 0 {
            self.ttft_s = Some(now.duration_since(self.submitted_at).as_secs_f64());
        } else if let Some(prev) = self.last_token_at {
            self.inter_token_s
                .record(now.duration_since(prev).as_secs_f64());
        }
        self.last_token_at = Some(now);
        self.tokens += 1;
    }
}

/// Engine-side session state, shared with every [`SessionHandle`] clone.
pub(crate) struct SessionShared {
    pub(crate) id: u64,
    /// Tokens delivered but not yet consumed by the pull side.
    pending: std::collections::VecDeque<i32>,
    /// How many of the slot's output tokens have been delivered — the
    /// watermark that makes delivery idempotent across preempt/restart.
    delivered: usize,
    finished: Option<FinishReason>,
    cancel_requested: bool,
    sink: Option<Box<dyn TokenSink>>,
    reject_reason: Option<String>,
    failure_reason: Option<String>,
    stats: SessionStats,
}

impl SessionShared {
    pub(crate) fn new(id: u64, sim_s: f64, drafter: String) -> Self {
        SessionShared {
            id,
            pending: std::collections::VecDeque::new(),
            delivered: 0,
            finished: None,
            cancel_requested: false,
            sink: None,
            reject_reason: None,
            failure_reason: None,
            stats: SessionStats::new(sim_s, drafter),
        }
    }

    pub(crate) fn set_reject_reason(&mut self, reason: String) {
        self.reject_reason = Some(reason);
    }

    pub(crate) fn set_failure_reason(&mut self, reason: String) {
        self.failure_reason = Some(reason);
    }

    pub(crate) fn set_sink(&mut self, sink: Box<dyn TokenSink>) {
        self.sink = Some(sink);
    }

    pub(crate) fn has_sink(&self) -> bool {
        self.sink.is_some()
    }

    /// True when the consumer asked for cancellation and the engine has
    /// not retired the session yet.
    pub(crate) fn wants_cancel(&self) -> bool {
        self.cancel_requested && self.finished.is_none()
    }

    /// Deliver every output token past the watermark, then record the
    /// round's acceptance.  Called by the engine after prefill and after
    /// each verification that touched this session's slot — only for
    /// *observed* sessions (a live consumer handle or a sink); unobserved
    /// ones take the cheap `note_round` path instead, so batch
    /// `Engine::run` pays no per-token clock reads or double-buffering.
    pub(crate) fn on_progress(&mut self, output: &[i32], round_accept: Option<usize>) {
        while self.delivered < output.len() {
            let tok = output[self.delivered];
            let index = self.delivered;
            self.delivered += 1;
            self.stats.on_token();
            self.pending.push_back(tok);
            if let Some(sink) = self.sink.as_mut() {
                sink.on_event(self.id, &TokenEvent::Token { token: tok, index });
            }
        }
        self.note_round(round_accept);
    }

    /// Acceptance accounting only (two integer adds).
    pub(crate) fn note_round(&mut self, round_accept: Option<usize>) {
        if let Some(acc) = round_accept {
            self.stats.rounds += 1;
            self.stats.accepted += acc as u64;
        }
    }

    pub(crate) fn finish(&mut self, reason: FinishReason) {
        if self.finished.is_some() {
            return;
        }
        self.finished = Some(reason);
        if let Some(sink) = self.sink.as_mut() {
            sink.on_event(self.id, &TokenEvent::Finished { reason });
        }
    }

    /// Apply the end-of-iteration simulated clock to any event from this
    /// iteration that still lacks a sim timestamp.  Idempotent: the first
    /// stamp after the first token / the finish wins, so TTFT includes
    /// the generating iteration's own cost (the engine advances `sim_s`
    /// only at the end of a step).
    pub(crate) fn stamp_sim(&mut self, sim_s: f64) {
        if self.stats.tokens > 0 && self.stats.first_token_sim_s.is_none() {
            self.stats.first_token_sim_s = Some(sim_s);
        }
        if self.finished.is_some() && self.stats.finished_sim_s.is_none() {
            self.stats.finished_sim_s = Some(sim_s);
        }
    }
}

/// Consumer view of one submitted request: incremental tokens, stats,
/// finish state, cancellation.  Clones observe the same stream.
#[derive(Clone)]
pub struct SessionHandle {
    shared: Rc<RefCell<SessionShared>>,
}

impl SessionHandle {
    pub(crate) fn new(shared: Rc<RefCell<SessionShared>>) -> Self {
        SessionHandle { shared }
    }

    pub fn id(&self) -> u64 {
        self.shared.borrow().id
    }

    /// Pull one undelivered token, if any (pull-style streaming).
    pub fn try_recv(&self) -> Option<i32> {
        self.shared.borrow_mut().pending.pop_front()
    }

    /// Pull every undelivered token (empty when none arrived since the
    /// last poll).
    pub fn drain(&self) -> Vec<i32> {
        self.shared.borrow_mut().pending.drain(..).collect()
    }

    /// Tokens delivered so far (consumed or not).
    pub fn tokens_delivered(&self) -> usize {
        self.shared.borrow().delivered
    }

    pub fn is_finished(&self) -> bool {
        self.shared.borrow().finished.is_some()
    }

    pub fn finish_reason(&self) -> Option<FinishReason> {
        self.shared.borrow().finished
    }

    /// Why the submit was rejected (only set for
    /// [`FinishReason::Rejected`] sessions).
    pub fn reject_reason(&self) -> Option<String> {
        self.shared.borrow().reject_reason.clone()
    }

    /// The rendered [`EngineError`](crate::fault::EngineError) that
    /// poisoned this session (only set for [`FinishReason::Failed`]
    /// sessions).
    pub fn failure_reason(&self) -> Option<String> {
        self.shared.borrow().failure_reason.clone()
    }

    /// Request cancellation.  Applied by the engine at the next iteration
    /// boundary: the slot, its scheduler bucket and its KV pages (device
    /// and host tier) are released through the regular retirement paths;
    /// tokens already delivered stay readable.
    pub fn cancel(&self) {
        self.shared.borrow_mut().cancel_requested = true;
    }

    /// Snapshot of the session's serving statistics.
    pub fn stats(&self) -> SessionStats {
        self.shared.borrow().stats.clone()
    }
}

/// Owning, session-first wrapper around an [`Engine`]: submit requests,
/// step the serving loop, read the final [`RunReport`].
pub struct EngineHandle {
    engine: Engine,
    started: Option<Instant>,
}

impl EngineHandle {
    pub fn new(rt: Rc<Runtime>, cfg: EngineConfig) -> Result<Self> {
        Ok(EngineHandle { engine: Engine::new(rt, cfg)?, started: None })
    }

    pub fn from_engine(engine: Engine) -> Self {
        EngineHandle { engine, started: None }
    }

    /// Admit a request (effective at the next `step`); returns its live
    /// session.
    pub fn submit(&mut self, req: Request) -> SessionHandle {
        self.engine.submit(req)
    }

    /// `submit` with a push-style sink receiving every `TokenEvent`.
    pub fn submit_with_sink(&mut self, req: Request, sink: Box<dyn TokenSink>) -> SessionHandle {
        self.engine.submit_with_sink(req, sink)
    }

    /// One engine iteration.  Returns `false` once fully idle (or the
    /// configured iteration cap is reached — see `iteration_cap_reached`
    /// to distinguish the two).
    pub fn step(&mut self) -> Result<bool> {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        if self.iteration_cap_reached() {
            return Ok(false);
        }
        self.engine.step()
    }

    /// True when the `max_iterations` safety valve stopped the loop (the
    /// engine may still hold unserved work).
    pub fn iteration_cap_reached(&self) -> bool {
        self.engine.iterations() >= self.engine.cfg.max_iterations
    }

    /// Step until idle.
    pub fn drive(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// The simulated serving clock (seconds).
    pub fn clock_s(&self) -> f64 {
        self.engine.clock_s()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// The engine's trace journal (empty unless `EngineConfig::trace`
    /// enabled tracing).
    pub fn tracer(&self) -> &crate::trace::Tracer {
        self.engine.tracer()
    }

    /// Assemble the run report (drains per-run aggregates; call once at
    /// the end, exactly like `Engine::run`'s return value).
    pub fn report(&mut self) -> RunReport {
        let wall = self
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        self.engine.take_report(wall)
    }
}

/// Serving loop that interleaves an arrival process with decode
/// iterations: each `step` first admits every request whose `arrival_s`
/// is due on the simulated clock, then runs one engine iteration.  When
/// the engine goes idle with arrivals still pending, the clock jumps to
/// the next arrival (we simulate the wait, we don't sleep through it).
pub struct EngineDriver {
    handle: EngineHandle,
    arrivals: Option<Box<dyn Iterator<Item = Request>>>,
    /// Next arrival, not yet due.
    staged: Option<Request>,
    handles: Vec<SessionHandle>,
    /// Stats folded out of pruned (finished) sessions — see
    /// `prune_finished`.
    retired: MetricsRegistry,
}

impl EngineDriver {
    pub fn new(handle: EngineHandle) -> Self {
        EngineDriver {
            handle,
            arrivals: None,
            staged: None,
            handles: Vec::new(),
            retired: MetricsRegistry::new(),
        }
    }

    pub fn with_arrivals(
        handle: EngineHandle,
        arrivals: impl Iterator<Item = Request> + 'static,
    ) -> Self {
        EngineDriver {
            handle,
            arrivals: Some(Box::new(arrivals)),
            staged: None,
            handles: Vec::new(),
            retired: MetricsRegistry::new(),
        }
    }

    /// Submit immediately (in addition to whatever the arrival process
    /// produces).
    pub fn submit(&mut self, req: Request) -> SessionHandle {
        let h = self.handle.submit(req);
        self.handles.push(h.clone());
        h
    }

    /// Sessions admitted so far (submission order).
    pub fn sessions(&self) -> &[SessionHandle] {
        &self.handles
    }

    pub fn handle(&self) -> &EngineHandle {
        &self.handle
    }

    pub fn handle_mut(&mut self) -> &mut EngineHandle {
        &mut self.handle
    }

    fn refill_staged(&mut self) {
        if self.staged.is_none() {
            self.staged = self.arrivals.as_mut().and_then(|it| it.next());
        }
    }

    fn inject_due(&mut self) {
        loop {
            self.refill_staged();
            let due = match &self.staged {
                Some(r) => r.arrival_s <= self.handle.clock_s(),
                None => false,
            };
            if !due {
                return;
            }
            let r = self.staged.take().unwrap();
            let h = self.handle.submit(r);
            self.handles.push(h);
        }
    }

    /// One driver iteration: admit due arrivals, run one engine step.
    /// Returns `false` when the engine is idle *and* the arrival process
    /// is exhausted — or when the `max_iterations` safety valve tripped
    /// (remaining arrivals are left unconsumed rather than admitted into
    /// a loop that will never serve them).
    pub fn step(&mut self) -> Result<bool> {
        if self.handle.iteration_cap_reached() {
            return Ok(false);
        }
        self.inject_due();
        if self.handle.step()? {
            return Ok(true);
        }
        if self.handle.iteration_cap_reached() {
            return Ok(false);
        }
        // Idle: fast-forward the serving clock to the next arrival.
        self.refill_staged();
        if let Some(r) = self.staged.take() {
            self.handle.engine_mut().advance_clock(r.arrival_s);
            let h = self.handle.submit(r);
            self.handles.push(h);
            self.inject_due();
            return Ok(true);
        }
        Ok(false)
    }

    /// Run until every arrival has been served (or cancelled).
    pub fn drive(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    fn fold_session(m: &mut MetricsRegistry, h: &SessionHandle) {
        let st = h.stats();
        // Per-drafter label series ride next to the aggregate (empty
        // label set) so mixed-drafter pools compare policies.
        let tag = st.drafter.clone();
        let by: &[(&str, &str)] = &[("drafter", &tag)];
        if let Some(t) = st.ttft_s {
            m.observe("ttft_s", &[], t);
            if !tag.is_empty() {
                m.observe("ttft_s", by, t);
            }
        }
        if let Some(t) = st.ttft_sim_s() {
            m.observe("ttft_sim_s", &[], t);
        }
        m.hist_mut("inter_token_s", &[]).merge(&st.inter_token_s);
        if st.rounds > 0 {
            m.observe("accepted_per_round", &[], st.mean_accepted_per_round());
            if !tag.is_empty() {
                m.observe("accepted_per_round", by, st.mean_accepted_per_round());
            }
        }
        match h.finish_reason() {
            Some(FinishReason::Completed) => {
                m.inc("sessions_completed", &[], 1.0);
                if !tag.is_empty() {
                    m.inc("sessions_completed", by, 1.0);
                }
            }
            Some(FinishReason::Cancelled) => m.inc("sessions_cancelled", &[], 1.0),
            Some(FinishReason::Rejected) => m.inc("sessions_rejected", &[], 1.0),
            Some(FinishReason::Failed) => m.inc("sessions_failed", &[], 1.0),
            None => m.inc("sessions_live", &[], 1.0),
        }
    }

    /// Drop finished sessions (their stats are folded into the running
    /// aggregate first, so `session_metrics` stays complete) and release
    /// their undrained token backlogs.  A long-lived serving loop should
    /// call this periodically; without it the driver retains every
    /// session for the trace's lifetime.  Returns how many were pruned.
    pub fn prune_finished(&mut self) -> usize {
        let before = self.handles.len();
        let mut kept = Vec::with_capacity(before);
        for h in self.handles.drain(..) {
            if h.is_finished() {
                Self::fold_session(&mut self.retired, &h);
            } else {
                kept.push(h);
            }
        }
        self.handles = kept;
        before - self.handles.len()
    }

    /// Aggregate per-session statistics into a typed
    /// [`MetricsRegistry`]: `ttft_s`, `ttft_sim_s`, `inter_token_s` and
    /// `accepted_per_round` histograms plus
    /// `sessions_{completed,cancelled,rejected,failed,live}` counters.
    /// Sessions
    /// carry their resolved drafter name, so `{drafter="<name>"}` label
    /// series land alongside the unlabelled aggregates (mixed-drafter
    /// pools).  Includes sessions already dropped by `prune_finished`.
    pub fn session_metrics(&self) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        m.merge_from(&self.retired);
        for h in &self.handles {
            Self::fold_session(&mut m, h);
        }
        m
    }

    /// Final run report (see [`EngineHandle::report`]).
    pub fn report(&mut self) -> RunReport {
        self.handle.report()
    }

    /// The engine's trace journal (empty unless tracing is enabled).
    pub fn tracer(&self) -> &crate::trace::Tracer {
        self.handle.tracer()
    }
}
