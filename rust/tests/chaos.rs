//! Chaos suite — the tentpole contract of the robustness PR.
//!
//! Every scenario runs the engine under a seeded fault plan (greedy
//! decoding, so unaffected sessions have a bit-identity oracle) and pins
//! the graceful-degradation contract:
//!
//! * no injected fault — transient I/O error, drafter panic, malformed
//!   proposal — ever escapes `Engine::run`/`drive` as a panic;
//! * transient faults retry with sim-clock backoff and every session
//!   still completes with outputs **bit-identical** to a fault-free run;
//! * drafter faults demote only the affected slot to vanilla (k=1)
//!   decoding — sessions finish `Completed` with vanilla-identical
//!   outputs, and probation re-promotes the slot later;
//! * exhausted reload faults poison exactly the offloaded session
//!   (`FinishReason::Failed` + `failure_reason`), releasing its KV while
//!   co-batched sessions finish bit-identically;
//! * the whole fault schedule is a pure function of the fault seed.

use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig, EngineHandle, FinishReason};
use sparsespec::fault::{FaultConfig, FaultPlan, FaultSite};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::model::ModelConfig;
use sparsespec::runtime::Runtime;
use sparsespec::spec::{
    DraftCtx, DraftMode, DraftPlan, Drafter, DrafterKind, DrafterRegistry, IndexPolicy,
};
use sparsespec::workload::{Dataset, Request, WorkloadGen};

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, seed)
            .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

fn faults(spec: &str, seed: u64) -> FaultConfig {
    FaultConfig::new(FaultPlan::parse(spec).expect("valid fault spec"), seed)
}

/// Transient-only chaos sweep: runtime step failures, offload/reload I/O
/// errors and delayed-verify stalls at realistic rates, across several
/// fault seeds.  Bounded retry + backoff must absorb all of them: zero
/// failed sessions and outputs bit-identical to the fault-free run (the
/// injector never touches the sampling RNG, and greedy decoding is
/// schedule-invariant).
#[test]
fn transient_faults_retry_and_complete_bit_identically() {
    let rt = runtime();
    let m = &rt.cfg.model;
    let budget = m.slots * m.max_seq / 16; // tight: forces offload traffic
    let cfg = |f: FaultConfig| {
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_schedule(sparsespec::scheduler::Schedule::Unified, true)
            .with_kv(KvPolicy::Dynamic, budget)
            .with_faults(f)
    };
    let reqs = small_requests(&rt, 8, 56, 99);

    let mut clean = Engine::new(rt.clone(), cfg(FaultConfig::off())).unwrap();
    let rc = clean.run(reqs.clone()).unwrap();
    assert!(rc.kv.offload_events > 0, "budget never pressured — sweep is vacuous");

    for fault_seed in [1u64, 7, 42] {
        let plan = "runtime:0.02,kv_offload:0.05,kv_reload:0.05,verify_stall:0.1";
        let mut eng = Engine::new(rt.clone(), cfg(faults(plan, fault_seed))).unwrap();
        let r = eng.run(reqs.clone()).unwrap();
        assert!(r.faults_injected > 0, "seed {fault_seed}: no faults fired");
        assert!(r.fault_retries > 0, "seed {fault_seed}: nothing retried");
        assert_eq!(r.requests_failed, 0, "transient faults must never fail a session");
        assert_eq!(r.requests_done, reqs.len());
        assert_eq!(
            rc.outputs, r.outputs,
            "seed {fault_seed}: transient faults changed generated tokens"
        );
        // retries charge the sim clock (backoff), never corrupt accounting
        assert!(r.sim_s.is_finite() && r.sim_s > 0.0);
    }
}

/// A drafter whose hooks genuinely panic is sandboxed at the trait
/// boundary: after `DEGRADE_FAULT_THRESHOLD` consecutive faults the slot
/// demotes to vanilla decoding and every session still completes with
/// vanilla-identical outputs — speculation is a pure accelerator, losing
/// it costs only speed.
#[test]
fn panicking_drafter_degrades_to_vanilla_and_completes() {
    struct Grenade;
    impl Drafter for Grenade {
        fn kind(&self) -> DrafterKind {
            DrafterKind::Custom { name: "grenade" }
        }
        fn mode(&self) -> DraftMode {
            DraftMode::Proposal
        }
        fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
            IndexPolicy::pillar(m.draft_budget)
        }
        fn plan(&mut self, _ctx: &DraftCtx) -> DraftPlan {
            panic!("grenade drafter always detonates");
        }
    }

    let rt = runtime();
    let reqs = small_requests(&rt, 4, 40, 5);
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(reqs.clone()).unwrap();

    let mut reg = DrafterRegistry::with_builtins();
    reg.register("grenade", |_, _| Ok(Box::new(Grenade)));
    // silence the default panic-hook backtraces while the sandbox is
    // exercised on purpose (restored after the run)
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let run = Engine::with_registry(
        rt.clone(),
        EngineConfig::new(DrafterKind::Custom { name: "grenade" }).with_k(8),
        reg,
    )
    .unwrap()
    .run(reqs.clone());
    std::panic::set_hook(prev);
    let r = run.expect("panicking drafter must not take the engine down");

    assert_eq!(r.requests_done, reqs.len());
    assert_eq!(r.requests_failed, 0, "drafter panics must not fail sessions");
    assert!(r.slot_degradations > 0, "no slot ever demoted");
    assert_eq!(base.outputs, r.outputs, "degraded decoding diverged from vanilla");
}

/// Injected drafter faults (panic on the self-spec planner, malformed
/// proposal batches on a proposal drafter) at rate 1.0: slots demote,
/// serve their probation window in vanilla mode, re-promote, fault again
/// — and everything still completes vanilla-identically.
#[test]
fn injected_drafter_faults_demote_probation_repromotes() {
    let rt = runtime();
    let reqs = small_requests(&rt, 4, 56, 17);
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(reqs.clone()).unwrap();

    for (drafter, plan) in [
        (DrafterKind::Pillar { w: 64 }, "drafter_panic:1.0"),
        (DrafterKind::NGram { n: 3 }, "drafter_malformed:1.0"),
    ] {
        let cfg = EngineConfig::new(drafter).with_k(8).with_faults(faults(plan, 3));
        let mut eng = Engine::new(rt.clone(), cfg).unwrap();
        let r = eng.run(reqs.clone()).unwrap();
        assert_eq!(r.requests_done, reqs.len(), "{plan}");
        assert_eq!(r.requests_failed, 0, "{plan}: drafter faults must stay non-fatal");
        assert!(r.faults_injected > 0, "{plan}: nothing fired");
        assert!(r.slot_degradations > 0, "{plan}: no demotion");
        // 56-token sessions decode far past one 16-round probation window,
        // so at least one slot must have been re-promoted (and demoted
        // again by the always-on fault)
        assert!(r.slot_promotions > 0, "{plan}: probation never re-promoted");
        assert_eq!(base.outputs, r.outputs, "{plan}: outputs diverged from vanilla");
    }
}

/// Reload faults past the patience budget poison exactly the suspended
/// session: it finishes `Failed` with a readable `failure_reason`, its KV
/// is released, and every other session completes with outputs
/// bit-identical to the fault-free run (blast radius = one session).
#[test]
fn exhausted_reload_faults_fail_only_the_poisoned_session() {
    let rt = runtime();
    let m = &rt.cfg.model;
    let budget = m.slots * m.max_seq / 16;
    let cfg = |f: FaultConfig| {
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_kv(KvPolicy::Dynamic, budget)
            .with_faults(f)
    };
    let reqs = small_requests(&rt, 8, 56, 99);

    let mut clean = Engine::new(rt.clone(), cfg(FaultConfig::off())).unwrap();
    let rc = clean.run(reqs.clone()).unwrap();
    assert!(rc.kv.offload_events > 0, "no offload pressure — test is vacuous");

    let mut handle = EngineHandle::new(rt.clone(), cfg(faults("kv_reload:1.0", 11))).unwrap();
    let sessions: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
    handle.drive().expect("exhausted reloads fail sessions, not the engine");
    let r = handle.report();

    let failed: Vec<_> =
        sessions.iter().filter(|s| s.finish_reason() == Some(FinishReason::Failed)).collect();
    assert!(!failed.is_empty(), "rate-1.0 reload faults never failed a session");
    assert_eq!(r.requests_failed, failed.len());
    for s in &failed {
        let why = s.failure_reason().expect("failed session records a reason");
        assert!(
            why.contains(FaultSite::KvReload.label()),
            "unhelpful failure reason: {why}"
        );
        assert!(!r.outputs.contains_key(&s.id()), "failed session leaked outputs");
    }
    // blast radius: everyone else completed, bit-identical to fault-free
    for s in &sessions {
        if s.finish_reason() != Some(FinishReason::Failed) {
            assert_eq!(s.finish_reason(), Some(FinishReason::Completed));
            assert_eq!(
                &s.drain(),
                &rc.outputs[&s.id()],
                "fault on another session disturbed request {}",
                s.id()
            );
        }
    }
    assert!(failed.len() < sessions.len(), "every session failed — no survivors to pin");
    // the poisoned sessions released their device + host KV
    assert_eq!(handle.engine().kv_used_tokens(), 0);
}

/// The chaos schedule is deterministic: the same fault seed replays the
/// same faults, retries and outputs; a different seed draws a different
/// schedule; and an explicitly disabled injector is indistinguishable
/// from the default config.
#[test]
fn fault_schedule_is_deterministic_in_the_fault_seed() {
    let rt = runtime();
    let reqs = small_requests(&rt, 5, 40, 31);
    let plan = "runtime:0.05,drafter_panic:0.1";
    let run = |f: FaultConfig| {
        let cfg = EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8).with_faults(f);
        Engine::new(rt.clone(), cfg).unwrap().run(reqs.clone()).unwrap()
    };

    let a = run(faults(plan, 77));
    let b = run(faults(plan, 77));
    assert!(a.faults_injected > 0);
    assert_eq!(a.faults_injected, b.faults_injected, "fault count not reproducible");
    assert_eq!(a.fault_retries, b.fault_retries);
    assert_eq!(a.slot_degradations, b.slot_degradations);
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.outputs, b.outputs);

    // a different fault seed draws a different schedule (seed sensitivity
    // of the decision stream itself is unit-tested in `fault::tests`) but
    // greedy outputs must survive any schedule
    let c = run(faults(plan, 78));
    assert_eq!(a.outputs, c.outputs, "greedy outputs must survive any schedule");

    // disabled injector ≡ default config: bit-identical everything
    let off = run(FaultConfig::off());
    let default_cfg =
        Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8))
            .unwrap()
            .run(reqs.clone())
            .unwrap();
    assert_eq!(off.faults_injected, 0);
    assert_eq!(off.outputs, default_cfg.outputs);
    assert_eq!(off.iterations, default_cfg.iterations);
    assert_eq!(off.sim_s, default_cfg.sim_s);
}
