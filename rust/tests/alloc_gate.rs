//! The zero-allocation invariant as a plain test: with the counting
//! allocator installed, a steady-state serial step loop (draft + dense
//! verify + sparse verify, every buffer arena-resident) must request no
//! new memory at all.  This is the same gate `cargo bench --
//! engine_iteration` enforces; having it as a test means plain `cargo
//! test` catches an allocation regression without running the bench.
//!
//! This file is its own test binary with a single test, so no concurrent
//! test can pollute the allocation count.  Sim-backend only: the pjrt
//! runner allocates per device fetch by design.

#![cfg(not(feature = "pjrt"))]

#[global_allocator]
static ALLOC: sparsespec::util::alloc::CountingAlloc = sparsespec::util::alloc::CountingAlloc;

use std::rc::Rc;

use sparsespec::runtime::{ModelRunner, Runtime};
use sparsespec::util::alloc;

#[test]
fn serial_arena_step_loop_is_allocation_free() {
    let dir = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Rc::new(Runtime::load(&dir).expect("runtime loads"));
    let m = rt.cfg.model.clone();
    let (s, pad) = (m.slots, m.prompt_pad);
    let q = m.spec_k + 1;
    let w = m.draft_budget;
    let per_head = m.layers * m.kv_heads;

    let active = vec![1i32; s];
    let ptokens: Vec<i32> = (0..s * pad).map(|i| (i % 97) as i32 + 1).collect();
    let plen = vec![pad as i32; s];
    let dtok: Vec<i32> = (0..s).map(|x| (x as i32 % 31) + 2).collect();
    let pos = vec![pad as i32; s];
    let vtok: Vec<i32> = (0..s * q).map(|i| (i % 89) as i32 + 1).collect();
    let qv = vec![q as i32; s];
    let idx: Vec<i32> = (0..s * per_head * w).map(|i| ((i * 13) % pad) as i32).collect();

    let mut r = ModelRunner::new(rt.clone()).unwrap();
    r.set_parallel(false);
    r.prefill(&ptokens, &plen, &active).unwrap();
    // Warmup: first calls may intern stats keys / size lazy state.
    for _ in 0..4 {
        r.draft(w, &dtok, &pos, &idx, &active).unwrap();
        r.verify(q, &vtok, &pos, &qv, &active).unwrap();
        r.sparse_verify(&vtok, &pos, &qv, &idx, &active).unwrap();
    }

    let base = alloc::allocations();
    assert!(base.is_some(), "counting allocator must be installed in this binary");
    for _ in 0..32 {
        r.draft(w, &dtok, &pos, &idx, &active).unwrap();
        r.verify(q, &vtok, &pos, &qv, &active).unwrap();
        r.sparse_verify(&vtok, &pos, &qv, &idx, &active).unwrap();
    }
    let n = alloc::allocations_since(base).expect("counter stays installed");
    assert_eq!(n, 0, "steady-state serial step loop allocated {n} time(s), expected 0");
}
