"""Layer-1 kernels: Pallas implementations + pure-jnp oracles.

`impl="ref"` (pure jnp) is what the CPU-serving artifacts lower through;
`impl="pallas"` (interpret mode) is the TPU-shaped implementation whose
numerics are pinned to the ref by pytest and which `aot.py` also lowers
into a compose-proof artifact (see DESIGN.md §2).
"""

from . import ref
from .pillar_attn import sparse_attn
from .full_attn import full_attn
from .fused_attn import fused_attn


def sparse(q, k_cache, v_cache, idx, pos, impl="ref"):
    if impl == "pallas":
        return sparse_attn(q, k_cache, v_cache, idx, pos)
    return ref.sparse_attn_ref(q, k_cache, v_cache, idx, pos)


def full(q, k_cache, v_cache, pos, q_valid, impl="ref"):
    """Returns (out, dump, lse)."""
    if impl == "pallas":
        return full_attn(q, k_cache, v_cache, pos, q_valid)
    return ref.full_attn_ref(q, k_cache, v_cache, pos, q_valid)


def fused(q, k_cache, v_cache, idx, pos, q_valid, kind, impl="ref"):
    if impl == "pallas":
        return fused_attn(q, k_cache, v_cache, idx, pos, q_valid, kind)
    return ref.fused_attn_ref(q, k_cache, v_cache, idx, pos, q_valid, kind)
