//! Request-trace record/replay: serialise a workload to JSON so a run can
//! be reproduced exactly across machines (and so failing benchmark
//! configurations can be shared as artefacts).

use super::Request;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::{anyhow, Result};

/// Serialise a request trace.
pub fn to_json(reqs: &[Request]) -> String {
    arr(reqs.iter().map(|r| {
        obj(vec![
            ("id", num(r.id as f64)),
            ("prompt", arr(r.prompt.iter().map(|&t| num(t as f64)))),
            ("max_new", num(r.max_new as f64)),
            ("arrival_s", num(r.arrival_s)),
            ("seed", s(&r.seed.to_string())), // u64-safe as string
        ])
    }))
    .to_string()
}

/// Parse a request trace back.
pub fn from_json(text: &str) -> Result<Vec<Request>> {
    let j = Json::parse(text).map_err(|e| anyhow!("trace parse: {e}"))?;
    let items = j.as_arr().ok_or_else(|| anyhow!("trace must be an array"))?;
    items
        .iter()
        .map(|it| {
            let id = it
                .get("id")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("missing id"))? as u64;
            let prompt = it
                .get("prompt")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing prompt"))?
                .iter()
                .filter_map(|t| t.as_i64().map(|x| x as i32))
                .collect();
            let max_new = it
                .get("max_new")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("missing max_new"))?;
            let arrival_s = it.get("arrival_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let seed = it
                .get("seed")
                .and_then(|v| v.as_str())
                .and_then(|x| x.parse().ok())
                .unwrap_or(0);
            Ok(Request { id, prompt, max_new, arrival_s, seed })
        })
        .collect()
}

pub fn save(path: &str, reqs: &[Request]) -> Result<()> {
    std::fs::write(path, to_json(reqs))?;
    Ok(())
}

pub fn load(path: &str) -> Result<Vec<Request>> {
    from_json(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Request> {
        vec![
            Request {
                id: 3,
                prompt: vec![1, 3, 55, 108, 6],
                max_new: 120,
                arrival_s: 0.5,
                seed: u64::MAX - 7,
            },
            Request {
                id: 4,
                prompt: vec![1],
                max_new: 8,
                arrival_s: 1.25,
                seed: 42,
            },
        ]
    }

    #[test]
    fn roundtrip_exact() {
        let reqs = sample();
        let text = to_json(&reqs);
        let back = from_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in reqs.iter().zip(back.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new, b.max_new);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.seed, b.seed); // u64::MAX survives (string-coded)
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_json("{}").is_err());
        assert!(from_json(r#"[{"id": 1}]"#).is_err());
        assert!(from_json("not json").is_err());
    }
}
