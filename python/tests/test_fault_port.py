"""Python twin of `rust/src/fault/mod.rs` (PR 7 robustness).

The Rust crate cannot run in every environment this repo is developed in,
so — like ``test_trace_port.py`` for the tracer — this twin re-implements
the fault injector's deterministic decision function bit-for-bit in
Python and pins, by parsing the Rust source directly:

* the splitmix64 finaliser (``mix64``) against known-good golden values,
* the per-site hash salts (ASCII tags) and spec-string labels,
* the transient-vs-fatal classification table of ``EngineError``,
* the retry / degradation policy constants,
* the schedule itself: per-site decision streams are a pure function of
  ``(seed, site, check_index)`` — independent across sites, exact at the
  rate endpoints, and empirically calibrated mid-range.

If any of these drift in the Rust source without a matching edit here,
a test below fails with a diff pointing at the divergence.
"""

from __future__ import annotations

import math
import re
from pathlib import Path

M64 = (1 << 64) - 1
GAMMA = 0x9E3779B97F4A7C15
MIX_MUL_1 = 0xBF58476D1CE4E5B9
MIX_MUL_2 = 0x94D049BB133111EB

REPO = Path(__file__).resolve().parents[2]
FAULT_RS = REPO / "rust" / "src" / "fault" / "mod.rs"

# ---------------------------------------------------------------------------
# Pinned tables — must mirror rust/src/fault/mod.rs exactly.
# ---------------------------------------------------------------------------

# FaultSite variant -> (spec/metrics label, per-site hash salt).
# The salts are ASCII tags so a hexdump of the hash input is self-describing.
SITES = {
    "RuntimeStep": ("runtime", 0x52554E54494D4531),  # b"RUNTIME1"
    "KvOffload": ("kv_offload", 0x4B564F46464C4431),  # b"KVOFFLD1"
    "KvReload": ("kv_reload", 0x4B5652454C4F4431),  # b"KVRELOD1"
    "VerifyStall": ("verify_stall", 0x565354414C4C3031),  # b"VSTALL01"
    "DrafterPanic": ("drafter_panic", 0x4450414E49433031),  # b"DPANIC01"
    "DrafterMalformed": ("drafter_malformed", 0x444D414C46524D31),  # b"DMALFRM1"
}

# EngineError variant -> ErrorClass. Transient errors are retried with
# bounded sim-clock backoff; fatal ones isolate the slot/session.
CLASSIFICATION = {
    "RuntimeStep": "Transient",
    "KvOffloadIo": "Transient",
    "KvReloadIo": "Transient",
    "VerifyStall": "Transient",
    "DrafterPanic": "Fatal",
    "MalformedProposal": "Fatal",
    "RetriesExhausted": "Fatal",
    "Internal": "Fatal",
}

# Retry / degradation policy knobs (engine defaults).
POLICY = {
    "MAX_STEP_RETRIES": 4,
    "STEP_BACKOFF_BASE_S": 5e-4,
    "RELOAD_FAULT_BUDGET": 8,
    "DEGRADE_FAULT_THRESHOLD": 2,
    "DEGRADE_ACCEPT_WINDOW": 8,
    "PROBATION_ROUNDS": 16,
}


# ---------------------------------------------------------------------------
# Bit-for-bit port of the injector's decision function.
# ---------------------------------------------------------------------------


def mix64(x: int) -> int:
    """splitmix64 finaliser — mirrors `fault::mix64` exactly."""
    x = (x + GAMMA) & M64
    x = ((x ^ (x >> 30)) * MIX_MUL_1) & M64
    x = ((x ^ (x >> 27)) * MIX_MUL_2) & M64
    return x ^ (x >> 31)


def threshold(rate: float) -> int:
    """`(rate * 2^64) as u128`: truncation toward zero, exact endpoints."""
    return int(rate * 2.0**64)


class FaultInjector:
    """Port of `fault::FaultInjector` for the sites/rates under test."""

    def __init__(self, rates: dict[str, float], seed: int) -> None:
        self.seed = seed & M64
        self.enabled = any(r != 0.0 for r in rates.values())
        self.thresholds = {site: threshold(rates.get(site, 0.0)) for site in SITES}
        self.checks = {site: 0 for site in SITES}
        self.fired = {site: 0 for site in SITES}

    def check(self, site: str) -> bool:
        if not self.enabled:
            return False
        n = self.checks[site]
        self.checks[site] += 1
        if self.thresholds[site] == 0:
            return False
        salt = SITES[site][1]
        h = mix64(self.seed ^ salt ^ ((n * GAMMA) & M64))
        hit = h < self.thresholds[site]
        if hit:
            self.fired[site] += 1
        return hit


def backoff_s(attempt: int) -> float:
    """Port of `fault::backoff_s`: doubling, capped exponent."""
    return POLICY["STEP_BACKOFF_BASE_S"] * float(1 << min(attempt, 16))


# ---------------------------------------------------------------------------
# Source pins: parse rust/src/fault/mod.rs and diff against the tables.
# ---------------------------------------------------------------------------


def rust_source() -> str:
    assert FAULT_RS.is_file(), f"missing Rust twin source: {FAULT_RS}"
    return FAULT_RS.read_text()


def test_mix64_matches_reference_splitmix64():
    # mix64(x) is exactly one step of splitmix64 seeded with state `x`.
    # Golden values from the reference implementation (Steele et al.,
    # "Fast Splittable Pseudorandom Number Generators", seed 0 stream).
    assert mix64(0) == 0xE220A8397B1DCDAF
    assert mix64(GAMMA) == 0x6E789E6AA1B965F4
    assert mix64((2 * GAMMA) & M64) == 0x06C45D188009454F
    # involution sanity: distinct inputs, distinct outputs, full 64-bit range
    outs = {mix64(i) for i in range(1024)}
    assert len(outs) == 1024
    assert all(0 <= o <= M64 for o in outs)


def test_mix64_constants_pinned_in_rust_source():
    src = rust_source()
    for c in (GAMMA, MIX_MUL_1, MIX_MUL_2):
        assert f"0x{c:X}" in src, f"mix64 constant 0x{c:X} missing from fault/mod.rs"


def test_site_labels_match_rust_source():
    src = rust_source()
    # label() arms: `FaultSite::RuntimeStep => "runtime",`
    arms = dict(re.findall(r'FaultSite::(\w+) => "([a-z_]+)",', src))
    expected = {site: label for site, (label, _) in SITES.items()}
    assert arms == expected


def test_site_salts_match_rust_source_and_are_ascii_tags():
    src = rust_source()
    # salt() arms: `FaultSite::RuntimeStep => 0x52554E54494D4531,`
    arms = {
        site: int(salt, 16)
        for site, salt in re.findall(r"FaultSite::(\w+) => (0x[0-9A-Fa-f]{16}),", src)
    }
    expected = {site: salt for site, (_, salt) in SITES.items()}
    assert arms == expected
    # each salt decodes to a printable 8-byte ASCII tag, and tags are unique
    tags = set()
    for site, salt in expected.items():
        tag = salt.to_bytes(8, "big").decode("ascii")
        assert tag.isprintable(), f"{site} salt is not an ASCII tag"
        tags.add(tag)
    assert len(tags) == len(SITES)


def test_error_classification_table_matches_rust_source():
    src = rust_source()
    # class() arms, one per line:
    # `EngineError::RuntimeStep { .. } => ErrorClass::Transient,`
    arms = dict(
        re.findall(r"EngineError::(\w+) \{ \.\. \} => ErrorClass::(\w+),", src)
    )
    assert arms == CLASSIFICATION
    # the taxonomy splits exactly 4 / 4 — drafter-side and exhausted/internal
    # faults are never retried
    fatal = [k for k, v in CLASSIFICATION.items() if v == "Fatal"]
    assert sorted(fatal) == [
        "DrafterPanic",
        "Internal",
        "MalformedProposal",
        "RetriesExhausted",
    ]


def test_policy_constants_match_rust_source():
    src = rust_source()
    consts = dict(
        re.findall(r"pub const ([A-Z_]+): (?:u32|f64) = ([0-9e.\-]+);", src)
    )
    assert set(consts) == set(POLICY), "policy constant set drifted"
    for name, want in POLICY.items():
        got = float(consts[name])
        assert math.isclose(got, want, rel_tol=0, abs_tol=0), (name, got, want)


def test_backoff_schedule():
    base = POLICY["STEP_BACKOFF_BASE_S"]
    assert backoff_s(0) == base
    assert backoff_s(1) == base * 2
    assert backoff_s(3) == base * 8
    # exponent is capped so the sim clock cannot overflow on a stuck fault
    assert backoff_s(16) == backoff_s(40) == base * (1 << 16)


# ---------------------------------------------------------------------------
# Schedule semantics (mirror the Rust unit tests so both sides agree on
# behaviour, not just on code shape).
# ---------------------------------------------------------------------------


def test_schedule_is_deterministic_and_sites_are_independent():
    rates = {"RuntimeStep": 0.3, "KvReload": 0.3}
    a = FaultInjector(rates, 42)
    b = FaultInjector(rates, 42)
    sa = [a.check("RuntimeStep") for _ in range(256)]
    sb = [b.check("RuntimeStep") for _ in range(256)]
    assert sa == sb
    # interleaving another site's checks must not shift the stream
    c = FaultInjector(rates, 42)
    sc = []
    for _ in range(256):
        c.check("KvReload")
        sc.append(c.check("RuntimeStep"))
    assert sa == sc
    # a different seed gives a different stream
    d = FaultInjector(rates, 43)
    sd = [d.check("RuntimeStep") for _ in range(256)]
    assert sa != sd
    assert any(sa), "rate 0.3 over 256 checks should fire at least once"


def test_golden_schedule_prefix_is_pinned():
    # The exact first-16 decisions for (seed=42, runtime:0.3) — any change
    # to the hash input layout, salt, or threshold math breaks this.  The
    # Rust injector replays this identical prefix for the same config.
    inj = FaultInjector({"RuntimeStep": 0.3}, 42)
    prefix = [inj.check("RuntimeStep") for _ in range(16)]
    golden = [
        mix64(42 ^ SITES["RuntimeStep"][1] ^ ((n * GAMMA) & M64)) < threshold(0.3)
        for n in range(16)
    ]
    assert prefix == golden
    assert inj.checks["RuntimeStep"] == 16
    assert inj.fired["RuntimeStep"] == sum(prefix)


def test_empirical_rate_is_calibrated():
    inj = FaultInjector({"RuntimeStep": 0.25}, 7)
    n = 20_000
    hits = sum(inj.check("RuntimeStep") for _ in range(n))
    rate = hits / n
    assert abs(rate - 0.25) < 0.02, f"empirical rate {rate}"
    assert inj.checks["RuntimeStep"] == n
    assert inj.fired["RuntimeStep"] == hits


def test_rate_endpoints_are_exact():
    inj = FaultInjector({"DrafterPanic": 1.0, "KvOffload": 0.0}, 11)
    for _ in range(1000):
        assert inj.check("DrafterPanic"), "rate 1.0 must always fire"
        assert not inj.check("KvOffload"), "rate 0.0 must never fire"
    # a fully-empty plan disables the injector: no counters advance
    off = FaultInjector({}, 99)
    assert not off.enabled
    for _ in range(100):
        assert not off.check("RuntimeStep")
    assert off.checks["RuntimeStep"] == 0


def test_threshold_conversion_truncates_like_rust_cast():
    assert threshold(0.0) == 0
    assert threshold(1.0) == 1 << 64
    assert threshold(0.5) == 1 << 63
    # truncation toward zero, as `as u128` does for positive floats
    assert threshold(0.25) == 1 << 62
    t = threshold(0.3)
    assert 0 < t < (1 << 64)
    assert t == int(0.3 * 2.0**64)
