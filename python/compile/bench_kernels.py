"""Kernel-level microbenchmark for Fig. 15 (build/bench-time only).

Measures the three batching strategies over a mixed draft/verify batch at
the L1 kernel level:

  sequential  — two pallas_calls: sparse(W) for draft rows, dense(T) for
                verify rows;
  naive_batch — one pallas_call where every row pays the dense template
                (the fused kernel with idx = full range for all rows);
  fused       — one pallas_call with per-row dispatch (our fused kernel).

Interpret-mode wallclock is a *numerics-path* measurement, not a TPU time
proxy (XLA traces both branches of the fused kernel); the TPU-shape
comparison lives in rust/src/bench/kernels.rs on top of the DeviceModel.
Results land in artifacts/kernel_bench.json for the Rust bench to report.

Run: cd python && python -m compile.bench_kernels
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_attn, full_attn, sparse_attn


def timeit(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), (tuple, list)) else None
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        leaf = out[0] if isinstance(out, (tuple, list)) else out
        leaf.block_until_ready()
    return (time.time() - t0) / reps


def main(out_path="../artifacts/kernel_bench.json"):
    rng = np.random.default_rng(0)
    S, Q, Hq, Hkv, D, T, W = 12, 9, 4, 2, 32, 512, 64
    k = 8
    q = jnp.asarray(rng.normal(size=(S, Q, Hq, D)).astype(np.float32))
    kc = jnp.asarray(rng.normal(size=(S, T, Hkv, D)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(S, T, Hkv, D)).astype(np.float32))
    pos = jnp.asarray(rng.integers(64, 300, size=(S,)).astype(np.int32))
    idx = jnp.asarray(rng.integers(0, 64, size=(S, Hkv, W)).astype(np.int32))
    idx_full = jnp.asarray(
        np.broadcast_to(np.arange(T, dtype=np.int32), (S, Hkv, T)).copy()
    )
    qv = jnp.asarray(np.full((S,), Q, np.int32))
    # 1/(k+1) of rows verify, rest draft
    kind = jnp.asarray((np.arange(S) % (k + 1) == 0).astype(np.int32))

    results = {}
    # sequential: sparse for draft rows + dense for verify rows
    t_sparse = timeit(lambda: sparse_attn(q[:, :1], kc, vc, idx, pos))
    t_dense = timeit(lambda: full_attn(q, kc, vc, pos, qv))
    results["sequential_s"] = t_sparse + t_dense
    results["sparse_call_s"] = t_sparse
    results["dense_call_s"] = t_dense
    # naive batch: everything through the fused kernel at dense width
    results["naive_batch_s"] = timeit(
        lambda: fused_attn(q, kc, vc, idx_full, pos, qv, jnp.ones_like(kind))
    )
    # fused: per-row dispatch
    results["fused_s"] = timeit(lambda: fused_attn(q, kc, vc, idx, pos, qv, kind))

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    for key, v in results.items():
        print(f"{key:>16}: {v*1e3:8.2f} ms")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
