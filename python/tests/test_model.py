# L2 model tests: step-function consistency against the dense training
# forward, KV bookkeeping, parameter manifest.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import MODEL as cfg

jax.config.update("jax_platform_name", "cpu")

S, T, L, Hkv, D, P, V = (
    cfg.slots,
    cfg.max_seq,
    cfg.layers,
    cfg.kv_heads,
    cfg.head_dim,
    cfg.prompt_pad,
    cfg.vocab,
)


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    seq = rng.integers(3, V, size=(S, 48)).astype(np.int32)
    return params, seq


def zero_kv():
    return jnp.zeros((L, S, T, Hkv, D)), jnp.zeros((L, S, T, Hkv, D))


def test_param_manifest_size():
    assert model.n_params(cfg) == 656512
    p = model.init_params(jax.random.PRNGKey(1))
    assert p.shape == (model.n_params(cfg),)
    pt = model.unpack(p, cfg)
    assert pt["embed"].shape == (V, cfg.hidden)
    assert pt["l0.wq"].shape == (cfg.hidden, cfg.q_dim)


def test_prefill_matches_dense_forward(setup):
    params, seq = setup
    kvk, kvv = zero_kv()
    plen = np.full((S,), 12, np.int32)
    active = np.ones((S,), np.int32)
    toks = np.zeros((S, P), np.int32)
    toks[:, :12] = seq[:, :12]
    prefill = jax.jit(model.make_prefill(cfg))
    lg, _, _ = prefill(params, kvk, kvv, jnp.asarray(toks), jnp.asarray(plen), jnp.asarray(active))
    dense = jax.jit(model.make_train_forward(cfg))(params, jnp.asarray(seq[:, :12]))
    np.testing.assert_allclose(lg, dense[:, 11], rtol=1e-4, atol=1e-4)


def test_verify_matches_dense_forward(setup):
    params, seq = setup
    kvk, kvv = zero_kv()
    plen = np.full((S,), 12, np.int32)
    active = np.ones((S,), np.int32)
    toks = np.zeros((S, P), np.int32)
    toks[:, :12] = seq[:, :12]
    prefill = jax.jit(model.make_prefill(cfg))
    _, kvk, kvv = prefill(params, kvk, kvv, jnp.asarray(toks), jnp.asarray(plen), jnp.asarray(active))
    Q = cfg.spec_k + 1
    verify = jax.jit(model.make_verify(cfg))
    vt = seq[:, 12 : 12 + Q]
    lg, _, _, dump = verify(
        params, kvk, kvv, jnp.asarray(vt), jnp.asarray(plen),
        jnp.asarray(np.full((S,), Q, np.int32)), jnp.asarray(active),
    )
    dense = jax.jit(model.make_train_forward(cfg))(params, jnp.asarray(seq[:, : 12 + Q]))
    np.testing.assert_allclose(lg, dense[:, 12 : 12 + Q], rtol=1e-4, atol=1e-4)
    # dump rows are probability distributions over attended positions
    sums = np.asarray(dump).sum(-1)
    np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-4)


def test_draft_with_complete_index_matches_dense(setup):
    params, seq = setup
    kvk, kvv = zero_kv()
    plen = np.full((S,), 12, np.int32)
    active = np.ones((S,), np.int32)
    toks = np.zeros((S, P), np.int32)
    toks[:, :12] = seq[:, :12]
    prefill = jax.jit(model.make_prefill(cfg))
    _, kvk, kvv = prefill(params, kvk, kvv, jnp.asarray(toks), jnp.asarray(plen), jnp.asarray(active))
    W = cfg.draft_budget
    idx = np.full((S, L, Hkv, W), -1, np.int32)
    idx[:, :, :, :13] = np.arange(13)
    draft = jax.jit(model.make_draft(cfg))
    lg, _, _ = draft(
        params, kvk, kvv, jnp.asarray(seq[:, 12]), jnp.asarray(plen),
        jnp.asarray(idx), jnp.asarray(active),
    )
    dense = jax.jit(model.make_train_forward(cfg))(params, jnp.asarray(seq[:, :13]))
    np.testing.assert_allclose(lg, dense[:, 12], rtol=1e-4, atol=1e-4)


def test_inactive_slots_untouched(setup):
    params, seq = setup
    kvk, kvv = zero_kv()
    plen = np.full((S,), 8, np.int32)
    active = np.zeros((S,), np.int32)
    active[0] = 1
    toks = np.zeros((S, P), np.int32)
    toks[:, :8] = seq[:, :8]
    prefill = jax.jit(model.make_prefill(cfg))
    _, kvk2, kvv2 = prefill(
        params, kvk, kvv, jnp.asarray(toks), jnp.asarray(plen), jnp.asarray(active)
    )
    # slot 0 written, slots 1.. remain zero
    assert float(jnp.abs(kvk2[:, 0, :8]).sum()) > 0
    assert float(jnp.abs(kvk2[:, 1:]).sum()) == 0.0
    assert float(jnp.abs(kvv2[:, 1:]).sum()) == 0.0


def test_kv_load_scatters_one_slot():
    kvk, kvv = zero_kv()
    rows_k = jnp.ones((L, T, Hkv, D))
    rows_v = jnp.full((L, T, Hkv, D), 2.0)
    kv_load = jax.jit(model.make_kv_load(cfg))
    kvk2, kvv2 = kv_load(kvk, kvv, jnp.asarray(np.array([3], np.int32)), rows_k, rows_v)
    assert float(kvk2[:, 3].min()) == 1.0
    assert float(kvv2[:, 3].max()) == 2.0
    assert float(jnp.abs(kvk2[:, [0, 1, 2] + list(range(4, S))]).sum()) == 0.0


def test_eagle_head_shapes():
    ep = model.eagle_init(jax.random.PRNGKey(3))
    assert ep.shape == (model.eagle_n_params(),)
    eagle = jax.jit(model.make_eagle(cfg))
    ctx = jnp.asarray(np.zeros((S, 4), np.int32))
    lg = eagle(ep, ctx)
    assert lg.shape == (S, V)


def test_rope_position_dependence():
    """Same token at different positions must produce different keys."""
    x = jnp.ones((1, 2, 2, D))
    r1 = model.rope(x, jnp.asarray(np.array([[1, 2]], np.int32)))
    r2 = model.rope(x, jnp.asarray(np.array([[3, 4]], np.int32)))
    assert float(jnp.abs(r1 - r2).max()) > 1e-3
    # position 0 is identity
    r0 = model.rope(x[:, :1], jnp.asarray(np.array([[0]], np.int32)))
    np.testing.assert_allclose(r0, x[:, :1], rtol=1e-6)
