# Python twin of the raw-speed arena pass (rust/src/runtime/arena.rs +
# the restructured kernels in rust/src/runtime/sim.rs).
#
# The Rust side keeps the seed-era kernels verbatim in
# `runtime::sim::reference` and bit-identity-tests the optimised kernels
# against them; this twin re-pins the three *restructurings* the arena
# pass made, independently of the Rust toolchain:
#
#   1. verify dump: filled once into the representative (layer 0, head 0)
#      row then replicated across the remaining L*Hkv-1 rows == the
#      seed-era per-row recompute, including the end=(base+qv).min(T)
#      truncation and the zeroed tail;
#   2. sparse visibility: the per-slot bitmask (build_vis + O(1) tests)
#      == the seed-era O(CTX*W) linear scan of the index row, including
#      -1 sentinel stop and out-of-range index handling, and the sparse
#      context hash built on either membership test folds identically;
#   3. arena view layouts: buffer capacities sized once from ModelConfig
#      cover every step shape (no step can ever resize), and the valid-
#      prefix view lengths per step type are what the engine reads.
#
# Constants and fold order MUST stay in lockstep with runtime/sim.rs
# (shared with test_sim_runtime_port.py).

M64 = (1 << 64) - 1
GOLDEN = 0x9E37_79B9_7F4A_7C15
SEED0 = 0xC0FF_EE00_5EED_1234
CTX = 8
LONG_MIN = 24
LONG_BAND = 5

# The synthetic ModelConfig (model/mod.rs SystemConfig::synthetic).
VOCAB = 512
LAYERS = 4
KV_HEADS = 2
MAX_SEQ = 512
SLOTS = 12
PROMPT_PAD = 32
SPEC_K = 8
DRAFT_BUDGET = 64
VERIFY_Q_VARIANTS = [1, 5, 9, 13, 17, 21]
DRAFT_W_VARIANTS = [16, 32, 64, 128, 256]


def mix64(seed):
    z = (seed + GOLDEN) & M64
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & M64
    return z ^ (z >> 31)


def dump_mass(t, end):
    mass = 1.0 / (1.0 + (end - 1 - t))
    if t < 4:
        mass += 3.0
    if abs(t - end // 2) <= LONG_BAND:
        mass += 2.0
    return mass


# --- 1. dump representative-row equality --------------------------------

def dump_reference(base, qv, t_dim):
    """Seed-era verify dump for one slot: every (layer, head) row
    recomputed (runtime::sim::reference::Runner::verify)."""
    end = min(base + qv, t_dim)
    rows = []
    for _lh in range(LAYERS * KV_HEADS):
        rows.append([dump_mass(t, end) if t < end else 0.0 for t in range(t_dim)])
    return rows


def dump_arena(base, qv, t_dim):
    """Arena verify dump: row (0, 0) computed once, then replicated
    (copy_from_slice) across the remaining L*Hkv-1 rows."""
    end = min(base + qv, t_dim)
    row0 = [dump_mass(t, end) if t < end else 0.0 for t in range(t_dim)]
    return [row0] + [list(row0) for _ in range(LAYERS * KV_HEADS - 1)]


def test_dump_replication_equals_per_row_recompute():
    for base, qv in [(0, 1), (7, 9), (100, 9), (MAX_SEQ - 4, 9), (MAX_SEQ - 1, 21)]:
        ref = dump_reference(base, qv, MAX_SEQ)
        got = dump_arena(base, qv, MAX_SEQ)
        assert got == ref, f"dump diverged at base={base} qv={qv}"


def test_dump_end_truncation_and_zero_tail():
    # Past-the-end positions stay zero; end clamps at T.
    rows = dump_arena(MAX_SEQ - 3, 9, MAX_SEQ)
    end = MAX_SEQ  # clamped
    for row in rows:
        assert all(x > 0.0 for x in row[:end])
    rows = dump_arena(10, 5, MAX_SEQ)
    for row in rows:
        assert all(x == 0.0 for x in row[15:])
        assert all(x > 0.0 for x in row[:15])


# --- 2. visibility bitmask == linear scan -------------------------------

def visible_linear(idx_row, t):
    """Seed-era membership: scan the ascending valid prefix (stop at the
    first -1 sentinel)."""
    for x in idx_row:
        if x < 0:
            return False
        if x == t:
            return True
    return False


def build_vis(idx_row, t_dim):
    """Arena path: one bitmask per slot; out-of-range indices ignored
    (the linear scan never matched them against any t < max_seq)."""
    words = [0] * ((t_dim + 63) // 64)
    cap = len(words) * 64
    for x in idx_row:
        if x < 0:
            break
        if x < cap:
            words[x >> 6] |= 1 << (x & 63)
    return words


def vis_test(words, t):
    return (words[t >> 6] >> (t & 63)) & 1 == 1


def idx_rows_for_test():
    rows = [
        [],                                   # empty -> nothing visible
        [-1, 5, 9],                           # sentinel first -> nothing
        [0, 1, 2, 3, -1, 7, 8],               # valid prefix then junk
        list(range(0, DRAFT_BUDGET)),         # full ascending row
        [0, 3, 64, 65, 127, 128, 450, 511],   # word-boundary positions
        [2, 511, MAX_SEQ + 10, -1],           # out-of-range index ignored
        [t * 7 % MAX_SEQ for t in range(DRAFT_BUDGET)],  # unsorted junk order
    ]
    # Deterministic pseudo-random rows (mix64-driven, like the Rust tests).
    for seed in range(4):
        h, row = seed, []
        for _ in range(DRAFT_BUDGET):
            h = mix64(h)
            row.append(h % (MAX_SEQ + 8))  # some intentionally OOB
        row.sort()
        cut = mix64(seed + 99) % DRAFT_BUDGET
        rows.append(row[:cut] + [-1] * (DRAFT_BUDGET - cut))
    return rows


def test_bitmask_equals_linear_scan_everywhere():
    for idx_row in idx_rows_for_test():
        words = build_vis(idx_row, MAX_SEQ)
        for t in range(MAX_SEQ):
            assert vis_test(words, t) == visible_linear(idx_row, t), (
                f"visibility diverged at t={t} for row {idx_row[:12]}..."
            )


def sparse_hash(kv, p, member):
    """sparse_ctx_hash fold, parameterised over the membership test —
    identical folds on either membership implementation is the invariant
    sparse_ctx_hash_vis relies on."""
    h = SEED0
    if p >= LONG_MIN:
        lp = p // 2
        if member(lp):
            h = mix64(h ^ (kv[lp] + 1))
    for t in range(max(p + 1 - CTX, 0), p + 1):
        if member(t):
            h = mix64(h ^ (kv[t] + 1))
    return h


def test_sparse_hash_identical_on_either_membership():
    kv = [(mix64(1000 + i) % (VOCAB - 1)) + 1 for i in range(MAX_SEQ)]
    for idx_row in idx_rows_for_test():
        words = build_vis(idx_row, MAX_SEQ)
        for p in [0, 5, CTX, LONG_MIN - 1, LONG_MIN, 100, 255, MAX_SEQ - 1]:
            a = sparse_hash(kv, p, lambda t: visible_linear(idx_row, t))
            b = sparse_hash(kv, p, lambda t: vis_test(words, t))
            assert a == b, f"hash diverged at p={p} for row {idx_row[:12]}..."


# --- 3. arena view layouts ----------------------------------------------

def arena_capacities():
    """StepArena::new sizing (arena.rs): worst case over every step."""
    q_max = max(VERIFY_Q_VARIANTS + [SPEC_K + 1, 1])
    return {
        "logits": SLOTS * q_max * VOCAB,
        "dump": SLOTS * LAYERS * KV_HEADS * MAX_SEQ,
        "vis_words": SLOTS * ((MAX_SEQ + 63) // 64),
    }


def view_lens(step, q=None):
    """Valid-prefix lengths (logits_len / dump_len) each step publishes."""
    if step in ("prefill", "draft", "eagle"):
        return SLOTS * VOCAB, None  # dump untouched
    if step == "verify":
        return SLOTS * q * VOCAB, SLOTS * LAYERS * KV_HEADS * MAX_SEQ
    if step == "sparse_verify":
        return SLOTS * (SPEC_K + 1) * VOCAB, None
    raise AssertionError(step)


def test_every_step_shape_fits_the_arena():
    caps = arena_capacities()
    shapes = [view_lens("prefill"), view_lens("draft"), view_lens("eagle"),
              view_lens("sparse_verify")]
    shapes += [view_lens("verify", q=q) for q in VERIFY_Q_VARIANTS]
    for logits_len, dump_len in shapes:
        assert logits_len <= caps["logits"], "a step would have to resize logits"
        if dump_len is not None:
            assert dump_len <= caps["dump"], "a step would have to resize the dump"
    # The worst logits shape is exactly the capacity (nothing wasted).
    assert max(l for l, _ in shapes) == caps["logits"]
    # Dense verify writes the full dump (valid prefix == capacity).
    assert view_lens("verify", q=SPEC_K + 1)[1] == caps["dump"]


def test_engine_row_offsets_match_views():
    # The engine reads slot i's rows at fixed strides of the views; check
    # the strides tile the valid prefix exactly.
    q = SPEC_K + 1
    logits_len, dump_len = view_lens("verify", q=q)
    per_logits = q * VOCAB
    per_dump = LAYERS * KV_HEADS * MAX_SEQ
    assert per_logits * SLOTS == logits_len
    assert per_dump * SLOTS == dump_len
    logits_len, _ = view_lens("draft")
    assert VOCAB * SLOTS == logits_len


def test_artifact_names_cover_variants():
    # ArtifactNames::new pre-renders one name per compiled variant; the
    # engine's hot path does pure lookups.  Pin the rendering.
    drafts = {w: f"draft_w{w}" for w in DRAFT_W_VARIANTS}
    verifies = {q: f"verify_q{q}" for q in VERIFY_Q_VARIANTS}
    assert drafts[64] == "draft_w64"
    assert verifies[SPEC_K + 1] == "verify_q9"
    assert 63 not in drafts and SPEC_K not in verifies  # misses stay misses
