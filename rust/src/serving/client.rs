//! `sparsespec-client`: open-loop load generator for the serving
//! front-end.
//!
//! Replays `workload` traffic over the wire protocol — one connection per
//! tenant, submissions paced by each request's `arrival_s` (compressed by
//! [`ClientConfig::time_scale`]), tokens consumed as they stream — and
//! measures everything from the *client* side: TTFT from the moment the
//! `Submit` frame hits the socket, inter-token gaps between `Token`
//! frames, goodput over completed sessions, and typed refusal counts per
//! [`ErrorCode`].  Client-side numbers are the ones a user would see;
//! they include wire, queueing and admission delay the in-process
//! `SessionStats` cannot.
//!
//! The generator is open-loop: arrival times come from the workload
//! trace, not from response latency, so an overloaded server shows up as
//! latency/refusals instead of silently throttled offered load.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{latency_block, MetricsRegistry};
use crate::workload::Request;

use super::wire::{self, Frame};

/// One tenant's share of the offered load.
#[derive(Clone, Debug)]
pub struct TenantLoad {
    pub name: String,
    /// Pre-generated requests; `arrival_s` paces submission.
    pub requests: Vec<Request>,
    /// Wire drafter name for every request of this tenant ("" = engine
    /// default; per-request `Request::drafter` overrides are not carried
    /// over the wire — name them here instead).
    pub drafter: String,
}

#[derive(Clone, Debug)]
pub struct ClientConfig {
    pub addr: String,
    pub tenants: Vec<TenantLoad>,
    /// Grant token credit back to the server every N consumed tokens.
    pub credit_every: u32,
    /// Divide workload `arrival_s` by this to get wall seconds (50 ⇒ one
    /// trace second replays in 20 ms).
    pub time_scale: f64,
    /// Hard wall-clock deadline; sessions still unterminated at the
    /// deadline count as failed.
    pub timeout_s: f64,
    /// Send a graceful `Shutdown` after all sessions terminate and wait
    /// for the server to drain.
    pub shutdown_after: bool,
}

impl ClientConfig {
    pub fn new(addr: &str) -> Self {
        ClientConfig {
            addr: addr.to_string(),
            tenants: Vec::new(),
            credit_every: 32,
            time_scale: 50.0,
            timeout_s: 60.0,
            shutdown_after: false,
        }
    }
}

/// Per-request terminal detail (accepted sessions and refusals alike).
#[derive(Clone, Debug)]
pub struct SessionDetail {
    /// Replica id echoed in `Accepted` when the server (or router) runs
    /// with one configured; `None` against a plain single server.
    pub replica: Option<u16>,
    /// "completed" / "cancelled" / "rejected" / "failed" /
    /// "refused:<code>" / "none" (never reached a terminal state).
    pub outcome: String,
}

/// Client-side run results.
pub struct ClientReport {
    /// `ttft_s` / `inter_token_s` histograms and session counters, both
    /// aggregate and `{tenant="…"}`-labelled.
    pub metrics: MetricsRegistry,
    /// Streamed output per request: `(tenant, client req id)` → tokens.
    pub outputs: BTreeMap<(String, u64), Vec<i32>>,
    /// Terminal detail per request: `(tenant, client req id)`.
    pub sessions: BTreeMap<(String, u64), SessionDetail>,
    pub completed: u64,
    pub cancelled: u64,
    /// Typed pre-admission refusals by [`super::wire::ErrorCode`] label.
    pub refused: BTreeMap<String, u64>,
    /// Engine-faulted plus deadline-expired sessions.
    pub failed: u64,
    pub wall_s: f64,
}

impl ClientReport {
    pub fn refused_total(&self) -> u64 {
        self.refused.values().sum()
    }

    /// Tokens from completed sessions per wall second.
    pub fn goodput_tok_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.metrics.get("tokens_completed") / self.wall_s
    }

    /// Human summary (the client binary's output); latency lines come
    /// from the shared [`latency_block`] helper the examples use.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "client: ok={} cancelled={} refused={} failed={} in {:.2}s  goodput={:.1} tok/s",
            self.completed,
            self.cancelled,
            self.refused_total(),
            self.failed,
            self.wall_s,
            self.goodput_tok_s(),
        );
        out.push_str(&latency_block(&self.metrics, &[]));
        let tenants: Vec<String> = self
            .outputs
            .keys()
            .map(|(t, _)| t.clone())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        if tenants.len() > 1 {
            for t in tenants {
                let by: &[(&str, &str)] = &[("tenant", &t)];
                let _ = writeln!(
                    out,
                    "  tenant {t}: ok={} tokens={}",
                    self.metrics.counter("sessions_completed", by),
                    self.metrics.counter("tokens_completed", by),
                );
                out.push_str(&latency_block(&self.metrics, by));
            }
        }
        for (code, n) in &self.refused {
            let _ = writeln!(out, "  refused[{code}] = {n}");
        }
        // Per-replica attribution — only when the server actually echoed
        // replica ids in `Accepted` (a plain single server does not; the
        // remainder prints under "n/a").
        let mut by_replica: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        for (key, d) in &self.sessions {
            if d.outcome != "completed" {
                continue;
            }
            let label = d.replica.map(|r| r.to_string()).unwrap_or_else(|| "n/a".to_string());
            let e = by_replica.entry(label).or_insert((0, 0));
            e.0 += 1;
            e.1 += self.outputs.get(key).map(|t| t.len() as u64).unwrap_or(0);
        }
        if by_replica.keys().any(|k| k != "n/a") {
            for (replica, (ok, tokens)) in &by_replica {
                let _ = writeln!(out, "  replica {replica}: ok={ok} tokens={tokens}");
            }
        }
        out
    }
}

/// Per-session receive state, filled in by the reader thread.
struct SessRecv {
    req_id: u64,
    replica: Option<u16>,
    tokens: Vec<i32>,
    submitted: Instant,
    first: Option<Instant>,
    last: Option<Instant>,
    finished: Option<u8>,
}

#[derive(Default)]
struct Shared {
    /// Submit wall time by client req id (written just before the frame).
    submitted: BTreeMap<u64, Instant>,
    /// Accepted sessions by server session id.
    by_session: BTreeMap<u64, SessRecv>,
    req_to_session: BTreeMap<u64, u64>,
    /// Pre-admission refusals: req id → error-code label.
    refusals: BTreeMap<u64, String>,
    /// Post-admission error details (slow reader, engine fault).
    session_errors: BTreeMap<u64, String>,
    /// Requests that reached a terminal state (refused or finished).
    terminal: usize,
    hello_window: Option<u32>,
    /// The opening `Hello` failed [`wire::expect_hello`] (wrong protocol
    /// version): the whole run is invalid, not just one request.
    hello_error: Option<String>,
    reader_dead: bool,
}

fn send(stream: &Mutex<TcpStream>, f: &Frame) -> Result<()> {
    let mut s = stream.lock().expect("client write lock");
    wire::write_frame(&mut *s, f).map_err(|e| anyhow!("client write: {e}"))
}

fn reader_loop(stream: TcpStream, write: Arc<Mutex<TcpStream>>, shared: Arc<Mutex<Shared>>, credit_every: u32) {
    let mut r = BufReader::new(stream);
    let mut consumed = 0u32;
    loop {
        let frame = match wire::read_frame(&mut r) {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => break,
        };
        let mut sh = shared.lock().expect("client shared lock");
        match frame {
            f @ Frame::Hello { .. } => match wire::expect_hello(&f) {
                Ok(window) => sh.hello_window = Some(window),
                Err(e) => {
                    // hard handshake failure: refuse to speak further
                    sh.hello_error = Some(e.to_string());
                    sh.reader_dead = true;
                    break;
                }
            },
            Frame::Accepted { req_id, session, replica } => {
                let submitted = sh.submitted.get(&req_id).copied().unwrap_or_else(Instant::now);
                sh.req_to_session.insert(req_id, session);
                sh.by_session.insert(
                    session,
                    SessRecv {
                        req_id,
                        replica,
                        tokens: Vec::new(),
                        submitted,
                        first: None,
                        last: None,
                        finished: None,
                    },
                );
            }
            Frame::Token { session, token, .. } => {
                if let Some(s) = sh.by_session.get_mut(&session) {
                    let now = Instant::now();
                    if s.first.is_none() {
                        s.first = Some(now);
                    }
                    s.last = Some(now);
                    s.tokens.push(token);
                }
                consumed += 1;
                if consumed >= credit_every {
                    drop(sh);
                    let _ = send(&write, &Frame::Credit { n: consumed });
                    consumed = 0;
                    continue;
                }
            }
            Frame::Finished { session, reason, .. } => {
                if let Some(s) = sh.by_session.get_mut(&session) {
                    if s.finished.is_none() {
                        s.finished = Some(reason);
                        sh.terminal += 1;
                    }
                }
            }
            Frame::Error { req_id, code, detail } => {
                // An Error for an accepted request annotates the session
                // (its Finished frame is the terminal event); an Error for
                // an unaccepted request IS the terminal event (refusal).
                if let Some(&session) = sh.req_to_session.get(&req_id) {
                    sh.session_errors.insert(session, detail);
                } else if req_id != 0 && !sh.refusals.contains_key(&req_id) {
                    sh.refusals.insert(req_id, code.label().to_string());
                    sh.terminal += 1;
                }
            }
            Frame::Pong { .. } => {}
            // server never sends client→server kinds; ignore defensively
            _ => {}
        }
    }
    shared.lock().expect("client shared lock").reader_dead = true;
}

struct TenantOutcome {
    name: String,
    shared: Arc<Mutex<Shared>>,
    sent: usize,
}

fn tenant_worker(
    addr: String,
    tenant: TenantLoad,
    credit_every: u32,
    time_scale: f64,
    deadline: Instant,
    start: Instant,
) -> Result<TenantOutcome> {
    let stream = TcpStream::connect(&addr)?;
    let _ = stream.set_nodelay(true);
    let write = Arc::new(Mutex::new(stream.try_clone()?));
    let shared = Arc::new(Mutex::new(Shared::default()));
    let r_shared = shared.clone();
    let r_write = write.clone();
    let reader = std::thread::spawn(move || reader_loop(stream, r_write, r_shared, credit_every));

    let scale = if time_scale > 0.0 { time_scale } else { 1.0 };
    let mut sent = 0usize;
    for req in &tenant.requests {
        let due = start + Duration::from_secs_f64(req.arrival_s / scale);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        if Instant::now() > deadline {
            break;
        }
        {
            let mut sh = shared.lock().expect("client shared lock");
            sh.submitted.insert(req.id, Instant::now());
        }
        send(
            &write,
            &Frame::Submit {
                req_id: req.id,
                seed: req.seed,
                max_new: req.max_new as u32,
                tenant: tenant.name.clone(),
                drafter: tenant.drafter.clone(),
                prompt: req.prompt.clone(),
            },
        )?;
        sent += 1;
    }

    // Wait for every submitted request to reach a terminal state.
    loop {
        {
            let sh = shared.lock().expect("client shared lock");
            if sh.terminal >= sent || sh.reader_dead {
                break;
            }
        }
        if Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    // Half-close: the server reader sees EOF and cleans the connection.
    {
        let s = write.lock().expect("client write lock");
        let _ = s.shutdown(std::net::Shutdown::Write);
    }
    let _ = reader.join();
    if let Some(e) = shared.lock().expect("client shared lock").hello_error.clone() {
        bail!("tenant {}: server handshake rejected: {e}", tenant.name);
    }
    Ok(TenantOutcome { name: tenant.name, shared, sent })
}

/// Replay the configured load and collect the client-side report.
pub fn run_load(cfg: ClientConfig) -> Result<ClientReport> {
    if cfg.tenants.is_empty() {
        bail!("client: no tenants configured");
    }
    let start = Instant::now();
    let deadline = start + Duration::from_secs_f64(cfg.timeout_s);
    let mut workers = Vec::new();
    for tenant in cfg.tenants.clone() {
        let addr = cfg.addr.clone();
        let (ce, ts) = (cfg.credit_every, cfg.time_scale);
        workers.push(std::thread::spawn(move || {
            tenant_worker(addr, tenant, ce, ts, deadline, start)
        }));
    }
    let mut outcomes = Vec::new();
    for w in workers {
        outcomes.push(w.join().map_err(|_| anyhow!("client worker panicked"))??);
    }
    let wall_s = start.elapsed().as_secs_f64();

    let mut report = ClientReport {
        metrics: MetricsRegistry::new(),
        outputs: BTreeMap::new(),
        sessions: BTreeMap::new(),
        completed: 0,
        cancelled: 0,
        refused: BTreeMap::new(),
        failed: 0,
        wall_s,
    };
    for o in outcomes {
        let sh = o.shared.lock().expect("client shared lock");
        let by: &[(&str, &str)] = &[("tenant", &o.name)];
        let mut terminal_seen = sh.refusals.len();
        for (req, code) in sh.refusals.iter() {
            *report.refused.entry(code.clone()).or_insert(0) += 1;
            report.sessions.insert(
                (o.name.clone(), *req),
                SessionDetail { replica: None, outcome: format!("refused:{code}") },
            );
        }
        for (_, s) in sh.by_session.iter() {
            report.outputs.insert((o.name.clone(), s.req_id), s.tokens.clone());
            let outcome = match s.finished {
                Some(0) => "completed",
                Some(1) => "cancelled",
                Some(2) => "rejected",
                Some(_) => "failed",
                None => "none",
            };
            report.sessions.insert(
                (o.name.clone(), s.req_id),
                SessionDetail { replica: s.replica, outcome: outcome.to_string() },
            );
            if let Some(first) = s.first {
                let ttft = first.duration_since(s.submitted).as_secs_f64();
                report.metrics.observe("ttft_s", &[], ttft);
                report.metrics.observe("ttft_s", by, ttft);
            }
            if let (Some(first), Some(last)) = (s.first, s.last) {
                // Mean gap recorded once per gap: per-frame reader-thread
                // timestamps are scheduler-noisy at microsecond generation
                // speeds; the session mean is the stable client-side
                // quantity (SessionStats keeps the per-gap histogram).
                if s.tokens.len() > 1 {
                    let itl = last.duration_since(first).as_secs_f64() / (s.tokens.len() - 1) as f64;
                    for _ in 1..s.tokens.len() {
                        report.metrics.observe("inter_token_s", &[], itl);
                        report.metrics.observe("inter_token_s", by, itl);
                    }
                }
            }
            match s.finished {
                Some(0) => {
                    terminal_seen += 1;
                    report.completed += 1;
                    report.metrics.inc("sessions_completed", &[], 1.0);
                    report.metrics.inc("sessions_completed", by, 1.0);
                    report.metrics.inc("tokens_completed", &[], s.tokens.len() as f64);
                    report.metrics.inc("tokens_completed", by, s.tokens.len() as f64);
                }
                Some(1) => {
                    terminal_seen += 1;
                    report.cancelled += 1;
                    report.metrics.inc("sessions_cancelled", &[], 1.0);
                    report.metrics.inc("sessions_cancelled", by, 1.0);
                }
                Some(_) => {
                    terminal_seen += 1;
                    report.failed += 1;
                    report.metrics.inc("sessions_failed", &[], 1.0);
                    report.metrics.inc("sessions_failed", by, 1.0);
                }
                None => {}
            }
        }
        // deadline-expired: submitted but never terminal
        let missing = o.sent.saturating_sub(terminal_seen) as u64;
        report.failed += missing;
        if missing > 0 {
            report.metrics.inc("sessions_failed", &[], missing as f64);
            report.metrics.inc("sessions_failed", by, missing as f64);
        }
    }

    if cfg.shutdown_after {
        drain_server(&cfg.addr)?;
    }
    Ok(report)
}

/// Ask the server to drain gracefully and wait until it does (its side of
/// every connection closes when the drain completes).
pub fn drain_server(addr: &str) -> Result<()> {
    let stream = TcpStream::connect(addr)?;
    let write = stream.try_clone()?;
    let mut w = write;
    wire::write_frame(&mut w, &Frame::Shutdown { abort: false })
        .map_err(|e| anyhow!("client write: {e}"))?;
    let mut r = BufReader::new(stream);
    // consume Hello (and anything else) until the server closes
    while let Ok(Some(_)) = wire::read_frame(&mut r) {}
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_counts_and_goodput() {
        let mut r = ClientReport {
            metrics: MetricsRegistry::new(),
            outputs: BTreeMap::new(),
            sessions: BTreeMap::new(),
            completed: 2,
            cancelled: 1,
            refused: BTreeMap::new(),
            failed: 0,
            wall_s: 2.0,
        };
        r.refused.insert("kv_shed".into(), 3);
        r.metrics.inc("tokens_completed", &[], 100.0);
        assert_eq!(r.refused_total(), 3);
        assert!((r.goodput_tok_s() - 50.0).abs() < 1e-9);
        let text = r.render();
        assert!(text.contains("ok=2"), "{text}");
        assert!(text.contains("refused[kv_shed] = 3"), "{text}");
        // no replica ids anywhere → the attribution block stays silent
        assert!(!text.contains("replica"), "{text}");
    }

    #[test]
    fn replica_attribution_prints_with_na_guard() {
        let mut r = ClientReport {
            metrics: MetricsRegistry::new(),
            outputs: BTreeMap::new(),
            sessions: BTreeMap::new(),
            completed: 3,
            cancelled: 0,
            refused: BTreeMap::new(),
            failed: 0,
            wall_s: 1.0,
        };
        let key0 = ("acme".to_string(), 1u64);
        let key1 = ("acme".to_string(), 2u64);
        let key2 = ("hobby".to_string(), 1u64);
        r.outputs.insert(key0.clone(), vec![1, 2, 3]);
        r.outputs.insert(key1.clone(), vec![4]);
        r.outputs.insert(key2.clone(), vec![5, 6]);
        r.sessions
            .insert(key0, SessionDetail { replica: Some(0), outcome: "completed".into() });
        r.sessions
            .insert(key1, SessionDetail { replica: Some(1), outcome: "completed".into() });
        // one session against a non-echoing server falls under "n/a"
        r.sessions.insert(key2, SessionDetail { replica: None, outcome: "completed".into() });
        let text = r.render();
        assert!(text.contains("replica 0: ok=1 tokens=3"), "{text}");
        assert!(text.contains("replica 1: ok=1 tokens=1"), "{text}");
        assert!(text.contains("replica n/a: ok=1 tokens=2"), "{text}");
    }
}
