# Cross-check of rust/src/runtime/sim.rs — the deterministic CPU fallback
# runtime (PR 4).
#
# A 1:1 Python port of the hash surrogate model (mix64 fold, logit rows,
# causal/sparse visibility, dump shape) is driven through a miniature
# single-request engine replicating the Rust engine's round structure
# (anchor + k sparse drafts -> dense verify -> greedy rollback -> pillar
# refresh).  It pins the *design* invariants the Rust integration tests
# assert once compiled:
#
#   1. greedy losslessness: every sparse drafter reproduces the vanilla
#      chain token-for-token, at any acceptance rate;
#   2. determinism: same seed => identical outputs;
#   3. the dump's long-range band makes PillarAttn selection beat the
#      pure sliding window in acceptance on long contexts (the Fig. 3
#      oracle-vs-window gap in miniature).
#
# Constants and fold order MUST stay in lockstep with runtime/sim.rs.

M64 = (1 << 64) - 1
GOLDEN = 0x9E37_79B9_7F4A_7C15
SEED0 = 0xC0FF_EE00_5EED_1234
VOCAB_MUL = 0xD6E8_FEB8_6659_FD93
CTX = 8
LONG_MIN = 24
LONG_BAND = 5
VOCAB = 512


def mix64(seed):
    z = (seed + GOLDEN) & M64
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & M64
    return z ^ (z >> 31)


def argmax_token(h):
    # fill_logits + argmax: values are distinct-ordered 24-bit ints, so
    # comparing the raw ints matches the f32 comparison bit-for-bit.
    best_v, best_x = 0, -1
    for v in range(VOCAB):
        x = mix64(h ^ ((v * VOCAB_MUL) & M64)) >> 40
        if x > best_x:
            best_v, best_x = v, x
    return best_v


def ctx_hash(kv, p, visible=None):
    h = SEED0
    if p >= LONG_MIN:
        lp = p // 2
        if visible is None or lp in visible:
            h = mix64(h ^ (kv[lp] + 1))
    for t in range(max(p + 1 - CTX, 0), p + 1):
        if visible is None or t in visible:
            h = mix64(h ^ (kv[t] + 1))
    return h


def dense_next(kv, p):
    return argmax_token(ctx_hash(kv, p))


def sparse_next(kv, p, idx_set):
    return argmax_token(ctx_hash(kv, p, visible=idx_set))


def dump_mass(t, length):
    mass = 1.0 / (1.0 + (length - 1 - t))
    if t < 4:
        mass += 3.0
    if abs(t - length // 2) <= LONG_BAND:
        mass += 2.0
    return mass


# --- policy / selection (semantics pinned by test_pillar_rust_port.py) ---

def pillar_policy(budget):
    sinks = min(4, budget // 8)
    recent = min(max(budget // 4, 8), budget - sinks)
    return budget, sinks, recent


def window_policy(budget):
    sinks = min(4, budget // 8)
    return budget, sinks, budget - sinks


def select(scores, length, policy):
    budget, sinks, recent = policy
    s_eff = min(sinks, length)
    lo = max(max(length - recent, 0), s_eff)
    out = list(range(min(s_eff, budget)))
    n_fixed = s_eff + (length - lo)
    if n_fixed >= budget:
        for t in range(lo, length):
            if len(out) >= budget:
                break
            out.append(t)
        return out
    rest = budget - n_fixed
    cand = sorted(range(s_eff, lo), key=lambda t: (-scores[t], t))
    out += cand[:rest]
    out += list(range(lo, length))
    return sorted(out)


def compose(crit, length, policy):
    budget, sinks, recent = policy
    s_eff = min(sinks, length)
    lo = max(length - recent, s_eff)
    out = list(range(s_eff)) + list(range(lo, length))
    for c in crit:
        if len(out) >= budget:
            break
        if s_eff <= c < lo:
            out.append(c)
    return set(out[:budget])


def refresh(length, policy):
    scores = [dump_mass(t, length) for t in range(length)]
    return select(scores, length, policy)


# --- miniature engine (mirrors engine/core.rs round structure) ----------

def vanilla(prompt, max_new):
    kv = list(prompt)
    out = []
    pending = dense_next(kv, len(kv) - 1)  # prefill
    out.append(pending)
    while len(out) < max_new:
        kv.append(pending)
        pending = dense_next(kv, len(kv) - 1)
        out.append(pending)
    return out


def speculative(prompt, max_new, k, policy):
    kv = list(prompt)
    pending = dense_next(kv, len(kv) - 1)
    out = [pending]
    crit = []
    rounds, accepted = 0, 0
    drafted = 0
    while len(out) < max_new:
        rsl = len(kv)
        anchor = pending
        kk = min(k, max(max_new - len(out), 1))
        # draft phase: sparse steps, index set recomposed per step
        kv_d = list(kv)
        drafts = []
        cur = anchor
        for _ in range(kk):
            p = len(kv_d)
            kv_d.append(cur)
            idx = compose(crit, p + 1, policy)
            d = sparse_next(kv_d, p, idx)
            drafts.append(d)
            cur = d
        # dense verify over anchor + drafts, greedy acceptance
        kv_v = list(kv) + [anchor] + drafts
        acc = 0
        next_tok = None
        for j, d in enumerate(drafts):
            tgt = dense_next(kv_v, rsl + j)
            if tgt == d:
                acc += 1
            else:
                next_tok = tgt
                break
        if next_tok is None:
            next_tok = dense_next(kv_v, rsl + len(drafts))
        rounds += 1
        accepted += acc
        drafted += len(drafts)
        take = min(acc, max_new - len(out))
        out += drafts[:take]
        if len(out) < max_new:
            out.append(next_tok)
        kv = list(kv) + [anchor] + drafts[:acc]  # rollback to frontier
        pending = next_tok
        crit = refresh(len(kv), policy)
    alpha = accepted / max(drafted, 1)
    return out, alpha


def prompt_for(seed, n=16):
    # arbitrary but deterministic prompt in-vocab
    return [1] + [(mix64(seed + i) % (VOCAB - 2)) + 1 for i in range(n - 1)]


def test_losslessness_all_policies():
    for seed in range(6):
        p = prompt_for(seed)
        base = vanilla(p, 120)
        for policy in [pillar_policy(64), pillar_policy(16),
                       window_policy(64), window_policy(32)]:
            got, _ = speculative(p, 120, 8, policy)
            assert got == base, f"seed={seed} policy={policy} diverged"


def test_determinism():
    p = prompt_for(3)
    a, aa = speculative(p, 150, 8, pillar_policy(64))
    b, ab = speculative(p, 150, 8, pillar_policy(64))
    assert a == b and aa == ab


def test_pillar_band_beats_window_on_long_contexts():
    # 300-token generations push contexts far past the window drafter's
    # reach of the long-range position p/2; the pillar dump band keeps it
    # visible.
    alphas_p, alphas_w = [], []
    for seed in range(4):
        p = prompt_for(seed + 100)
        _, ap = speculative(p, 300, 8, pillar_policy(64))
        _, aw = speculative(p, 300, 8, window_policy(32))
        alphas_p.append(ap)
        alphas_w.append(aw)
    mean_p = sum(alphas_p) / len(alphas_p)
    mean_w = sum(alphas_w) / len(alphas_w)
    assert mean_p > 0.9, f"pillar acceptance collapsed: {mean_p}"
    assert mean_p > mean_w + 0.15, f"no pillar/window gap: {mean_p} vs {mean_w}"


def test_short_contexts_fully_accepted():
    # below LONG_MIN there is no long-range dependence; any policy whose
    # recent window covers CTX accepts everything.
    p = prompt_for(7, n=8)
    _, alpha = speculative(p, 12, 8, window_policy(64))
    assert alpha == 1.0
