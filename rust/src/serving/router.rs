//! `sparsespec-router`: scale-out serving front door over N
//! `sparsespec-server` replicas.
//!
//! The router speaks wire v1 **both ways**: upstream it presents the
//! identical protocol a single server does (an unchanged
//! `sparsespec-client` cannot tell the difference), downstream it is an
//! ordinary client of each replica.  One core thread owns all routing
//! state — the same single-writer discipline as the server's engine
//! thread — fed by reader threads for every client and replica socket.
//!
//! # Routing
//!
//! Each `Submit` is placed by [`RouterPolicy`]: sessions are grouped
//! into **length buckets** by projected KV cost (`prompt + max_new + 2`)
//! and the request goes to the replica with the least projected load
//! *within its bucket* — so one replica does not end up with all the
//! long-generation sessions while another idles on shorts.  Ties break
//! by total live-session count, then lowest replica index.  Per-tenant
//! **stickiness** pins a tenant to its last replica while that replica
//! is `Up`, so multi-turn prefix reuse lands where the KV pages already
//! are.
//!
//! # Credit accounting, end to end
//!
//! Token frames from a replica are re-queued to the client through the
//! same credit-gated [`ConnOut`] the server uses, and the router only
//! returns credit *downstream* for tokens it actually queued upstream.
//! A slow client therefore stalls exactly its own per-replica delegated
//! connections (the router opens one downstream connection per
//! (client, replica) pair), the replica's slow-reader policing fires
//! against exactly that client's sessions, and everyone else keeps
//! streaming.
//!
//! # Health and failover
//!
//! Each replica has a control connection carrying periodic `Ping`
//! health checks: a missed reply degrades the replica (no *new*
//! sessions routed to it), [`RouterConfig::down_after_missed`] misses —
//! or any replica-socket EOF outside a drain — marks it `Down`.  On
//! `Down`, sessions that have not streamed a token are transparently
//! **resubmitted** to a surviving replica; mid-stream sessions fail
//! fast with [`ErrorCode::ReplicaDown`] (a silent resubmit would replay
//! already-delivered tokens).  Graceful fleet drain forwards `Shutdown`
//! to every replica and waits for each one's held sessions.
//!
//! # Fleet metrics
//!
//! `/metrics` serves the **one-merge rollup**: every replica's lossless
//! `/snapshot` (`MetricsRegistry::decode_text`) merged with the
//! router-local registry (per-replica routed / resubmitted /
//! failed-over counters, health transitions, live-session and pending
//! gauges).  Counters sum, gauges last-write-win, histograms
//! concatenate — associative, so the rollup equals what a single
//! registry would have recorded.  Routing decisions also land as
//! Perfetto instants (`--trace-out`), so a timeline shows each
//! request's replica hop.

use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::MetricsRegistry;
use crate::trace::{TraceConfig, Track, Tracer};

use super::server::{metrics_http_loop, ConnOut};
use super::wire::{self, ErrorCode, Frame, WireError};

// ---------------------------------------------------------------------------
// Routing policy (pure state machine — twinned by
// python/tests/test_router_port.py)
// ---------------------------------------------------------------------------

/// Replica health as seen by the router's state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Answering pings; eligible for new sessions.
    Up,
    /// Missed a ping: existing sessions keep streaming, no new routing.
    Degraded,
    /// Socket gone or pings exhausted: sessions failed over.  Terminal.
    Down,
}

/// One routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub replica: usize,
    pub bucket: usize,
    /// The tenant-stickiness fast path was taken.
    pub sticky: bool,
}

/// Bucket-aware least-loaded replica selection with tenant stickiness.
///
/// Pure and deterministic: every decision is a function of the recorded
/// loads, so the unit tests (and the Python twin) drive it without any
/// sockets.
pub struct RouterPolicy {
    bucket_edges: Vec<usize>,
    health: Vec<ReplicaHealth>,
    live: Vec<usize>,
    /// Projected KV cost per `[replica][bucket]`.
    load: Vec<Vec<usize>>,
    sticky: BTreeMap<String, usize>,
}

impl RouterPolicy {
    /// `bucket_edges` are ascending upper bounds; costs above the last
    /// edge share the final overflow bucket.
    pub fn new(replicas: usize, mut bucket_edges: Vec<usize>) -> Self {
        bucket_edges.sort_unstable();
        bucket_edges.dedup();
        let buckets = bucket_edges.len() + 1;
        RouterPolicy {
            bucket_edges,
            health: vec![ReplicaHealth::Up; replicas],
            live: vec![0; replicas],
            load: vec![vec![0; buckets]; replicas],
            sticky: BTreeMap::new(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.health.len()
    }

    pub fn n_buckets(&self) -> usize {
        self.bucket_edges.len() + 1
    }

    /// Bucket index for a projected KV cost: the count of edges strictly
    /// below `cost` (bucket 0 is `cost <= edges[0]`).
    pub fn bucket_of(&self, cost: usize) -> usize {
        self.bucket_edges.iter().filter(|e| cost > **e).count()
    }

    /// Route one session: sticky replica if still `Up`, else the `Up`
    /// replica with the least projected load in the session's bucket,
    /// ties broken by live-session count then lowest index.  Records the
    /// load and stickiness; `None` when no replica is `Up`.
    pub fn route(&mut self, tenant: &str, cost: usize) -> Option<RouteDecision> {
        let bucket = self.bucket_of(cost);
        if let Some(&r) = self.sticky.get(tenant) {
            if self.health[r] == ReplicaHealth::Up {
                self.live[r] += 1;
                self.load[r][bucket] += cost;
                return Some(RouteDecision { replica: r, bucket, sticky: true });
            }
        }
        let best = (0..self.replicas())
            .filter(|&r| self.health[r] == ReplicaHealth::Up)
            .min_by_key(|&r| (self.load[r][bucket], self.live[r], r))?;
        self.live[best] += 1;
        self.load[best][bucket] += cost;
        self.sticky.insert(tenant.to_string(), best);
        Some(RouteDecision { replica: best, bucket, sticky: false })
    }

    /// Return a finished/failed session's projected load.
    pub fn release(&mut self, replica: usize, bucket: usize, cost: usize) {
        self.live[replica] = self.live[replica].saturating_sub(1);
        self.load[replica][bucket] = self.load[replica][bucket].saturating_sub(cost);
    }

    pub fn set_health(&mut self, replica: usize, h: ReplicaHealth) {
        self.health[replica] = h;
    }

    pub fn health(&self, replica: usize) -> ReplicaHealth {
        self.health[replica]
    }

    pub fn live_sessions(&self, replica: usize) -> usize {
        self.live[replica]
    }
}

/// What to do with a session whose replica went down.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailoverAction {
    /// Nothing streamed yet: resubmit transparently to a survivor.
    Resubmit,
    /// Tokens already left the router (or sit undelivered): fail fast
    /// with [`ErrorCode::ReplicaDown`] — a resubmit would replay output.
    FailFast,
}

/// The failover contract: resubmit iff zero tokens were forwarded to the
/// client *and* none are buffered from the dead replica.
pub fn failover_action(sent: u32, pending: usize) -> FailoverAction {
    if sent == 0 && pending == 0 {
        FailoverAction::Resubmit
    } else {
        FailoverAction::FailFast
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// One replica endpoint.
#[derive(Clone, Debug)]
pub struct ReplicaSpec {
    /// Wire-protocol address of the replica.
    pub addr: String,
    /// The replica's `/metrics`+`/snapshot` HTTP address; `None` leaves
    /// that replica out of the fleet rollup (routing still works).
    pub metrics_addr: Option<String>,
}

/// Router configuration.  Defaults mirror [`super::ServerConfig`] where
/// the knobs overlap.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub replicas: Vec<ReplicaSpec>,
    /// Upstream listen address (port 0 ⇒ ephemeral, see [`Router::addr`]).
    pub addr: String,
    /// Fleet `/metrics` + `/snapshot` address (`None` disables).
    pub metrics_addr: Option<String>,
    /// Token-credit window granted to each upstream client in `Hello`.
    pub send_window: u32,
    /// Outbound frame-queue bound per upstream connection.
    pub send_queue_cap: usize,
    /// Ascending bucket upper bounds on projected KV cost
    /// (`prompt + max_new + 2`); one overflow bucket is added above.
    pub bucket_edges: Vec<usize>,
    /// Milliseconds between health `Ping`s on each replica control
    /// connection.
    pub ping_every_ms: u64,
    /// Consecutive unanswered pings before a replica is declared Down
    /// (1 unanswered ping already degrades it).
    pub down_after_missed: u32,
    /// Milliseconds between fleet-rollup refreshes of `/metrics`.
    pub rollup_every_ms: u64,
    /// Export the router's Perfetto trace here on drain.
    pub trace_out: Option<String>,
}

impl RouterConfig {
    pub fn new(replicas: Vec<ReplicaSpec>) -> Self {
        RouterConfig {
            replicas,
            addr: "127.0.0.1:7533".into(),
            metrics_addr: None,
            send_window: 1024,
            send_queue_cap: 1024 + 64,
            bucket_edges: vec![128, 256, 512],
            ping_every_ms: 500,
            down_after_missed: 3,
            rollup_every_ms: 200,
            trace_out: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Core thread
// ---------------------------------------------------------------------------

enum Ev {
    ClientConn { conn: u64, out: Arc<ConnOut> },
    ClientFrame { conn: u64, frame: Frame },
    ClientClosed { conn: u64 },
    /// `conn` is the owning client connection for delegated links, 0 for
    /// the replica's control connection.
    ReplicaFrame { replica: usize, conn: u64, frame: Frame },
    ReplicaClosed { replica: usize, conn: u64 },
    Shutdown { abort: bool },
}

struct RSession {
    conn: u64,
    client_req: u64,
    tenant: String,
    replica: usize,
    bucket: usize,
    cost: usize,
    /// Replica-assigned session id (post-`Accepted`).
    down_sid: Option<u64>,
    /// `Accepted` already forwarded upstream (suppressed on resubmit).
    accepted_fwd: bool,
    /// Client cancelled before the replica accepted.
    cancel_wanted: bool,
    /// Token frames queued to the client so far.
    sent: u32,
    /// Received from the replica, not yet past the client's credit gate.
    pending: VecDeque<i32>,
    /// Replica's terminal `Finished { reason, tokens }`.
    fin: Option<(u8, u32)>,
    /// The downstream `Submit` (req_id = router sid), kept for resubmit.
    submit: Frame,
}

struct DownLink {
    stream: TcpStream,
    /// Credit owed to the replica for tokens we queued upstream; flushed
    /// as one batched `Credit` per loop pass.
    owed: u32,
}

/// State shared with the rollup + HTTP threads.
struct RollupShared {
    local: Mutex<MetricsRegistry>,
    last_snaps: Mutex<Vec<Option<MetricsRegistry>>>,
    exposition: Arc<Mutex<String>>,
    snapshot: Arc<Mutex<String>>,
}

/// Final state handed back by [`Router::join`].
pub struct RouterSummary {
    /// Router-local series only (routed / resubmitted / failed-over /
    /// health transitions).
    pub local: MetricsRegistry,
    /// The associative merge of every replica's final snapshot.
    pub replicas_merged: MetricsRegistry,
    /// `local ⊕ replicas_merged` — what `/metrics` served.
    pub fleet: MetricsRegistry,
    pub exposition: String,
    pub routed: u64,
    pub resubmitted: u64,
    pub failed_over: u64,
}

struct RouterCore {
    cfg: RouterConfig,
    policy: RouterPolicy,
    conns: BTreeMap<u64, Arc<ConnOut>>,
    sessions: BTreeMap<u64, RSession>,
    by_down: BTreeMap<(usize, u64), u64>,
    links: BTreeMap<(u64, usize), DownLink>,
    control: Vec<Option<TcpStream>>,
    control_open: Vec<bool>,
    missed_pings: Vec<u32>,
    next_sid: u64,
    draining: bool,
    metrics: MetricsRegistry,
    shared: Arc<RollupShared>,
    tracer: Tracer,
    t0: Instant,
    ev_tx: Sender<Ev>,
    routed: u64,
    resubmitted: u64,
    failed_over: u64,
}

impl RouterCore {
    fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn trace_instant(&mut self, name: &str, track: Track, args: crate::trace::Args) {
        if self.tracer.enabled() {
            let t = self.now_s();
            self.tracer.instant(name, track, t, args);
        }
    }

    fn health_transition(&mut self, replica: usize, to: ReplicaHealth) {
        if self.policy.health(replica) == to {
            return;
        }
        self.policy.set_health(replica, to);
        let label = match to {
            ReplicaHealth::Up => "up",
            ReplicaHealth::Degraded => "degraded",
            ReplicaHealth::Down => "down",
        };
        let rl = replica.to_string();
        self.metrics
            .inc("router_health_transitions", &[("replica", &rl), ("to", label)], 1.0);
        self.trace_instant(
            "replica_health",
            Track::Scheduler,
            vec![("replica", (replica as u64).into()), ("to", label.into())],
        );
    }

    /// Open (or reuse) the delegated downstream connection for a
    /// (client, replica) pair.
    fn ensure_link(&mut self, conn: u64, replica: usize) -> Result<(), WireError> {
        if self.links.contains_key(&(conn, replica)) {
            return Ok(());
        }
        let addr = self.cfg.replicas[replica].addr.clone();
        let stream = TcpStream::connect(&addr).map_err(|e| WireError::Io(e.to_string()))?;
        let _ = stream.set_nodelay(true);
        let read_half = stream.try_clone().map_err(|e| WireError::Io(e.to_string()))?;
        let tx = self.ev_tx.clone();
        std::thread::spawn(move || replica_reader(replica, conn, read_half, tx, true));
        self.links.insert((conn, replica), DownLink { stream, owed: 0 });
        Ok(())
    }

    fn write_down(&mut self, conn: u64, replica: usize, f: &Frame) -> Result<(), WireError> {
        let link = self
            .links
            .get_mut(&(conn, replica))
            .ok_or_else(|| WireError::Io("no link".into()))?;
        wire::write_frame(&mut link.stream, f)
    }

    fn refuse(&mut self, conn: u64, req_id: u64, code: ErrorCode, detail: String) {
        self.metrics.inc("router_refused", &[("code", code.label())], 1.0);
        if let Some(out) = self.conns.get(&conn) {
            out.push_ctrl(Frame::Error { req_id, code, detail });
        }
    }

    fn on_submit(&mut self, conn: u64, frame: Frame) {
        let Frame::Submit { req_id, seed, max_new, tenant, drafter, prompt } = frame else {
            return;
        };
        if !self.conns.contains_key(&conn) {
            return;
        }
        if self.draining {
            return self.refuse(conn, req_id, ErrorCode::Draining, "router is draining".into());
        }
        let cost = prompt.len() + max_new as usize + 2;
        let sid = self.next_sid;
        self.next_sid += 1;
        let submit = Frame::Submit { req_id: sid, seed, max_new, tenant: tenant.clone(), drafter, prompt };
        let sess = RSession {
            conn,
            client_req: req_id,
            tenant,
            replica: usize::MAX,
            bucket: 0,
            cost,
            down_sid: None,
            accepted_fwd: false,
            cancel_wanted: false,
            sent: 0,
            pending: VecDeque::new(),
            fin: None,
            submit,
        };
        self.sessions.insert(sid, sess);
        self.route_session(sid);
    }

    /// Place (or re-place) session `sid` on a live replica, writing its
    /// `Submit` downstream.  A replica that fails the write is marked
    /// Down (with full failover for its other sessions) and the loop
    /// retries on the survivors; with nobody left the session gets the
    /// typed [`ErrorCode::ReplicaDown`] refusal.
    fn route_session(&mut self, sid: u64) {
        loop {
            let Some(s) = self.sessions.get(&sid) else { return };
            let (conn, tenant, cost, client_req, accepted_fwd, sent) =
                (s.conn, s.tenant.clone(), s.cost, s.client_req, s.accepted_fwd, s.sent);
            let Some(d) = self.policy.route(&tenant, cost) else {
                self.metrics.inc("router_refused", &[("code", ErrorCode::ReplicaDown.label())], 1.0);
                if let Some(out) = self.conns.get(&conn) {
                    out.push_ctrl(Frame::Error {
                        req_id: client_req,
                        code: ErrorCode::ReplicaDown,
                        detail: "no live replica".into(),
                    });
                    if accepted_fwd {
                        out.push_ctrl(Frame::Finished { session: sid, reason: 3, tokens: sent });
                    }
                }
                self.sessions.remove(&sid);
                return;
            };
            let ok = self.ensure_link(conn, d.replica).is_ok() && {
                let submit = self.sessions[&sid].submit.clone();
                self.write_down(conn, d.replica, &submit).is_ok()
            };
            if ok {
                if let Some(s) = self.sessions.get_mut(&sid) {
                    s.replica = d.replica;
                    s.bucket = d.bucket;
                    s.down_sid = None;
                }
                let rl = d.replica.to_string();
                self.routed += 1;
                self.metrics.inc("router_routed", &[("replica", &rl)], 1.0);
                if d.sticky {
                    self.metrics.inc("router_sticky_hits", &[("replica", &rl)], 1.0);
                }
                self.trace_instant(
                    "route",
                    Track::Session,
                    vec![
                        ("sid", sid.into()),
                        ("replica", (d.replica as u64).into()),
                        ("bucket", (d.bucket as u64).into()),
                        ("sticky", if d.sticky { "yes".into() } else { "no".into() }),
                    ],
                );
                return;
            }
            // the write itself failed: the replica is gone — release the
            // just-recorded load, fail the replica over, and retry
            self.policy.release(d.replica, d.bucket, cost);
            self.health_transition(d.replica, ReplicaHealth::Down);
            self.replica_down(d.replica, Some(sid));
        }
    }

    /// Failover for every session on a dead replica.  `skip` excludes the
    /// session currently being routed by the caller's retry loop.
    fn replica_down(&mut self, replica: usize, skip: Option<u64>) {
        // flush anything already terminal so it is not failed over
        self.deliver();
        self.missed_pings[replica] = 0;
        if let Some(stream) = self.control[replica].take() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        self.control_open[replica] = false;
        let dead_links: Vec<(u64, usize)> = self
            .links
            .keys()
            .filter(|(_, r)| *r == replica)
            .copied()
            .collect();
        for k in dead_links {
            if let Some(l) = self.links.remove(&k) {
                let _ = l.stream.shutdown(std::net::Shutdown::Both);
            }
        }
        // a session whose Finished already arrived needs nothing more
        // from the replica: leave it to drain through the client's
        // credit gate instead of failing it over
        let victims: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(sid, s)| s.replica == replica && s.fin.is_none() && Some(**sid) != skip)
            .map(|(&sid, _)| sid)
            .collect();
        let rl = replica.to_string();
        for sid in victims {
            let s = self.sessions.get(&sid).expect("victim exists");
            let action = failover_action(s.sent, s.pending.len());
            self.policy.release(replica, s.bucket, s.cost);
            if let Some(down) = s.down_sid {
                self.by_down.remove(&(replica, down));
            }
            match action {
                FailoverAction::Resubmit => {
                    self.resubmitted += 1;
                    self.metrics.inc("router_resubmitted", &[("replica", &rl)], 1.0);
                    self.trace_instant(
                        "resubmit",
                        Track::Session,
                        vec![("sid", sid.into()), ("from", (replica as u64).into())],
                    );
                    self.route_session(sid);
                }
                FailoverAction::FailFast => {
                    self.failed_over += 1;
                    self.metrics.inc("router_failed_over", &[("replica", &rl)], 1.0);
                    let s = self.sessions.remove(&sid).expect("victim exists");
                    self.trace_instant(
                        "replica_down_session",
                        Track::Session,
                        vec![("sid", sid.into()), ("sent", (s.sent as u64).into())],
                    );
                    if let Some(out) = self.conns.get(&s.conn) {
                        out.push_ctrl(Frame::Error {
                            req_id: s.client_req,
                            code: ErrorCode::ReplicaDown,
                            detail: format!("replica {replica} went down mid-stream"),
                        });
                        out.push_ctrl(Frame::Finished { session: sid, reason: 3, tokens: s.sent });
                    }
                }
            }
        }
    }

    fn on_replica_frame(&mut self, replica: usize, frame: Frame) {
        match frame {
            Frame::Hello { .. } => {} // validated by the reader thread
            Frame::Pong { .. } => {
                self.missed_pings[replica] = 0;
                if self.policy.health(replica) == ReplicaHealth::Degraded {
                    self.health_transition(replica, ReplicaHealth::Up);
                }
            }
            Frame::Accepted { req_id: sid, session: down_sid, .. } => {
                let Some(s) = self.sessions.get_mut(&sid) else {
                    // session evaporated (client gone / failed over):
                    // release it on the replica immediately
                    let cancel = Frame::Cancel { session: down_sid };
                    let link_conn = self.links.keys().find(|(_, r)| *r == replica).map(|k| k.0);
                    if let Some(c) = link_conn {
                        let _ = self.write_down(c, replica, &cancel);
                    }
                    return;
                };
                if s.replica != replica {
                    return; // stale accept from the dead replica
                }
                s.down_sid = Some(down_sid);
                let (conn, client_req, cancel_wanted, fwd) =
                    (s.conn, s.client_req, s.cancel_wanted, s.accepted_fwd);
                self.by_down.insert((replica, down_sid), sid);
                if !fwd {
                    if let Some(out) = self.conns.get(&conn) {
                        out.push_ctrl(Frame::Accepted {
                            req_id: client_req,
                            session: sid,
                            replica: Some(replica as u16),
                        });
                    }
                    if let Some(s) = self.sessions.get_mut(&sid) {
                        s.accepted_fwd = true;
                    }
                }
                if cancel_wanted {
                    let _ = self.write_down(conn, replica, &Frame::Cancel { session: down_sid });
                }
            }
            Frame::Token { session: down_sid, token, .. } => {
                if let Some(&sid) = self.by_down.get(&(replica, down_sid)) {
                    if let Some(s) = self.sessions.get_mut(&sid) {
                        s.pending.push_back(token);
                    }
                }
            }
            Frame::Finished { session: down_sid, reason, tokens } => {
                if let Some(&sid) = self.by_down.get(&(replica, down_sid)) {
                    if let Some(s) = self.sessions.get_mut(&sid) {
                        s.fin = Some((reason, tokens));
                    }
                }
            }
            Frame::Error { req_id: sid, code, detail } => {
                if sid == 0 {
                    // connection-scoped notice from the replica (e.g. a
                    // draining refusal at accept): surface as a counter
                    self.metrics
                        .inc("router_replica_errors", &[("code", code.label())], 1.0);
                    return;
                }
                let Some(s) = self.sessions.get_mut(&sid) else { return };
                if s.replica != replica {
                    return;
                }
                let (conn, client_req) = (s.conn, s.client_req);
                let pre_accept = s.down_sid.is_none() && s.fin.is_none();
                if let Some(out) = self.conns.get(&conn) {
                    out.push_ctrl(Frame::Error { req_id: client_req, code, detail });
                }
                if pre_accept {
                    // typed refusal before the replica accepted: terminal
                    let s = self.sessions.remove(&sid).expect("session exists");
                    self.policy.release(replica, s.bucket, s.cost);
                    self.metrics.inc("router_refused", &[("code", code.label())], 1.0);
                }
            }
            _ => {}
        }
    }

    fn on_ev(&mut self, ev: Ev) {
        match ev {
            Ev::ClientConn { conn, out } => {
                if self.draining {
                    out.push_ctrl(Frame::Error {
                        req_id: 0,
                        code: ErrorCode::Draining,
                        detail: "router is draining".into(),
                    });
                    out.close();
                    return;
                }
                self.metrics.inc("router_connections_total", &[], 1.0);
                self.conns.insert(conn, out);
            }
            Ev::ClientClosed { conn } => {
                let orphans: Vec<u64> = self
                    .sessions
                    .iter()
                    .filter(|(_, s)| s.conn == conn)
                    .map(|(&sid, _)| sid)
                    .collect();
                for sid in orphans {
                    let (replica, down_sid) = {
                        let s = self.sessions.get_mut(&sid).expect("orphan exists");
                        s.cancel_wanted = true;
                        s.pending.clear();
                        (s.replica, s.down_sid)
                    };
                    if let Some(down) = down_sid {
                        let _ = self.write_down(conn, replica, &Frame::Cancel { session: down });
                    }
                }
                if let Some(out) = self.conns.remove(&conn) {
                    out.close();
                }
            }
            Ev::ClientFrame { conn, frame } => match frame {
                f @ Frame::Submit { .. } => self.on_submit(conn, f),
                Frame::Cancel { session: sid } => {
                    let Some(s) = self.sessions.get_mut(&sid) else { return };
                    if s.conn != conn {
                        return;
                    }
                    match s.down_sid {
                        Some(down) => {
                            let replica = s.replica;
                            let _ = self.write_down(conn, replica, &Frame::Cancel { session: down });
                        }
                        None => s.cancel_wanted = true,
                    }
                }
                Frame::Credit { n } => {
                    if let Some(out) = self.conns.get(&conn) {
                        out.add_credit(n);
                    }
                }
                Frame::Ping { nonce } => {
                    if let Some(out) = self.conns.get(&conn) {
                        out.push_ctrl(Frame::Pong { nonce });
                    }
                }
                Frame::Shutdown { abort } => self.begin_drain(abort),
                other => {
                    if let Some(out) = self.conns.get(&conn) {
                        out.push_ctrl(Frame::Error {
                            req_id: 0,
                            code: ErrorCode::Protocol,
                            detail: format!("unexpected frame kind 0x{:02x}", other.kind()),
                        });
                    }
                }
            },
            Ev::ReplicaFrame { replica, frame, .. } => self.on_replica_frame(replica, frame),
            Ev::ReplicaClosed { replica, conn } => {
                if self.draining {
                    // expected during fleet drain: the replica finished
                    // its held sessions and closed every connection
                    if conn == 0 {
                        self.control_open[replica] = false;
                        self.control[replica] = None;
                    }
                    self.links.remove(&(conn, replica));
                    if self.policy.health(replica) != ReplicaHealth::Down
                        && !self.sessions.values().any(|s| s.replica == replica)
                    {
                        return;
                    }
                }
                if self.policy.health(replica) != ReplicaHealth::Down {
                    self.health_transition(replica, ReplicaHealth::Down);
                    self.replica_down(replica, None);
                }
            }
            Ev::Shutdown { abort } => self.begin_drain(abort),
        }
    }

    fn begin_drain(&mut self, abort: bool) {
        if self.draining {
            return;
        }
        self.draining = true;
        let abort_flag: u64 = abort as u64;
        self.trace_instant("fleet_drain", Track::Engine, vec![("abort", abort_flag.into())]);
        for r in 0..self.cfg.replicas.len() {
            if !self.control_open[r] {
                continue;
            }
            let ok = match self.control[r].as_mut() {
                Some(stream) => wire::write_frame(stream, &Frame::Shutdown { abort }).is_ok(),
                None => false,
            };
            if !ok {
                self.health_transition(r, ReplicaHealth::Down);
                self.replica_down(r, None);
            }
        }
    }

    /// Move buffered replica tokens through each client's credit gate,
    /// finalise sessions whose replica reported `Finished`, and record
    /// the downstream credit owed for every token that made it through.
    fn deliver(&mut self) {
        let mut done: Vec<u64> = Vec::new();
        let mut owed: Vec<(u64, usize, u32)> = Vec::new();
        for (&sid, s) in self.sessions.iter_mut() {
            let Some(out) = self.conns.get(&s.conn) else {
                s.pending.clear();
                if s.fin.is_some() {
                    done.push(sid);
                }
                continue;
            };
            let mut moved = 0u32;
            while let Some(&tok) = s.pending.front() {
                let f = Frame::Token { session: sid, index: s.sent, token: tok };
                if out.try_token(f) {
                    s.pending.pop_front();
                    s.sent += 1;
                    moved += 1;
                } else {
                    break;
                }
            }
            if moved > 0 {
                owed.push((s.conn, s.replica, moved));
            }
            if s.fin.is_some() && s.pending.is_empty() {
                done.push(sid);
            }
        }
        for (conn, replica, n) in owed {
            if let Some(link) = self.links.get_mut(&(conn, replica)) {
                link.owed += n;
            }
        }
        for sid in done {
            let Some(s) = self.sessions.remove(&sid) else { continue };
            let (reason, _) = s.fin.expect("finished session has a reason");
            self.policy.release(s.replica, s.bucket, s.cost);
            if let Some(down) = s.down_sid {
                self.by_down.remove(&(s.replica, down));
            }
            let rl = s.replica.to_string();
            let outcome = match reason {
                0 => "completed",
                1 => "cancelled",
                2 => "rejected",
                _ => "failed",
            };
            self.metrics
                .inc("router_sessions_finished", &[("replica", &rl), ("outcome", outcome)], 1.0);
            if let Some(out) = self.conns.get(&s.conn) {
                out.push_ctrl(Frame::Finished { session: sid, reason, tokens: s.sent });
            }
        }
    }

    /// Return batched credit downstream for tokens that cleared the
    /// client gate.  Only then may the replica send more — this is what
    /// stretches per-connection flow control across the hop.
    fn flush_credits(&mut self) {
        let mut dead: Vec<usize> = Vec::new();
        for ((_, replica), link) in self.links.iter_mut() {
            if link.owed == 0 {
                continue;
            }
            let f = Frame::Credit { n: link.owed };
            if wire::write_frame(&mut link.stream, &f).is_ok() {
                link.owed = 0;
            } else {
                dead.push(*replica);
            }
        }
        for r in dead {
            if self.policy.health(r) != ReplicaHealth::Down {
                self.health_transition(r, ReplicaHealth::Down);
                self.replica_down(r, None);
            }
        }
    }

    fn health_tick(&mut self, nonce: u64) {
        let mut dead: Vec<usize> = Vec::new();
        for r in 0..self.cfg.replicas.len() {
            if !self.control_open[r] || self.policy.health(r) == ReplicaHealth::Down {
                continue;
            }
            if self.missed_pings[r] >= self.cfg.down_after_missed {
                dead.push(r);
                continue;
            }
            if self.missed_pings[r] >= 1 && self.policy.health(r) == ReplicaHealth::Up {
                self.health_transition(r, ReplicaHealth::Degraded);
            }
            let ok = match self.control[r].as_mut() {
                Some(stream) => wire::write_frame(stream, &Frame::Ping { nonce }).is_ok(),
                None => false,
            };
            if ok {
                self.missed_pings[r] += 1;
            } else {
                dead.push(r);
            }
        }
        for r in dead {
            self.health_transition(r, ReplicaHealth::Down);
            self.replica_down(r, None);
        }
    }

    fn publish_local(&mut self) {
        let mut m = self.metrics.snapshot();
        for r in 0..self.cfg.replicas.len() {
            let rl = r.to_string();
            let h = match self.policy.health(r) {
                ReplicaHealth::Up => 2.0,
                ReplicaHealth::Degraded => 1.0,
                ReplicaHealth::Down => 0.0,
            };
            m.set_gauge("router_replica_health", &[("replica", &rl)], h);
            m.set_gauge(
                "router_sessions_live",
                &[("replica", &rl)],
                self.policy.live_sessions(r) as f64,
            );
            let pending: usize = self
                .sessions
                .values()
                .filter(|s| s.replica == r)
                .map(|s| s.pending.len())
                .sum();
            m.set_gauge("router_pending_tokens", &[("replica", &rl)], pending as f64);
        }
        m.set_gauge("router_draining", &[], self.draining as u64 as f64);
        *self.shared.local.lock().expect("local registry lock") = m;
    }

    fn run(mut self, rx: Receiver<Ev>) -> Result<RouterSummary> {
        let mut last_ping = Instant::now();
        let mut last_publish = Instant::now() - Duration::from_secs(1);
        let mut nonce = 0u64;
        loop {
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(ev) => self.on_ev(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => self.draining = true,
            }
            loop {
                match rx.try_recv() {
                    Ok(ev) => self.on_ev(ev),
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            self.deliver();
            self.flush_credits();
            if last_ping.elapsed().as_millis() as u64 >= self.cfg.ping_every_ms {
                last_ping = Instant::now();
                nonce += 1;
                self.health_tick(nonce);
            }
            if last_publish.elapsed().as_millis() as u64 >= 50 {
                last_publish = Instant::now();
                self.publish_local();
            }
            if self.draining
                && self.sessions.is_empty()
                && self.control_open.iter().all(|open| !open)
            {
                break;
            }
        }
        self.publish_local();
        if let Some(path) = &self.cfg.trace_out {
            let json = self.tracer.export_chrome_string();
            if let Err(e) = std::fs::write(path, json) {
                eprintln!("router: trace export to {path} failed: {e}");
            }
        }
        // Final rollup: prefer a live fetch of each replica's terminal
        // snapshot (published before it closes its wire connections),
        // falling back to the rollup thread's last good copy.
        let mut replicas_merged = MetricsRegistry::new();
        {
            let last = self.shared.last_snaps.lock().expect("snaps lock");
            for (i, spec) in self.cfg.replicas.iter().enumerate() {
                let fresh = spec
                    .metrics_addr
                    .as_deref()
                    .and_then(|a| http_get_text(a, "/snapshot").ok())
                    .and_then(|t| MetricsRegistry::decode_text(&t).ok());
                if let Some(snap) = fresh.or_else(|| last[i].clone()) {
                    replicas_merged.merge_from(&snap);
                }
            }
        }
        let local = self.metrics.snapshot();
        let mut fleet = local.snapshot();
        fleet.merge_from(&replicas_merged);
        let exposition = fleet.expose_prometheus("sparsespec");
        *self.shared.exposition.lock().expect("exposition lock") = exposition.clone();
        *self.shared.snapshot.lock().expect("snapshot lock") = fleet.encode_text();
        for out in self.conns.values() {
            out.close();
            out.force_shutdown();
        }
        Ok(RouterSummary {
            local,
            replicas_merged,
            fleet,
            exposition,
            routed: self.routed,
            resubmitted: self.resubmitted,
            failed_over: self.failed_over,
        })
    }
}

// ---------------------------------------------------------------------------
// Reader / rollup threads and plumbing
// ---------------------------------------------------------------------------

fn client_reader(conn: u64, stream: TcpStream, out: Arc<ConnOut>, tx: Sender<Ev>) {
    let mut r = std::io::BufReader::new(stream);
    loop {
        match wire::read_frame(&mut r) {
            Ok(Some(f)) => {
                if tx.send(Ev::ClientFrame { conn, frame: f }).is_err() {
                    break;
                }
            }
            Ok(None) => break,
            Err(WireError::Io(_)) => break,
            Err(e) => {
                out.push_ctrl(Frame::Error {
                    req_id: 0,
                    code: ErrorCode::Protocol,
                    detail: e.to_string(),
                });
                out.close();
                break;
            }
        }
    }
    let _ = tx.send(Ev::ClientClosed { conn });
}

/// Reader for a replica-facing socket.  `check_hello` consumes and
/// validates the opening `Hello` (delegated links; the control link's
/// Hello is validated synchronously in [`Router::spawn`]).
fn replica_reader(replica: usize, conn: u64, stream: TcpStream, tx: Sender<Ev>, check_hello: bool) {
    let mut r = std::io::BufReader::new(stream);
    if check_hello {
        match wire::read_frame(&mut r) {
            Ok(Some(f)) if wire::expect_hello(&f).is_ok() => {}
            _ => {
                let _ = tx.send(Ev::ReplicaClosed { replica, conn });
                return;
            }
        }
    }
    loop {
        match wire::read_frame(&mut r) {
            Ok(Some(f)) => {
                if tx.send(Ev::ReplicaFrame { replica, conn, frame: f }).is_err() {
                    break;
                }
            }
            _ => break,
        }
    }
    let _ = tx.send(Ev::ReplicaClosed { replica, conn });
}

fn accept_loop(
    listener: TcpListener,
    tx: Sender<Ev>,
    stop: Arc<AtomicBool>,
    window: u32,
    queue_cap: usize,
) {
    let next_conn = AtomicU64::new(1);
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let _ = stream.set_nodelay(true);
        let conn = next_conn.fetch_add(1, Ordering::SeqCst);
        let Ok(write_half) = stream.try_clone() else { continue };
        let Ok(keep) = stream.try_clone() else { continue };
        let out = ConnOut::new(queue_cap, window, Some(keep));
        out.push_ctrl(Frame::Hello { version: wire::PROTOCOL_VERSION, window });
        if tx.send(Ev::ClientConn { conn, out: out.clone() }).is_err() {
            break;
        }
        let w_out = out.clone();
        std::thread::spawn(move || w_out.writer_loop(write_half));
        let r_tx = tx.clone();
        std::thread::spawn(move || client_reader(conn, stream, out, r_tx));
    }
}

/// One-shot HTTP/1.1 GET returning the response body.
pub(crate) fn http_get_text(addr: &str, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    let mut buf = String::new();
    stream.read_to_string(&mut buf)?;
    let (head, body) = buf
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response from {addr}{path}"))?;
    let status = head.split_whitespace().nth(1).unwrap_or("");
    if status != "200" {
        return Err(anyhow!("GET {addr}{path}: status {status}"));
    }
    Ok(body.to_string())
}

fn rollup_loop(cfg: RouterConfig, shared: Arc<RollupShared>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        for (i, spec) in cfg.replicas.iter().enumerate() {
            let Some(addr) = spec.metrics_addr.as_deref() else { continue };
            if let Ok(text) = http_get_text(addr, "/snapshot") {
                if let Ok(snap) = MetricsRegistry::decode_text(&text) {
                    shared.last_snaps.lock().expect("snaps lock")[i] = Some(snap);
                }
            }
        }
        let mut fleet = shared.local.lock().expect("local registry lock").snapshot();
        {
            let last = shared.last_snaps.lock().expect("snaps lock");
            for snap in last.iter().flatten() {
                fleet.merge_from(snap);
            }
        }
        *shared.exposition.lock().expect("exposition lock") = fleet.expose_prometheus("sparsespec");
        *shared.snapshot.lock().expect("snapshot lock") = fleet.encode_text();
        std::thread::sleep(Duration::from_millis(cfg.rollup_every_ms.max(10)));
    }
}

// ---------------------------------------------------------------------------
// Public handle
// ---------------------------------------------------------------------------

/// Running router handle, mirroring [`super::Server`].
pub struct Router {
    addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    tx: Sender<Ev>,
    stop: Arc<AtomicBool>,
    core: Option<JoinHandle<Result<RouterSummary>>>,
    aux: Vec<JoinHandle<()>>,
}

impl Router {
    /// Bind the upstream listener, handshake every replica's control
    /// connection (a version mismatch or unreachable replica fails here,
    /// not mid-traffic), and start the core/accept/rollup threads.
    pub fn spawn(cfg: RouterConfig) -> Result<Router> {
        if cfg.replicas.is_empty() {
            return Err(anyhow!("router needs at least one replica"));
        }
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let metrics_listener = match &cfg.metrics_addr {
            Some(a) => Some(TcpListener::bind(a)?),
            None => None,
        };
        let metrics_addr = match &metrics_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let (tx, rx) = channel::<Ev>();
        let stop = Arc::new(AtomicBool::new(false));
        let exposition = Arc::new(Mutex::new(String::new()));
        let snapshot = Arc::new(Mutex::new(MetricsRegistry::new().encode_text()));
        let shared = Arc::new(RollupShared {
            local: Mutex::new(MetricsRegistry::new()),
            last_snaps: Mutex::new(vec![None; cfg.replicas.len()]),
            exposition: exposition.clone(),
            snapshot: snapshot.clone(),
        });

        // control connections, with the Hello version handshake up front
        let mut control: Vec<Option<TcpStream>> = Vec::new();
        for (i, spec) in cfg.replicas.iter().enumerate() {
            let stream = TcpStream::connect(&spec.addr)
                .map_err(|e| anyhow!("replica {i} ({}): connect: {e}", spec.addr))?;
            let _ = stream.set_nodelay(true);
            stream.set_read_timeout(Some(Duration::from_secs(5)))?;
            let mut r = std::io::BufReader::new(
                stream.try_clone().map_err(|e| anyhow!("replica {i}: clone: {e}"))?,
            );
            let hello = wire::read_frame(&mut r)
                .map_err(|e| anyhow!("replica {i}: reading Hello: {e}"))?
                .ok_or_else(|| anyhow!("replica {i}: closed before Hello"))?;
            wire::expect_hello(&hello)
                .map_err(|e| anyhow!("replica {i} rejected ({e}): refusing to route to it"))?;
            stream.set_read_timeout(None)?;
            let tx2 = tx.clone();
            std::thread::spawn(move || {
                // BufReader keeps any bytes past Hello it already pulled
                let mut r = r;
                loop {
                    match wire::read_frame(&mut r) {
                        Ok(Some(f)) => {
                            if tx2.send(Ev::ReplicaFrame { replica: i, conn: 0, frame: f }).is_err() {
                                break;
                            }
                        }
                        _ => break,
                    }
                }
                let _ = tx2.send(Ev::ReplicaClosed { replica: i, conn: 0 });
            });
            control.push(Some(stream));
        }

        let n = cfg.replicas.len();
        let tracer = if cfg.trace_out.is_some() {
            Tracer::new(TraceConfig::on())
        } else {
            Tracer::disabled()
        };
        let core_state = RouterCore {
            policy: RouterPolicy::new(n, cfg.bucket_edges.clone()),
            conns: BTreeMap::new(),
            sessions: BTreeMap::new(),
            by_down: BTreeMap::new(),
            links: BTreeMap::new(),
            control,
            control_open: vec![true; n],
            missed_pings: vec![0; n],
            next_sid: 1,
            draining: false,
            metrics: MetricsRegistry::new(),
            shared: shared.clone(),
            tracer,
            t0: Instant::now(),
            ev_tx: tx.clone(),
            routed: 0,
            resubmitted: 0,
            failed_over: 0,
            cfg: cfg.clone(),
        };
        let core = std::thread::Builder::new()
            .name("sparsespec-router".into())
            .spawn(move || core_state.run(rx))?;

        let mut aux = Vec::new();
        let a_tx = tx.clone();
        let a_stop = stop.clone();
        let (window, qcap) = (cfg.send_window, cfg.send_queue_cap);
        aux.push(
            std::thread::Builder::new()
                .name("sparsespec-router-accept".into())
                .spawn(move || accept_loop(listener, a_tx, a_stop, window, qcap))?,
        );
        if let Some(ml) = metrics_listener {
            let routes = vec![("/metrics", exposition), ("/snapshot", snapshot)];
            let m_stop = stop.clone();
            aux.push(
                std::thread::Builder::new()
                    .name("sparsespec-router-metrics".into())
                    .spawn(move || metrics_http_loop(ml, routes, m_stop))?,
            );
        }
        let r_shared = shared;
        let r_stop = stop.clone();
        let r_cfg = cfg;
        aux.push(
            std::thread::Builder::new()
                .name("sparsespec-router-rollup".into())
                .spawn(move || rollup_loop(r_cfg, r_shared, r_stop))?,
        );
        Ok(Router { addr, metrics_addr, tx, stop, core: Some(core), aux })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Begin the fleet drain (forwards `Shutdown` to every replica).
    pub fn shutdown(&self, abort: bool) {
        let _ = self.tx.send(Ev::Shutdown { abort });
    }

    /// Wait for the drain to complete and return the final summary.
    pub fn join(mut self) -> Result<RouterSummary> {
        let summary = self
            .core
            .take()
            .expect("join called once")
            .join()
            .map_err(|_| anyhow!("router core thread panicked"))??;
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(m) = self.metrics_addr {
            let _ = TcpStream::connect_timeout(&m, Duration::from_millis(200));
        }
        for t in self.aux.drain(..) {
            let _ = t.join();
        }
        Ok(summary)
    }
}

// ---------------------------------------------------------------------------
// Policy unit tests (deterministic, no sockets; twinned by
// python/tests/test_router_port.py)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(n: usize) -> RouterPolicy {
        RouterPolicy::new(n, vec![100, 200])
    }

    #[test]
    fn bucket_edges_partition_costs() {
        let p = policy(2);
        assert_eq!(p.n_buckets(), 3);
        assert_eq!(p.bucket_of(1), 0);
        assert_eq!(p.bucket_of(100), 0, "edges are inclusive upper bounds");
        assert_eq!(p.bucket_of(101), 1);
        assert_eq!(p.bucket_of(200), 1);
        assert_eq!(p.bucket_of(201), 2);
        assert_eq!(p.bucket_of(100_000), 2, "overflow bucket");
    }

    #[test]
    fn least_loaded_within_bucket_not_globally() {
        let mut p = policy(2);
        // replica 0 carries heavy long-bucket load...
        let d = p.route("long-a", 500).unwrap();
        assert_eq!((d.replica, d.bucket), (0, 2));
        // ...so another long session goes to replica 1 (new tenant)
        assert_eq!(p.route("long-b", 400).unwrap().replica, 1);
        // but a *short* session sees equal short-bucket loads (0, 0) and
        // falls to the live-count tie-break: both carry one session, so
        // lowest index wins — bucket-aware, not global-load
        let d = p.route("short-a", 50).unwrap();
        assert_eq!((d.replica, d.bucket), (0, 0));
    }

    #[test]
    fn ties_break_by_live_count_then_index() {
        let mut p = policy(3);
        // equal bucket loads everywhere; live counts 0,0,0 → index 0
        assert_eq!(p.route("t1", 50).unwrap().replica, 0);
        // live 1,0,0 → replica 1
        assert_eq!(p.route("t2", 50).unwrap().replica, 1);
        // loads now 50,50,0 in bucket 0 → replica 2 by load
        assert_eq!(p.route("t3", 50).unwrap().replica, 2);
    }

    #[test]
    fn tenant_stickiness_follows_while_up() {
        let mut p = policy(2);
        let first = p.route("acme", 50).unwrap();
        assert!(!first.sticky);
        // pile opposing load on the *other* replica so least-loaded would
        // pick it — stickiness must win anyway
        for _ in 0..5 {
            p.route("other", 50).unwrap();
        }
        let again = p.route("acme", 50).unwrap();
        assert_eq!(again.replica, first.replica);
        assert!(again.sticky);
    }

    #[test]
    fn stickiness_does_not_follow_into_degraded_or_down() {
        let mut p = policy(2);
        let first = p.route("acme", 50).unwrap();
        assert_eq!(first.replica, 0);
        p.set_health(0, ReplicaHealth::Degraded);
        let moved = p.route("acme", 50).unwrap();
        assert_eq!(moved.replica, 1, "degraded replica gets no new sessions");
        assert!(!moved.sticky);
        // the tenant re-sticks to its new home
        p.set_health(0, ReplicaHealth::Up);
        assert_eq!(p.route("acme", 50).unwrap().replica, 1);
    }

    #[test]
    fn release_rebalances_future_routing() {
        let mut p = policy(2);
        let d0 = p.route("a", 150).unwrap();
        assert_eq!(d0.replica, 0);
        assert_eq!(p.route("b", 150).unwrap().replica, 1);
        // finish replica 0's session: next mid-bucket session goes back
        p.release(d0.replica, d0.bucket, 150);
        assert_eq!(p.route("c", 150).unwrap().replica, 0);
        assert_eq!(p.live_sessions(0), 1);
    }

    #[test]
    fn no_live_replica_routes_none() {
        let mut p = policy(2);
        p.set_health(0, ReplicaHealth::Down);
        p.set_health(1, ReplicaHealth::Degraded);
        assert_eq!(p.route("acme", 50), None);
        p.set_health(1, ReplicaHealth::Up);
        assert!(p.route("acme", 50).is_some());
    }

    #[test]
    fn failover_contract_resubmit_vs_fail_fast() {
        // nothing streamed, nothing buffered → transparent resubmit
        assert_eq!(failover_action(0, 0), FailoverAction::Resubmit);
        // a single forwarded token pins the session to fail-fast
        assert_eq!(failover_action(1, 0), FailoverAction::FailFast);
        assert_eq!(failover_action(42, 3), FailoverAction::FailFast);
        // buffered-but-undelivered tokens also forbid resubmit (the
        // replica already committed output we may re-deliver)
        assert_eq!(failover_action(0, 1), FailoverAction::FailFast);
    }

    #[test]
    fn projected_load_is_cost_weighted() {
        let mut p = RouterPolicy::new(2, vec![1000]);
        // one big session on 0 outweighs two smaller on 1
        assert_eq!(p.route("big", 900).unwrap().replica, 0);
        assert_eq!(p.route("s1", 300).unwrap().replica, 1);
        assert_eq!(p.route("s2", 300).unwrap().replica, 1, "600 < 900");
        assert_eq!(p.route("s3", 300).unwrap().replica, 1, "sticky");
        assert_eq!(p.route("s4", 300).unwrap().replica, 0, "1200 > 900 now");
    }
}
