//! Quickstart: serve a small batch of reasoning requests with SparseSpec
//! (PillarAttn self-speculation), compare against vanilla decoding, then
//! stream one session token-by-token through the serving API.
//!
//!   cargo run --release --example quickstart
//!   (add `make artifacts` + `--features pjrt` for the real XLA path; the
//!    default build serves on the deterministic CPU fallback runtime)


use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig, EngineHandle};
use sparsespec::runtime::Runtime;
use sparsespec::spec::DrafterKind;
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Rc::new(Runtime::load(&dir)?);
    println!(
        "loaded {} artifacts on {} (model: {} params, trained={})",
        rt.cfg.artifacts.len(),
        rt.platform_name(),
        rt.cfg.n_params,
        rt.cfg.trained
    );

    let n_req = 8;
    let mk_reqs = || {
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, 42)
            .offline_batch(n_req)
    };

    // Vanilla autoregressive baseline.
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla))?;
    let rv = vanilla.run(mk_reqs())?;
    println!("{}", rv.summary());

    // SparseSpec: PillarAttn self-speculation, k=8, W=128 (the acceptance-
    // saturation knee of the fig12 sensitivity sweep).
    let mut ours = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 128 }).with_k(8),
    )?;
    let ro = ours.run(mk_reqs())?;
    println!("{}", ro.summary());

    // Losslessness: greedy speculative decoding must reproduce the
    // vanilla outputs token-for-token.
    let mut same = 0usize;
    let mut total = 0usize;
    for (id, out_v) in &rv.outputs {
        let out_o = &ro.outputs[id];
        total += out_v.len().max(out_o.len());
        same += out_v.iter().zip(out_o.iter()).filter(|(a, b)| a == b).count();
    }
    println!(
        "losslessness: {}/{} tokens identical ({:.2}%)",
        same, total, 100.0 * same as f64 / total as f64
    );
    println!(
        "wallclock speedup: {:.2}x | simulated-H100 speedup: {:.2}x",
        rv.wall_s / ro.wall_s,
        rv.sim_s / ro.sim_s
    );

    // ------------------------------------------------------------------
    // Streaming quickstart: submit one session and consume its tokens as
    // verification accepts them (see engine::api for the full surface —
    // EngineDriver adds live arrival processes, TokenSink adds push-style
    // delivery, SessionHandle::cancel stops a generation mid-flight).
    // ------------------------------------------------------------------
    let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
        .k(8)
        .build(&rt.cfg.model)?;
    let mut handle = EngineHandle::new(rt.clone(), cfg)?;
    let req = mk_reqs().remove(0);
    let expect = req.max_new;
    let session = handle.submit(req);
    print!("streaming session {} ({expect} tokens):", session.id());
    let mut chunks = 0usize;
    while handle.step()? {
        let batch = session.drain();
        if !batch.is_empty() {
            chunks += 1;
            print!(" +{}", batch.len());
        }
    }
    let stats = session.stats();
    println!(
        "\n  done: {} tokens in {chunks} increments, ttft={:.4}s, {:.2} accepted/round",
        stats.tokens,
        stats.ttft_s.unwrap_or(0.0),
        stats.mean_accepted_per_round()
    );
    Ok(())
}
