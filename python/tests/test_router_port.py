"""Python twin of the router placement policy in
`rust/src/serving/router.rs` (scale-out serving PR).

Per the working convention (no Rust toolchain in the authoring
container), this twin re-implements the pure routing state machine —
``bucket_of`` / ``route`` / ``release`` / ``failover_action`` — and runs
the same deterministic scenarios as the Rust unit tests, then pins the
load-bearing lines of the Rust source by regex:

* the bucket rule: count of edges strictly below the cost,
* the selection key ``(load[r][bucket], live[r], r)`` over ``Up``
  replicas only,
* tenant stickiness that follows only while the sticky replica is Up,
* the failover contract: resubmit iff ``sent == 0 && pending == 0``,
* the projected-cost formula ``prompt + max_new + 2`` and the default
  bucket edges / health-check knobs of ``RouterConfig``.

If the policy drifts in Rust without a matching edit here, a test below
fails pointing at the divergence.
"""

from __future__ import annotations

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
ROUTER_RS = REPO / "rust" / "src" / "serving" / "router.rs"

UP, DEGRADED, DOWN = "up", "degraded", "down"

# RouterConfig defaults pinned against the Rust source below.
DEFAULT_BUCKET_EDGES = [128, 256, 512]
DEFAULT_PING_EVERY_MS = 500
DEFAULT_DOWN_AFTER_MISSED = 3


class RouterPolicy:
    """Twin of ``router::RouterPolicy`` — pure and deterministic."""

    def __init__(self, replicas: int, bucket_edges: list[int]):
        self.bucket_edges = sorted(set(bucket_edges))
        n_buckets = len(self.bucket_edges) + 1
        self.health = [UP] * replicas
        self.live = [0] * replicas
        self.load = [[0] * n_buckets for _ in range(replicas)]
        self.sticky: dict[str, int] = {}

    def replicas(self) -> int:
        return len(self.health)

    def n_buckets(self) -> int:
        return len(self.bucket_edges) + 1

    def bucket_of(self, cost: int) -> int:
        return sum(1 for e in self.bucket_edges if cost > e)

    def route(self, tenant: str, cost: int):
        """Returns ``(replica, bucket, sticky)`` or ``None``."""
        bucket = self.bucket_of(cost)
        r = self.sticky.get(tenant)
        if r is not None and self.health[r] == UP:
            self.live[r] += 1
            self.load[r][bucket] += cost
            return (r, bucket, True)
        up = [r for r in range(self.replicas()) if self.health[r] == UP]
        if not up:
            return None
        best = min(up, key=lambda r: (self.load[r][bucket], self.live[r], r))
        self.live[best] += 1
        self.load[best][bucket] += cost
        self.sticky[tenant] = best
        return (best, bucket, False)

    def release(self, replica: int, bucket: int, cost: int) -> None:
        self.live[replica] = max(0, self.live[replica] - 1)
        self.load[replica][bucket] = max(0, self.load[replica][bucket] - cost)


def failover_action(sent: int, pending: int) -> str:
    """Twin of ``router::failover_action``."""
    return "resubmit" if sent == 0 and pending == 0 else "fail_fast"


def policy(n: int) -> RouterPolicy:
    return RouterPolicy(n, [100, 200])


# ---------------------------------------------------------------------------
# Scenario twins of the Rust unit tests
# ---------------------------------------------------------------------------

def test_bucket_edges_partition_costs():
    p = policy(2)
    assert p.n_buckets() == 3
    assert p.bucket_of(1) == 0
    assert p.bucket_of(100) == 0  # edges are inclusive upper bounds
    assert p.bucket_of(101) == 1
    assert p.bucket_of(200) == 1
    assert p.bucket_of(201) == 2
    assert p.bucket_of(100_000) == 2  # overflow bucket


def test_least_loaded_within_bucket_not_globally():
    p = policy(2)
    assert p.route("long-a", 500) == (0, 2, False)
    assert p.route("long-b", 400)[0] == 1
    # a short session sees equal short-bucket loads and falls to the
    # live-count tie-break: bucket-aware, not global-load
    assert p.route("short-a", 50) == (0, 0, False)


def test_ties_break_by_live_count_then_index():
    p = policy(3)
    assert p.route("t1", 50)[0] == 0
    assert p.route("t2", 50)[0] == 1
    assert p.route("t3", 50)[0] == 2


def test_tenant_stickiness_follows_while_up():
    p = policy(2)
    first = p.route("acme", 50)
    assert first[2] is False
    for _ in range(5):
        p.route("other", 50)
    again = p.route("acme", 50)
    assert again[0] == first[0]
    assert again[2] is True


def test_stickiness_does_not_follow_into_degraded_or_down():
    p = policy(2)
    assert p.route("acme", 50)[0] == 0
    p.health[0] = DEGRADED
    moved = p.route("acme", 50)
    assert moved[0] == 1 and moved[2] is False
    p.health[0] = UP
    # the tenant re-sticks to its new home
    assert p.route("acme", 50)[0] == 1


def test_release_rebalances_future_routing():
    p = policy(2)
    r0, b0, _ = p.route("a", 150)
    assert r0 == 0
    assert p.route("b", 150)[0] == 1
    p.release(r0, b0, 150)
    assert p.route("c", 150)[0] == 0
    assert p.live[0] == 1


def test_no_live_replica_routes_none():
    p = policy(2)
    p.health[0] = DOWN
    p.health[1] = DEGRADED
    assert p.route("acme", 50) is None
    p.health[1] = UP
    assert p.route("acme", 50) is not None


def test_failover_contract_resubmit_vs_fail_fast():
    assert failover_action(0, 0) == "resubmit"
    assert failover_action(1, 0) == "fail_fast"
    assert failover_action(42, 3) == "fail_fast"
    # buffered-but-undelivered tokens also forbid resubmit
    assert failover_action(0, 1) == "fail_fast"


def test_projected_load_is_cost_weighted():
    p = RouterPolicy(2, [1000])
    assert p.route("big", 900)[0] == 0
    assert p.route("s1", 300)[0] == 1
    assert p.route("s2", 300)[0] == 1  # 600 < 900
    assert p.route("s3", 300)[0] == 1  # sticky
    assert p.route("s4", 300)[0] == 0  # 1200 > 900 now


def test_bucket_edges_are_sorted_and_deduped():
    p = RouterPolicy(2, [200, 100, 200])
    assert p.bucket_edges == [100, 200]
    assert p.n_buckets() == 3


# ---------------------------------------------------------------------------
# Source pins against rust/src/serving/router.rs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rust_src() -> str:
    assert ROUTER_RS.exists(), f"missing {ROUTER_RS}"
    return ROUTER_RS.read_text()


def test_bucket_rule_is_pinned(rust_src):
    # count of edges strictly below the cost
    assert re.search(
        r"bucket_edges\.iter\(\)\.filter\(\|e\| cost > \*\*e\)\.count\(\)", rust_src
    ), "bucket_of rule drifted in router.rs"


def test_selection_key_is_pinned(rust_src):
    # min over Up replicas by (bucket load, live count, index)
    assert re.search(
        r"min_by_key\(\|&r\| \(self\.load\[r\]\[bucket\], self\.live\[r\], r\)\)", rust_src
    ), "least-loaded selection key drifted in router.rs"
    assert "ReplicaHealth::Up" in rust_src


def test_stickiness_follows_only_while_up(rust_src):
    m = re.search(
        r"if let Some\(&r\) = self\.sticky\.get\(tenant\) \{\s*"
        r"if self\.health\[r\] == ReplicaHealth::Up", rust_src,
    )
    assert m, "tenant-stickiness Up guard drifted in router.rs"


def test_failover_contract_is_pinned(rust_src):
    assert re.search(
        r"if sent == 0 && pending == 0 \{\s*FailoverAction::Resubmit", rust_src
    ), "failover_action contract drifted in router.rs"


def test_projected_cost_formula_is_pinned(rust_src):
    assert re.search(
        r"prompt\.len\(\) \+ max_new as usize \+ 2", rust_src
    ), "projected KV cost formula drifted in router.rs"


def test_router_config_defaults_are_pinned(rust_src):
    edges = ", ".join(str(e) for e in DEFAULT_BUCKET_EDGES)
    assert re.search(rf"bucket_edges: vec!\[{edges}\]", rust_src)
    assert re.search(rf"ping_every_ms: {DEFAULT_PING_EVERY_MS}\b", rust_src)
    assert re.search(rf"down_after_missed: {DEFAULT_DOWN_AFTER_MISSED}\b", rust_src)


def test_midstream_failover_uses_typed_replica_down(rust_src):
    # fail-fast surfaces ErrorCode::ReplicaDown and a failed Finished
    assert "ErrorCode::ReplicaDown" in rust_src
    assert re.search(r"Frame::Finished \{ session: sid, reason: 3", rust_src)


def test_router_validates_replica_hello(rust_src):
    # wire hardening satellite: both the synchronous control handshake
    # and delegated-link readers go through wire::expect_hello
    assert rust_src.count("expect_hello") >= 2, (
        "router must validate the replica Hello on control and delegated links"
    )
