//! Host-side model metadata: parses `artifacts/config.json` (the contract
//! written by `python/compile/aot.py`) into typed configs shared by the
//! runtime, the engine and the workload generator.

mod config;

pub use config::{ArtifactInfo, EagleConfig, GrammarConfig, ModelConfig, SystemConfig};
