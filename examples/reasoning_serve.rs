//! The paper's headline experiment in miniature: serve all three reasoning
//! datasets with every training-free system and print the Fig. 10-style
//! comparison table.
//!
//!   cargo run --release --example reasoning_serve [-- --requests 12]

use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::runtime::Runtime;
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rt = Rc::new(Runtime::load(&args.str("artifacts", "artifacts"))?);
    let n = args.usize("requests", 12);
    let systems: Vec<(&str, DrafterKind)> = vec![
        ("vllm", DrafterKind::Vanilla),
        ("vllm-ngram", DrafterKind::NGram { n: 3 }),
        ("magicdec", DrafterKind::Window { w: 128 }),
        ("triforce", DrafterKind::TriForce { w: 64 }),
        ("sparsespec", DrafterKind::Pillar { w: 128 }),
    ];
    println!(
        "{:<14} {:<14} {:>10} {:>12} {:>8} {:>8}",
        "dataset", "system", "wall tok/s", "sim tok/s", "alpha", "acc/rnd"
    );
    for ds in Dataset::all() {
        let mut base = 0.0;
        for (name, d) in &systems {
            let reqs = WorkloadGen::new(
                rt.cfg.grammar.clone(),
                rt.cfg.model.clone(),
                ds,
                42,
            )
            .offline_batch(n);
            let mut eng = Engine::new(rt.clone(), EngineConfig::new(*d).with_k(8))?;
            let r = eng.run(reqs)?;
            if *name == "vllm" {
                base = r.sim_tok_s();
            }
            println!(
                "{:<14} {:<14} {:>10.1} {:>9.1} ({:>4.2}x) {:>8.2} {:>8.2}",
                ds.name(),
                name,
                r.wall_tok_s(),
                r.sim_tok_s(),
                r.sim_tok_s() / base,
                r.accept.alpha(),
                r.accept.mean_accepted()
            );
        }
    }
    Ok(())
}
