# Grammar + RNG golden tests: pin the Python generator to the Rust port
# (rust/src/workload/grammar.rs and rust/src/util/rng.rs carry the same
# constants in their unit tests).
import numpy as np

from compile.data import SplitMix64, TraceGen, training_batch, prompt
from compile.config import GRAMMAR


def test_splitmix_golden():
    # Known first output of SplitMix64(0); same value asserted in Rust.
    assert SplitMix64(0).next_u64() == 0xE220A8397B1DCDAF
    r = SplitMix64(7)
    vals = [r.next_u64() for _ in range(4)]
    assert len(set(vals)) == 4


def test_trace_golden_cross_language():
    # Pinned in rust/src/workload/grammar.rs::grammar_golden_cross_language.
    assert TraceGen(7).take(24) == [
        1, 3, 55, 108, 6, 3, 34, 283, 6, 3, 26, 97, 6, 3, 38, 334, 6, 3,
        33, 185, 6, 3, 59, 124,
    ]
    assert TraceGen(123).take(12) == [1, 3, 59, 204, 6, 3, 56, 335, 6, 3, 18, 96]


def test_queries_return_latest_definition():
    g = GRAMMAR
    toks = TraceGen(42).take(600)
    defs = {}
    i, queries = 0, 0
    while i + 4 < len(toks):
        if toks[i] == g.def_tok:
            defs[toks[i + 1]] = toks[i + 2]
            i += 4
        elif toks[i] == g.qry:
            assert toks[i + 2] == g.eq
            if toks[i + 1] in defs:
                assert toks[i + 3] == defs[toks[i + 1]]
                queries += 1
            i += 5
        else:
            i += 1
    assert queries >= 5


def test_focus_locality():
    g = GRAMMAR
    toks = TraceGen(5).take(3000)
    qslots = []
    i = 0
    while i + 4 < len(toks):
        if toks[i] == g.qry:
            qslots.append(toks[i + 1])
            i += 5
        else:
            i += 1
    same = sum(1 for a, b in zip(qslots, qslots[1:]) if a == b)
    assert same / max(len(qslots) - 1, 1) > 0.5


def test_filler_chains_are_mode_and_position_keyed():
    g = GRAMMAR
    succ = {g.filler_next(340, m, 0) for m in range(g.n_modes)}
    assert len(succ) > 8
    # position-in-run changes the successor too (anti-induction property)
    assert g.filler_next(340, 0, 0) != g.filler_next(340, 0, 1)
    for f in succ:
        assert g.filler_base <= f < g.filler_base + g.n_filler


def test_training_batch_shape_and_range():
    b = training_batch(9, 4, 64)
    assert b.shape == (4, 65)
    assert b.dtype == np.int32
    assert b.min() >= 0 and b.max() < 512
    # deterministic
    b2 = training_batch(9, 4, 64)
    np.testing.assert_array_equal(b, b2)


def test_prompt_bounded_deterministic():
    p1 = prompt(77)
    p2 = prompt(77)
    assert p1 == p2
    assert 16 <= len(p1) <= 32
    assert p1[0] == GRAMMAR.bos
