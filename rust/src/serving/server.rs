//! `sparsespec-server`: the network serving front-end.
//!
//! One engine thread owns the (single-threaded, `Rc`-state) [`Engine`]
//! behind an [`EngineHandle`]; every TCP connection gets a reader thread
//! (frames → control channel) and a writer thread (bounded outbound frame
//! queue → socket).  The engine thread is the only place sessions are
//! touched, so the serving loop stays exactly as deterministic as the
//! in-process API.
//!
//! Traffic policing (the point of this module — not just plumbing):
//!
//! * **Admission control against the KV budget** — a request whose
//!   worst-case footprint (`prompt_pad + max_new + k + 2`) exceeds the
//!   device budget is refused with [`ErrorCode::AdmissionReject`] instead
//!   of queueing forever; queued requests are only released into the
//!   engine while the projected resident footprint fits the budget.
//! * **Load shedding** — when device-KV utilisation crosses
//!   [`ServerConfig::kv_shed_watermark`], new submissions are refused
//!   with [`ErrorCode::KvShed`] while already-admitted sessions run to
//!   completion.
//! * **Per-tenant fairness** — submissions land in bounded per-tenant
//!   queues (overflow → [`ErrorCode::TenantQueueFull`]) drained by
//!   deficit-weighted round-robin ([`WrrQueues`]), so a flooding tenant
//!   cannot starve the others.
//! * **Backpressure on slow readers** — token frames are credit-gated
//!   (see [`super::wire`]): a client that stops granting credit stalls
//!   its connection; after [`ServerConfig::stall_ticks`] serving-loop
//!   ticks the connection's sessions are cancelled
//!   ([`ErrorCode::SlowReader`]) and everyone else keeps streaming.
//! * **Graceful drain** — `Shutdown` (wire frame or [`Server::shutdown`])
//!   stops admissions, serves out the queued + live sessions, flushes
//!   every connection, then returns the final [`RunReport`].
//!
//! Observability rides along unchanged: the engine's `Tracer` and
//! `FaultInjector` are threaded through [`ServerConfig::engine`], and a
//! `/metrics` endpoint serves `MetricsRegistry::expose_prometheus()`
//! verbatim with per-tenant labelled series.

use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::engine::{EngineConfig, EngineHandle, FinishReason, RunReport, SessionHandle};
use crate::metrics::MetricsRegistry;
use crate::runtime::Runtime;
use crate::spec::DrafterKind;
use crate::workload::Request;

use super::wire::{self, ErrorCode, Frame, WireError};

/// Server configuration.  `engine` carries the full [`EngineConfig`] —
/// tracing (`TraceConfig`) and chaos (`FaultConfig`) included — so
/// everything that works in-process works over the wire.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Artifact directory (`Runtime::load`; missing `config.json` falls
    /// back to the synthetic sim model, same as everywhere else).
    pub artifacts: String,
    pub engine: EngineConfig,
    /// Listen address; port 0 binds an ephemeral port (read it back via
    /// [`Server::addr`]).
    pub addr: String,
    /// HTTP `/metrics` listen address (`None` disables the endpoint).
    pub metrics_addr: Option<String>,
    /// Initial token-credit window granted per connection in `Hello`.
    pub send_window: u32,
    /// Bound on each connection's outbound frame queue (control frames
    /// included); tokens are additionally credit-gated.
    pub send_queue_cap: usize,
    /// Serving-loop ticks a connection may sit stalled (undelivered
    /// tokens, zero credit or full queue) before its sessions are
    /// drop-to-cancelled with [`ErrorCode::SlowReader`].
    pub stall_ticks: u64,
    /// Device-KV utilisation fraction above which *new* submissions are
    /// shed with [`ErrorCode::KvShed`].
    pub kv_shed_watermark: f64,
    /// Bound on each tenant's admission queue.
    pub tenant_queue_cap: usize,
    /// Max sessions submitted into the engine at once (0 ⇒ 2 × model
    /// slots).  Keeps the engine's internal queue bounded so WRR order
    /// and KV-aware admission stay in the server's hands.
    pub max_inflight: usize,
    /// Tenant → WRR weight; unlisted tenants weigh 1.0.
    pub tenant_weights: BTreeMap<String, f64>,
    /// Export the engine's Chrome/Perfetto trace here on drain (requires
    /// `engine.trace` enabled).
    pub trace_out: Option<String>,
    /// Refresh the published `/metrics` exposition every N loop ticks.
    pub metrics_publish_every: u64,
    /// Replica index echoed in every `Accepted` frame (the optional
    /// trailing wire field) so clients and the router can attribute
    /// sessions.  `None` (the default) omits the field.
    pub replica_id: Option<u16>,
}

impl ServerConfig {
    pub fn new(artifacts: &str, engine: EngineConfig) -> Self {
        ServerConfig {
            artifacts: artifacts.to_string(),
            engine,
            addr: "127.0.0.1:7433".into(),
            metrics_addr: None,
            send_window: 1024,
            send_queue_cap: 1024 + 64,
            stall_ticks: 2000,
            kv_shed_watermark: 0.85,
            tenant_queue_cap: 64,
            max_inflight: 0,
            tenant_weights: BTreeMap::new(),
            trace_out: None,
            metrics_publish_every: 16,
            replica_id: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Weighted round-robin tenant queues (deficit round-robin)
// ---------------------------------------------------------------------------

/// Bounded per-tenant FIFO queues drained by deficit-weighted round-robin.
///
/// Each admission round visits tenants in name order: a non-empty queue
/// earns its weight in deficit, then releases one item per whole unit of
/// deficit.  Over saturated queues the admitted mix converges to the
/// weight ratio (pinned by the unit tests below and the Python twin in
/// `python/tests/test_serving_port.py`); empty queues forfeit their
/// deficit, so there is no banking across idle periods.
pub struct WrrQueues<T> {
    tenants: BTreeMap<String, TenantQ<T>>,
    weights: BTreeMap<String, f64>,
    cap: usize,
}

struct TenantQ<T> {
    weight: f64,
    deficit: f64,
    q: VecDeque<T>,
}

impl<T> WrrQueues<T> {
    pub fn new(weights: BTreeMap<String, f64>, cap: usize) -> Self {
        WrrQueues { tenants: BTreeMap::new(), weights, cap }
    }

    fn weight_of(&self, tenant: &str) -> f64 {
        let w = self.weights.get(tenant).copied().unwrap_or(1.0);
        if w.is_finite() && w > 0.0 { w } else { 1.0 }
    }

    /// Enqueue; `Err(item)` when the tenant's queue is at capacity.
    pub fn push(&mut self, tenant: &str, item: T) -> std::result::Result<(), T> {
        let w = self.weight_of(tenant);
        let tq = self
            .tenants
            .entry(tenant.to_string())
            .or_insert_with(|| TenantQ { weight: w, deficit: 0.0, q: VecDeque::new() });
        if tq.q.len() >= self.cap {
            return Err(item);
        }
        tq.q.push_back(item);
        Ok(())
    }

    /// One DRR round: visit tenants in name order, top up deficits, pop
    /// while `can_admit` allows.  `can_admit` models a *global* resource
    /// (KV headroom, inflight cap): the first refusal ends the round.
    /// Returns `(tenant, item)` pairs in admission order.
    pub fn admit_round(
        &mut self,
        max: usize,
        mut can_admit: impl FnMut(&T) -> bool,
    ) -> Vec<(String, T)> {
        let mut out = Vec::new();
        for (name, tq) in self.tenants.iter_mut() {
            if tq.q.is_empty() {
                tq.deficit = 0.0; // no banking while idle
                continue;
            }
            tq.deficit += tq.weight;
            while tq.deficit >= 1.0 && out.len() < max {
                let Some(front) = tq.q.front() else { break };
                if !can_admit(front) {
                    return out; // global resource exhausted: end the round
                }
                tq.deficit -= 1.0;
                out.push((name.clone(), tq.q.pop_front().expect("front checked")));
            }
            if out.len() >= max {
                break;
            }
        }
        out
    }

    /// Remove one queued item by predicate (queued-but-unadmitted cancel).
    pub fn remove(&mut self, mut pred: impl FnMut(&T) -> bool) -> Option<T> {
        for tq in self.tenants.values_mut() {
            if let Some(i) = tq.q.iter().position(&mut pred) {
                return tq.q.remove(i);
            }
        }
        None
    }

    pub fn total_len(&self) -> usize {
        self.tenants.values().map(|t| t.q.len()).sum()
    }

    /// `(tenant, depth)` in name order — queue-depth gauges.
    pub fn depths(&self) -> Vec<(String, usize)> {
        self.tenants.iter().map(|(n, t)| (n.clone(), t.q.len())).collect()
    }
}

// ---------------------------------------------------------------------------
// Per-connection outbound queue (bounded, credit-gated for tokens)
// ---------------------------------------------------------------------------

struct OutState {
    q: VecDeque<Frame>,
    credit: u32,
    /// No further frames will be enqueued; writer flushes then exits.
    closed: bool,
    /// Socket write failed / peer gone; everything drops.
    broken: bool,
}

pub(crate) struct ConnOut {
    cap: usize,
    st: Mutex<OutState>,
    cv: Condvar,
    /// Kept for force-shutdown on drain (wakes a blocked peer reader).
    stream: Mutex<Option<TcpStream>>,
}

impl ConnOut {
    pub(crate) fn new(cap: usize, window: u32, stream: Option<TcpStream>) -> Arc<ConnOut> {
        Arc::new(ConnOut {
            cap,
            st: Mutex::new(OutState {
                q: VecDeque::new(),
                credit: window,
                closed: false,
                broken: false,
            }),
            cv: Condvar::new(),
            stream: Mutex::new(stream),
        })
    }

    /// Queue a token frame iff credit and queue space allow.
    pub(crate) fn try_token(&self, f: Frame) -> bool {
        let mut st = self.st.lock().expect("conn out lock");
        if st.closed || st.broken || st.credit == 0 || st.q.len() >= self.cap {
            return false;
        }
        st.credit -= 1;
        st.q.push_back(f);
        self.cv.notify_one();
        true
    }

    /// Queue a control frame (never credit-gated; ignores the cap so
    /// per-session terminal frames cannot deadlock behind a full queue —
    /// control volume is bounded by session count).
    pub(crate) fn push_ctrl(&self, f: Frame) -> bool {
        let mut st = self.st.lock().expect("conn out lock");
        if st.closed || st.broken {
            return false;
        }
        st.q.push_back(f);
        self.cv.notify_one();
        true
    }

    pub(crate) fn add_credit(&self, n: u32) {
        let mut st = self.st.lock().expect("conn out lock");
        st.credit = st.credit.saturating_add(n);
        self.cv.notify_one();
    }

    pub(crate) fn is_broken(&self) -> bool {
        self.st.lock().expect("conn out lock").broken
    }

    /// Flush-and-close: the writer drains the queue then half-closes.
    pub(crate) fn close(&self) {
        self.st.lock().expect("conn out lock").closed = true;
        self.cv.notify_all();
    }

    /// Hard shutdown of the socket (drain finalisation): unblocks the
    /// peer and our reader thread.
    pub(crate) fn force_shutdown(&self) {
        if let Some(s) = self.stream.lock().expect("stream lock").take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    pub(crate) fn writer_loop(self: &Arc<Self>, stream: TcpStream) {
        let mut w = std::io::BufWriter::new(stream);
        loop {
            let batch: Vec<Frame> = {
                let mut st = self.st.lock().expect("conn out lock");
                while st.q.is_empty() && !st.closed && !st.broken {
                    let (g, _) = self
                        .cv
                        .wait_timeout(st, Duration::from_millis(100))
                        .expect("conn out lock");
                    st = g;
                }
                if st.broken {
                    return;
                }
                if st.q.is_empty() && st.closed {
                    break;
                }
                st.q.drain(..).collect()
            };
            for f in &batch {
                if wire::write_frame(&mut w, f).is_err() {
                    self.st.lock().expect("conn out lock").broken = true;
                    return;
                }
            }
            if w.flush().is_err() {
                self.st.lock().expect("conn out lock").broken = true;
                return;
            }
        }
        let _ = w.flush();
        if let Ok(s) = w.into_inner() {
            let _ = s.shutdown(std::net::Shutdown::Write);
        }
    }
}

// ---------------------------------------------------------------------------
// Engine thread
// ---------------------------------------------------------------------------

enum Ctrl {
    Conn { conn: u64, out: Arc<ConnOut> },
    Frame { conn: u64, frame: Frame },
    Closed { conn: u64 },
    Shutdown { abort: bool },
}

struct PendingReq {
    conn: u64,
    session: u64,
    client_req: u64,
    tenant: String,
    req: Request,
}

struct LiveSession {
    client_req: u64,
    conn: u64,
    tenant: String,
    handle: SessionHandle,
    /// Drained from the engine but not yet queued (credit/queue limited).
    pending: VecDeque<i32>,
    /// Token frames queued so far (the wire `index`).
    sent: u32,
}

struct ConnState {
    out: Arc<ConnOut>,
    stall_since: Option<u64>,
}

/// Final state handed back by [`Server::join`].
pub struct ServerSummary {
    pub report: RunReport,
    /// The last-published Prometheus exposition (per-tenant series
    /// included), with the final run-report registry merged in.
    pub exposition: String,
    pub sessions_completed: u64,
    pub sessions_cancelled: u64,
    pub sessions_refused: u64,
}

struct EngineThread {
    cfg: ServerConfig,
    handle: EngineHandle,
    prompt_pad: usize,
    slots: usize,
    conns: BTreeMap<u64, ConnState>,
    live: BTreeMap<u64, LiveSession>,
    queues: WrrQueues<PendingReq>,
    metrics: MetricsRegistry,
    published: Arc<Mutex<String>>,
    /// Lossless snapshot text (`MetricsRegistry::encode_text`) served at
    /// `/snapshot` — the router's fleet-rollup transport.
    published_snap: Arc<Mutex<String>>,
    next_session: u64,
    tick: u64,
    draining: bool,
    completed: u64,
    cancelled: u64,
    refused: u64,
}

impl EngineThread {
    fn refuse(&mut self, conn: u64, req_id: u64, code: ErrorCode, detail: String, tenant: &str) {
        self.refused += 1;
        self.metrics.inc("sessions_refused", &[("code", code.label())], 1.0);
        if !tenant.is_empty() {
            self.metrics
                .inc("sessions_refused", &[("code", code.label()), ("tenant", tenant)], 1.0);
        }
        if let Some(c) = self.conns.get(&conn) {
            c.out.push_ctrl(Frame::Error { req_id, code, detail });
        }
    }

    fn on_submit(
        &mut self,
        conn: u64,
        req_id: u64,
        seed: u64,
        max_new: u32,
        tenant: String,
        drafter: String,
        prompt: Vec<i32>,
    ) {
        if !self.conns.contains_key(&conn) {
            return;
        }
        if self.draining {
            return self.refuse(conn, req_id, ErrorCode::Draining, "server is draining".into(), &tenant);
        }
        if prompt.is_empty() || prompt.len() > self.prompt_pad {
            let d = format!("prompt length {} outside (0, {}]", prompt.len(), self.prompt_pad);
            return self.refuse(conn, req_id, ErrorCode::AdmissionReject, d, &tenant);
        }
        if max_new == 0 {
            return self.refuse(conn, req_id, ErrorCode::AdmissionReject, "max_new == 0".into(), &tenant);
        }
        let budget = self.cfg.engine.kv_budget;
        let worst = self.prompt_pad + max_new as usize + self.cfg.engine.k + 2;
        if worst > budget {
            let d = format!("worst-case {worst} KV tokens can never fit budget {budget}");
            return self.refuse(conn, req_id, ErrorCode::AdmissionReject, d, &tenant);
        }
        let used = self.handle.engine().kv_used_tokens();
        let watermark = self.cfg.kv_shed_watermark;
        if (used as f64) > watermark * budget as f64 {
            let d = format!(
                "kv pressure {:.3} over watermark {watermark:.3}",
                used as f64 / budget as f64
            );
            return self.refuse(conn, req_id, ErrorCode::KvShed, d, &tenant);
        }
        let drafter_kind = if drafter.is_empty() {
            None
        } else {
            match DrafterKind::parse_name(&drafter) {
                Some(k) => Some(k),
                None => {
                    let d = format!("unknown drafter '{drafter}'");
                    return self.refuse(conn, req_id, ErrorCode::DrafterRejected, d, &tenant);
                }
            }
        };
        let session = self.next_session;
        self.next_session += 1;
        let req = Request {
            id: session,
            prompt,
            max_new: max_new as usize,
            arrival_s: self.handle.clock_s(),
            seed,
            drafter: drafter_kind,
        };
        let pend = PendingReq { conn, session, client_req: req_id, tenant: tenant.clone(), req };
        match self.queues.push(&tenant, pend) {
            Ok(()) => {
                self.metrics.inc("sessions_submitted", &[("tenant", &tenant)], 1.0);
                if let Some(c) = self.conns.get(&conn) {
                    c.out.push_ctrl(Frame::Accepted {
                        req_id,
                        session,
                        replica: self.cfg.replica_id,
                    });
                }
            }
            Err(_) => {
                let d = format!("tenant '{tenant}' queue at capacity {}", self.cfg.tenant_queue_cap);
                self.refuse(conn, req_id, ErrorCode::TenantQueueFull, d, &tenant);
            }
        }
    }

    fn cancel_conn_sessions(&mut self, conn: u64, code: Option<ErrorCode>) {
        let victims: Vec<u64> = self
            .live
            .iter()
            .filter(|(_, l)| l.conn == conn)
            .map(|(&s, _)| s)
            .collect();
        for s in victims {
            if let Some(l) = self.live.get_mut(&s) {
                l.handle.cancel();
                l.pending.clear();
                if let (Some(code), Some(c)) = (code, self.conns.get(&conn)) {
                    c.out.push_ctrl(Frame::Error {
                        req_id: l.client_req,
                        code,
                        detail: format!("session {s} dropped: {}", code.label()),
                    });
                }
            }
        }
        // queued-but-unadmitted requests from this connection die too
        while let Some(p) = self.queues.remove(|p| p.conn == conn) {
            self.cancelled += 1;
            self.metrics.inc("sessions_cancelled", &[("tenant", &p.tenant)], 1.0);
            if let Some(c) = self.conns.get(&conn) {
                c.out.push_ctrl(Frame::Finished { session: p.session, reason: 1, tokens: 0 });
            }
        }
    }

    fn on_ctrl(&mut self, msg: Ctrl) {
        match msg {
            Ctrl::Conn { conn, out } => {
                if self.draining {
                    out.push_ctrl(Frame::Error {
                        req_id: 0,
                        code: ErrorCode::Draining,
                        detail: "server is draining".into(),
                    });
                    out.close();
                    return;
                }
                self.metrics.inc("connections_total", &[], 1.0);
                self.conns.insert(conn, ConnState { out, stall_since: None });
            }
            Ctrl::Closed { conn } => {
                self.cancel_conn_sessions(conn, None);
                if let Some(c) = self.conns.remove(&conn) {
                    c.out.close();
                }
            }
            Ctrl::Shutdown { abort } => self.begin_drain(abort),
            Ctrl::Frame { conn, frame } => match frame {
                Frame::Submit { req_id, seed, max_new, tenant, drafter, prompt } => {
                    self.on_submit(conn, req_id, seed, max_new, tenant, drafter, prompt)
                }
                Frame::Cancel { session } => {
                    if let Some(l) = self.live.get_mut(&session) {
                        if l.conn == conn {
                            l.handle.cancel();
                            l.pending.clear();
                        }
                    } else if let Some(p) =
                        self.queues.remove(|p| p.session == session && p.conn == conn)
                    {
                        self.cancelled += 1;
                        self.metrics.inc("sessions_cancelled", &[("tenant", &p.tenant)], 1.0);
                        if let Some(c) = self.conns.get(&conn) {
                            c.out.push_ctrl(Frame::Finished { session, reason: 1, tokens: 0 });
                        }
                    }
                }
                Frame::Credit { n } => {
                    if let Some(c) = self.conns.get(&conn) {
                        c.out.add_credit(n);
                        // granting credit ends a stall immediately
                    }
                    if let Some(c) = self.conns.get_mut(&conn) {
                        c.stall_since = None;
                    }
                }
                Frame::Ping { nonce } => {
                    if let Some(c) = self.conns.get(&conn) {
                        c.out.push_ctrl(Frame::Pong { nonce });
                    }
                }
                Frame::Shutdown { abort } => self.begin_drain(abort),
                other => {
                    // server→client kinds arriving at the server are a
                    // protocol violation; answer typed, keep serving.
                    if let Some(c) = self.conns.get(&conn) {
                        c.out.push_ctrl(Frame::Error {
                            req_id: 0,
                            code: ErrorCode::Protocol,
                            detail: format!("unexpected frame kind 0x{:02x}", other.kind()),
                        });
                    }
                }
            },
        }
    }

    fn begin_drain(&mut self, abort: bool) {
        self.draining = true;
        if abort {
            let sessions: Vec<u64> = self.live.keys().copied().collect();
            for s in sessions {
                if let Some(l) = self.live.get_mut(&s) {
                    l.handle.cancel();
                    l.pending.clear();
                }
            }
            // flush queued-but-unadmitted work as cancelled
            while let Some(p) = self.queues.remove(|_| true) {
                self.cancelled += 1;
                self.metrics.inc("sessions_cancelled", &[("tenant", &p.tenant)], 1.0);
                if let Some(c) = self.conns.get(&p.conn) {
                    c.out.push_ctrl(Frame::Finished { session: p.session, reason: 1, tokens: 0 });
                }
            }
        }
    }

    /// Release queued requests into the engine: one DRR round bounded by
    /// the inflight cap and the projected KV footprint.
    fn admit(&mut self) {
        let est = self.prompt_pad + self.cfg.engine.k + 2;
        let budget = self.cfg.engine.kv_budget;
        let max_inflight = if self.cfg.max_inflight == 0 {
            self.slots * 2
        } else {
            self.cfg.max_inflight
        };
        let mut inflight = self.live.len();
        // Sessions admitted but not yet generating still owe their
        // worst-case prompt footprint to the projection.
        let unstarted = self
            .live
            .values()
            .filter(|l| l.handle.tokens_delivered() == 0 && !l.handle.is_finished())
            .count();
        let mut projected = self.handle.engine().kv_used_tokens() + unstarted * est;
        let admitted = self.queues.admit_round(usize::MAX, |_req| {
            if inflight < max_inflight && projected + est <= budget {
                inflight += 1;
                projected += est;
                true
            } else {
                false
            }
        });
        for (tenant, p) in admitted {
            let h = self.handle.submit(p.req);
            self.metrics.inc("sessions_admitted", &[("tenant", &tenant)], 1.0);
            self.live.insert(
                p.session,
                LiveSession {
                    client_req: p.client_req,
                    conn: p.conn,
                    tenant,
                    handle: h,
                    pending: VecDeque::new(),
                    sent: 0,
                },
            );
        }
    }

    /// Move accepted tokens to connection queues (credit-gated), emit
    /// terminal frames, fold finished sessions into the metrics, and run
    /// the stall clock on blocked connections.
    fn deliver(&mut self) {
        let mut blocked_conns: Vec<u64> = Vec::new();
        let mut finished: Vec<u64> = Vec::new();
        for (&sid, l) in self.live.iter_mut() {
            for t in l.handle.drain() {
                l.pending.push_back(t);
            }
            let Some(c) = self.conns.get(&l.conn) else {
                // connection vanished: cancel, nothing to deliver to
                l.handle.cancel();
                l.pending.clear();
                if l.handle.is_finished() {
                    finished.push(sid);
                }
                continue;
            };
            if c.out.is_broken() {
                l.handle.cancel();
                l.pending.clear();
            }
            let mut streamed = 0u64;
            while let Some(&tok) = l.pending.front() {
                let f = Frame::Token { session: sid, index: l.sent, token: tok };
                if c.out.try_token(f) {
                    l.pending.pop_front();
                    l.sent += 1;
                    streamed += 1;
                } else {
                    blocked_conns.push(l.conn);
                    break;
                }
            }
            if streamed > 0 {
                self.metrics.inc("tokens_streamed", &[("tenant", &l.tenant)], streamed as f64);
            }
            if l.handle.is_finished() && l.pending.is_empty() {
                finished.push(sid);
            }
        }

        // stall clock: a connection is stalled while any of its sessions
        // has undeliverable tokens; past the allowance it is dropped
        let mut stalled_out: Vec<u64> = Vec::new();
        for (&cid, c) in self.conns.iter_mut() {
            if blocked_conns.contains(&cid) {
                let since = *c.stall_since.get_or_insert(self.tick);
                if self.tick.saturating_sub(since) > self.cfg.stall_ticks {
                    c.stall_since = None;
                    stalled_out.push(cid);
                }
            } else {
                c.stall_since = None;
            }
        }
        for cid in stalled_out {
            self.metrics.inc("slow_reader_drops", &[], 1.0);
            self.cancel_conn_sessions(cid, Some(ErrorCode::SlowReader));
        }

        for sid in finished {
            let Some(l) = self.live.remove(&sid) else { continue };
            let reason = l.handle.finish_reason().expect("finished session has a reason");
            let tenant = l.tenant.clone();
            let by: &[(&str, &str)] = &[("tenant", &tenant)];
            match reason {
                FinishReason::Completed => {
                    self.completed += 1;
                    self.metrics.inc("sessions_completed", by, 1.0);
                }
                FinishReason::Cancelled => {
                    self.cancelled += 1;
                    self.metrics.inc("sessions_cancelled", by, 1.0);
                }
                FinishReason::Rejected => {
                    let detail = l.handle.reject_reason().unwrap_or_default();
                    self.refused += 1;
                    self.metrics
                        .inc("sessions_refused", &[("code", "drafter_rejected"), ("tenant", &tenant)], 1.0);
                    if let Some(c) = self.conns.get(&l.conn) {
                        c.out.push_ctrl(Frame::Error {
                            req_id: l.client_req,
                            code: ErrorCode::DrafterRejected,
                            detail,
                        });
                    }
                }
                FinishReason::Failed => {
                    let detail = l.handle.failure_reason().unwrap_or_default();
                    self.metrics.inc("sessions_failed", by, 1.0);
                    if let Some(c) = self.conns.get(&l.conn) {
                        c.out.push_ctrl(Frame::Error {
                            req_id: l.client_req,
                            code: ErrorCode::EngineFault,
                            detail,
                        });
                    }
                }
            }
            let st = l.handle.stats();
            if let Some(t) = st.ttft_s {
                self.metrics.observe("ttft_s", by, t);
            }
            if let Some(t) = st.ttft_sim_s() {
                self.metrics.observe("ttft_sim_s", by, t);
            }
            self.metrics.hist_mut("inter_token_s", by).merge(&st.inter_token_s);
            if !st.drafter.is_empty() {
                self.metrics.inc(
                    "sessions_finished",
                    &[("tenant", &tenant), ("drafter", &st.drafter)],
                    1.0,
                );
            }
            if let Some(c) = self.conns.get(&l.conn) {
                c.out.push_ctrl(Frame::Finished {
                    session: sid,
                    reason: wire::reason_to_wire(reason),
                    tokens: l.sent,
                });
            }
        }
    }

    fn publish_metrics(&mut self) {
        let mut m = self.metrics.snapshot();
        let budget = self.cfg.engine.kv_budget;
        let used = self.handle.engine().kv_used_tokens();
        m.set_gauge("kv_used_tokens", &[], used as f64);
        if budget < usize::MAX / 4 {
            m.set_gauge("kv_utilization", &[], used as f64 / budget as f64);
        }
        m.set_gauge("sessions_live", &[], self.live.len() as f64);
        m.set_gauge("draining", &[], self.draining as u64 as f64);
        for (tenant, depth) in self.queues.depths() {
            m.set_gauge("queue_depth", &[("tenant", &tenant)], depth as f64);
        }
        *self.published.lock().expect("exposition lock") = m.expose_prometheus("sparsespec");
        *self.published_snap.lock().expect("snapshot lock") = m.encode_text();
    }

    fn run(mut self, ctrl_rx: Receiver<Ctrl>) -> Result<ServerSummary> {
        loop {
            let busy = !self.live.is_empty() || self.queues.total_len() > 0;
            if !busy && !self.draining {
                // idle: block briefly instead of spinning
                match ctrl_rx.recv_timeout(Duration::from_millis(10)) {
                    Ok(m) => self.on_ctrl(m),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => self.draining = true,
                }
            }
            loop {
                match ctrl_rx.try_recv() {
                    Ok(m) => self.on_ctrl(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            self.tick += 1;
            self.admit();
            let progressed = self.handle.step()?;
            self.deliver();
            if self.tick % self.cfg.metrics_publish_every.max(1) == 0 {
                self.publish_metrics();
            }
            if self.draining && self.live.is_empty() && self.queues.total_len() == 0 {
                break;
            }
            if !progressed && !self.live.is_empty() {
                // engine idle but frames still undeliverable (credit):
                // don't spin hot against the stall clock
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // finalise: trace export, final metrics, close every connection
        if let Some(path) = &self.cfg.trace_out {
            let json = self.handle.tracer().export_chrome_string();
            if let Err(e) = std::fs::write(path, json) {
                eprintln!("serving: trace export to {path} failed: {e}");
            }
        }
        let report = self.handle.report();
        let mut final_m = self.metrics.snapshot();
        final_m.merge_from(&report.registry());
        let exposition = final_m.expose_prometheus("sparsespec");
        *self.published.lock().expect("exposition lock") = exposition.clone();
        *self.published_snap.lock().expect("snapshot lock") = final_m.encode_text();
        for c in self.conns.values() {
            c.out.close();
            c.out.force_shutdown();
        }
        Ok(ServerSummary {
            report,
            exposition,
            sessions_completed: self.completed,
            sessions_cancelled: self.cancelled,
            sessions_refused: self.refused,
        })
    }
}

// ---------------------------------------------------------------------------
// Listener / reader / metrics threads + the public Server handle
// ---------------------------------------------------------------------------

fn reader_loop(conn: u64, stream: TcpStream, out: Arc<ConnOut>, ctrl: Sender<Ctrl>) {
    let mut r = std::io::BufReader::new(stream);
    loop {
        match wire::read_frame(&mut r) {
            Ok(Some(f)) => {
                if ctrl.send(Ctrl::Frame { conn, frame: f }).is_err() {
                    break;
                }
            }
            Ok(None) => break,
            Err(WireError::Io(_)) => break,
            Err(e) => {
                // malformed frame: typed refusal, then hang up (framing is
                // lost, resync is not possible on a length-prefixed stream)
                out.push_ctrl(Frame::Error {
                    req_id: 0,
                    code: ErrorCode::Protocol,
                    detail: e.to_string(),
                });
                out.close();
                break;
            }
        }
    }
    let _ = ctrl.send(Ctrl::Closed { conn });
}

fn accept_loop(
    listener: TcpListener,
    ctrl: Sender<Ctrl>,
    stop: Arc<AtomicBool>,
    window: u32,
    queue_cap: usize,
) {
    let next_conn = AtomicU64::new(1);
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let _ = stream.set_nodelay(true);
        let conn = next_conn.fetch_add(1, Ordering::SeqCst);
        let Ok(write_half) = stream.try_clone() else { continue };
        let Ok(keep) = stream.try_clone() else { continue };
        let out = ConnOut::new(queue_cap, window, Some(keep));
        out.push_ctrl(Frame::Hello { version: wire::PROTOCOL_VERSION, window });
        if ctrl.send(Ctrl::Conn { conn, out: out.clone() }).is_err() {
            break;
        }
        let w_out = out.clone();
        std::thread::spawn(move || w_out.writer_loop(write_half));
        let r_ctrl = ctrl.clone();
        std::thread::spawn(move || reader_loop(conn, stream, out, r_ctrl));
    }
}

/// Minimal HTTP/1.1 responder serving published text documents by path.
/// The server mounts `/metrics` (Prometheus exposition, verbatim) and
/// `/snapshot` (lossless `MetricsRegistry::encode_text`, the router's
/// rollup transport); the router reuses the same loop for its fleet
/// endpoints.  Each route matches exactly or with a `?query` suffix.
pub(crate) fn metrics_http_loop(
    listener: TcpListener,
    routes: Vec<(&'static str, Arc<Mutex<String>>)>,
    stop: Arc<AtomicBool>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(mut stream) = stream else { continue };
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let mut buf = [0u8; 1024];
        let mut head = Vec::new();
        loop {
            match stream.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    head.extend_from_slice(&buf[..n]);
                    if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        let line = String::from_utf8_lossy(&head);
        let path = line.split_whitespace().nth(1).unwrap_or("");
        let hit = routes.iter().find(|(p, _)| {
            path == *p || (path.starts_with(p) && path.as_bytes().get(p.len()) == Some(&b'?'))
        });
        let (status, body) = match hit {
            Some((_, doc)) => ("200 OK", doc.lock().expect("published doc lock").clone()),
            None => {
                let served: Vec<&str> = routes.iter().map(|(p, _)| *p).collect();
                ("404 Not Found", format!("served paths: {}\n", served.join(" ")))
            }
        };
        let resp = format!(
            "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let _ = stream.write_all(resp.as_bytes());
        let _ = stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Running server handle: bound addresses, drain trigger, join.
pub struct Server {
    addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    ctrl: Sender<Ctrl>,
    stop: Arc<AtomicBool>,
    engine_thread: Option<JoinHandle<Result<ServerSummary>>>,
    aux_threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind, start the engine/listener/metrics threads, return once the
    /// engine is constructed (so config errors surface here, not later).
    pub fn spawn(cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let metrics_listener = match &cfg.metrics_addr {
            Some(a) => Some(TcpListener::bind(a)?),
            None => None,
        };
        let metrics_addr = match &metrics_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let (ctrl_tx, ctrl_rx) = channel::<Ctrl>();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let published = Arc::new(Mutex::new(String::new()));
        let published_snap = Arc::new(Mutex::new(MetricsRegistry::new().encode_text()));
        let stop = Arc::new(AtomicBool::new(false));

        let engine_published = published.clone();
        let engine_snap = published_snap.clone();
        let engine_cfg = cfg.clone();
        let engine_thread = std::thread::Builder::new()
            .name("sparsespec-engine".into())
            .spawn(move || -> Result<ServerSummary> {
                let weights = engine_cfg.tenant_weights.clone();
                let queue_cap = engine_cfg.tenant_queue_cap;
                let et = (|| -> Result<EngineThread> {
                    let rt = Rc::new(Runtime::load(&engine_cfg.artifacts)?);
                    let prompt_pad = rt.cfg.model.prompt_pad;
                    let slots = rt.cfg.model.slots;
                    let handle = EngineHandle::new(rt, engine_cfg.engine.clone())?;
                    Ok(EngineThread {
                        cfg: engine_cfg,
                        handle,
                        prompt_pad,
                        slots,
                        conns: BTreeMap::new(),
                        live: BTreeMap::new(),
                        queues: WrrQueues::new(weights, queue_cap),
                        metrics: MetricsRegistry::new(),
                        published: engine_published,
                        published_snap: engine_snap,
                        next_session: 1,
                        tick: 0,
                        draining: false,
                        completed: 0,
                        cancelled: 0,
                        refused: 0,
                    })
                })();
                match et {
                    Ok(et) => {
                        let _ = ready_tx.send(Ok(()));
                        et.run(ctrl_rx)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        Err(e)
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow!("server startup: {e}"))?;

        let mut aux = Vec::new();
        let a_ctrl = ctrl_tx.clone();
        let a_stop = stop.clone();
        let window = cfg.send_window;
        let qcap = cfg.send_queue_cap;
        aux.push(
            std::thread::Builder::new()
                .name("sparsespec-accept".into())
                .spawn(move || accept_loop(listener, a_ctrl, a_stop, window, qcap))?,
        );
        if let Some(ml) = metrics_listener {
            let routes = vec![("/metrics", published.clone()), ("/snapshot", published_snap.clone())];
            let m_stop = stop.clone();
            aux.push(
                std::thread::Builder::new()
                    .name("sparsespec-metrics".into())
                    .spawn(move || metrics_http_loop(ml, routes, m_stop))?,
            );
        }
        Ok(Server {
            addr,
            metrics_addr,
            ctrl: ctrl_tx,
            stop,
            engine_thread: Some(engine_thread),
            aux_threads: aux,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Begin drain (`abort` cancels live sessions first).  Idempotent.
    pub fn shutdown(&self, abort: bool) {
        let _ = self.ctrl.send(Ctrl::Shutdown { abort });
    }

    /// Wait for the drain to complete and return the final summary.
    /// (Call [`Server::shutdown`] first, or have a client send the
    /// `Shutdown` frame.)
    pub fn join(mut self) -> Result<ServerSummary> {
        let summary = self
            .engine_thread
            .take()
            .expect("join called once")
            .join()
            .map_err(|_| anyhow!("engine thread panicked"))??;
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept loops
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(m) = self.metrics_addr {
            let _ = TcpStream::connect_timeout(&m, Duration::from_millis(200));
        }
        for t in self.aux_threads.drain(..) {
            let _ = t.join();
        }
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(weights: &[(&str, f64)], cap: usize) -> WrrQueues<u32> {
        let w = weights.iter().map(|(n, v)| (n.to_string(), *v)).collect();
        WrrQueues::new(w, cap)
    }

    #[test]
    fn wrr_respects_weights_under_saturation() {
        let mut qs = q(&[("a", 2.0), ("b", 1.0)], 1000);
        for i in 0..300u32 {
            qs.push("a", i).unwrap();
            qs.push("b", 1000 + i).unwrap();
        }
        let mut got_a = 0usize;
        let mut got_b = 0usize;
        for _ in 0..60 {
            for (t, _) in qs.admit_round(3, |_| true) {
                if t == "a" {
                    got_a += 1;
                } else {
                    got_b += 1;
                }
            }
        }
        assert_eq!(got_a + got_b, 180);
        let ratio = got_a as f64 / got_b as f64;
        assert!((ratio - 2.0).abs() < 0.2, "2:1 weights must admit ~2:1 (got {ratio})");
    }

    #[test]
    fn wrr_is_fifo_within_a_tenant_and_bounded() {
        let mut qs = q(&[], 3);
        qs.push("t", 1).unwrap();
        qs.push("t", 2).unwrap();
        qs.push("t", 3).unwrap();
        assert_eq!(qs.push("t", 4), Err(4), "cap is enforced");
        // weight 1 ⇒ one item per round; items come out in FIFO order
        let mut admitted: Vec<u32> = Vec::new();
        for _ in 0..3 {
            admitted.extend(qs.admit_round(10, |_| true).into_iter().map(|(_, v)| v));
        }
        assert_eq!(admitted, vec![1, 2, 3], "FIFO per tenant");
        assert_eq!(qs.total_len(), 0);
    }

    #[test]
    fn wrr_global_refusal_ends_the_round() {
        // 'a' weighs 3: it asks for three admissions, exhausting the
        // global allowance; 'b' is then refused, which ends the round
        let mut qs = q(&[("a", 3.0)], 100);
        for i in 0..10u32 {
            qs.push("a", i).unwrap();
            qs.push("b", 100 + i).unwrap();
        }
        let mut allowed = 3;
        let admitted = qs.admit_round(usize::MAX, |_| {
            if allowed > 0 {
                allowed -= 1;
                true
            } else {
                false
            }
        });
        assert!(admitted.iter().all(|(t, _)| t == "a"), "{admitted:?}");
        assert_eq!(admitted.len(), 3, "refusal stops everything, nothing is lost");
        assert_eq!(qs.total_len(), 17);
    }

    #[test]
    fn wrr_idle_tenants_do_not_bank_deficit() {
        let mut qs = q(&[("a", 4.0)], 100);
        // several empty rounds must not accumulate deficit for 'a'
        for _ in 0..10 {
            assert!(qs.admit_round(10, |_| true).is_empty());
        }
        for i in 0..10u32 {
            qs.push("a", i).unwrap();
            qs.push("b", 100 + i).unwrap();
        }
        let first: Vec<String> =
            qs.admit_round(usize::MAX, |_| true).into_iter().map(|(t, _)| t).collect();
        let a_first = first.iter().filter(|t| *t == "a").count();
        assert!(a_first <= 4, "one round grants at most the weight (got {a_first})");
    }

    #[test]
    fn conn_out_credit_gating_and_ctrl_bypass() {
        let out = ConnOut::new(4, 2, None);
        let tok = |i| Frame::Token { session: 1, index: i, token: 7 };
        assert!(out.try_token(tok(0)));
        assert!(out.try_token(tok(1)));
        assert!(!out.try_token(tok(2)), "credit exhausted");
        assert!(out.push_ctrl(Frame::Pong { nonce: 1 }), "control bypasses credit");
        out.add_credit(1);
        assert!(out.try_token(tok(2)));
        assert!(!out.try_token(tok(3)), "queue cap binds even with credit");
        out.close();
        assert!(!out.push_ctrl(Frame::Pong { nonce: 2 }), "closed refuses everything");
    }
}
