//! Metrics: counters, streaming histograms, per-phase timers, and report
//! emission (markdown + CSV).  Built from scratch (no external crates).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

pub mod registry;

pub use registry::{MetricKey, MetricsRegistry};

/// Reservoir-less exact histogram: keeps all samples (our runs are at most
/// a few hundred thousand samples, so exactness is cheaper than HDR-style
/// bucketing and gives exact p50/p99 for the reports).
///
/// Reads — including `percentile`/`max` — take `&self`: the lazy sort
/// happens behind interior mutability, so report readers (examples, the
/// session-metrics aggregator) no longer clone whole histograms just to
/// look at p50/p99.  Single-threaded by design (like the engine).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: RefCell<Vec<f64>>,
    sorted: Cell<bool>,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.samples.get_mut().push(v);
        self.sorted.set(false);
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &Histogram) {
        if other.is_empty() {
            return;
        }
        self.samples
            .get_mut()
            .extend_from_slice(&other.samples.borrow());
        self.sorted.set(false);
    }

    /// Copy of the raw samples (ascending iff a sorted read happened).
    pub fn samples(&self) -> Vec<f64> {
        self.samples.borrow().clone()
    }

    pub fn len(&self) -> usize {
        self.samples.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.borrow().is_empty()
    }

    pub fn mean(&self) -> f64 {
        let s = self.samples.borrow();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().sum::<f64>() / s.len() as f64
    }

    pub fn std(&self) -> f64 {
        let s = self.samples.borrow();
        if s.len() < 2 {
            return 0.0;
        }
        let m = s.iter().sum::<f64>() / s.len() as f64;
        (s.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (s.len() - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples
            .borrow()
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .borrow()
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&self) {
        if !self.sorted.get() {
            self.samples
                .borrow_mut()
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted.set(true);
        }
    }

    /// Exact percentile (nearest-rank). `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.samples.borrow().len();
        if n == 0 {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = ((p / 100.0) * (n - 1) as f64).round() as usize;
        self.samples.borrow()[rank.min(n - 1)]
    }

    pub fn sum(&self) -> f64 {
        self.samples.borrow().iter().sum()
    }
}

/// Canonical `"name[tag]"` key for per-group metric breakdowns (e.g. the
/// per-drafter acceptance/TTFT columns of a mixed-drafter pool).
pub fn keyed(name: &str, tag: &str) -> String {
    format!("{name}[{tag}]")
}

/// Shared TTFT / inter-token report block over a registry's `ttft_s` and
/// `inter_token_s` histograms (empty string when neither has samples).
/// One renderer for the `reasoning_serve` / `online_chat` examples and
/// the `sparsespec-client` load generator, so latency lines stay
/// comparable across all three.
pub fn latency_block(m: &MetricsRegistry, labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    if let Some(ttft) = m.histogram("ttft_s", labels) {
        if !ttft.is_empty() {
            let _ = writeln!(
                out,
                "  TTFT:        p50={:.4}s p99={:.4}s max={:.4}s (n={})",
                ttft.percentile(50.0),
                ttft.percentile(99.0),
                ttft.max(),
                ttft.len()
            );
        }
    }
    if let Some(itl) = m.histogram("inter_token_s", labels) {
        if !itl.is_empty() {
            let _ = writeln!(
                out,
                "  inter-token: p50={:.5}s p99={:.5}s (n={})",
                itl.percentile(50.0),
                itl.percentile(99.0),
                itl.len()
            );
        }
    }
    out
}

/// Fixed-width right-aligned p50 table cell with an `n/a` guard for
/// missing/empty histograms — the other half of the shared report
/// rendering (the per-system / per-drafter summary tables).
pub fn p50_cell(
    m: &MetricsRegistry,
    name: &str,
    labels: &[(&str, &str)],
    width: usize,
    prec: usize,
) -> String {
    match m.histogram(name, labels) {
        Some(h) if !h.is_empty() => format!("{:>width$.prec$}", h.percentile(50.0)),
        _ => format!("{:>width$}", "n/a"),
    }
}

/// Named counters + histograms + monotonically-sampled traces.
#[derive(Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
    /// Time-series traces (iteration-indexed), e.g. GEMM batch size per
    /// iteration for Fig. 14 or memory utilisation for Fig. 5.
    pub traces: BTreeMap<String, Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += by;
    }

    pub fn set(&mut self, name: &str, v: f64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    /// `observe` into the `"name[tag]"` breakdown histogram (see [`keyed`]).
    pub fn observe_keyed(&mut self, name: &str, tag: &str, v: f64) {
        self.observe(&keyed(name, tag), v);
    }

    /// `inc` on the `"name[tag]"` breakdown counter (see [`keyed`]).
    pub fn inc_keyed(&mut self, name: &str, tag: &str, by: f64) {
        self.inc(&keyed(name, tag), by);
    }

    pub fn trace(&mut self, name: &str, v: f64) {
        self.traces.entry(name.to_string()).or_default().push(v);
    }

    pub fn hist(&mut self, name: &str) -> &mut Histogram {
        self.histograms.entry(name.to_string()).or_default()
    }

    /// Fold another `Metrics` into this one: counters add, histograms
    /// merge samples, traces concatenate.
    pub fn merge_from(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
        for (k, t) in &other.traces {
            self.traces.entry(k.clone()).or_default().extend_from_slice(t);
        }
    }

    /// Run `f`, recording its wallclock (seconds) into histogram `name`.
    /// The phase-timer idiom used by the bench harness for hot-path
    /// accounting (e.g. PillarAttn selection).
    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        r
    }

    /// Render a compact markdown report.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            let _ = writeln!(out, "| counter | value |\n|---|---|");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "| {k} | {v:.4} |");
            }
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(
                out,
                "\n| histogram | n | mean | p50 | p99 | max |\n|---|---|---|---|---|---|"
            );
            for (k, h) in &self.histograms {
                let (n, mean, max) = (h.len(), h.mean(), h.max());
                let p50 = h.percentile(50.0);
                let p99 = h.percentile(99.0);
                let _ = writeln!(
                    out,
                    "| {k} | {n} | {mean:.4} | {p50:.4} | {p99:.4} | {max:.4} |"
                );
            }
        }
        out
    }

    /// Dump one trace as CSV (`iter,value`).
    pub fn trace_csv(&self, name: &str) -> String {
        let mut out = String::from("iter,value\n");
        if let Some(t) = self.traces.get(name) {
            for (i, v) in t.iter().enumerate() {
                let _ = writeln!(out, "{i},{v}");
            }
        }
        out
    }
}

/// Scoped wall-clock timer: `let _t = Stopwatch::new(); ... t.secs()`.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.min(), 1.0);
    }

    #[test]
    fn counters_and_traces() {
        let mut m = Metrics::new();
        m.inc("tokens", 5.0);
        m.inc("tokens", 3.0);
        assert_eq!(m.get("tokens"), 8.0);
        m.trace("bs", 4.0);
        m.trace("bs", 6.0);
        assert_eq!(m.traces["bs"], vec![4.0, 6.0]);
        let csv = m.trace_csv("bs");
        assert!(csv.contains("1,6"));
    }

    #[test]
    fn markdown_report_renders() {
        let mut m = Metrics::new();
        m.inc("a", 1.0);
        m.observe("lat", 0.5);
        m.observe("lat", 1.5);
        let md = m.to_markdown();
        assert!(md.contains("| a | 1.0000 |"));
        assert!(md.contains("| lat | 2 |"));
    }

    #[test]
    fn time_records_and_returns() {
        let mut m = Metrics::new();
        let v = m.time("scope", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(m.histograms["scope"].len(), 1);
        assert!(m.histograms["scope"].max() >= 0.0);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }

    #[test]
    fn percentile_takes_shared_ref_and_interleaves_with_record() {
        let mut h = Histogram::default();
        for i in 0..10 {
            h.record((9 - i) as f64);
        }
        let r = &h; // shared reads only
        assert_eq!(r.percentile(0.0), 0.0);
        assert_eq!(r.percentile(100.0), 9.0);
        // recording after a sorted read invalidates and re-sorts lazily
        h.record(100.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert_eq!(h.len(), 11);
    }

    #[test]
    fn latency_block_renders_and_guards_empty() {
        let empty = MetricsRegistry::new();
        assert_eq!(latency_block(&empty, &[]), "");
        let mut m = MetricsRegistry::new();
        m.observe("ttft_s", &[], 0.25);
        m.observe("inter_token_s", &[], 0.001);
        m.observe("inter_token_s", &[], 0.003);
        let text = latency_block(&m, &[]);
        assert!(text.contains("TTFT:        p50=0.2500s"), "{text}");
        assert!(text.contains("inter-token: p50="), "{text}");
        assert!(text.contains("(n=2)"), "{text}");
        // labelled series are independent of the aggregate
        assert_eq!(latency_block(&m, &[("tenant", "a")]), "");
    }

    #[test]
    fn p50_cell_formats_and_falls_back() {
        let mut m = MetricsRegistry::new();
        m.observe("ttft_s", &[], 1.5);
        assert_eq!(p50_cell(&m, "ttft_s", &[], 12, 4), "      1.5000");
        assert_eq!(p50_cell(&m, "ttft_s", &[("d", "x")], 12, 4), "         n/a");
        assert_eq!(p50_cell(&m, "missing", &[], 8, 2), "     n/a");
    }

    #[test]
    fn keyed_breakdowns_land_next_to_aggregates() {
        let mut m = Metrics::new();
        m.observe("ttft_s", 0.5);
        m.observe_keyed("ttft_s", "pillar_w64", 0.5);
        m.inc_keyed("sessions_completed", "ngram_n3", 1.0);
        assert_eq!(keyed("ttft_s", "pillar_w64"), "ttft_s[pillar_w64]");
        assert_eq!(m.histograms["ttft_s[pillar_w64]"].len(), 1);
        assert_eq!(m.get("sessions_completed[ngram_n3]"), 1.0);
    }

    #[test]
    fn metrics_merge_from_accumulates() {
        let mut a = Metrics::new();
        a.inc("n", 2.0);
        a.observe("lat", 1.0);
        let mut b = Metrics::new();
        b.inc("n", 3.0);
        b.observe("lat", 5.0);
        b.trace("t", 7.0);
        a.merge_from(&b);
        assert_eq!(a.get("n"), 5.0);
        assert_eq!(a.histograms["lat"].len(), 2);
        assert_eq!(a.traces["t"], vec![7.0]);
    }

    #[test]
    fn merge_folds_samples() {
        let mut a = Histogram::default();
        a.record(1.0);
        let mut b = Histogram::default();
        b.record(3.0);
        b.record(5.0);
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.max(), 5.0);
        assert_eq!(b.len(), 2, "merge must not drain the source");
    }
}
