//! Substrate utilities built from scratch for this environment (the offline
//! registry only carries the `xla` closure — no tokio / clap / serde / rand /
//! proptest / criterion; DESIGN.md §1 documents the substitution).

pub mod alloc;
pub mod cli;
pub mod json;
pub mod ptest;
pub mod rng;
pub mod threadpool;
