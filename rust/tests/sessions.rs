//! Session-API integration tests: the tentpole contract of the streaming
//! redesign.
//!
//! * `Engine::run` is a thin wrapper over submit + drive — bit-identical
//!   `RunReport.outputs` on a fixed seed (pinned across several seeds and
//!   drafters, and against the arrival-interleaved driver under greedy
//!   decoding).
//! * Tokens arrive incrementally: sessions observe partial outputs while
//!   the engine is still busy (TTFT strictly precedes completion).
//! * `cancel()` mid-generation releases the slot and KV pages and leaves
//!   every other session's output untouched.


use std::cell::RefCell;
use std::rc::Rc;

use sparsespec::engine::{
    Engine, EngineConfig, EngineDriver, EngineHandle, FinishReason, TokenEvent,
};
use sparsespec::runtime::Runtime;
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::workload::{Dataset, Request, WorkloadGen};

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs = WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, seed)
        .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

/// The acceptance-criterion pin: legacy `Engine::run` and the session API
/// produce bit-identical outputs on the same trace and seed — across
/// seeds, and for both the vanilla and self-speculative drafters.
#[test]
fn run_is_a_bit_identical_wrapper_over_submit_drive() {
    let rt = runtime();
    for seed in [1u64, 42, 1337] {
        for drafter in [DrafterKind::Vanilla, DrafterKind::Pillar { w: 64 }] {
            let reqs = small_requests(&rt, 5, 40, seed);
            let mut legacy = Engine::new(rt.clone(), EngineConfig::new(drafter).with_k(8)).unwrap();
            let rl = legacy.run(reqs.clone()).unwrap();

            let mut handle =
                EngineHandle::new(rt.clone(), EngineConfig::new(drafter).with_k(8)).unwrap();
            let sessions: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
            handle.drive().unwrap();
            let rs = handle.report();

            assert_eq!(rl.outputs, rs.outputs, "seed={seed} {drafter:?}");
            assert_eq!(rl.tokens_generated, rs.tokens_generated);
            assert_eq!(rl.iterations, rs.iterations);
            assert_eq!(rl.requests_done, rs.requests_done);
            // and each session's incremental stream equals the batch output
            for (sess, req) in sessions.iter().zip(&reqs) {
                assert_eq!(sess.finish_reason(), Some(FinishReason::Completed));
                assert_eq!(&sess.drain(), &rl.outputs[&req.id], "stream != output");
                let st = sess.stats();
                assert_eq!(st.tokens, rl.outputs[&req.id].len());
                assert!(st.rounds > 0 || st.tokens <= 1);
            }
        }
    }
}

/// Arrival-interleaved driving (requests admitted on the serving clock)
/// must still produce the batch outputs under greedy decoding.
#[test]
fn arrival_interleaved_driver_matches_batch_outputs() {
    let rt = runtime();
    let mk_gen = || {
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::NonReasoningAime, 5)
    };
    let trace = mk_gen().online_trace(3.0, 8.0);
    assert!(trace.len() >= 4, "trace too small to be meaningful");

    let cfg = || EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8);
    let mut legacy = Engine::new(rt.clone(), cfg()).unwrap();
    let rl = legacy.run(trace.clone()).unwrap();

    let mut driver = EngineDriver::with_arrivals(
        EngineHandle::new(rt.clone(), cfg()).unwrap(),
        mk_gen().online_arrivals(3.0, 8.0),
    );
    driver.drive().unwrap();
    assert_eq!(driver.sessions().len(), trace.len());
    let rs = driver.report();
    assert_eq!(rl.outputs, rs.outputs);
    // the driver advanced the serving clock at least to the last arrival
    let last = trace.last().unwrap().arrival_s;
    assert!(rs.sim_s >= last, "clock {} never reached arrival {last}", rs.sim_s);
    // pruning drops finished sessions but keeps their stats aggregated
    let before = driver.session_metrics();
    assert_eq!(driver.prune_finished(), trace.len());
    assert!(driver.sessions().is_empty());
    let after = driver.session_metrics();
    assert_eq!(
        after.get("sessions_completed") as usize,
        trace.len(),
        "pruned stats lost"
    );
    assert_eq!(before.get("sessions_completed"), after.get("sessions_completed"));
}

/// Streaming is incremental: under the unified schedule a session's first
/// token lands while the engine is still busy, and strictly before the
/// session (and the run) completes.
#[test]
fn ttft_strictly_precedes_completion_under_unified() {
    let rt = runtime();
    let cfg = EngineConfig::new(DrafterKind::Pillar { w: 64 })
        .with_k(8)
        .with_schedule(Schedule::Unified, false);
    let mut handle = EngineHandle::new(rt.clone(), cfg).unwrap();
    let sessions: Vec<_> = small_requests(&rt, 6, 48, 7)
        .into_iter()
        .map(|r| handle.submit(r))
        .collect();
    let mut saw_partial_while_busy = false;
    loop {
        let busy = handle.step().unwrap();
        if !busy {
            break;
        }
        // some session mid-stream: tokens out, not finished
        if sessions.iter().any(|s| s.tokens_delivered() > 0 && !s.is_finished()) {
            saw_partial_while_busy = true;
        }
    }
    assert!(saw_partial_while_busy, "no incremental delivery observed");
    for s in &sessions {
        let st = s.stats();
        let first = st.first_token_sim_s.expect("first token recorded");
        let fin = st.finished_sim_s.expect("finish recorded");
        assert!(first < fin, "ttft {first} !< completion {fin}");
        assert!(st.ttft_s.is_some());
        assert!(st.mean_accepted_per_round() >= 0.0);
    }
}

/// Mid-generation cancellation releases the slot and KV pages through the
/// retire path, later work proceeds in the freed capacity, and no other
/// session's output changes.
#[test]
fn cancel_mid_generation_releases_capacity_and_isolates() {
    let rt = runtime();
    let cfg = || EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8);
    let mut reqs = small_requests(&rt, 6, 56, 21);
    // pin the victim to a long generation so "mid-generation" is
    // unambiguous (a round delivers at most k+1 tokens, so the cancel
    // lands far from completion)
    reqs[2].max_new = 56;

    // reference without any cancellation
    let mut reference = Engine::new(rt.clone(), cfg()).unwrap();
    let rr = reference.run(reqs.clone()).unwrap();

    let mut handle = EngineHandle::new(rt.clone(), cfg()).unwrap();
    let sessions: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
    let victim = sessions[2].clone();
    // step until the victim is visibly mid-generation, then cancel
    while victim.tokens_delivered() < 4 {
        assert!(handle.step().unwrap(), "victim never got 4 tokens");
    }
    assert!(!victim.is_finished());
    victim.cancel();
    handle.drive().unwrap();

    assert_eq!(victim.finish_reason(), Some(FinishReason::Cancelled));
    let delivered = victim.tokens_delivered();
    assert!(delivered >= 4 && delivered < rr.outputs[&victim.id()].len());
    // all KV accounting returned to zero once everyone retired
    assert_eq!(handle.engine().kv_used_tokens(), 0);

    // a session submitted after the cancel still completes (freed slot
    // is reusable)
    let mut late = small_requests(&rt, 1, 24, 99);
    late[0].id = 1000;
    let late_sess = handle.submit(late.remove(0));
    handle.drive().unwrap();
    assert_eq!(late_sess.finish_reason(), Some(FinishReason::Completed));

    let report = handle.report();
    assert_eq!(report.requests_cancelled, 1);
    assert!(!report.outputs.contains_key(&victim.id()));
    for (id, out) in &rr.outputs {
        if *id == victim.id() {
            continue;
        }
        assert_eq!(out, &report.outputs[id], "cancel disturbed request {id}");
    }
}

/// Cancelling a request that is still queued (never admitted) finishes the
/// session with zero tokens and leaves the rest untouched.
#[test]
fn cancel_queued_request_before_admission() {
    let rt = runtime();
    let slots = rt.cfg.model.slots;
    // more requests than slots so the tail stays queued at step 1
    let reqs = small_requests(&rt, slots + 3, 24, 31);
    let mut handle =
        EngineHandle::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let sessions: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
    let queued = sessions.last().unwrap().clone();
    queued.cancel(); // before any step: still in the admission queue
    handle.drive().unwrap();
    assert_eq!(queued.finish_reason(), Some(FinishReason::Cancelled));
    assert_eq!(queued.tokens_delivered(), 0);
    let report = handle.report();
    assert_eq!(report.requests_cancelled, 1);
    assert_eq!(report.requests_done, reqs.len() - 1);
    assert_eq!(handle.engine().kv_used_tokens(), 0);
}

/// Push-style delivery: a TokenSink observes the same stream the pull side
/// drains, terminated by a Finished event.
#[test]
fn token_sink_sees_full_stream_and_finish() {
    let rt = runtime();
    let mut handle =
        EngineHandle::new(rt.clone(), EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8))
            .unwrap();
    let mut reqs = small_requests(&rt, 1, 32, 3);
    let events: Rc<RefCell<Vec<TokenEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink_events = events.clone();
    let session = handle.submit_with_sink(
        reqs.remove(0),
        Box::new(move |_id: u64, ev: &TokenEvent| sink_events.borrow_mut().push(*ev)),
    );
    handle.drive().unwrap();
    let evs = events.borrow();
    let toks: Vec<i32> = evs
        .iter()
        .filter_map(|e| match e {
            TokenEvent::Token { token, .. } => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(toks, session.drain(), "push and pull streams differ");
    assert!(matches!(
        evs.last(),
        Some(TokenEvent::Finished { reason: FinishReason::Completed })
    ));
    // indices are the 0-based output positions, in order
    for (i, e) in evs.iter().filter(|e| matches!(e, TokenEvent::Token { .. })).enumerate() {
        if let TokenEvent::Token { index, .. } = e {
            assert_eq!(*index, i);
        }
    }
}
