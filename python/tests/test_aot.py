# AOT path tests: lowering produces parseable HLO text with the right
# entry signature; config export is complete for the Rust side.
import json

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.config import MODEL as cfg, export_json


def test_hlo_text_lowering_smoke():
    spec = lambda s, d=jnp.float32: jax.ShapeDtypeStruct(s, d)
    NP = model.n_params(cfg)
    S, T, L, Hkv, D = cfg.slots, cfg.max_seq, cfg.layers, cfg.kv_heads, cfg.head_dim
    low = jax.jit(model.make_draft(cfg)).lower(
        spec((NP,)), spec((L, S, T, Hkv, D)), spec((L, S, T, Hkv, D)),
        spec((S,), jnp.int32), spec((S,), jnp.int32),
        spec((S, L, Hkv, cfg.draft_budget), jnp.int32), spec((S,), jnp.int32),
    )
    text = aot.to_hlo_text(low)
    # HLO text, not a serialized proto (the xla-0.5.1 compatibility rule)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # entry takes 7 parameters
    assert text.count("parameter(") >= 7


def test_config_export_complete():
    doc = json.loads(export_json())
    for key in ("model", "grammar", "eagle"):
        assert key in doc
    m = doc["model"]
    for f in ("vocab", "hidden", "layers", "slots", "max_seq", "spec_k",
              "draft_budget", "verify_q_variants", "draft_w_variants"):
        assert f in m, f
    g = doc["grammar"]
    for f in ("mode_base", "n_modes", "focus_query_prob", "focus_switch_prob",
              "mode_mul", "mode_add"):
        assert f in g, f
    assert len(g["mode_mul"]) == g["n_modes"]


def test_vanilla_variant_present():
    # verify_q1 is the vanilla autoregressive baseline artifact
    assert 1 in cfg.verify_q_variants
    assert cfg.spec_k + 1 in cfg.verify_q_variants
