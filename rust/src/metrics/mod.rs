//! Metrics: counters, streaming histograms, per-phase timers, and report
//! emission (markdown + CSV).  Built from scratch (no external crates).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

/// Reservoir-less exact histogram: keeps all samples (our runs are at most
/// a few hundred thousand samples, so exactness is cheaper than HDR-style
/// bucketing and gives exact p50/p99 for the reports).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank). `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = ((p / 100.0) * (self.samples.len() - 1) as f64).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Named counters + histograms + monotonically-sampled traces.
#[derive(Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
    /// Time-series traces (iteration-indexed), e.g. GEMM batch size per
    /// iteration for Fig. 14 or memory utilisation for Fig. 5.
    pub traces: BTreeMap<String, Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += by;
    }

    pub fn set(&mut self, name: &str, v: f64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    pub fn trace(&mut self, name: &str, v: f64) {
        self.traces.entry(name.to_string()).or_default().push(v);
    }

    pub fn hist(&mut self, name: &str) -> &mut Histogram {
        self.histograms.entry(name.to_string()).or_default()
    }

    /// Run `f`, recording its wallclock (seconds) into histogram `name`.
    /// The phase-timer idiom used by the bench harness for hot-path
    /// accounting (e.g. PillarAttn selection).
    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        r
    }

    /// Render a compact markdown report.
    pub fn to_markdown(&mut self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            let _ = writeln!(out, "| counter | value |\n|---|---|");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "| {k} | {v:.4} |");
            }
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(
                out,
                "\n| histogram | n | mean | p50 | p99 | max |\n|---|---|---|---|---|---|"
            );
            let names: Vec<String> = self.histograms.keys().cloned().collect();
            for k in names {
                let h = self.histograms.get_mut(&k).unwrap();
                let (n, mean, max) = (h.len(), h.mean(), h.max());
                let p50 = h.percentile(50.0);
                let p99 = h.percentile(99.0);
                let _ = writeln!(
                    out,
                    "| {k} | {n} | {mean:.4} | {p50:.4} | {p99:.4} | {max:.4} |"
                );
            }
        }
        out
    }

    /// Dump one trace as CSV (`iter,value`).
    pub fn trace_csv(&self, name: &str) -> String {
        let mut out = String::from("iter,value\n");
        if let Some(t) = self.traces.get(name) {
            for (i, v) in t.iter().enumerate() {
                let _ = writeln!(out, "{i},{v}");
            }
        }
        out
    }
}

/// Scoped wall-clock timer: `let _t = Stopwatch::new(); ... t.secs()`.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.min(), 1.0);
    }

    #[test]
    fn counters_and_traces() {
        let mut m = Metrics::new();
        m.inc("tokens", 5.0);
        m.inc("tokens", 3.0);
        assert_eq!(m.get("tokens"), 8.0);
        m.trace("bs", 4.0);
        m.trace("bs", 6.0);
        assert_eq!(m.traces["bs"], vec![4.0, 6.0]);
        let csv = m.trace_csv("bs");
        assert!(csv.contains("1,6"));
    }

    #[test]
    fn markdown_report_renders() {
        let mut m = Metrics::new();
        m.inc("a", 1.0);
        m.observe("lat", 0.5);
        m.observe("lat", 1.5);
        let md = m.to_markdown();
        assert!(md.contains("| a | 1.0000 |"));
        assert!(md.contains("| lat | 2 |"));
    }

    #[test]
    fn time_records_and_returns() {
        let mut m = Metrics::new();
        let v = m.time("scope", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(m.histograms["scope"].len(), 1);
        assert!(m.histograms["scope"].max() >= 0.0);
    }

    #[test]
    fn empty_histogram_safe() {
        let mut h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }
}
