//! Arena-backed step buffers shared by every runtime backend.
//!
//! The serving hot loop calls a runner step function (draft / verify /
//! sparse-verify / eagle / prefill) several times per iteration.  Before
//! the raw-speed pass each call allocated fresh output `Vec`s (logits plus,
//! for verify, a `slots × layers × kv_heads × max_seq` attention dump) —
//! pure allocator churn, since the consumer always finishes with the
//! buffers before the next step runs.  [`StepArena`] replaces that with
//! buffers sized **once** from [`ModelConfig`] at runner construction:
//! each step writes into the arena and the caller reads borrowed views
//! back through `ModelRunner::logits()` / `ModelRunner::dump()`.
//!
//! Capacity is the worst case over every step shape, so no step ever
//! resizes:
//!
//! * `logits`: `slots × q_max × vocab`, where `q_max` covers every
//!   compiled `verify_q` variant, the TriForce sparse-verify shape
//!   (`spec_k + 1`) and the single-row draft/prefill/eagle shape.
//! * `dump`: `slots × layers × kv_heads × max_seq` (dense verify only).
//! * `vis`: one visibility bitmask word-row per slot
//!   (`ceil(max_seq / 64)` words) — the sparse-attention kernels build it
//!   once per call and test positions in O(1) instead of scanning the
//!   index row per position.
//!
//! [`ArtifactNames`] is the other per-call allocation killed here: step
//! functions used to `format!("draft_w{w}")` / `format!("verify_q{q}")` on
//! every invocation; the names are a pure function of the config's variant
//! lists, so they are rendered once up front and borrowed thereafter.

use std::collections::BTreeMap;

use crate::model::ModelConfig;

/// Reusable step-output buffers (see module docs).  Owned by the
/// `ModelRunner` of each backend; views are handed out by the runner's
/// `logits()` / `dump()` accessors after a step fills them.
pub struct StepArena {
    pub(crate) logits: Vec<f32>,
    pub(crate) dump: Vec<f32>,
    /// Per-slot visibility bitmasks, `words_per_slot` u64 words per slot.
    pub(crate) vis: Vec<u64>,
    pub(crate) words_per_slot: usize,
    /// Valid prefix of `logits` written by the most recent step.
    pub(crate) logits_len: usize,
    /// Valid prefix of `dump` written by the most recent dense verify.
    pub(crate) dump_len: usize,
}

impl StepArena {
    pub fn new(m: &ModelConfig) -> Self {
        let q_max = m
            .verify_q_variants
            .iter()
            .copied()
            .chain([m.spec_k + 1, 1])
            .max()
            .unwrap_or(1);
        let words_per_slot = m.max_seq.div_ceil(64);
        StepArena {
            logits: vec![0.0; m.slots * q_max * m.vocab],
            dump: vec![0.0; m.slots * m.layers * m.kv_heads * m.max_seq],
            vis: vec![0; m.slots * words_per_slot],
            words_per_slot,
            logits_len: 0,
            dump_len: 0,
        }
    }

    /// The logits view of the most recent step.
    pub fn logits(&self) -> &[f32] {
        &self.logits[..self.logits_len]
    }

    /// The attention-mass dump of the most recent dense verify.
    pub fn dump(&self) -> &[f32] {
        &self.dump[..self.dump_len]
    }

    /// Total capacity in f32 elements (steady-state allocation tests pin
    /// this against reallocation).
    pub fn capacity_elems(&self) -> usize {
        self.logits.capacity() + self.dump.capacity()
    }
}

/// Pre-rendered artifact names for every compiled variant, so the hot
/// path never formats a name.  Misses (a `w`/`q` outside the config's
/// variant lists) are a validation error in every backend, so lookups on
/// the serving path always hit.
pub struct ArtifactNames {
    draft: BTreeMap<usize, String>,
    verify: BTreeMap<usize, String>,
}

impl ArtifactNames {
    pub fn new(m: &ModelConfig) -> Self {
        let draft = m
            .draft_w_variants
            .iter()
            .map(|&w| (w, format!("draft_w{w}")))
            .collect();
        let verify = m
            .verify_q_variants
            .iter()
            .map(|&q| (q, format!("verify_q{q}")))
            .collect();
        ArtifactNames { draft, verify }
    }

    pub fn draft(&self, w: usize) -> Option<&str> {
        self.draft.get(&w).map(String::as_str)
    }

    pub fn verify(&self, q: usize) -> Option<&str> {
        self.verify.get(&q).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    #[test]
    fn arena_covers_every_step_shape() {
        let m = SystemConfig::synthetic("a").model;
        let a = StepArena::new(&m);
        let q_max = m.verify_q_variants.iter().copied().max().unwrap().max(m.spec_k + 1);
        assert!(a.logits.len() >= m.slots * q_max * m.vocab);
        assert_eq!(a.dump.len(), m.slots * m.layers * m.kv_heads * m.max_seq);
        assert_eq!(a.vis.len(), m.slots * m.max_seq.div_ceil(64));
        assert!(a.logits().is_empty(), "no step ran yet");
    }

    #[test]
    fn names_cover_config_variants() {
        let m = SystemConfig::synthetic("a").model;
        let n = ArtifactNames::new(&m);
        for &w in &m.draft_w_variants {
            assert_eq!(n.draft(w).unwrap(), format!("draft_w{w}"));
        }
        for &q in &m.verify_q_variants {
            assert_eq!(n.verify(q).unwrap(), format!("verify_q{q}"));
        }
        assert!(n.draft(63).is_none());
    }
}
