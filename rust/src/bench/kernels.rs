//! Fig. 15 — fused vs sequential vs naive-batch attention.
//!
//! Two data sources, combined (DESIGN.md §1 fused-kernel substitution):
//!
//! 1. **Measured**: wallclock of the real artifacts on this CPU —
//!    `draft_w64` (sparse template), `verify_q9` (dense template) — giving
//!    the per-launch costs of the *Sequential* strategy, and the
//!    `draft_w256`-as-dense cost standing in for the one-size-fits-all
//!    *Naive Batch* template (every row pays the widest gather).
//! 2. **Modelled**: the `DeviceModel` launch-overhead + bandwidth account
//!    of the three strategies at paper scale, which is where the 1.3x /
//!    1.8x shape comes from on a real accelerator.
//!
//! The Pallas fused kernel itself (python/compile/kernels/fused_attn.py)
//! is numerics-verified against both paths in pytest; interpret-mode
//! wallclock is not a TPU proxy, hence the split here.

use super::BenchCtx;
use crate::metrics::Metrics;
use crate::perfmodel::DeviceModel;
use crate::runtime::ModelRunner;
use crate::spec::{select_into, IndexPolicy, PillarState, SelectScratch};
use crate::util::json::{arr, num, obj, s as jstr, Json};
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::fmt::Write as _;
use std::hint::black_box;
use std::time::Instant;

pub fn fig15_fused_kernel(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 15: fused vs sequential vs naive-batch attention");
    let rt = ctx.rt()?;
    let m = rt.cfg.model.clone();
    let mut runner = ModelRunner::new(rt.clone())?;
    let s = m.slots;
    let k = m.spec_k;
    let q = k + 1;

    // Warm both artifacts, then measure steady-state call time.
    let token = vec![5i32; s];
    let pos = vec![64i32; s];
    let active = vec![1i32; s];
    let w = m.draft_budget;
    let idx: Vec<i32> = (0..s * m.layers * m.kv_heads * w)
        .map(|i| (i % 64) as i32)
        .collect();
    let vt = vec![5i32; s * q];
    let qv = vec![q as i32; s];

    let reps = 5;
    runner.draft(w, &token, &pos, &idx, &active)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        runner.draft(w, &token, &pos, &idx, &active)?;
    }
    let t_draft = t0.elapsed().as_secs_f64() / reps as f64;

    runner.verify(q, &vt, &pos, &qv, &active)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        runner.verify(q, &vt, &pos, &qv, &active)?;
    }
    let t_verify = t0.elapsed().as_secs_f64() / reps as f64;

    // Naive batch: every row pays the dense/widest template.  Measured
    // stand-in: the W=256 gather draft (widest sparse tile) + dense call.
    let w_wide = 256;
    let idx_wide: Vec<i32> = (0..s * m.layers * m.kv_heads * w_wide)
        .map(|i| (i % 64) as i32)
        .collect();
    runner.draft(w_wide, &token, &pos, &idx_wide, &active)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        runner.draft(w_wide, &token, &pos, &idx_wide, &active)?;
    }
    let t_wide = t0.elapsed().as_secs_f64() / reps as f64;

    println!(
        "  measured artifact costs: draft(sparse W=64) {:.1}ms, verify(dense) {:.1}ms, widest-template draft {:.1}ms",
        t_draft * 1e3,
        t_verify * 1e3,
        t_wide * 1e3
    );

    // Modelled comparison at paper scale: a mixed batch of B rows, 1/(k+1)
    // of them dense (verify) and the rest sparse.
    let dev = DeviceModel::default();
    let b = 128.0;
    let n_verify = b / (k as f64 + 1.0);
    let n_draft = b - n_verify;
    let bpt = m.kv_bytes_per_token() as f64 * 50.0; // unscale lengths
    let ctx_len = 300.0;
    let sparse_bytes = n_draft * (w as f64) * bpt;
    let dense_bytes = n_verify * ctx_len * bpt;

    // Sequential: two launches, each at its best template (full BW each,
    // but pays two launch latencies + loses inter-kernel pipelining on the
    // small sparse kernel: model that as a fixed efficiency of 50% BW for
    // the sparse launch, per the paper's FlashInfer profile).
    let t_seq = dev.t_attn(dense_bytes) / 0.85
        + dev.t_attn(sparse_bytes) / 0.50
        + 2.0 * dev.t_launch;
    // Naive batch: one launch, one-size-fits-all template: dense rows fine,
    // sparse rows read at dense-template efficiency AND pad to the dense
    // tile (extra bytes), per the paper's "degrade to 50%" profile.
    let t_naive = (dev.t_attn(dense_bytes) + dev.t_attn(n_draft * ctx_len * bpt)) / 0.85
        + dev.t_launch;
    // Fused: one launch, on-chip dispatch to the best template per row:
    // both classes near their peak efficiency (85% / 80%).
    let t_fused = dev.t_attn(dense_bytes) / 0.85
        + dev.t_attn(sparse_bytes) / 0.80
        + dev.t_launch;

    println!(
        "  modelled (paper-scale): sequential {:.2}ms, naive-batch {:.2}ms, fused {:.2}ms",
        t_seq * 1e3,
        t_naive * 1e3,
        t_fused * 1e3
    );
    println!(
        "  fused speedup: {:.2}x vs sequential (paper 1.3x), {:.2}x vs naive batch (paper 1.8x)",
        t_seq / t_fused,
        t_naive / t_fused
    );

    // Kernel-level pallas microbench results, if the python side produced
    // them (make kernel-bench).
    let kb = std::path::Path::new(&rt.cfg.dir).join("kernel_bench.json");
    if let Ok(txt) = std::fs::read_to_string(&kb) {
        if let Ok(j) = crate::util::json::Json::parse(&txt) {
            println!("  pallas interpret-mode microbench (numerics-path, not TPU-time):");
            for key in j.keys() {
                if let Some(v) = j.get(key).and_then(|x| x.as_f64()) {
                    println!("    {key}: {:.2} ms", v * 1e3);
                }
            }
        }
    }

    let mut csv = String::from("strategy,modelled_ms,measured_component_ms\n");
    let _ = writeln!(csv, "sequential,{:.4},{:.4}", t_seq * 1e3, (t_draft + t_verify) * 1e3);
    let _ = writeln!(csv, "naive_batch,{:.4},{:.4}", t_naive * 1e3, (t_wide + t_verify) * 1e3);
    let _ = writeln!(csv, "fused,{:.4},", t_fused * 1e3);
    ctx.save("fig15.csv", &csv)
}

// ---------------------------------------------------------------------
// pillar_select — critical-token selection throughput (EXPERIMENTS.md §Perf)
// ---------------------------------------------------------------------

/// The seed-era selection this PR replaced — the single shared copy lives
/// in `spec::pillar::reference` (also the equivalence-test oracle), so the
/// bench baseline stays *measured* against the exact seed semantics.
use crate::spec::pillar::reference::topk_indices as legacy_topk_indices;

/// Sweep T ∈ {4k, 16k, 64k} × W ∈ {64, 128, 256}: per-call latency of the
/// legacy selection vs the zero-allocation partial-select path, plus the
/// threadpool-parallel multi-head refresh.  Emits BENCH_pillar_select.json.
/// Rep counts scale with `--requests` / BENCH_REQUESTS (CI smoke uses 2).
pub fn pillar_select(ctx: &mut BenchCtx) -> Result<()> {
    println!("pillar_select: legacy full-sort+HashSet vs partial-select+scratch");
    let mut metrics = Metrics::new();
    let scale = ctx.n_requests.max(1);
    let mut entries: Vec<Json> = Vec::new();
    let mut min_speedup_64k = f64::INFINITY;
    println!(
        "  {:<7} {:>5} {:>12} {:>12} {:>12} {:>9} {:>10}",
        "T", "W", "legacy_us", "fast_us", "compose_us", "speedup", "Mcand/s"
    );
    for &t in &[4096usize, 16384, 65536] {
        let mut rng = Xoshiro256::new(ctx.seed ^ t as u64);
        let scores: Vec<f32> = (0..t).map(|_| rng.unit() as f32).collect();
        for &w in &[64usize, 128, 256] {
            let policy = IndexPolicy::pillar(w);
            let mut scratch = SelectScratch::default();
            let mut out = vec![0i32; w];
            // Correctness tie-down before timing anything.
            let legacy_first =
                metrics.time("sanity_s", || legacy_topk_indices(&scores, t, &policy));
            select_into(&scores, t, &policy, &mut scratch, &mut out);
            anyhow::ensure!(out == legacy_first, "selection mismatch at T={t} W={w}");

            let reps = ((1usize << 19) / t).max(1) * scale;
            let t0 = Instant::now();
            for _ in 0..reps {
                black_box(legacy_topk_indices(black_box(&scores), t, &policy));
            }
            let legacy_s = t0.elapsed().as_secs_f64() / reps as f64;

            let fast_reps = reps * 8;
            let t0 = Instant::now();
            for _ in 0..fast_reps {
                select_into(black_box(&scores), t, &policy, &mut scratch, &mut out);
                black_box(&out);
            }
            let fast_s = t0.elapsed().as_secs_f64() / fast_reps as f64;

            // compose_into steady-state (sinks/recent + frozen critical).
            let mut st1 = PillarState::new(1, 1, policy);
            st1.refresh_from(&scores, t, t);
            let mut cout = vec![0i32; w];
            let t0 = Instant::now();
            for _ in 0..fast_reps {
                st1.compose_into(&mut cout, t);
                black_box(&cout);
            }
            let compose_s = t0.elapsed().as_secs_f64() / fast_reps as f64;

            let speedup = legacy_s / fast_s;
            if t == 65536 {
                min_speedup_64k = min_speedup_64k.min(speedup);
            }
            metrics.observe("legacy_us", legacy_s * 1e6);
            metrics.observe("fast_us", fast_s * 1e6);
            metrics.observe("speedup", speedup);
            println!(
                "  {:<7} {:>5} {:>12.1} {:>12.1} {:>12.2} {:>8.1}x {:>10.1}",
                t,
                w,
                legacy_s * 1e6,
                fast_s * 1e6,
                compose_s * 1e6,
                speedup,
                t as f64 / fast_s / 1e6
            );
            entries.push(obj(vec![
                ("t", num(t as f64)),
                ("w", num(w as f64)),
                ("legacy_us", num(legacy_s * 1e6)),
                ("fast_us", num(fast_s * 1e6)),
                ("compose_us", num(compose_s * 1e6)),
                ("speedup", num(speedup)),
                ("cand_per_s", num(t as f64 / fast_s)),
            ]));
        }
    }

    // Threadpool-parallel refresh across (layer, head) pairs of one state.
    let (layers, kv_heads, t, w) = (8usize, 4usize, 16384usize, 128usize);
    let heads = layers * kv_heads;
    let mut rng = Xoshiro256::new(ctx.seed ^ 0xa5a5);
    let dump: Vec<f32> = (0..heads * t).map(|_| rng.unit() as f32).collect();
    let pol = IndexPolicy::pillar(w);
    let mut serial = PillarState::new(layers, kv_heads, pol);
    let mut par = PillarState::new(layers, kv_heads, pol);
    let pool = ThreadPool::new(4);
    serial.refresh_from(&dump, t, t); // warm scratch
    par.refresh_parallel(&dump, t, t, &pool);
    let reps = (scale * 2).max(2);
    let t0 = Instant::now();
    for _ in 0..reps {
        serial.refresh_from(black_box(&dump), t, t);
    }
    let serial_s = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        par.refresh_parallel(black_box(&dump), t, t, &pool);
    }
    let par_s = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "  refresh {heads} heads × T={t}: serial {:.2}ms, pool(4) {:.2}ms ({:.2}x)",
        serial_s * 1e3,
        par_s * 1e3,
        serial_s / par_s
    );
    println!(
        "  min speedup at T=65536: {:.1}x (gate: >= 5x)\n{}",
        min_speedup_64k,
        metrics.to_markdown()
    );

    let json = obj(vec![
        ("experiment", jstr("pillar_select")),
        ("harness", jstr("cargo bench -- pillar_select")),
        ("entries", arr(entries)),
        (
            "parallel_refresh",
            obj(vec![
                ("heads", num(heads as f64)),
                ("t", num(t as f64)),
                ("w", num(w as f64)),
                ("workers", num(pool.workers() as f64)),
                ("serial_ms", num(serial_s * 1e3)),
                ("pool_ms", num(par_s * 1e3)),
                ("scaling", num(serial_s / par_s)),
            ]),
        ),
        ("min_speedup_t65536", num(min_speedup_64k)),
    ]);
    ctx.save("BENCH_pillar_select.json", &json.to_string())?;
    // The acceptance gate is enforced, not just printed — after saving the
    // JSON so a regression still leaves its evidence on disk.  Expected
    // headroom is ~30-50x, so 5x tolerates noisy smoke runners.
    anyhow::ensure!(
        min_speedup_64k >= 5.0,
        "pillar_select gate failed: min speedup at T=65536 is {min_speedup_64k:.2}x, need >= 5x"
    );
    Ok(())
}
