//! sparsespec-server — the network serving front-end.
//!
//! Binds the wire protocol on `--listen`, serves `/metrics` on
//! `--metrics-addr`, and polices traffic: KV-budget admission control,
//! watermark load-shedding, bounded per-tenant queues under weighted
//! round-robin, slow-reader drop-to-cancel, graceful drain on the wire
//! `Shutdown` frame (or SIGINT-free: any client can request the drain).
//!
//! Examples:
//!   sparsespec-server --listen 127.0.0.1:7433 --metrics-addr 127.0.0.1:7434 \
//!       --drafter pillar --k 8 --kv-policy dynamic --kv-budget 2048 \
//!       --shed-watermark 0.85 --tenant-weights acme:2,hobby:1 \
//!       --trace-out reports/server_trace.json

use std::collections::BTreeMap;

use sparsespec::engine::EngineConfig;
use sparsespec::kv_cache::KvPolicy;
use sparsespec::scheduler::Schedule;
use sparsespec::serving::{Server, ServerConfig};
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: sparsespec-server [flags]\n\
         \x20 --listen ADDR          wire-protocol listen address (default 127.0.0.1:7433; port 0 = ephemeral)\n\
         \x20 --metrics-addr ADDR    HTTP /metrics listen address (off unless given)\n\
         \x20 --artifacts DIR        artifact directory (default ./artifacts; falls back to the sim model)\n\
         \x20 --drafter NAME  --w W  --ngram-n N   default drafter (as the sparsespec CLI)\n\
         \x20 --k K  --schedule lockstep|unified  --delayed  --kv-policy conservative|preempt|dynamic\n\
         \x20 --kv-budget TOKENS  --temp T  --seed S  --adaptive-k\n\
         \x20 --shed-watermark F     refuse new work above this KV utilisation (default 0.85)\n\
         \x20 --send-window N        initial per-connection token credit (default 1024)\n\
         \x20 --stall-ticks N        serving-loop ticks before a stalled reader is dropped (default 2000)\n\
         \x20 --tenant-queue-cap N   per-tenant admission queue bound (default 64)\n\
         \x20 --max-inflight N       sessions in the engine at once (default 2x slots)\n\
         \x20 --tenant-weights SPEC  name:weight[,name:weight..] for weighted round-robin\n\
         \x20 --replica-id N         echo this replica id in every Accepted frame (router fleets)\n\
         \x20 --trace-out FILE       export the Perfetto trace on drain  --trace-sample N\n\
         \x20 --fault-plan SPEC  --fault-seed S   chaos injection (as the sparsespec CLI)"
    );
    std::process::exit(2)
}

fn parse_weights(spec: &str) -> Option<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let (name, w) = part.split_once(':')?;
        let w: f64 = w.parse().ok()?;
        if !w.is_finite() || w <= 0.0 {
            return None;
        }
        out.insert(name.to_string(), w);
    }
    Some(out)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.bool("help", false) {
        usage();
    }
    let artifacts = args.str("artifacts", "artifacts");

    // Engine configuration — same flags as `sparsespec serve`.
    let rt_probe = sparsespec::runtime::Runtime::load(&artifacts)?;
    let w = args.usize("w", rt_probe.cfg.model.draft_budget);
    let n = args.usize("ngram-n", 3);
    let drafter =
        DrafterKind::parse(&args.str("drafter", "pillar"), w, n).unwrap_or_else(|| usage());
    let schedule = Schedule::parse(&args.str("schedule", "lockstep")).unwrap_or_else(|| usage());
    let kv_policy = KvPolicy::parse(&args.str("kv-policy", "dynamic")).unwrap_or_else(|| usage());
    let mut cfg = EngineConfig::new(drafter)
        .with_k(args.usize("k", rt_probe.cfg.model.spec_k))
        .with_schedule(schedule, args.bool("delayed", false))
        .with_kv(kv_policy, args.usize("kv-budget", usize::MAX / 2));
    cfg.temperature = args.f64("temp", 0.0) as f32;
    cfg.seed = args.u64("seed", 7);
    cfg.adaptive_k = args.bool("adaptive-k", false);
    // A server runs until drained, not until an experiment's iteration cap.
    cfg.max_iterations = u64::MAX;
    let trace_out = args.opt("trace-out").map(|s| s.to_string());
    if trace_out.is_some() {
        cfg.trace =
            sparsespec::trace::TraceConfig::on().with_sampling(args.usize("trace-sample", 1));
    }
    if let Some(spec) = args.opt("fault-plan") {
        let plan = sparsespec::fault::FaultPlan::parse(spec)?;
        cfg.fault = sparsespec::fault::FaultConfig::new(plan, args.u64("fault-seed", 0));
        println!("chaos: fault plan [{}] seed {}", cfg.fault.plan.to_spec(), cfg.fault.seed);
    }
    drop(rt_probe);

    let mut scfg = ServerConfig::new(&artifacts, cfg);
    scfg.addr = args.str("listen", "127.0.0.1:7433");
    scfg.metrics_addr = args.opt("metrics-addr").map(|s| s.to_string());
    scfg.kv_shed_watermark = args.f64("shed-watermark", 0.85);
    scfg.send_window = args.u64("send-window", 1024) as u32;
    scfg.send_queue_cap = scfg.send_window as usize + 64;
    scfg.stall_ticks = args.u64("stall-ticks", 2000);
    scfg.tenant_queue_cap = args.usize("tenant-queue-cap", 64);
    scfg.max_inflight = args.usize("max-inflight", 0);
    scfg.trace_out = trace_out;
    scfg.replica_id = args.opt("replica-id").map(|s| s.parse::<u16>().unwrap_or_else(|_| usage()));
    if let Some(spec) = args.opt("tenant-weights") {
        scfg.tenant_weights = parse_weights(spec).unwrap_or_else(|| usage());
    }

    let server = Server::spawn(scfg)?;
    println!("sparsespec-server listening on {}", server.addr());
    if let Some(m) = server.metrics_addr() {
        println!("metrics on http://{m}/metrics");
    }
    println!("(drain with the wire Shutdown frame, e.g. sparsespec-client --shutdown)");

    let summary = server.join()?;
    println!(
        "drained: completed={} cancelled={} refused={}",
        summary.sessions_completed, summary.sessions_cancelled, summary.sessions_refused
    );
    println!("{}", summary.report.summary());
    if let Some(path) = args.opt("metrics-out") {
        std::fs::write(path, &summary.exposition)?;
        println!("metrics exposition saved to {path}");
    }
    Ok(())
}
