//! Scale-out router tests over real TCP (loopback, ephemeral ports): the
//! acceptance criteria of the N-replica serving subsystem.
//!
//! * A 2-tenant workload through `sparsespec-router` + 2 real replicas
//!   streams **bit-identical** to a single in-process `Engine::run` of
//!   the union, partitioned by the routing decision — the router adds
//!   placement and transport, never different math.  The fleet
//!   `/metrics` rollup equals the associative merge of the replicas'
//!   individual `/snapshot`s plus the router-local registry.
//! * Killing a replica mid-load yields typed `ReplicaDown` errors only
//!   for its mid-stream sessions, transparently resubmits its queued
//!   ones, and never disturbs the surviving replica's outputs.
//! * A replica whose `Hello` carries the wrong protocol version is
//!   rejected at `Router::spawn` (and by the unchanged client) instead
//!   of being routed to blind.

use std::collections::BTreeMap;
use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::metrics::MetricsRegistry;
use sparsespec::runtime::Runtime;
use sparsespec::serving::{
    run_load, wire, ClientConfig, ErrorCode, Frame, ReplicaSpec, Router, RouterConfig, Server,
    ServerConfig, TenantLoad,
};
use sparsespec::spec::DrafterKind;
use sparsespec::workload::{Dataset, Request, WorkloadGen};
use std::rc::Rc;

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, seed)
            .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

fn reference_outputs(
    rt: &Rc<Runtime>,
    cfg: EngineConfig,
    reqs: Vec<Request>,
) -> BTreeMap<u64, Vec<i32>> {
    let mut eng = Engine::new(rt.clone(), cfg).expect("reference engine");
    eng.run(reqs).expect("reference run").outputs
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let reader = BufReader::new(stream.try_clone().expect("clone"));
    (stream, reader)
}

fn read_frames_until(
    r: &mut BufReader<TcpStream>,
    deadline: Instant,
    mut done: impl FnMut(&Frame) -> bool,
) -> Vec<Frame> {
    let mut out = Vec::new();
    loop {
        assert!(Instant::now() < deadline, "deadline waiting for frames; got {out:?}");
        match wire::read_frame(r) {
            Ok(Some(f)) => {
                let stop = done(&f);
                out.push(f);
                if stop {
                    return out;
                }
            }
            Ok(None) => panic!("peer hung up early; got {out:?}"),
            Err(e) => panic!("wire error {e}; got {out:?}"),
        }
    }
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("http connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").expect("GET");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("http body");
    assert!(resp.starts_with("HTTP/1.1 200"), "GET {path}: {resp}");
    resp.split_once("\r\n\r\n").expect("http header split").1.to_string()
}

fn mk_cfg() -> EngineConfig {
    let mut c = EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8);
    c.max_iterations = u64::MAX;
    c
}

fn spawn_replica(id: u16) -> (Server, ReplicaSpec) {
    let mut scfg = ServerConfig::new(&artifacts_dir(), mk_cfg());
    scfg.addr = "127.0.0.1:0".into();
    scfg.metrics_addr = Some("127.0.0.1:0".into());
    scfg.replica_id = Some(id);
    let server = Server::spawn(scfg).expect("replica spawns");
    let spec = ReplicaSpec {
        addr: server.addr().to_string(),
        metrics_addr: Some(server.metrics_addr().expect("replica metrics").to_string()),
    };
    (server, spec)
}

/// Acceptance pin: 2 tenants through router + 2 replicas, bit-identical
/// to the in-process union run; fleet `/metrics` serves per-replica
/// labelled series; the drain summary's rollup equals the associative
/// merge of the replicas' own final `/snapshot`s.
#[test]
fn two_tenants_through_router_bit_identical_with_fleet_rollup() {
    let rt = runtime();
    let mut acme = small_requests(&rt, 4, 32, 11);
    let mut hobby = small_requests(&rt, 4, 32, 22);
    for (i, r) in acme.iter_mut().enumerate() {
        r.id = 1000 + i as u64;
    }
    for (i, r) in hobby.iter_mut().enumerate() {
        r.id = 2000 + i as u64;
    }
    let mut union = acme.clone();
    union.extend(hobby.iter().cloned());
    let reference = reference_outputs(&rt, mk_cfg(), union);

    let (server0, spec0) = spawn_replica(0);
    let (server1, spec1) = spawn_replica(1);
    let replica_metrics =
        [server0.metrics_addr().unwrap(), server1.metrics_addr().unwrap()];
    let trace_path = std::env::temp_dir().join(format!("router_trace_{}.json", std::process::id()));
    let mut rcfg = RouterConfig::new(vec![spec0, spec1]);
    rcfg.addr = "127.0.0.1:0".into();
    rcfg.metrics_addr = Some("127.0.0.1:0".into());
    rcfg.trace_out = Some(trace_path.to_string_lossy().into_owned());
    let router = Router::spawn(rcfg).expect("router spawns");
    let fleet_metrics = router.metrics_addr().expect("fleet metrics listener");

    let mut ccfg = ClientConfig::new(&router.addr().to_string());
    ccfg.timeout_s = 60.0;
    ccfg.tenants.push(TenantLoad { name: "acme".into(), requests: acme.clone(), drafter: String::new() });
    ccfg.tenants.push(TenantLoad { name: "hobby".into(), requests: hobby.clone(), drafter: String::new() });
    let report = run_load(ccfg).expect("client run");

    assert_eq!(report.completed, 8, "all sessions complete: {}", report.render());
    assert_eq!(report.failed, 0);
    assert_eq!(report.refused_total(), 0);
    for (tenant, reqs) in [("acme", &acme), ("hobby", &hobby)] {
        for r in reqs.iter() {
            let got = report
                .outputs
                .get(&(tenant.to_string(), r.id))
                .unwrap_or_else(|| panic!("missing output for {tenant}/{}", r.id));
            assert_eq!(
                got,
                &reference[&r.id],
                "tenant {tenant} req {} streamed tokens differ from Engine::run",
                r.id
            );
        }
    }

    // Replica attribution: every session carries the router's echo, one
    // replica per tenant (stickiness), both replicas used across tenants.
    let mut per_tenant: BTreeMap<&str, Vec<u16>> = BTreeMap::new();
    for ((tenant, _), d) in &report.sessions {
        let r = d.replica.unwrap_or_else(|| panic!("missing replica echo for {tenant}"));
        assert!(r < 2, "unknown replica {r}");
        per_tenant.entry(tenant.as_str()).or_default().push(r);
    }
    let mut homes = Vec::new();
    for (tenant, rs) in &per_tenant {
        assert!(
            rs.windows(2).all(|w| w[0] == w[1]),
            "tenant {tenant} was not sticky: {rs:?}"
        );
        homes.push(rs[0]);
    }
    homes.sort_unstable();
    assert_eq!(homes, vec![0, 1], "the two tenants must land on distinct replicas");

    // Fleet /metrics: poll until the rollup shows per-replica routing
    // counters alongside replica-side per-tenant series.
    let deadline = Instant::now() + Duration::from_secs(20);
    let body = loop {
        let body = http_get(fleet_metrics, "/metrics");
        if body.contains("sparsespec_router_routed{replica=\"0\"}")
            && body.contains("sparsespec_router_routed{replica=\"1\"}")
            && body.contains("tenant=\"acme\"")
        {
            break body;
        }
        assert!(Instant::now() < deadline, "fleet rollup never converged:\n{body}");
        std::thread::sleep(Duration::from_millis(100));
    };
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("unparseable: {line}"));
        assert!(name.starts_with("sparsespec_"), "unprefixed series: {line}");
        value.parse::<f64>().unwrap_or_else(|_| panic!("non-numeric sample: {line}"));
    }

    router.shutdown(false);
    let summary = router.join().expect("fleet drain");
    assert_eq!(summary.routed, 8);
    assert_eq!(summary.resubmitted, 0);
    assert_eq!(summary.failed_over, 0);
    assert_eq!(
        summary.local.counter("router_routed", &[("replica", "0")])
            + summary.local.counter("router_routed", &[("replica", "1")]),
        8.0
    );

    // The rollup acceptance: merging the replicas' own terminal
    // snapshots (still served until Server::join) reproduces the
    // summary's `replicas_merged` exactly, and local ⊕ replicas equals
    // the fleet registry `/metrics` exposed.
    let mut merged = MetricsRegistry::new();
    for addr in replica_metrics {
        let snap = MetricsRegistry::decode_text(&http_get(addr, "/snapshot"))
            .expect("replica snapshot decodes");
        merged.merge_from(&snap);
    }
    assert_eq!(
        merged.encode_text(),
        summary.replicas_merged.encode_text(),
        "fleet rollup differs from the associative merge of replica snapshots"
    );
    let mut recomputed = summary.local.snapshot();
    recomputed.merge_from(&summary.replicas_merged);
    assert_eq!(recomputed.encode_text(), summary.fleet.encode_text());
    assert_eq!(summary.fleet.counter("sessions_completed", &[("tenant", "acme")]), 4.0);
    assert_eq!(summary.fleet.counter("sessions_completed", &[("tenant", "hobby")]), 4.0);
    assert!(summary.exposition.contains("sparsespec_router_routed"));

    let s0 = server0.join().expect("replica 0 drains");
    let s1 = server1.join().expect("replica 1 drains");
    assert_eq!(s0.sessions_completed + s1.sessions_completed, 8);
    assert_eq!(s0.sessions_completed, 4, "stickiness splits 4/4");

    let trace = std::fs::read_to_string(&trace_path).expect("router trace exported");
    assert!(trace.contains("\"route\""), "routing instants missing from trace");
    let _ = std::fs::remove_file(&trace_path);
}

// ---------------------------------------------------------------------------
// Scripted fake replica: speaks wire v1 (or a wrong version) well enough
// to accept sessions and stream a few tokens, then dies on command.
// ---------------------------------------------------------------------------

struct FakeReplica {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    socks: Arc<Mutex<Vec<TcpStream>>>,
}

impl FakeReplica {
    /// Every accepted connection: send `Hello{version}`, answer `Ping`,
    /// accept each `Submit` — the first submit on a connection also
    /// streams 3 tokens (never finishing), later ones stay queued.
    fn spawn(version: u8) -> FakeReplica {
        let listener = TcpListener::bind("127.0.0.1:0").expect("fake bind");
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let socks: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let (l_stop, l_socks) = (stop.clone(), socks.clone());
        std::thread::spawn(move || {
            let mut next_base = 1000u64;
            for stream in listener.incoming() {
                if l_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if let Ok(c) = stream.try_clone() {
                    l_socks.lock().unwrap().push(c);
                }
                let base = next_base;
                next_base += 100;
                std::thread::spawn(move || fake_conn(stream, version, base));
            }
        });
        FakeReplica { addr, stop, socks }
    }

    /// Hard-kill: every open socket is shut down at once, as a crashed
    /// process would.
    fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for s in self.socks.lock().unwrap().iter() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        // unblock the accept loop
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
    }
}

fn fake_conn(mut stream: TcpStream, version: u8, session_base: u64) {
    let window = 1u32 << 20;
    if wire::write_frame(&mut stream, &Frame::Hello { version, window }).is_err() {
        return;
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut r = BufReader::new(read_half);
    let mut submits = 0u64;
    loop {
        match wire::read_frame(&mut r) {
            Ok(Some(Frame::Submit { req_id, .. })) => {
                let session = session_base + submits;
                submits += 1;
                if wire::write_frame(&mut stream, &Frame::Accepted { req_id, session, replica: None })
                    .is_err()
                {
                    return;
                }
                if submits == 1 {
                    // mid-stream forever: tokens without a Finished
                    for (i, tok) in [7, 8, 9].into_iter().enumerate() {
                        let f = Frame::Token { session, index: i as u32, token: tok };
                        if wire::write_frame(&mut stream, &f).is_err() {
                            return;
                        }
                    }
                }
            }
            Ok(Some(Frame::Ping { nonce })) => {
                if wire::write_frame(&mut stream, &Frame::Pong { nonce }).is_err() {
                    return;
                }
            }
            Ok(Some(_)) => {} // Credit / Cancel / Shutdown: ignored
            _ => return,
        }
    }
}

/// Frame bookkeeping shared by the failover test's read loops.
fn on_frame(
    f: &Frame,
    sid_of: &mut BTreeMap<u64, u64>,
    replica_of: &mut BTreeMap<u64, u16>,
    tokens: &mut BTreeMap<u64, Vec<i32>>,
    errors: &mut BTreeMap<u64, ErrorCode>,
    finished: &mut BTreeMap<u64, (u8, u32)>,
) {
    match f {
        Frame::Accepted { req_id, session, replica } => {
            assert!(
                sid_of.insert(*req_id, *session).is_none(),
                "duplicate Accepted for req {req_id}"
            );
            replica_of.insert(*req_id, replica.expect("router echoes replica"));
        }
        Frame::Token { session, token, .. } => {
            tokens.entry(*session).or_default().push(*token);
        }
        Frame::Error { req_id, code, .. } => {
            errors.insert(*req_id, *code);
        }
        Frame::Finished { session, reason, tokens: n } => {
            finished.insert(*session, (*reason, *n));
        }
        _ => {}
    }
}

/// Acceptance pin of the failover contract: killing a replica mid-load
/// fails its mid-stream session fast with a typed `ReplicaDown`,
/// transparently resubmits its not-yet-streamed one, and leaves the
/// surviving replica's outputs bit-identical.
#[test]
fn replica_death_fails_fast_midstream_and_resubmits_queued() {
    let rt = runtime();
    let deadline = Instant::now() + Duration::from_secs(60);
    let fake = FakeReplica::spawn(wire::PROTOCOL_VERSION);
    let (server, real_spec) = spawn_replica(1);

    // Two real requests run on the survivor; the reference pins their
    // outputs (id keys match the client-side req ids below).
    let mut safe = small_requests(&rt, 1, 24, 44).remove(0);
    safe.id = 2;
    let mut queued = small_requests(&rt, 1, 24, 55).remove(0);
    queued.id = 3;
    let reference = reference_outputs(&rt, mk_cfg(), vec![safe.clone(), queued.clone()]);

    let trace_path =
        std::env::temp_dir().join(format!("router_failover_trace_{}.json", std::process::id()));
    let mut rcfg = RouterConfig::new(vec![
        ReplicaSpec { addr: fake.addr.to_string(), metrics_addr: None },
        real_spec,
    ]);
    rcfg.addr = "127.0.0.1:0".into();
    rcfg.trace_out = Some(trace_path.to_string_lossy().into_owned());
    let router = Router::spawn(rcfg).expect("router spawns");

    // Deterministic placement with default edges [128, 256, 512] and
    // distinct tenants (no stickiness coupling), submitted in order:
    //  req 1 "doomed": overflow bucket, all loads zero   → replica 0
    //  req 2 "safe":   bucket 0 loads (0,0), live (1,0)  → replica 1
    //  req 3 "queued": bucket 0 loads (0, cost_safe)     → replica 0
    let (mut cw, mut cr) = connect(router.addr());
    wire::write_frame(&mut cw, &Frame::Credit { n: 1 << 20 }).expect("credit");
    let doomed_prompt = small_requests(&rt, 1, 24, 66).remove(0).prompt;
    wire::write_frame(
        &mut cw,
        &Frame::Submit {
            req_id: 1,
            seed: 9,
            max_new: 600,
            tenant: "doomed".into(),
            drafter: String::new(),
            prompt: doomed_prompt,
        },
    )
    .expect("submit doomed");
    for (req_id, tenant, r) in [(2u64, "safe", &safe), (3u64, "queued", &queued)] {
        wire::write_frame(
            &mut cw,
            &Frame::Submit {
                req_id,
                seed: r.seed,
                max_new: r.max_new as u32,
                tenant: tenant.into(),
                drafter: String::new(),
                prompt: r.prompt.clone(),
            },
        )
        .expect("submit");
    }

    // Sync point: all three accepted (with the router's replica echo)
    // and the doomed session visibly mid-stream (3 tokens forwarded).
    // Every frame kind is tracked in both read loops — the fast survivor
    // session may finish before the kill.
    let mut sid_of: BTreeMap<u64, u64> = BTreeMap::new(); // req -> session
    let mut replica_of: BTreeMap<u64, u16> = BTreeMap::new();
    let mut tokens: BTreeMap<u64, Vec<i32>> = BTreeMap::new(); // session -> toks
    let mut errors: BTreeMap<u64, ErrorCode> = BTreeMap::new(); // req -> code
    let mut finished: BTreeMap<u64, (u8, u32)> = BTreeMap::new(); // session -> (reason, toks)
    read_frames_until(&mut cr, deadline, |f| {
        on_frame(f, &mut sid_of, &mut replica_of, &mut tokens, &mut errors, &mut finished);
        sid_of.len() == 3
            && tokens.get(&sid_of[&1]).map(|t| t.len()).unwrap_or(0) >= 3
    });
    assert_eq!(replica_of[&1], 0, "doomed must land on the fake replica");
    assert_eq!(replica_of[&2], 1, "safe must land on the survivor");
    assert_eq!(replica_of[&3], 0, "queued must land on the fake replica");

    // Kill the fake: the router must fail the mid-stream session fast
    // and resubmit the queued one to the survivor.
    fake.kill();
    read_frames_until(&mut cr, deadline, |f| {
        on_frame(f, &mut sid_of, &mut replica_of, &mut tokens, &mut errors, &mut finished);
        finished.len() == 3
    });

    // Mid-stream: typed fail-fast, exactly the 3 already-streamed tokens.
    assert_eq!(errors.get(&1), Some(&ErrorCode::ReplicaDown), "errors: {errors:?}");
    assert_eq!(finished[&sid_of[&1]], (3, 3), "doomed ends failed with 3 tokens");
    assert_eq!(tokens[&sid_of[&1]], vec![7, 8, 9]);
    // Queued: resubmitted transparently — no error, no duplicate
    // Accepted (sid_of insert would have panicked), completes on the
    // survivor bit-identical to the reference.
    assert!(!errors.contains_key(&3), "queued session must not surface an error: {errors:?}");
    assert_eq!(finished[&sid_of[&3]].0, 0, "queued completes after resubmit");
    assert_eq!(tokens[&sid_of[&3]], reference[&queued.id]);
    // Survivor untouched throughout.
    assert!(!errors.contains_key(&2), "survivor session errored: {errors:?}");
    assert_eq!(finished[&sid_of[&2]].0, 0);
    assert_eq!(tokens[&sid_of[&2]], reference[&safe.id]);

    drop(cw);
    drop(cr);
    router.shutdown(false);
    let summary = router.join().expect("fleet drain");
    assert_eq!(summary.resubmitted, 1);
    assert_eq!(summary.failed_over, 1);
    assert_eq!(summary.routed, 4, "3 placements + 1 resubmit");
    assert_eq!(
        summary.local.counter("router_health_transitions", &[("replica", "0"), ("to", "down")]),
        1.0
    );

    let s = server.join().expect("survivor drains");
    assert_eq!(s.sessions_completed, 2, "safe + resubmitted queued");

    let trace = std::fs::read_to_string(&trace_path).expect("router trace exported");
    assert!(trace.contains("\"resubmit\""), "resubmit instant missing");
    assert!(trace.contains("\"replica_down_session\""), "fail-fast instant missing");
    let _ = std::fs::remove_file(&trace_path);
}

/// Wire hardening: a replica (or server) speaking the wrong protocol
/// version is rejected up front — by `Router::spawn` for the fleet, and
/// by the unchanged client's handshake for a direct connection.
#[test]
fn wrong_protocol_version_is_rejected_by_router_and_client() {
    let rt = runtime();
    let fake = FakeReplica::spawn(wire::PROTOCOL_VERSION + 1);

    let mut rcfg =
        RouterConfig::new(vec![ReplicaSpec { addr: fake.addr.to_string(), metrics_addr: None }]);
    rcfg.addr = "127.0.0.1:0".into();
    let err = Router::spawn(rcfg).err().expect("version mismatch must fail spawn");
    assert!(
        format!("{err:#}").contains("rejected"),
        "unexpected spawn error: {err:#}"
    );

    let mut ccfg = ClientConfig::new(&fake.addr.to_string());
    ccfg.timeout_s = 10.0;
    ccfg.tenants.push(TenantLoad {
        name: "t".into(),
        requests: small_requests(&rt, 1, 8, 7),
        drafter: String::new(),
    });
    let err = run_load(ccfg).err().expect("client must refuse a v2 server");
    assert!(
        format!("{err:#}").contains("handshake rejected"),
        "unexpected client error: {err:#}"
    );
    fake.kill();
}
