//! Fault injection and the typed engine-error taxonomy.
//!
//! The engine's robustness contract (see ISSUE 7 / EXPERIMENTS.md
//! §Robustness) is that speculation is a *pure accelerator*: any fault on
//! the speculation side degrades the affected slot to vanilla (k=1,
//! non-speculative) decoding and the session still finishes `Completed`;
//! only exhausted-retry I/O faults poison a session (`FinishReason::
//! Failed`), and even then co-batched sessions' outputs stay bit-identical
//! to a fault-free run.
//!
//! This module provides the two pieces that contract is built on:
//!
//! * [`FaultInjector`] — a deterministic, seed-driven chaos source.  Each
//!   injection site keeps its own check counter and decides "fault here?"
//!   by hashing `(seed, site, counter)` with a splitmix64 finaliser and
//!   comparing against `rate · 2⁶⁴`.  The decision stream is a pure
//!   function of the seed and the per-site check index: it never touches
//!   the engine's sampling RNG (so enabling the injector cannot perturb
//!   generated tokens), replays identically for the same seed, and is
//!   cheap enough that the disabled path is a single branch.
//!   `python/tests/test_fault_port.py` pins the exact schedule.
//!
//! * [`EngineError`] — the typed taxonomy replacing panics on fallible
//!   paths.  [`EngineError::class`] splits errors into
//!   [`ErrorClass::Transient`] (bounded retry + exponential backoff on the
//!   sim clock) and [`ErrorClass::Fatal`] (isolate: degrade the slot or
//!   fail the session, never the batch).
//!
//! # Inject your own fault / handle an `EngineError`
//!
//! ```
//! use sparsespec::fault::{
//!     EngineError, ErrorClass, FaultConfig, FaultInjector, FaultPlan, FaultSite,
//! };
//!
//! // A fault plan is `site:rate` pairs — the same string the CLI takes
//! // via `--fault-plan` (with `--fault-seed` choosing the schedule).
//! let plan = FaultPlan::parse("runtime:0.25,kv_reload:1.0")?;
//! let cfg = FaultConfig { plan, seed: 7 };
//!
//! // Deterministic: two injectors with the same config agree exactly.
//! let mut a = FaultInjector::new(&cfg);
//! let mut b = FaultInjector::new(&cfg);
//! let fire_a: Vec<bool> = (0..64).map(|_| a.check(FaultSite::RuntimeStep)).collect();
//! let fire_b: Vec<bool> = (0..64).map(|_| b.check(FaultSite::RuntimeStep)).collect();
//! assert_eq!(fire_a, fire_b);
//! assert!(b.check(FaultSite::KvReload), "rate 1.0 always fires");
//!
//! // The taxonomy tells callers how to react: transient errors are
//! // retried with backoff, fatal ones isolate the slot/session.
//! let io = EngineError::KvReloadIo { req_id: 3, detail: "injected".into() };
//! assert_eq!(io.class(), ErrorClass::Transient);
//! let poison = EngineError::DrafterPanic {
//!     drafter: "my_plugin".into(),
//!     hook: "plan",
//!     detail: "index out of bounds".into(),
//! };
//! assert!(poison.class() == ErrorClass::Fatal && poison.is_fatal());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! To exercise the whole stack end-to-end, pass the config through the
//! engine builder: `EngineConfig::builder(..).faults(cfg).build(&m)?` —
//! every injected fault, retry, degradation and recovery then shows up as
//! `fault`/`fault_retry`/`slot_degrade`/`slot_promote` trace events and
//! `faults_injected`/`fault_retries`/... counters in the
//! [`MetricsRegistry`](crate::metrics::MetricsRegistry).

use anyhow::{bail, Result};
use std::fmt;

// ---------------------------------------------------------------------------
// Retry / degradation policy knobs (engine defaults; pinned by the twin)
// ---------------------------------------------------------------------------

/// Max attempts for one logical runtime step before giving up (1 initial
/// try + `MAX_STEP_RETRIES - 1` retries).
pub const MAX_STEP_RETRIES: u32 = 4;
/// First backoff charged to the **sim clock** after a transient runtime
/// fault; doubles per retry (0.5ms, 1ms, 2ms, ...).
pub const STEP_BACKOFF_BASE_S: f64 = 5e-4;
/// Consecutive reload faults tolerated per suspended request before the
/// session is declared `Failed` (each skipped reload retries naturally on
/// a later iteration, so this is a patience budget, not a tight loop).
pub const RELOAD_FAULT_BUDGET: u32 = 8;
/// Consecutive drafter faults (panic / malformed proposal) before the
/// slot is demoted to vanilla decoding.
pub const DEGRADE_FAULT_THRESHOLD: u32 = 2;
/// Consecutive zero-accept speculation rounds before the slot is demoted
/// (acceptance collapse: speculation is pure waste at α≈0).
pub const DEGRADE_ACCEPT_WINDOW: u32 = 8;
/// Rounds a demoted slot spends in vanilla decoding before it is
/// re-promoted and allowed to speculate again.
pub const PROBATION_ROUNDS: u32 = 16;

/// Sim-clock backoff before retry number `attempt` (0-based), doubling
/// from [`STEP_BACKOFF_BASE_S`].
pub fn backoff_s(attempt: u32) -> f64 {
    STEP_BACKOFF_BASE_S * f64::from(1u32 << attempt.min(16))
}

// ---------------------------------------------------------------------------
// Fault sites + plan
// ---------------------------------------------------------------------------

/// Where in the engine a fault can be injected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// A runtime step function (prefill/draft/verify/kv_load) fails.
    RuntimeStep,
    /// The async KV offload write errors (host-tier I/O).
    KvOffload,
    /// Reading a suspended request's KV back errors (host-tier I/O).
    KvReload,
    /// A delayed-verification promise stalls (extra sim latency).
    VerifyStall,
    /// A drafter lifecycle hook panics.
    DrafterPanic,
    /// A drafter returns a malformed proposal batch.
    DrafterMalformed,
}

impl FaultSite {
    pub const ALL: [FaultSite; 6] = [
        FaultSite::RuntimeStep,
        FaultSite::KvOffload,
        FaultSite::KvReload,
        FaultSite::VerifyStall,
        FaultSite::DrafterPanic,
        FaultSite::DrafterMalformed,
    ];

    /// The spec-string / metrics-label name of this site.
    pub fn label(self) -> &'static str {
        match self {
            FaultSite::RuntimeStep => "runtime",
            FaultSite::KvOffload => "kv_offload",
            FaultSite::KvReload => "kv_reload",
            FaultSite::VerifyStall => "verify_stall",
            FaultSite::DrafterPanic => "drafter_panic",
            FaultSite::DrafterMalformed => "drafter_malformed",
        }
    }

    /// Parse a spec-string site name (the inverse of [`Self::label`]).
    pub fn parse(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|site| site.label() == s)
    }

    /// Per-site hash salt so each site draws an independent decision
    /// stream from the same seed (values are ASCII tags, pinned by the
    /// Python twin — do not change without updating it).
    fn salt(self) -> u64 {
        match self {
            FaultSite::RuntimeStep => 0x52554E54494D4531,
            FaultSite::KvOffload => 0x4B564F46464C4431,
            FaultSite::KvReload => 0x4B5652454C4F4431,
            FaultSite::VerifyStall => 0x565354414C4C3031,
            FaultSite::DrafterPanic => 0x4450414E49433031,
            FaultSite::DrafterMalformed => 0x444D414C46524D31,
        }
    }
}

/// Per-site fault rates in `[0, 1]`.  `Default` is all-zero (no faults).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    rates: [f64; 6],
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `site:rate[,site:rate...]` spec, e.g.
    /// `"runtime:0.01,kv_reload:0.05"`.  Sites: `runtime`, `kv_offload`,
    /// `kv_reload`, `verify_stall`, `drafter_panic`, `drafter_malformed`.
    /// An empty string is the empty (disabled) plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((site, rate)) = part.split_once(':') else {
                bail!("fault plan entry `{part}` is not `site:rate`");
            };
            let Some(site) = FaultSite::parse(site.trim()) else {
                bail!(
                    "unknown fault site `{}` (expected one of: {})",
                    site.trim(),
                    FaultSite::ALL.map(|s| s.label()).join(", ")
                );
            };
            let rate: f64 = rate
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault rate `{}` is not a number", rate.trim()))?;
            if !(0.0..=1.0).contains(&rate) {
                bail!("fault rate {rate} for `{}` outside [0, 1]", site.label());
            }
            plan.rates[site as usize] = rate;
        }
        Ok(plan)
    }

    /// Builder-style single-site rate.
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> Self {
        self.rates[site as usize] = rate.clamp(0.0, 1.0);
        self
    }

    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site as usize]
    }

    /// True when every rate is zero (the injector compiles to one branch).
    pub fn is_empty(&self) -> bool {
        self.rates.iter().all(|&r| r == 0.0)
    }

    /// Canonical spec string (round-trips through [`Self::parse`]).
    pub fn to_spec(&self) -> String {
        FaultSite::ALL
            .iter()
            .filter(|s| self.rates[**s as usize] > 0.0)
            .map(|s| format!("{}:{}", s.label(), self.rates[*s as usize]))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Engine-facing fault configuration: a plan plus the schedule seed.
/// `Default` is disabled (empty plan).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultConfig {
    pub plan: FaultPlan,
    pub seed: u64,
}

impl FaultConfig {
    /// Disabled config (no faults; zero overhead on the engine path).
    pub fn off() -> Self {
        Self::default()
    }

    pub fn new(plan: FaultPlan, seed: u64) -> Self {
        Self { plan, seed }
    }
}

// ---------------------------------------------------------------------------
// Deterministic injector
// ---------------------------------------------------------------------------

/// splitmix64 finaliser — the injector's entire source of randomness.
/// Mirrored bit-for-bit in `python/tests/test_fault_port.py`.
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Deterministic, seed-driven fault source.  See the module docs for the
/// decision function; per-site `checks`/`fired` counters are exposed for
/// reporting.  The injector deliberately owns no engine state and no RNG:
/// with the plan empty, [`FaultInjector::check`] is a single branch and
/// the engine's behaviour is bit-identical to not having an injector at
/// all (CI-gated by the `fault_overhead` bench).
#[derive(Clone, Debug)]
pub struct FaultInjector {
    enabled: bool,
    seed: u64,
    /// `rate · 2⁶⁴` per site, as u128 so rate=1.0 is exactly "always".
    thresholds: [u128; 6],
    checks: [u64; 6],
    fired: [u64; 6],
}

impl FaultInjector {
    /// Injector that never fires (the production default).
    pub fn disabled() -> Self {
        Self::new(&FaultConfig::off())
    }

    pub fn new(cfg: &FaultConfig) -> Self {
        let mut thresholds = [0u128; 6];
        for site in FaultSite::ALL {
            let rate = cfg.plan.rate(site).clamp(0.0, 1.0);
            // exact at the endpoints: 0 → never, 1 → 2^64 (always).
            thresholds[site as usize] = (rate * 18_446_744_073_709_551_616.0) as u128;
        }
        FaultInjector {
            enabled: !cfg.plan.is_empty(),
            seed: cfg.seed,
            thresholds,
            checks: [0; 6],
            fired: [0; 6],
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Should a fault fire at this site, at this site's next check index?
    /// Advances the per-site counter only when enabled, so a disabled
    /// injector is stateless and free.
    #[inline]
    pub fn check(&mut self, site: FaultSite) -> bool {
        if !self.enabled {
            return false;
        }
        let i = site as usize;
        let n = self.checks[i];
        self.checks[i] += 1;
        if self.thresholds[i] == 0 {
            return false;
        }
        let h = mix64(self.seed ^ site.salt() ^ n.wrapping_mul(0x9E3779B97F4A7C15));
        let hit = (h as u128) < self.thresholds[i];
        if hit {
            self.fired[i] += 1;
        }
        hit
    }

    /// How many times [`Self::check`] was called for `site`.
    pub fn checks(&self, site: FaultSite) -> u64 {
        self.checks[site as usize]
    }

    /// How many checks fired for `site`.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.fired[site as usize]
    }

    /// Total faults fired across all sites.
    pub fn total_fired(&self) -> u64 {
        self.fired.iter().sum()
    }
}

// ---------------------------------------------------------------------------
// Typed error taxonomy
// ---------------------------------------------------------------------------

/// How a caller should react to an [`EngineError`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorClass {
    /// Retry with bounded exponential backoff on the sim clock.
    Transient,
    /// Do not retry: isolate (degrade the slot / fail the session).
    Fatal,
}

/// The typed error taxonomy for fallible engine paths.  Carried inside
/// `anyhow::Error` across existing `Result` plumbing (downcast to react),
/// so no new dependency is needed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// A runtime step function (prefill/draft/verify/kv_load) failed.
    RuntimeStep { artifact: String, detail: String },
    /// The async host-tier offload write failed.
    KvOffloadIo { req_id: u64, detail: String },
    /// Reading a suspended request's host-tier KV back failed.
    KvReloadIo { req_id: u64, detail: String },
    /// A delayed-verification promise stalled past its deadline.
    VerifyStall { detail: String },
    /// A drafter lifecycle hook panicked (caught at the sandbox boundary).
    DrafterPanic { drafter: String, hook: &'static str, detail: String },
    /// A drafter returned a shape-invalid proposal batch.
    MalformedProposal { drafter: String, detail: String },
    /// A transient fault persisted past its retry budget.
    RetriesExhausted { site: FaultSite, attempts: u32, last: String },
    /// An internal invariant was violated (always a bug).
    Internal { detail: String },
}

impl EngineError {
    /// Transient-vs-fatal classification table (pinned by
    /// `python/tests/test_fault_port.py` — update both together).
    pub fn class(&self) -> ErrorClass {
        match self {
            EngineError::RuntimeStep { .. } => ErrorClass::Transient,
            EngineError::KvOffloadIo { .. } => ErrorClass::Transient,
            EngineError::KvReloadIo { .. } => ErrorClass::Transient,
            EngineError::VerifyStall { .. } => ErrorClass::Transient,
            EngineError::DrafterPanic { .. } => ErrorClass::Fatal,
            EngineError::MalformedProposal { .. } => ErrorClass::Fatal,
            EngineError::RetriesExhausted { .. } => ErrorClass::Fatal,
            EngineError::Internal { .. } => ErrorClass::Fatal,
        }
    }

    pub fn is_transient(&self) -> bool {
        self.class() == ErrorClass::Transient
    }

    pub fn is_fatal(&self) -> bool {
        self.class() == ErrorClass::Fatal
    }

    /// Stable metrics-label name for this error kind.
    pub fn kind_label(&self) -> &'static str {
        match self {
            EngineError::RuntimeStep { .. } => "runtime_step",
            EngineError::KvOffloadIo { .. } => "kv_offload_io",
            EngineError::KvReloadIo { .. } => "kv_reload_io",
            EngineError::VerifyStall { .. } => "verify_stall",
            EngineError::DrafterPanic { .. } => "drafter_panic",
            EngineError::MalformedProposal { .. } => "malformed_proposal",
            EngineError::RetriesExhausted { .. } => "retries_exhausted",
            EngineError::Internal { .. } => "internal",
        }
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::RuntimeStep { artifact, detail } => {
                write!(f, "runtime step `{artifact}` failed: {detail}")
            }
            EngineError::KvOffloadIo { req_id, detail } => {
                write!(f, "kv offload I/O error for request {req_id}: {detail}")
            }
            EngineError::KvReloadIo { req_id, detail } => {
                write!(f, "kv reload I/O error for request {req_id}: {detail}")
            }
            EngineError::VerifyStall { detail } => write!(f, "delayed verify stalled: {detail}"),
            EngineError::DrafterPanic { drafter, hook, detail } => {
                write!(f, "drafter `{drafter}` panicked in `{hook}`: {detail}")
            }
            EngineError::MalformedProposal { drafter, detail } => {
                write!(f, "drafter `{drafter}` produced a malformed proposal: {detail}")
            }
            EngineError::RetriesExhausted { site, attempts, last } => write!(
                f,
                "{} fault persisted after {attempts} attempts (last: {last})",
                site.label()
            ),
            EngineError::Internal { detail } => write!(f, "internal invariant violated: {detail}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Render a caught panic payload into a readable detail string (the
/// sandbox boundary around drafter hooks uses this).
pub fn panic_detail(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parse_roundtrip_and_errors() {
        let p = FaultPlan::parse("runtime:0.01, kv_reload:0.5,drafter_panic:1.0").unwrap();
        assert_eq!(p.rate(FaultSite::RuntimeStep), 0.01);
        assert_eq!(p.rate(FaultSite::KvReload), 0.5);
        assert_eq!(p.rate(FaultSite::DrafterPanic), 1.0);
        assert_eq!(p.rate(FaultSite::KvOffload), 0.0);
        assert!(!p.is_empty());
        assert_eq!(FaultPlan::parse(&p.to_spec()).unwrap(), p);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("bogus:0.1").is_err());
        assert!(FaultPlan::parse("runtime:1.5").is_err());
        assert!(FaultPlan::parse("runtime").is_err());
        assert!(FaultPlan::parse("runtime:x").is_err());
    }

    #[test]
    fn injector_is_deterministic_and_sites_are_independent() {
        let cfg = FaultConfig::new(FaultPlan::parse("runtime:0.3,kv_reload:0.3").unwrap(), 42);
        let mut a = FaultInjector::new(&cfg);
        let mut b = FaultInjector::new(&cfg);
        let sa: Vec<bool> = (0..256).map(|_| a.check(FaultSite::RuntimeStep)).collect();
        let sb: Vec<bool> = (0..256).map(|_| b.check(FaultSite::RuntimeStep)).collect();
        assert_eq!(sa, sb);
        // interleaving checks of another site must not shift the stream
        let mut c = FaultInjector::new(&cfg);
        let sc: Vec<bool> = (0..256)
            .map(|_| {
                c.check(FaultSite::KvReload);
                c.check(FaultSite::RuntimeStep)
            })
            .collect();
        assert_eq!(sa, sc);
        // different seed → different stream (overwhelmingly likely)
        let mut d = FaultInjector::new(&FaultConfig::new(cfg.plan.clone(), 43));
        let sd: Vec<bool> = (0..256).map(|_| d.check(FaultSite::RuntimeStep)).collect();
        assert_ne!(sa, sd);
    }

    #[test]
    fn injector_rates_are_calibrated() {
        let cfg = FaultConfig::new(FaultPlan::new().with_rate(FaultSite::RuntimeStep, 0.25), 7);
        let mut inj = FaultInjector::new(&cfg);
        let n = 20_000u64;
        let mut hits = 0u64;
        for _ in 0..n {
            if inj.check(FaultSite::RuntimeStep) {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "empirical rate {rate}");
        assert_eq!(inj.checks(FaultSite::RuntimeStep), n);
        assert_eq!(inj.fired(FaultSite::RuntimeStep), hits);
        assert_eq!(inj.total_fired(), hits);
    }

    #[test]
    fn rate_endpoints_are_exact() {
        let cfg = FaultConfig::new(
            FaultPlan::new()
                .with_rate(FaultSite::DrafterPanic, 1.0)
                .with_rate(FaultSite::KvOffload, 0.0)
                .with_rate(FaultSite::RuntimeStep, 0.5),
            11,
        );
        let mut inj = FaultInjector::new(&cfg);
        for _ in 0..1000 {
            assert!(inj.check(FaultSite::DrafterPanic));
            assert!(!inj.check(FaultSite::KvOffload));
        }
        // disabled injector: never fires, never counts
        let mut off = FaultInjector::disabled();
        assert!(!off.enabled());
        for _ in 0..100 {
            assert!(!off.check(FaultSite::RuntimeStep));
        }
        assert_eq!(off.checks(FaultSite::RuntimeStep), 0);
    }

    #[test]
    fn classification_table() {
        use ErrorClass::*;
        let cases: Vec<(EngineError, ErrorClass)> = vec![
            (
                EngineError::RuntimeStep { artifact: "verify_q9".into(), detail: "x".into() },
                Transient,
            ),
            (EngineError::KvOffloadIo { req_id: 1, detail: "x".into() }, Transient),
            (EngineError::KvReloadIo { req_id: 1, detail: "x".into() }, Transient),
            (EngineError::VerifyStall { detail: "x".into() }, Transient),
            (
                EngineError::DrafterPanic { drafter: "p".into(), hook: "plan", detail: "x".into() },
                Fatal,
            ),
            (
                EngineError::MalformedProposal { drafter: "p".into(), detail: "x".into() },
                Fatal,
            ),
            (
                EngineError::RetriesExhausted {
                    site: FaultSite::KvReload,
                    attempts: 8,
                    last: "x".into(),
                },
                Fatal,
            ),
            (EngineError::Internal { detail: "x".into() }, Fatal),
        ];
        for (err, class) in cases {
            assert_eq!(err.class(), class, "{err}");
            assert_eq!(err.is_fatal(), class == Fatal);
            // every kind has a stable label and a Display impl
            assert!(!err.kind_label().is_empty());
            assert!(!err.to_string().is_empty());
        }
    }

    #[test]
    fn engine_error_downcasts_through_anyhow() {
        let err: anyhow::Error =
            EngineError::KvReloadIo { req_id: 9, detail: "injected".into() }.into();
        let e = err.downcast_ref::<EngineError>().expect("downcast");
        assert!(e.is_transient());
    }

    #[test]
    fn backoff_doubles() {
        assert_eq!(backoff_s(0), STEP_BACKOFF_BASE_S);
        assert_eq!(backoff_s(1), STEP_BACKOFF_BASE_S * 2.0);
        assert_eq!(backoff_s(3), STEP_BACKOFF_BASE_S * 8.0);
    }
}
