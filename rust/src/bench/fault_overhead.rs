//! `fault_overhead` — fault-injector cost on the engine iteration path.
//!
//! The robustness tentpole promises that the injector is a pure chaos
//! *option*: with no fault plan the engine must behave — and cost —
//! exactly as if the injector did not exist.  Two measurements:
//!
//! 1. **Micro**: per-`check()` latency of a disabled injector (one branch
//!    on `enabled`) against an armed one (per-site counter + splitmix64
//!    hash + threshold compare — the price a chaos run pays per site
//!    probe).
//! 2. **End-to-end**: paired engine runs over the identical workload with
//!    the default config and an explicit `FaultConfig::off()`.  Outputs,
//!    iteration counts and fault counters must be bit-identical (the
//!    disabled injector never perturbs generation), and the run's
//!    per-iteration wallclock anchors the extrapolated ratio.
//!
//! Gate (enforced after saving, like `trace_overhead`): the disabled
//! injector extrapolated to a full iteration's worth of site probes must
//! stay under **1%** of an engine iteration.  Emits
//! `reports/BENCH_fault_overhead.json`.

use super::BenchCtx;
use crate::engine::{Engine, EngineConfig};
use crate::fault::{FaultConfig, FaultInjector, FaultPlan, FaultSite};
use crate::spec::DrafterKind;
use crate::util::json::{num, obj, s as jstr};
use crate::workload::{Dataset, WorkloadGen};
use anyhow::Result;
use std::hint::black_box;
use std::time::Instant;

pub fn fault_overhead(ctx: &mut BenchCtx) -> Result<()> {
    println!("fault_overhead: injector cost, disabled vs armed");
    let reps = 400_000 * ctx.n_requests.max(1);

    // Micro: disabled injector — the branch every fallible callsite pays
    // in production (no plan configured).
    let mut off = FaultInjector::disabled();
    let t0 = Instant::now();
    for i in 0..reps {
        let site = FaultSite::ALL[i % FaultSite::ALL.len()];
        black_box(off.check(black_box(site)));
    }
    let off_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
    anyhow::ensure!(
        off.total_fired() == 0 && off.checks(FaultSite::RuntimeStep) == 0,
        "disabled injector must neither fire nor count"
    );

    // Micro: armed injector at a mid-range rate (worst case per probe:
    // counter bump + hash + compare, independent of whether it fires).
    let cfg = FaultConfig::new(
        FaultPlan::new()
            .with_rate(FaultSite::RuntimeStep, 0.01)
            .with_rate(FaultSite::KvReload, 0.01),
        ctx.seed,
    );
    let mut on = FaultInjector::new(&cfg);
    let t0 = Instant::now();
    for i in 0..reps {
        let site = FaultSite::ALL[i % FaultSite::ALL.len()];
        black_box(on.check(black_box(site)));
    }
    let on_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
    println!("  per check(): disabled {off_ns:.2}ns, armed {on_ns:.2}ns");

    // End-to-end: default config vs explicit FaultConfig::off() — the
    // injector-disabled engine must be indistinguishable from one built
    // before the injector existed.
    let rt = ctx.rt()?;
    let m = rt.cfg.model.clone();
    let n_req = ctx.n_requests.max(4);
    let mk_reqs = |seed: u64| {
        WorkloadGen::new(rt.cfg.grammar.clone(), m.clone(), Dataset::Aime, seed)
            .offline_batch(n_req)
    };
    let mut eng_default = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )?;
    let r_default = eng_default.run(mk_reqs(ctx.seed))?;
    let mut eng_off = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_faults(FaultConfig::off()),
    )?;
    let r_off = eng_off.run(mk_reqs(ctx.seed))?;
    anyhow::ensure!(
        r_default.outputs == r_off.outputs,
        "a disabled injector changed engine outputs (must be bit-identical)"
    );
    anyhow::ensure!(
        r_default.iterations == r_off.iterations,
        "a disabled injector changed the iteration schedule"
    );
    anyhow::ensure!(
        r_off.faults_injected == 0 && r_off.fault_retries == 0 && r_off.requests_failed == 0,
        "a disabled injector reported fault activity"
    );
    println!("  {}", r_off.summary());
    let iter_us = r_off.wall_s * 1e6 / r_off.iterations.max(1) as f64;

    // Probe bound per iteration: one runtime-step probe per launched
    // step artifact (prefill/draft/verify/kv_load: ≤ a handful), one per
    // pressure action and reload poll, one drafter probe per live slot —
    // slots + 16 is a comfortable ceiling, mirroring trace_overhead.
    let probes_per_iter = (m.slots + 16) as f64;
    let off_us_per_iter = off_ns * probes_per_iter / 1e3;
    let ratio_off = off_us_per_iter / iter_us.max(1e-9);
    println!(
        "  per-iteration: engine {iter_us:.1}us, disabled-injector bound \
         {off_us_per_iter:.4}us ({:.4}% — gate < 1%)",
        ratio_off * 100.0
    );

    let json = obj(vec![
        ("experiment", jstr("fault_overhead")),
        ("harness", jstr("cargo bench -- fault_overhead")),
        ("check_disabled_ns", num(off_ns)),
        ("check_armed_ns", num(on_ns)),
        ("engine_iter_us", num(iter_us)),
        ("probes_per_iter_bound", num(probes_per_iter)),
        ("overhead_ratio_disabled", num(ratio_off)),
        ("outputs_bit_identical", num(1.0)),
        ("iterations_identical", num(1.0)),
    ]);
    ctx.save("BENCH_fault_overhead.json", &json.to_string())?;
    // Enforced after saving, so a regression still leaves evidence.
    anyhow::ensure!(
        ratio_off < 0.01,
        "fault_overhead gate failed: disabled injector costs {:.3}% of an \
         engine iteration (need < 1%)",
        ratio_off * 100.0
    );
    Ok(())
}
