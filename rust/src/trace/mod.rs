//! Span-based structured tracing on both the simulated serving clock and
//! the wall clock (the observability tentpole).
//!
//! The engine emits *events* — phase spans (draft / verify / prefill),
//! delayed-verification overlap windows, KV transitions, scheduler
//! decisions, and per-session lifecycle marks — into a bounded ring-buffer
//! journal owned by a [`Tracer`].  Two exporters turn the journal into
//! files:
//!
//! * [`Tracer::export_chrome`] — Chrome/Perfetto trace-event JSON
//!   (`{"traceEvents": [...]}`): open the file at <https://ui.perfetto.dev>
//!   and read the draft/verify/overlap structure directly off the
//!   timeline.  Wall-clock microseconds drive the `ts` axis; the simulated
//!   serving clock rides along as `args.sim_us` on every event.
//! * [`Tracer::export_jsonl`] — one JSON object per line, for ad-hoc
//!   `grep`/pandas analysis.
//!
//! Tracing is **config-gated and cheap when off**: every emission method
//! first checks a single bool ([`Tracer::enabled`] for lifecycle events,
//! [`Tracer::hot`] for per-iteration spans, which additionally respects
//! the `sample_every` knob).  The `trace_overhead` bench enforces the
//! budget (<1% of an engine iteration disabled, <5% enabled).
//!
//! # Add your own span
//!
//! ```
//! use sparsespec::trace::{TraceConfig, Tracer, Track};
//!
//! let mut tracer = Tracer::new(TraceConfig::on());
//! let sim_s = 0.0;
//! tracer.iter_begin(0, sim_s);          // opens the iteration span
//! tracer.begin("my_phase", Track::Engine, sim_s);
//! // ... do the work ...
//! tracer.end("my_phase", Track::Engine, sim_s, vec![("items", 3.0.into())]);
//! tracer.iter_end(sim_s + 0.001, vec![]);
//! let json = tracer.export_chrome_string();
//! assert!(json.contains("my_phase"));
//! ```

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

/// Canonical span / event names, so the Rust emitters, the tests, and the
/// Python schema twin (`python/tests/test_trace_port.py`) can never drift.
pub mod names {
    pub const ITERATION: &str = "iteration";
    pub const ADMIT: &str = "admit";
    pub const DRAFT: &str = "draft";
    pub const PROPOSE: &str = "propose";
    pub const VERIFY: &str = "verify";
    pub const DELAYED_VERIFY_OVERLAP: &str = "delayed_verify_overlap";
    pub const KV_ADMIT: &str = "kv_admit";
    pub const KV_OFFLOAD: &str = "kv_offload";
    pub const KV_PREEMPT: &str = "kv_preempt";
    pub const KV_RELOAD: &str = "kv_reload";
    pub const KV_FORGET: &str = "kv_forget";
    pub const BUCKET_ASSIGN: &str = "bucket_assign";
    pub const ADAPTIVE_K: &str = "adaptive_k";
    pub const SESSION_SUBMIT: &str = "session_submit";
    pub const SESSION_FIRST_TOKEN: &str = "session_first_token";
    pub const SESSION_FINISH: &str = "session_finish";
    pub const FAULT: &str = "fault";
    pub const FAULT_RETRY: &str = "fault_retry";
    pub const SLOT_DEGRADE: &str = "slot_degrade";
    pub const SLOT_PROMOTE: &str = "slot_promote";
    pub const SESSION_FAIL: &str = "session_fail";
}

/// Tracing knobs, carried on `EngineConfig` (see
/// `EngineConfig::builder().tracing(...)`).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Master switch.  When false every emission is a single branch.
    pub enabled: bool,
    /// Ring-buffer capacity in events; the oldest events are dropped (and
    /// counted) once the journal is full.
    pub capacity: usize,
    /// Record per-iteration spans only every Nth iteration (1 = all).
    /// Lifecycle events (sessions, KV transitions) are always recorded
    /// while enabled — they are rare and are the ones you can't
    /// reconstruct from a sampled timeline.
    pub sample_every: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, capacity: 65_536, sample_every: 1 }
    }
}

impl TraceConfig {
    /// Tracing enabled with default capacity and no sampling.
    pub fn on() -> Self {
        TraceConfig { enabled: true, ..Default::default() }
    }

    pub fn with_sampling(mut self, every: u64) -> Self {
        self.sample_every = every.max(1);
        self
    }

    pub fn with_capacity(mut self, cap: usize) -> Self {
        self.capacity = cap.max(16);
        self
    }
}

/// Perfetto "thread" lanes.  One lane per subsystem keeps nesting local:
/// span begin/end pairs form a stack *per track*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Track {
    /// Iteration + phase spans of the coordinator loop.
    Engine,
    /// Per-artifact device time (from `runtime::StepStats` deltas).
    Device,
    /// Bucket / admission decisions.
    Scheduler,
    /// KV admit/evict/offload/reload/forget transitions.
    Kv,
    /// Session lifecycle instants.
    Session,
    /// Drafter-internal events (AdaptiveK k-trajectory).
    Drafter,
    /// Delayed-verification overlap windows (may cross iteration
    /// boundaries, so they get a dedicated lane).
    Overlap,
}

impl Track {
    pub fn tid(self) -> u64 {
        match self {
            Track::Engine => 1,
            Track::Device => 2,
            Track::Scheduler => 3,
            Track::Kv => 4,
            Track::Session => 5,
            Track::Drafter => 6,
            Track::Overlap => 7,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Track::Engine => "engine",
            Track::Device => "device",
            Track::Scheduler => "scheduler",
            Track::Kv => "kv",
            Track::Session => "session",
            Track::Drafter => "drafter",
            Track::Overlap => "overlap",
        }
    }

    fn all() -> [Track; 7] {
        [
            Track::Engine,
            Track::Device,
            Track::Scheduler,
            Track::Kv,
            Track::Session,
            Track::Drafter,
            Track::Overlap,
        ]
    }
}

/// Event argument value (stringly-typed JSON scalar).
#[derive(Clone, Debug, PartialEq)]
pub enum ArgVal {
    F(f64),
    S(String),
}

impl From<f64> for ArgVal {
    fn from(v: f64) -> Self {
        ArgVal::F(v)
    }
}

impl From<usize> for ArgVal {
    fn from(v: usize) -> Self {
        ArgVal::F(v as f64)
    }
}

impl From<u64> for ArgVal {
    fn from(v: u64) -> Self {
        ArgVal::F(v as f64)
    }
}

impl From<&str> for ArgVal {
    fn from(v: &str) -> Self {
        ArgVal::S(v.to_string())
    }
}

impl From<String> for ArgVal {
    fn from(v: String) -> Self {
        ArgVal::S(v)
    }
}

impl ArgVal {
    fn to_json(&self) -> Json {
        match self {
            ArgVal::F(v) => num(*v),
            ArgVal::S(v) => s(v),
        }
    }
}

pub type Args = Vec<(&'static str, ArgVal)>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
    /// Pre-paired span with an explicit duration (device sub-spans).
    Complete,
    Instant,
    Counter,
    /// Async begin/end: interleaving (non-nested) intervals matched by
    /// `id` — concurrent KV offloads.
    AsyncBegin,
    AsyncEnd,
}

impl EventKind {
    fn label(self) -> &'static str {
        match self {
            EventKind::Begin => "B",
            EventKind::End => "E",
            EventKind::Complete => "X",
            EventKind::Instant => "i",
            EventKind::Counter => "C",
            EventKind::AsyncBegin => "b",
            EventKind::AsyncEnd => "e",
        }
    }
}

/// One journal entry.  `wall_us` is microseconds since the tracer's epoch
/// (the Chrome `ts` axis); `sim_us` is the simulated serving clock.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    pub kind: EventKind,
    pub track: Track,
    /// Correlation id for async events (0 otherwise).
    pub id: u64,
    pub wall_us: f64,
    pub sim_us: f64,
    /// Explicit duration for `Complete` events only.
    pub dur_us: f64,
    pub args: Args,
}

/// Bounded structured-event journal + exporters.  Owned by the engine;
/// emission methods are no-ops (one branch) when tracing is off.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    epoch: Instant,
    events: VecDeque<TraceEvent>,
    dropped: u64,
    /// Is the *current* iteration sampled?  Decided at `iter_begin`.
    sampled: bool,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Self {
        Tracer {
            cfg,
            epoch: Instant::now(),
            events: VecDeque::new(),
            dropped: 0,
            sampled: false,
        }
    }

    pub fn disabled() -> Self {
        Tracer::new(TraceConfig::default())
    }

    /// Master gate: lifecycle events (sessions, KV transitions) record
    /// whenever this is true.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Hot gate: per-iteration spans/counters record only when the current
    /// iteration is sampled.  Callers building non-trivial `Args` should
    /// guard on this first so the vec is never allocated off the sample.
    #[inline]
    pub fn hot(&self) -> bool {
        self.sampled
    }

    /// Microseconds since the tracer epoch (the wall `ts` axis).
    #[inline]
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted from the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.events.len() >= self.cfg.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    fn push_now(
        &mut self,
        name: &str,
        kind: EventKind,
        track: Track,
        id: u64,
        sim_s: f64,
        args: Args,
    ) {
        let wall_us = self.now_us();
        self.push(TraceEvent {
            name: name.to_string(),
            kind,
            track,
            id,
            wall_us,
            sim_us: sim_s * 1e6,
            dur_us: 0.0,
            args,
        });
    }

    /// Open the iteration span and decide whether this iteration is
    /// sampled.  Must be called once per engine step before any
    /// `hot()`-gated emission.
    pub fn iter_begin(&mut self, iter: u64, sim_s: f64) {
        if !self.cfg.enabled {
            return;
        }
        self.sampled = iter % self.cfg.sample_every == 0;
        if self.sampled {
            self.push_now(
                names::ITERATION,
                EventKind::Begin,
                Track::Engine,
                0,
                sim_s,
                vec![("iter", ArgVal::F(iter as f64))],
            );
        }
    }

    /// Close the iteration span; `sim_s` here is the *advanced* clock, so
    /// iteration spans carry real simulated durations.
    pub fn iter_end(&mut self, sim_s: f64, args: Args) {
        if self.sampled {
            self.push_now(names::ITERATION, EventKind::End, Track::Engine, 0, sim_s, args);
        }
    }

    pub fn begin(&mut self, name: &str, track: Track, sim_s: f64) {
        if self.sampled {
            self.push_now(name, EventKind::Begin, track, 0, sim_s, Vec::new());
        }
    }

    pub fn end(&mut self, name: &str, track: Track, sim_s: f64, args: Args) {
        if self.sampled {
            self.push_now(name, EventKind::End, track, 0, sim_s, args);
        }
    }

    /// A span whose endpoints were measured by the caller (device
    /// sub-spans reconstructed from `StepStats` deltas).
    pub fn complete_at(
        &mut self,
        name: &str,
        track: Track,
        wall_us: f64,
        dur_us: f64,
        sim_s: f64,
        args: Args,
    ) {
        if self.sampled {
            self.push(TraceEvent {
                name: name.to_string(),
                kind: EventKind::Complete,
                track,
                id: 0,
                wall_us,
                sim_us: sim_s * 1e6,
                dur_us,
                args,
            });
        }
    }

    /// Lifecycle instant — recorded whenever tracing is enabled
    /// (not subject to sampling).
    pub fn instant(&mut self, name: &str, track: Track, sim_s: f64, args: Args) {
        if self.cfg.enabled {
            self.push_now(name, EventKind::Instant, track, 0, sim_s, args);
        }
    }

    /// Sampled counter series (queue depths, KV utilisation).
    pub fn counter(&mut self, name: &'static str, sim_s: f64, value: f64) {
        if self.sampled {
            self.push_now(
                name,
                EventKind::Counter,
                Track::Engine,
                0,
                sim_s,
                vec![("value", ArgVal::F(value))],
            );
        }
    }

    /// Async interval start, matched to its end by `id` — for intervals
    /// that interleave rather than nest (concurrent KV offloads).
    /// Recorded whenever enabled: transitions are rare and non-local.
    pub fn async_begin(&mut self, name: &str, track: Track, id: u64, sim_s: f64, args: Args) {
        if self.cfg.enabled {
            self.push_now(name, EventKind::AsyncBegin, track, id, sim_s, args);
        }
    }

    pub fn async_end(&mut self, name: &str, track: Track, id: u64, sim_s: f64, args: Args) {
        if self.cfg.enabled {
            self.push_now(name, EventKind::AsyncEnd, track, id, sim_s, args);
        }
    }

    // -- exporters ----------------------------------------------------

    /// Chrome/Perfetto trace-event JSON.  Begin/End pairs are folded into
    /// complete (`"ph":"X"`) events per track; a Begin whose End was lost
    /// to ring eviction (or vice versa) is skipped rather than corrupting
    /// the timeline.
    pub fn export_chrome(&self) -> Json {
        let mut out: Vec<Json> = Vec::with_capacity(self.events.len() + 8);
        // Metadata: one process, one named thread lane per track.
        out.push(obj(vec![
            ("name", s("process_name")),
            ("ph", s("M")),
            ("pid", num(1.0)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", s("sparsespec"))])),
        ]));
        for t in Track::all() {
            out.push(obj(vec![
                ("name", s("thread_name")),
                ("ph", s("M")),
                ("pid", num(1.0)),
                ("tid", num(t.tid() as f64)),
                ("args", obj(vec![("name", s(t.label()))])),
            ]));
        }
        // Per-track stacks of pending Begins (index into self.events order
        // is already chronological).
        let mut stacks: Vec<Vec<&TraceEvent>> = vec![Vec::new(); 8];
        for ev in &self.events {
            let tid = ev.track.tid() as f64;
            match ev.kind {
                EventKind::Begin => stacks[ev.track.tid() as usize].push(ev),
                EventKind::End => {
                    let stack = &mut stacks[ev.track.tid() as usize];
                    // Unwind to the matching Begin; anything above it lost
                    // its End to eviction/sampling and is dropped.
                    while let Some(b) = stack.pop() {
                        if b.name == ev.name {
                            let mut fields = vec![
                                ("name", s(&b.name)),
                                ("cat", s(b.track.label())),
                                ("ph", s("X")),
                                ("pid", num(1.0)),
                                ("tid", num(tid)),
                                ("ts", num(b.wall_us)),
                                ("dur", num((ev.wall_us - b.wall_us).max(0.0))),
                            ];
                            let mut a = vec![
                                ("sim_us", num(b.sim_us)),
                                ("sim_dur_us", num((ev.sim_us - b.sim_us).max(0.0))),
                            ];
                            for (k, v) in b.args.iter().chain(ev.args.iter()) {
                                a.push((*k, v.to_json()));
                            }
                            fields.push(("args", obj(a)));
                            out.push(obj(fields));
                            break;
                        }
                    }
                }
                EventKind::Complete => {
                    let mut a = vec![("sim_us", num(ev.sim_us))];
                    for (k, v) in &ev.args {
                        a.push((*k, v.to_json()));
                    }
                    out.push(obj(vec![
                        ("name", s(&ev.name)),
                        ("cat", s(ev.track.label())),
                        ("ph", s("X")),
                        ("pid", num(1.0)),
                        ("tid", num(tid)),
                        ("ts", num(ev.wall_us)),
                        ("dur", num(ev.dur_us)),
                        ("args", obj(a)),
                    ]));
                }
                EventKind::Instant => {
                    let mut a = vec![("sim_us", num(ev.sim_us))];
                    for (k, v) in &ev.args {
                        a.push((*k, v.to_json()));
                    }
                    out.push(obj(vec![
                        ("name", s(&ev.name)),
                        ("cat", s(ev.track.label())),
                        ("ph", s("i")),
                        ("s", s("t")),
                        ("pid", num(1.0)),
                        ("tid", num(tid)),
                        ("ts", num(ev.wall_us)),
                        ("args", obj(a)),
                    ]));
                }
                EventKind::Counter => {
                    let mut a = vec![("sim_us", num(ev.sim_us))];
                    for (k, v) in &ev.args {
                        a.push((*k, v.to_json()));
                    }
                    out.push(obj(vec![
                        ("name", s(&ev.name)),
                        ("ph", s("C")),
                        ("pid", num(1.0)),
                        ("tid", num(tid)),
                        ("ts", num(ev.wall_us)),
                        ("args", obj(a)),
                    ]));
                }
                EventKind::AsyncBegin | EventKind::AsyncEnd => {
                    let ph = if ev.kind == EventKind::AsyncBegin { "b" } else { "e" };
                    let mut a = vec![("sim_us", num(ev.sim_us))];
                    for (k, v) in &ev.args {
                        a.push((*k, v.to_json()));
                    }
                    out.push(obj(vec![
                        ("name", s(&ev.name)),
                        ("cat", s(ev.track.label())),
                        ("ph", s(ph)),
                        ("id", num(ev.id as f64)),
                        ("pid", num(1.0)),
                        ("tid", num(tid)),
                        ("ts", num(ev.wall_us)),
                        ("args", obj(a)),
                    ]));
                }
            }
        }
        obj(vec![
            ("traceEvents", arr(out)),
            ("displayTimeUnit", s("ms")),
            ("otherData", obj(vec![("dropped_events", num(self.dropped as f64))])),
        ])
    }

    pub fn export_chrome_string(&self) -> String {
        self.export_chrome().to_string()
    }

    /// JSONL: one raw journal entry per line (no pairing), for ad-hoc
    /// analysis.  `kind` uses the Chrome phase letters.
    pub fn export_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in &self.events {
            let mut fields = vec![
                ("name", s(&ev.name)),
                ("kind", s(ev.kind.label())),
                ("track", s(ev.track.label())),
                ("wall_us", num(ev.wall_us)),
                ("sim_us", num(ev.sim_us)),
            ];
            if ev.id != 0 {
                fields.push(("id", num(ev.id as f64)));
            }
            if ev.kind == EventKind::Complete {
                fields.push(("dur_us", num(ev.dur_us)));
            }
            if !ev.args.is_empty() {
                fields.push((
                    "args",
                    obj(ev.args.iter().map(|(k, v)| (*k, v.to_json())).collect()),
                ));
            }
            out.push_str(&obj(fields).to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chrome_events(t: &Tracer) -> Vec<Json> {
        match t.export_chrome().get("traceEvents") {
            Some(Json::Arr(v)) => v.clone(),
            _ => panic!("traceEvents missing"),
        }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        t.iter_begin(0, 0.0);
        t.begin(names::DRAFT, Track::Engine, 0.0);
        t.end(names::DRAFT, Track::Engine, 0.0, vec![]);
        t.instant(names::SESSION_SUBMIT, Track::Session, 0.0, vec![]);
        t.counter("queue_depth", 0.0, 3.0);
        t.async_begin(names::KV_OFFLOAD, Track::Kv, 7, 0.0, vec![]);
        assert!(t.is_empty());
        assert!(!t.enabled());
        assert!(!t.hot());
    }

    #[test]
    fn begin_end_pairs_fold_into_complete_events() {
        let mut t = Tracer::new(TraceConfig::on());
        t.iter_begin(0, 0.0);
        t.begin(names::DRAFT, Track::Engine, 0.0);
        t.end(names::DRAFT, Track::Engine, 0.0, vec![("slots", 4.0.into())]);
        t.iter_end(0.002, vec![]);
        let evs = chrome_events(&t);
        let xs: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2, "draft + iteration spans");
        let draft = xs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some(names::DRAFT))
            .unwrap();
        assert!(draft.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            draft.get("args").unwrap().get("slots").unwrap().as_f64(),
            Some(4.0)
        );
        // the iteration span carries the advanced sim clock as duration
        let it = xs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some(names::ITERATION))
            .unwrap();
        let sim_dur = it.get("args").unwrap().get("sim_dur_us").unwrap().as_f64().unwrap();
        assert!((sim_dur - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn orphan_begin_is_skipped_not_corrupting() {
        let mut t = Tracer::new(TraceConfig::on());
        t.iter_begin(0, 0.0);
        t.begin(names::DRAFT, Track::Engine, 0.0);
        // no end for draft; verify opens and closes cleanly
        t.begin(names::VERIFY, Track::Engine, 0.0);
        t.end(names::VERIFY, Track::Engine, 0.0, vec![]);
        t.iter_end(0.001, vec![]);
        let evs = chrome_events(&t);
        let names_out: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert!(names_out.contains(&names::VERIFY));
        // the unmatched draft begin does not appear as a span...
        assert!(!names_out.contains(&names::DRAFT));
        // ...and the iteration end unwound past it and still paired.
        assert!(names_out.contains(&names::ITERATION));
    }

    #[test]
    fn sampling_skips_iterations_but_keeps_lifecycle() {
        let mut t = Tracer::new(TraceConfig::on().with_sampling(4));
        for iter in 0..8u64 {
            t.iter_begin(iter, iter as f64);
            assert_eq!(t.hot(), iter % 4 == 0, "iter {iter}");
            t.begin(names::DRAFT, Track::Engine, iter as f64);
            t.end(names::DRAFT, Track::Engine, iter as f64, vec![]);
            t.instant(names::SESSION_SUBMIT, Track::Session, iter as f64, vec![]);
            t.iter_end(iter as f64 + 0.5, vec![]);
        }
        let instants = t
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Instant)
            .count();
        assert_eq!(instants, 8, "lifecycle instants are never sampled away");
        let begins = t
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Begin && e.name == names::DRAFT)
            .count();
        assert_eq!(begins, 2, "iterations 0 and 4 only");
    }

    #[test]
    fn ring_buffer_bounds_memory_and_counts_drops() {
        let mut t = Tracer::new(TraceConfig::on().with_capacity(16));
        for iter in 0..64u64 {
            t.iter_begin(iter, 0.0);
            t.iter_end(0.0, vec![]);
        }
        assert_eq!(t.len(), 16);
        assert_eq!(t.dropped(), 2 * 64 - 16);
        // export still parses and reports the drop count
        let parsed = Json::parse(&t.export_chrome_string()).unwrap();
        assert_eq!(
            parsed.get("otherData").unwrap().get("dropped_events").unwrap().as_f64(),
            Some((2 * 64 - 16) as f64)
        );
    }

    #[test]
    fn async_events_pass_through_with_ids() {
        let mut t = Tracer::new(TraceConfig::on());
        t.async_begin(names::KV_OFFLOAD, Track::Kv, 3, 0.0, vec![("bytes", 1024.0.into())]);
        t.async_begin(names::KV_OFFLOAD, Track::Kv, 4, 0.1, vec![]);
        t.async_end(names::KV_OFFLOAD, Track::Kv, 3, 0.2, vec![]);
        t.async_end(names::KV_OFFLOAD, Track::Kv, 4, 0.3, vec![]);
        let evs = chrome_events(&t);
        let b: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("b"))
            .collect();
        let e: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("e"))
            .collect();
        assert_eq!((b.len(), e.len()), (2, 2));
        assert_eq!(b[0].get("id").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let mut t = Tracer::new(TraceConfig::on());
        t.iter_begin(0, 0.0);
        t.counter("kv_used_tokens", 0.0, 42.0);
        t.iter_end(0.001, vec![("gemm_rows", 12.0.into())]);
        let text = t.export_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for l in lines {
            let v = Json::parse(l).expect("jsonl line parses");
            assert!(v.get("sim_us").is_some());
            assert!(v.get("wall_us").is_some());
        }
    }

    #[test]
    fn counter_shape_matches_chrome_schema() {
        let mut t = Tracer::new(TraceConfig::on());
        t.iter_begin(0, 1.0);
        t.counter("queue_depth", 1.0, 5.0);
        let evs = chrome_events(&t);
        let c = evs
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("C"))
            .expect("counter event present");
        assert_eq!(c.get("args").unwrap().get("value").unwrap().as_f64(), Some(5.0));
        assert_eq!(c.get("args").unwrap().get("sim_us").unwrap().as_f64(), Some(1e6));
    }
}
