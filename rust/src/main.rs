//! SparseSpec CLI — the Layer-3 launcher.
//!
//! Subcommands:
//!   serve   run one engine configuration over a generated workload
//!   bench   regenerate a paper table/figure (or `all`)
//!   info    show artifact + config summary
//!
//! Examples:
//!   sparsespec serve --drafter pillar --dataset aime --requests 16 --k 8
//!   sparsespec bench fig10
//!   sparsespec bench all --out reports

use std::rc::Rc;

use sparsespec::bench::{run_named, BenchCtx};
use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::runtime::Runtime;
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;
use sparsespec::workload::{Dataset, WorkloadGen};

fn usage() -> ! {
    eprintln!(
        "usage: sparsespec <serve|bench|info> [flags]\n\
         serve flags: --drafter vanilla|pillar|magicdec|oracle|ngram|eagle|triforce\n\
         \x20            --dataset aime|olympiad|livecode|short  --requests N  --k K  --w W\n\
         \x20            --schedule lockstep|unified  --delayed  --kv-policy conservative|preempt|dynamic\n\
         \x20            --kv-budget TOKENS  --temp T  --seed S  --online-rate R --horizon SECS\n\
         \x20            --adaptive-k  (feedback-adaptive speculation length, bounded by --k)\n\
         \x20            --workload-in FILE  --workload-out FILE  (request trace replay/save)\n\
         \x20            --trace-out FILE  (Perfetto/Chrome trace JSON)  --trace-sample N\n\
         \x20            --metrics-out FILE  (Prometheus text exposition)  --ttft-slo SECS\n\
         \x20            --fault-plan SPEC  (chaos: site:rate[,site:rate..]; sites: runtime,\n\
         \x20            kv_offload, kv_reload, verify_stall, drafter_panic, drafter_malformed)\n\
         \x20            --fault-seed S  (fault schedule seed, default 0)\n\
         bench:  table1 fig2 fig3 fig4 fig5 table2 fig10 fig11 fig12_accept fig12_sens fig13 fig14 fig15 pillar_select drafter_dispatch trace_overhead fault_overhead all\n\
         common: --artifacts DIR (default ./artifacts)  --out DIR (default ./reports)"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let artifacts = args.str("artifacts", "artifacts");
    match cmd {
        "info" => {
            let rt = Runtime::load(&artifacts)?;
            println!("platform: {}", rt.platform_name());
            println!("model: {:?}", rt.cfg.model);
            println!("params: {} (trained: {})", rt.cfg.n_params, rt.cfg.trained);
            println!("artifacts ({}):", rt.cfg.artifacts.len());
            for (name, info) in &rt.cfg.artifacts {
                println!("  {name:<16} {}", info.file);
            }
            Ok(())
        }
        "serve" => {
            let rt = Rc::new(Runtime::load(&artifacts)?);
            let w = args.usize("w", rt.cfg.model.draft_budget);
            let n = args.usize("ngram-n", 3);
            let drafter = DrafterKind::parse(&args.str("drafter", "pillar"), w, n)
                .unwrap_or_else(|| usage());
            let dataset =
                Dataset::parse(&args.str("dataset", "aime")).unwrap_or_else(|| usage());
            let schedule = Schedule::parse(&args.str("schedule", "lockstep"))
                .unwrap_or_else(|| usage());
            let kv_policy = KvPolicy::parse(&args.str("kv-policy", "dynamic"))
                .unwrap_or_else(|| usage());
            let mut cfg = EngineConfig::new(drafter)
                .with_k(args.usize("k", rt.cfg.model.spec_k))
                .with_schedule(schedule, args.bool("delayed", false))
                .with_kv(kv_policy, args.usize("kv-budget", usize::MAX / 2));
            cfg.temperature = args.f64("temp", 0.0) as f32;
            cfg.seed = args.u64("seed", 7);
            cfg.verbose = args.bool("verbose", false);
            cfg.adaptive_k = args.bool("adaptive-k", false);
            cfg.ttft_slo_s = args.f64("ttft-slo", 1.0);
            let trace_out = args.opt("trace-out").map(|s| s.to_string());
            if trace_out.is_some() {
                cfg.trace = sparsespec::trace::TraceConfig::on()
                    .with_sampling(args.usize("trace-sample", 1));
            }
            if let Some(spec) = args.opt("fault-plan") {
                let plan = sparsespec::fault::FaultPlan::parse(spec)?;
                cfg.fault = sparsespec::fault::FaultConfig::new(plan, args.u64("fault-seed", 0));
                println!(
                    "chaos: fault plan [{}] seed {}",
                    cfg.fault.plan.to_spec(),
                    cfg.fault.seed
                );
            }
            let mut gen = WorkloadGen::new(
                rt.cfg.grammar.clone(),
                rt.cfg.model.clone(),
                dataset,
                args.u64("seed", 7),
            );
            let reqs = if let Some(path) = args.opt("workload-in") {
                sparsespec::workload::trace::load(path)?
            } else if let Some(rate) = args.opt("online-rate") {
                let rate: f64 = rate.parse().unwrap_or(2.0);
                gen.online_trace(rate, args.f64("horizon", 30.0))
            } else {
                gen.offline_batch(args.usize("requests", 12))
            };
            if let Some(path) = args.opt("workload-out") {
                sparsespec::workload::trace::save(path, &reqs)?;
                println!("workload trace saved to {path}");
            }
            println!(
                "serving {} {} requests with {}",
                reqs.len(),
                dataset.name(),
                drafter.name()
            );
            let mut engine = Engine::new(rt, cfg)?;
            let report = engine.run(reqs)?;
            println!("{}", report.summary());
            let lat = &report.request_latency_s;
            if !lat.is_empty() {
                println!(
                    "request latency: p50={:.2}s p99={:.2}s",
                    lat.percentile(50.0),
                    lat.percentile(99.0)
                );
            }
            let slo = &report.slo;
            if !slo.ttft_sim_s.is_empty() {
                println!(
                    "slo (sim): ttft p50={:.3}s p99={:.3}s  itl p50={:.4}s p99={:.4}s  \
                     goodput={:.2} req/s ({}/{} within {:.2}s ttft)",
                    slo.ttft_sim_s.percentile(50.0),
                    slo.ttft_sim_s.percentile(99.0),
                    slo.itl_sim_s.percentile(50.0),
                    slo.itl_sim_s.percentile(99.0),
                    slo.goodput_rps,
                    slo.completed_within_ttft,
                    slo.completed,
                    slo.ttft_target_s,
                );
            }
            if let Some(path) = &trace_out {
                std::fs::write(path, engine.export_trace_chrome())?;
                println!(
                    "perfetto trace saved to {path} ({} events, {} dropped)",
                    engine.tracer().len(),
                    engine.tracer().dropped()
                );
            }
            if let Some(path) = args.opt("metrics-out") {
                std::fs::write(path, report.registry().expose_prometheus("sparsespec"))?;
                println!("metrics exposition saved to {path}");
            }
            if args.bool("stats", false) {
                println!("\nper-artifact phase times (s):");
                println!(
                    "{:<16} {:>6} {:>9} {:>9} {:>9}",
                    "artifact", "calls", "upload", "exec", "fetch"
                );
                for (name, p) in &report.step_stats.per_artifact {
                    println!(
                        "{:<16} {:>6} {:>9.3} {:>9.3} {:>9.3}",
                        name, p.calls, p.upload_s, p.exec_s, p.fetch_s
                    );
                }
            }
            Ok(())
        }
        "bench" => {
            let name = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let mut ctx = BenchCtx::new(&artifacts, &args.str("out", "reports"))?;
            ctx.n_requests = args.usize("requests", 12);
            ctx.seed = args.u64("seed", 42);
            run_named(&mut ctx, name)
        }
        _ => usage(),
    }
}
