//! Bit-identity suite for the arena-backed hot path: the slot-parallel
//! sim kernels and the pooled verify processing are *optimisations*, so
//! `EngineConfig::parallel` must not change a single output bit —
//! outputs, iteration counts, the schedule trace, and the structured
//! trace span-name sequence all have to match the serial path exactly.
//! Plus: `ThreadPool::scope` over disjoint chunks is deterministic for
//! any worker count (the property the kernels' fan-out relies on).

use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig, RunReport};
use sparsespec::runtime::Runtime;
use sparsespec::spec::DrafterKind;
use sparsespec::trace::TraceConfig;
use sparsespec::util::json::Json;
use sparsespec::util::threadpool::ThreadPool;
use sparsespec::workload::{Dataset, WorkloadGen};

fn runtime() -> Rc<Runtime> {
    let dir = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Rc::new(Runtime::load(&dir).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize) -> Vec<sparsespec::workload::Request> {
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, 7)
            .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

/// `ph:name` per journal line — everything about a span that must be
/// schedule-determined (wall timestamps/durations legitimately differ).
fn span_names(jsonl: &str) -> Vec<String> {
    jsonl
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let j = Json::parse(l).expect("journal line parses");
            format!(
                "{}:{}",
                j.get("ph").and_then(|x| x.as_str()).unwrap_or("?"),
                j.get("name").and_then(|x| x.as_str()).unwrap_or("?")
            )
        })
        .collect()
}

fn run_once(
    rt: &Rc<Runtime>,
    drafter: DrafterKind,
    parallel: bool,
    temperature: f32,
) -> (RunReport, Vec<String>) {
    let mut cfg = EngineConfig::new(drafter).with_k(8);
    cfg.parallel = parallel;
    cfg.temperature = temperature;
    cfg.trace = TraceConfig::on();
    let mut eng = Engine::new(rt.clone(), cfg).unwrap();
    let rep = eng.run(small_requests(rt, 4, 40)).unwrap();
    let names = span_names(&eng.export_trace_jsonl());
    (rep, names)
}

fn assert_identical(drafter: DrafterKind, temperature: f32) {
    let rt = runtime();
    let (par, par_spans) = run_once(&rt, drafter, true, temperature);
    let (ser, ser_spans) = run_once(&rt, drafter, false, temperature);
    let tag = format!("{} t={temperature}", drafter.name());
    assert_eq!(par.outputs, ser.outputs, "outputs diverged [{tag}]");
    assert_eq!(par.iterations, ser.iterations, "iterations diverged [{tag}]");
    assert_eq!(
        par.tokens_generated, ser.tokens_generated,
        "token counts diverged [{tag}]"
    );
    assert_eq!(
        par.trace.csv(),
        ser.trace.csv(),
        "schedule trace diverged [{tag}]"
    );
    assert_eq!(par_spans, ser_spans, "trace span names diverged [{tag}]");
    assert!(!par_spans.is_empty(), "tracing was on but no spans [{tag}]");
}

#[test]
fn pillar_greedy_bit_identical_parallel_vs_serial() {
    assert_identical(DrafterKind::Pillar { w: 64 }, 0.0);
}

#[test]
fn pillar_stochastic_bit_identical_parallel_vs_serial() {
    // Temperature > 0 exercises the verify rng-seed draw order — the
    // serial path must consume the engine rng in the same per-slot order
    // as the pooled path.
    assert_identical(DrafterKind::Pillar { w: 64 }, 0.8);
}

#[test]
fn ngram_bit_identical_parallel_vs_serial() {
    assert_identical(DrafterKind::NGram { n: 3 }, 0.0);
}

#[test]
fn eagle_bit_identical_parallel_vs_serial() {
    assert_identical(DrafterKind::Eagle, 0.0);
}

#[test]
fn vanilla_bit_identical_parallel_vs_serial() {
    assert_identical(DrafterKind::Vanilla, 0.0);
}

#[test]
fn triforce_bit_identical_parallel_vs_serial() {
    // TriForce drives the sparse-verify kernel (visibility bitmask path).
    assert_identical(DrafterKind::TriForce { w: 64 }, 0.0);
}

/// The fan-out shape the kernels use — disjoint `chunks_mut` of one
/// buffer, one boxed job per worker chunk — must produce byte-identical
/// buffers for every worker count, including counts that do not divide
/// the slot count.
#[test]
fn threadpool_chunked_fill_deterministic_for_any_worker_count() {
    let (slots, per) = (13usize, 37usize);
    let fill = |s: usize, out: &mut [f32]| {
        for (i, x) in out.iter_mut().enumerate() {
            *x = ((s * 1_000_003 + i * 7919) % 104_729) as f32;
        }
    };
    let mut want = vec![0.0f32; slots * per];
    for (s, ch) in want.chunks_mut(per).enumerate() {
        fill(s, ch);
    }
    for workers in [1usize, 2, 3, 5, 8] {
        let pool = ThreadPool::new(workers);
        let mut got = vec![-1.0f32; slots * per];
        let nc = workers.min(slots);
        let spc = slots.div_ceil(nc);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = got
            .chunks_mut(spc * per)
            .enumerate()
            .map(|(ci, bch)| {
                Box::new(move || {
                    for (r, out) in bch.chunks_mut(per).enumerate() {
                        fill(ci * spc + r, out);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(got, want, "worker count {workers} changed the fill");
    }
}

/// Kernel-level spot check against the seed-era executable spec (the
/// same oracle the `engine_iteration` bench baselines against).
#[cfg(not(feature = "pjrt"))]
#[test]
fn arena_kernels_match_reference_runner() {
    use sparsespec::runtime::{reference, ModelRunner};
    let rt = runtime();
    let m = rt.cfg.model.clone();
    let (s, pad) = (m.slots, m.prompt_pad);
    let q = m.spec_k + 1;
    let w = m.draft_budget;
    let per_head = m.layers * m.kv_heads;

    let active: Vec<i32> = (0..s).map(|i| (i % 2 == 0) as i32).collect();
    let ptokens: Vec<i32> = (0..s * pad).map(|i| (i % 97) as i32 + 1).collect();
    let plen = vec![pad as i32; s];
    let dtok: Vec<i32> = (0..s).map(|x| (x as i32 % 31) + 2).collect();
    let pos = vec![pad as i32; s];
    let vtok: Vec<i32> = (0..s * q).map(|i| (i % 89) as i32 + 1).collect();
    let qv = vec![q as i32; s];
    let idx: Vec<i32> = (0..s * per_head * w).map(|i| ((i * 13) % pad) as i32).collect();

    let mut rr = reference::Runner::new(m.clone(), rt.cfg.eagle.ctx);
    let ref_prefill = rr.prefill(&ptokens, &plen, &active);
    let ref_draft = rr.draft(w, &dtok, &pos, &idx, &active);
    let (ref_vl, ref_vd) = rr.verify(q, &vtok, &pos, &qv, &active);
    let ref_sv = rr.sparse_verify(&vtok, &pos, &qv, &idx, &active);

    for parallel in [false, true] {
        let mut r = ModelRunner::new(rt.clone()).unwrap();
        r.set_parallel(parallel);
        r.prefill(&ptokens, &plen, &active).unwrap();
        assert_eq!(r.logits(), &ref_prefill[..], "prefill parallel={parallel}");
        r.draft(w, &dtok, &pos, &idx, &active).unwrap();
        assert_eq!(r.logits(), &ref_draft[..], "draft parallel={parallel}");
        r.verify(q, &vtok, &pos, &qv, &active).unwrap();
        assert_eq!(r.logits(), &ref_vl[..], "verify logits parallel={parallel}");
        assert_eq!(r.dump(), &ref_vd[..], "verify dump parallel={parallel}");
        r.sparse_verify(&vtok, &pos, &qv, &idx, &active).unwrap();
        assert_eq!(r.logits(), &ref_sv[..], "sparse_verify parallel={parallel}");
    }
}
