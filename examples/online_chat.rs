//! Latency-oriented online serving (§2.2), session-style: Poisson arrivals
//! stream in on the serving clock, tokens stream out as verification
//! accepts them, and one unlucky request is cancelled mid-generation.
//!
//! Demonstrates the full session API: `EngineDriver` + `online_arrivals`
//! (no pre-materialised trace), incremental `SessionHandle::drain`,
//! per-session TTFT / inter-token stats, `cancel()` isolation (the
//! cancelled request releases its slot + KV without disturbing anyone
//! else — checked against a batch reference run of the same trace), and a
//! **mixed-drafter session pool**: per-session drafter overrides serve
//! pillar + ngram + vanilla sessions through ONE engine with per-drafter
//! acceptance/TTFT columns.
//!
//!   cargo run --release --example online_chat [-- --rate 1.5 --horizon 20]
//!   (add `--trace-out trace.json` to export a Perfetto trace of the
//!    live-serving run; add `--fault-plan runtime:0.02,verify_stall:0.1`
//!    to serve the same trace under injected transient faults)


use std::rc::Rc;

use sparsespec::engine::{
    Engine, EngineConfig, EngineDriver, EngineHandle, FinishReason,
};
use sparsespec::metrics::{latency_block, p50_cell};
use sparsespec::runtime::Runtime;
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rt = Rc::new(Runtime::load(&args.str("artifacts", "artifacts"))?);
    let rate = args.f64("rate", 1.5);
    let horizon = args.f64("horizon", 20.0);
    let mk_gen = || {
        WorkloadGen::new(
            rt.cfg.grammar.clone(),
            rt.cfg.model.clone(),
            Dataset::LiveCodeBench,
            17,
        )
    };
    let trace_out = args.opt("trace-out").map(|s| s.to_string());
    // Optional chaos (`--fault-plan site:rate,... --fault-seed N`): live
    // serving under injected faults — retries and degradations show up in
    // the summary line, greedy outputs stay schedule-independent.
    let fault_cfg = match args.opt("fault-plan") {
        Some(spec) => sparsespec::fault::FaultConfig::new(
            sparsespec::fault::FaultPlan::parse(spec)?,
            args.u64("fault-seed", 0),
        ),
        None => sparsespec::fault::FaultConfig::off(),
    };
    let mk_cfg = |traced: bool| {
        let mut b = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
            .k(8)
            .schedule(Schedule::Unified)
            .delayed_verify(true)
            .faults(fault_cfg.clone());
        if traced {
            b = b.tracing(sparsespec::trace::TraceConfig::on());
        }
        b.build(&rt.cfg.model)
    };

    // Batch reference over the identical trace (greedy decoding, so
    // per-request outputs are schedule-independent): the oracle for the
    // cancellation-isolation check below.
    let reference = {
        let reqs = mk_gen().online_trace(rate, horizon);
        println!(
            "trace: {} arrivals over {horizon}s at {rate}/s (LiveCodeBench profile)",
            reqs.len()
        );
        let mut eng = Engine::new(rt.clone(), mk_cfg(false)?)?;
        eng.run(reqs)?
    };

    // Live serving: requests are admitted when they arrive on the serving
    // clock; tokens are pulled incrementally from each session.
    let mut driver = EngineDriver::with_arrivals(
        EngineHandle::new(rt.clone(), mk_cfg(trace_out.is_some())?)?,
        mk_gen().online_arrivals(rate, horizon),
    );
    let mut streamed = 0usize;
    let mut cancelled_id: Option<u64> = None;
    while driver.step()? {
        for s in driver.sessions() {
            streamed += s.drain().len();
        }
        // Cancel the third admitted request once it is visibly mid-
        // generation (a few tokens out, more to come).
        if cancelled_id.is_none() && driver.sessions().len() >= 3 {
            let victim = driver.sessions()[2].clone();
            if !victim.is_finished() && victim.tokens_delivered() >= 4 {
                victim.cancel();
                cancelled_id = Some(victim.id());
            }
        }
    }
    if let Some(path) = &trace_out {
        std::fs::write(path, driver.tracer().export_chrome_string())?;
        println!("  perfetto trace saved to {path}");
    }
    let report = driver.report();
    println!("  {}", report.summary());
    println!(
        "  streamed {} tokens incrementally across {} sessions ({} cancelled)",
        streamed,
        driver.sessions().len(),
        report.requests_cancelled,
    );

    // Streaming latency metrics (wallclock), from per-session stats —
    // rendered by the shared helper the client binary also uses.
    let m = driver.session_metrics();
    print!("{}", latency_block(&m, &[]));

    // Cancellation isolation: every non-cancelled session's output must be
    // bit-identical to the batch reference; the cancelled one kept its
    // partial stream and released slot + KV.
    if let Some(vid) = cancelled_id {
        let mut intact = 0usize;
        for (id, out) in &reference.outputs {
            if *id == vid {
                continue;
            }
            assert_eq!(
                Some(out),
                report.outputs.get(id),
                "cancelling {vid} disturbed request {id}"
            );
            intact += 1;
        }
        let victim = driver
            .sessions()
            .iter()
            .find(|s| s.id() == vid)
            .expect("victim session");
        assert_eq!(victim.finish_reason(), Some(FinishReason::Cancelled));
        println!(
            "  cancelled session {vid} after {} tokens ({} expected); \
             {intact} other outputs bit-identical to the batch reference",
            victim.tokens_delivered(),
            reference.outputs.get(&vid).map(|o| o.len()).unwrap_or(0),
        );
    } else {
        println!("  (trace too short to stage a cancellation demo)");
    }

    // ------------------------------------------------------------------
    // Mixed-drafter session pool: the same engine serves pillar (the
    // engine default), ngram and vanilla sessions concurrently via
    // per-session overrides; columns missing a sample print `n/a`
    // (vanilla never drafts, so it has no alpha).
    // ------------------------------------------------------------------
    println!("\nmixed-drafter session pool (per-session override):");
    let pool_cfg = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
        .k(8)
        .allow_drafter(DrafterKind::NGram { n: 3 })
        .allow_drafter(DrafterKind::Vanilla)
        .build(&rt.cfg.model)?;
    let mut pool = EngineDriver::new(EngineHandle::new(rt.clone(), pool_cfg)?);
    let kinds = [None, Some(DrafterKind::NGram { n: 3 }), Some(DrafterKind::Vanilla)];
    let mut gen = mk_gen();
    for i in 0..9u64 {
        let mut r = gen.next_request(0.0);
        r.id = 10_000 + i;
        r.max_new = r.max_new.min(48);
        r.drafter = kinds[i as usize % kinds.len()];
        pool.submit(r);
    }
    pool.drive()?;
    let pr = pool.report();
    println!("  {}", pr.summary());
    let pm = pool.session_metrics();
    println!(
        "  {:<14} {:>9} {:>8} {:>8} {:>12}",
        "drafter", "sessions", "acc/rnd", "alpha", "ttft p50(s)"
    );
    for (name, acc) in &pr.accept_by {
        let by: &[(&str, &str)] = &[("drafter", name)];
        let sessions = pm.counter("sessions_completed", by);
        let acc_rnd = if acc.rounds > 0 {
            format!("{:>8.2}", acc.mean_accepted())
        } else {
            format!("{:>8}", "n/a")
        };
        let alpha = if acc.drafted > 0 {
            format!("{:>8.2}", acc.alpha())
        } else {
            format!("{:>8}", "n/a")
        };
        let ttft = p50_cell(&pm, "ttft_s", by, 12, 4);
        println!("  {name:<14} {sessions:>9} {acc_rnd} {alpha} {ttft}");
    }
    assert_eq!(pr.requests_done, 9, "mixed pool must serve every session");
    Ok(())
}
