//! Workload generation: the reasoning-trace grammar (shared with training),
//! dataset profiles mirroring the paper's Table 1 length statistics, and
//! request traces (batch/offline and Poisson online arrivals).

pub mod grammar;
pub mod trace;

pub use grammar::{classify_next, TokenClass, TraceGen};

use crate::model::{GrammarConfig, ModelConfig};
use crate::spec::DrafterKind;
use crate::util::rng::Xoshiro256;

/// One serving request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Generation budget for this request (the "output length" the paper's
    /// datasets induce; unknown to admission policies unless oracle).
    pub max_new: usize,
    /// Arrival time in seconds from trace start (0 for offline batches).
    pub arrival_s: f64,
    /// Grammar seed — continuation of the prompt's trace, used by the
    /// N-gram-style drafters for *their* view of history only.
    pub seed: u64,
    /// Per-session drafter override: `None` uses the engine default;
    /// `Some(kind)` resolves through the engine's `DrafterRegistry` at
    /// submit time (invalid kinds reject the session without queuing it).
    pub drafter: Option<DrafterKind>,
}

/// Dataset profiles: the paper's Table 1 (Qwen3-14B outputs), linearly
/// scaled by 1/50 to our 512-token context window.  Input lengths scale to
/// our 32-token prompt pad.  `scale_note` documents the mapping in reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// AIME: 13185 ± 7626 out  ->  264 ± 152
    Aime,
    /// OlympiadBench: 10233 ± 7889  ->  205 ± 158
    OlympiadBench,
    /// LiveCodeBench: 10254 ± 7458  ->  205 ± 149
    LiveCodeBench,
    /// Non-reasoning reference (Qwen2.5-32B column of Table 1, AIME row):
    /// 1732 ± 997 -> 35 ± 20.  Used for the Table 1 contrast.
    NonReasoningAime,
    /// The long-generation *steady-state* slice of AIME (400 ± 60): the
    /// paper's 10K+-token regime, where resident contexts dwarf the draft
    /// budget.  Uniform 1/50 scaling of the whole AIME distribution keeps
    /// many short requests whose contexts are comparable to W (s_eff ~ 0.4,
    /// a regime the paper never operates in); this slice restores the
    /// paper's context-to-budget ratio as far as the 512-token window
    /// allows (s_eff ~ 0.16).
    AimeLong,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "aime" => Some(Dataset::Aime),
            "olympiad" | "olympiadbench" => Some(Dataset::OlympiadBench),
            "livecode" | "livecodebench" | "lcb" => Some(Dataset::LiveCodeBench),
            "nonreasoning" | "short" => Some(Dataset::NonReasoningAime),
            "aimelong" | "aime-long" | "long" => Some(Dataset::AimeLong),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Aime => "AIME",
            Dataset::OlympiadBench => "OlympiadBench",
            Dataset::LiveCodeBench => "LiveCodeBench",
            Dataset::NonReasoningAime => "NonReasoning",
            Dataset::AimeLong => "AIME-long",
        }
    }

    /// (mean, std) of the scaled output-length distribution.
    pub fn out_profile(&self) -> (f64, f64) {
        match self {
            Dataset::Aime => (264.0, 152.0),
            Dataset::OlympiadBench => (205.0, 158.0),
            Dataset::LiveCodeBench => (205.0, 149.0),
            Dataset::NonReasoningAime => (35.0, 20.0),
            Dataset::AimeLong => (400.0, 60.0),
        }
    }

    /// Paper-scale (unscaled) statistics, for the Table 1 report.
    pub fn paper_profile(&self) -> (f64, f64) {
        match self {
            Dataset::Aime => (13185.0, 7626.0),
            Dataset::OlympiadBench => (10233.0, 7889.0),
            Dataset::LiveCodeBench => (10254.0, 7458.0),
            Dataset::NonReasoningAime => (1732.0, 997.0),
            Dataset::AimeLong => (13185.0, 7626.0),
        }
    }

    pub fn all() -> [Dataset; 3] {
        [Dataset::Aime, Dataset::OlympiadBench, Dataset::LiveCodeBench]
    }
}

/// Arrival-curve shapes for the online request generators.
///
/// `Uniform` is the homogeneous Poisson process — the original code path,
/// bit-identical RNG consumption to the pre-curve generators.  The other
/// shapes are *nonhomogeneous* Poisson processes sampled by thinning
/// (candidates at the peak rate, each kept with probability
/// `λ(t)/λ_peak`), which preserves per-seed determinism: the same seed,
/// rate, horizon and curve always yield the same trace.  Both shapes
/// preserve the requested mean rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalCurve {
    /// Constant rate (homogeneous Poisson).
    Uniform,
    /// Square wave with 50% duty cycle over [`ArrivalCurve::BURSTY_CYCLES`]
    /// cycles per horizon: bursts at `ratio`× the quiet rate.
    Bursty { ratio: f64 },
    /// Sinusoid with one cycle per horizon (a compressed day);
    /// peak-to-trough rate ratio is `ratio`.
    Diurnal { ratio: f64 },
}

impl ArrivalCurve {
    /// Burst/quiet alternations per horizon for `Bursty`.
    pub const BURSTY_CYCLES: f64 = 4.0;

    /// Parse `"uniform"`, `"bursty:<ratio>"` or `"diurnal:<ratio>"`
    /// (ratio > 1).
    pub fn parse(s: &str) -> Option<ArrivalCurve> {
        let s = s.trim().to_ascii_lowercase();
        if s == "uniform" || s == "poisson" {
            return Some(ArrivalCurve::Uniform);
        }
        let (kind, ratio) = s.split_once(':')?;
        let ratio: f64 = ratio.parse().ok()?;
        if !ratio.is_finite() || ratio <= 1.0 {
            return None;
        }
        match kind {
            "bursty" => Some(ArrivalCurve::Bursty { ratio }),
            "diurnal" => Some(ArrivalCurve::Diurnal { ratio }),
            _ => None,
        }
    }

    /// `λ(t) / λ_mean` — normalised intensity at `t ∈ [0, horizon)`.
    fn intensity(&self, t: f64, horizon_s: f64) -> f64 {
        match *self {
            ArrivalCurve::Uniform => 1.0,
            ArrivalCurve::Bursty { ratio } => {
                // mean-preserving square wave: burst = 2r/(r+1)·mean,
                // quiet = 2/(r+1)·mean
                let phase = (t / horizon_s * Self::BURSTY_CYCLES).fract();
                if phase < 0.5 {
                    2.0 * ratio / (ratio + 1.0)
                } else {
                    2.0 / (ratio + 1.0)
                }
            }
            ArrivalCurve::Diurnal { ratio } => {
                // 1 + a·sin: (1+a)/(1-a) = ratio  ⇒  a = (r-1)/(r+1)
                let a = (ratio - 1.0) / (ratio + 1.0);
                1.0 + a * (2.0 * std::f64::consts::PI * t / horizon_s).sin()
            }
        }
    }

    /// `max_t λ(t) / λ_mean` — the thinning envelope.
    fn peak(&self) -> f64 {
        match *self {
            ArrivalCurve::Uniform => 1.0,
            ArrivalCurve::Bursty { ratio } => 2.0 * ratio / (ratio + 1.0),
            ArrivalCurve::Diurnal { ratio } => 1.0 + (ratio - 1.0) / (ratio + 1.0),
        }
    }

    /// Advance `t` to the next accepted arrival.  Returns `false` once
    /// past the horizon.  `Uniform` takes the plain exponential-gap path
    /// (identical RNG stream to the pre-curve generators); curved shapes
    /// thin candidates drawn at the peak rate.
    fn next_arrival(&self, rng: &mut Xoshiro256, t: &mut f64, rate: f64, horizon_s: f64) -> bool {
        match self {
            ArrivalCurve::Uniform => {
                *t += rng.exponential(rate);
                *t <= horizon_s
            }
            curved => {
                let peak = curved.peak();
                loop {
                    *t += rng.exponential(rate * peak);
                    if *t > horizon_s {
                        return false;
                    }
                    if rng.unit() < curved.intensity(*t, horizon_s) / peak {
                        return true;
                    }
                }
            }
        }
    }
}

/// Generates request traces for a dataset profile.
pub struct WorkloadGen {
    pub grammar: GrammarConfig,
    pub model: ModelConfig,
    pub dataset: Dataset,
    rng: Xoshiro256,
    next_id: u64,
}

impl WorkloadGen {
    pub fn new(grammar: GrammarConfig, model: ModelConfig, dataset: Dataset, seed: u64) -> Self {
        WorkloadGen {
            grammar,
            model,
            dataset,
            rng: Xoshiro256::new(seed ^ 0xDA7A_5E7),
            next_id: 0,
        }
    }

    /// Clamp generation budget so prompt + output (+ draft overshoot k)
    /// always fits the KV window.
    fn clamp_new(&self, n: f64) -> usize {
        let hi = self.model.max_seq - self.model.prompt_pad - self.model.spec_k - 2;
        (n.round() as usize).clamp(8, hi)
    }

    pub fn next_request(&mut self, arrival_s: f64) -> Request {
        let (mean, std) = self.dataset.out_profile();
        let raw = self.rng.lognormal_mean_std(mean, std);
        let max_new = self.clamp_new(raw);
        let seed = self.rng.next_u64();
        let prompt = TraceGen::prompt(seed, self.grammar.clone());
        let id = self.next_id;
        self.next_id += 1;
        Request { id, prompt, max_new, arrival_s, seed, drafter: None }
    }

    /// Offline batch: `n` requests, all available at t=0 (the RL-rollout /
    /// throughput-oriented setting of §2.2).
    pub fn offline_batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request(0.0)).collect()
    }

    /// Online trace: Poisson arrivals at `rate` req/s for `horizon_s`,
    /// fully materialised (batch/replay use).  `online_arrivals` is the
    /// streaming equivalent for the session-serving driver and yields the
    /// identical sequence for the same generator state.
    pub fn online_trace(&mut self, rate: f64, horizon_s: f64) -> Vec<Request> {
        self.online_trace_curve(rate, horizon_s, ArrivalCurve::Uniform)
    }

    /// `online_trace` under an [`ArrivalCurve`]: `Uniform` reproduces the
    /// plain Poisson trace bit-for-bit; `Bursty`/`Diurnal` shape the
    /// instantaneous rate (production-shaped load for the serving
    /// client) while preserving the mean and per-seed determinism.
    pub fn online_trace_curve(
        &mut self,
        rate: f64,
        horizon_s: f64,
        curve: ArrivalCurve,
    ) -> Vec<Request> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while curve.next_arrival(&mut self.rng, &mut t, rate, horizon_s) {
            let r = self.next_request(t);
            out.push(r);
        }
        out
    }

    /// Streaming Poisson arrival process: consumes the generator and
    /// yields requests one at a time with increasing `arrival_s`, so an
    /// `EngineDriver` can interleave admission with decode iterations
    /// instead of materialising the whole trace upfront.
    pub fn online_arrivals(self, rate: f64, horizon_s: f64) -> OnlineArrivals {
        self.online_arrivals_curve(rate, horizon_s, ArrivalCurve::Uniform)
    }

    /// Streaming form of [`WorkloadGen::online_trace_curve`] — identical
    /// sequence for the same generator state, curve included.
    pub fn online_arrivals_curve(
        self,
        rate: f64,
        horizon_s: f64,
        curve: ArrivalCurve,
    ) -> OnlineArrivals {
        OnlineArrivals { gen: self, rate, horizon_s, curve, t: 0.0, done: false }
    }
}

/// Iterator form of the Poisson online trace (see
/// `WorkloadGen::online_arrivals`).  Bit-identical to `online_trace` for
/// the same generator state and parameters.
pub struct OnlineArrivals {
    gen: WorkloadGen,
    rate: f64,
    horizon_s: f64,
    curve: ArrivalCurve,
    t: f64,
    done: bool,
}

impl Iterator for OnlineArrivals {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        if !self
            .curve
            .next_arrival(&mut self.gen.rng, &mut self.t, self.rate, self.horizon_s)
        {
            self.done = true;
            return None;
        }
        Some(self.gen.next_request(self.t))
    }
}

/// How a multi-replica load generator splits one trace across N shards
/// (one shard per client connection / replica in scale-out experiments).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ShardShape {
    /// Round-robin: every shard sees the same arrival mix.
    Even,
    /// Hot-spot: shard 0 receives the `hot` fraction of requests, the
    /// remainder round-robins over the other shards.  Stresses the
    /// router's least-loaded balancing.
    Skewed { hot: f64 },
    /// Rank requests by projected KV cost (`prompt + max_new`) and give
    /// each shard one contiguous cost quantile — shard 0 the shortest,
    /// the last shard the longest.  Stresses bucket-aware placement.
    ByLength,
}

impl ShardShape {
    /// `even` | `skewed:<hot-fraction>` | `bylength`.
    pub fn parse(s: &str) -> Option<ShardShape> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "even" => return Some(ShardShape::Even),
            "bylength" | "by-length" => return Some(ShardShape::ByLength),
            _ => {}
        }
        let (kind, hot) = s.split_once(':')?;
        if kind != "skewed" {
            return None;
        }
        let hot: f64 = hot.parse().ok()?;
        if !hot.is_finite() || !(0.0..=1.0).contains(&hot) {
            return None;
        }
        Some(ShardShape::Skewed { hot })
    }
}

/// Deterministically split `reqs` into `n` shards under `shape`.  Every
/// request lands in exactly one shard; within a shard the original
/// arrival order is preserved (so replays stay time-sorted).
pub fn shard_requests(reqs: Vec<Request>, n: usize, shape: ShardShape) -> Vec<Vec<Request>> {
    let n = n.max(1);
    let mut shards: Vec<Vec<Request>> = (0..n).map(|_| Vec::new()).collect();
    match shape {
        ShardShape::Even => {
            for (i, r) in reqs.into_iter().enumerate() {
                shards[i % n].push(r);
            }
        }
        ShardShape::Skewed { hot } => {
            if n == 1 {
                shards[0] = reqs;
            } else {
                // shard 0 takes every request whose position crosses the
                // next multiple of 1/hot — a largest-remainder assignment
                // that spreads the hot picks evenly through time instead
                // of front-loading them
                let mut acc = 0.0f64;
                let mut cold = 0usize;
                for r in reqs.into_iter() {
                    acc += hot;
                    if acc >= 1.0 {
                        acc -= 1.0;
                        shards[0].push(r);
                    } else {
                        shards[1 + cold % (n - 1)].push(r);
                        cold += 1;
                    }
                }
            }
        }
        ShardShape::ByLength => {
            let mut order: Vec<usize> = (0..reqs.len()).collect();
            order.sort_by_key(|&i| (reqs[i].prompt.len() + reqs[i].max_new, i));
            // rank → shard by quantile; then scatter back in input order
            let mut shard_of = vec![0usize; reqs.len()];
            for (rank, &i) in order.iter().enumerate() {
                shard_of[i] = rank * n / reqs.len().max(1);
            }
            for (i, r) in reqs.into_iter().enumerate() {
                shards[shard_of[i].min(n - 1)].push(r);
            }
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfgs() -> (GrammarConfig, ModelConfig) {
        let g = GrammarConfig {
            pad: 0, bos: 1, eos: 2, def_tok: 3, qry: 4, eq: 5, sep: 6,
            slot_base: 16, n_slots: 48, value_base: 80, n_values: 256,
            filler_base: 336, n_filler: 120, mode_base: 456, n_modes: 12,
            n_defs: 8, redefine_prob: 0.08, query_prob: 0.30,
            focus_query_prob: 0.85, focus_switch_prob: 0.18,
            mode_mul: vec![1, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43],
            mode_add: vec![3, 8, 1, 14, 5, 11, 2, 7, 9, 4, 13, 6],
        };
        let m = ModelConfig {
            vocab: 512, hidden: 128, layers: 4, q_heads: 4, kv_heads: 2,
            head_dim: 32, ffn: 256, max_seq: 512, slots: 12, prompt_pad: 32,
            spec_k: 8, draft_budget: 64,
            verify_q_variants: vec![1, 5, 9, 13, 17, 21],
            draft_w_variants: vec![16, 32, 64, 128, 256],
        };
        (g, m)
    }

    #[test]
    fn lengths_match_profile() {
        let (g, m) = cfgs();
        let mut w = WorkloadGen::new(g, m, Dataset::Aime, 1);
        let reqs = w.offline_batch(2000);
        let mean: f64 =
            reqs.iter().map(|r| r.max_new as f64).sum::<f64>() / reqs.len() as f64;
        // Clamping truncates the log-normal tail, so the mean lands below
        // the raw profile mean; it must stay in a sane band.
        assert!(mean > 150.0 && mean < 290.0, "mean={mean}");
        let max = reqs.iter().map(|r| r.max_new).max().unwrap();
        assert!(max <= 512 - 32 - 8 - 2);
    }

    #[test]
    fn nonreasoning_is_much_shorter() {
        let (g, m) = cfgs();
        let mut a = WorkloadGen::new(g.clone(), m.clone(), Dataset::Aime, 1);
        let mut b = WorkloadGen::new(g, m, Dataset::NonReasoningAime, 1);
        let la: usize = a.offline_batch(500).iter().map(|r| r.max_new).sum();
        let lb: usize = b.offline_batch(500).iter().map(|r| r.max_new).sum();
        // Table 1's ~7x reasoning-vs-non-reasoning gap (clamped somewhat).
        assert!(la as f64 / lb as f64 > 4.0, "ratio={}", la as f64 / lb as f64);
    }

    #[test]
    fn prompts_are_valid_grammar() {
        let (g, m) = cfgs();
        let mut w = WorkloadGen::new(g.clone(), m, Dataset::LiveCodeBench, 9);
        for r in w.offline_batch(20) {
            assert!(!r.prompt.is_empty() && r.prompt.len() <= 32);
            assert_eq!(r.prompt[0], g.bos);
            assert!(r.prompt.iter().all(|&t| t >= 0 && t < 512));
        }
    }

    #[test]
    fn online_arrivals_sorted_and_rate_plausible() {
        let (g, m) = cfgs();
        let mut w = WorkloadGen::new(g, m, Dataset::Aime, 4);
        let trace = w.online_trace(10.0, 50.0);
        assert!(trace.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        let n = trace.len() as f64;
        assert!((n / 50.0 - 10.0).abs() < 2.0, "rate={}", n / 50.0);
    }

    #[test]
    fn online_arrivals_iterator_matches_trace() {
        let (g, m) = cfgs();
        let trace =
            WorkloadGen::new(g.clone(), m.clone(), Dataset::Aime, 11).online_trace(5.0, 20.0);
        let streamed: Vec<Request> =
            WorkloadGen::new(g, m, Dataset::Aime, 11).online_arrivals(5.0, 20.0).collect();
        assert_eq!(trace.len(), streamed.len());
        for (a, b) in trace.iter().zip(streamed.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new, b.max_new);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.seed, b.seed);
        }
        // exhausted iterators stay exhausted
        let mut it = WorkloadGen::new(cfgs().0, cfgs().1, Dataset::Aime, 11)
            .online_arrivals(5.0, 0.0);
        assert!(it.next().is_none());
        assert!(it.next().is_none());
    }

    #[test]
    fn arrival_curve_parses() {
        assert_eq!(ArrivalCurve::parse("uniform"), Some(ArrivalCurve::Uniform));
        assert_eq!(ArrivalCurve::parse("bursty:4"), Some(ArrivalCurve::Bursty { ratio: 4.0 }));
        assert_eq!(
            ArrivalCurve::parse("diurnal:2.5"),
            Some(ArrivalCurve::Diurnal { ratio: 2.5 })
        );
        assert_eq!(ArrivalCurve::parse("bursty:1"), None, "ratio must exceed 1");
        assert_eq!(ArrivalCurve::parse("bursty:-3"), None);
        assert_eq!(ArrivalCurve::parse("sawtooth:2"), None);
        assert_eq!(ArrivalCurve::parse("bursty"), None);
    }

    #[test]
    fn uniform_curve_is_bitwise_the_old_path() {
        let (g, m) = cfgs();
        let old = WorkloadGen::new(g.clone(), m.clone(), Dataset::Aime, 11).online_trace(5.0, 20.0);
        let new = WorkloadGen::new(g, m, Dataset::Aime, 11)
            .online_trace_curve(5.0, 20.0, ArrivalCurve::Uniform);
        assert_eq!(old.len(), new.len());
        for (a, b) in old.iter().zip(new.iter()) {
            assert_eq!((a.id, &a.prompt, a.max_new, a.seed), (b.id, &b.prompt, b.max_new, b.seed));
            assert_eq!(a.arrival_s, b.arrival_s, "RNG consumption must be unchanged");
        }
    }

    #[test]
    fn bursty_trace_is_deterministic_per_seed_and_streams_identically() {
        let (g, m) = cfgs();
        let curve = ArrivalCurve::Bursty { ratio: 4.0 };
        let a = WorkloadGen::new(g.clone(), m.clone(), Dataset::Aime, 21)
            .online_trace_curve(8.0, 40.0, curve);
        let b = WorkloadGen::new(g.clone(), m.clone(), Dataset::Aime, 21)
            .online_trace_curve(8.0, 40.0, curve);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.seed, y.seed);
        }
        // streaming twin yields the same sequence
        let streamed: Vec<Request> = WorkloadGen::new(g.clone(), m.clone(), Dataset::Aime, 21)
            .online_arrivals_curve(8.0, 40.0, curve)
            .collect();
        assert_eq!(a.len(), streamed.len());
        for (x, y) in a.iter().zip(streamed.iter()) {
            assert_eq!(x.arrival_s, y.arrival_s);
        }
        // and a different seed actually moves the trace
        let c = WorkloadGen::new(g, m, Dataset::Aime, 22).online_trace_curve(8.0, 40.0, curve);
        assert!(
            c.len() != a.len()
                || c.iter().zip(a.iter()).any(|(x, y)| x.arrival_s != y.arrival_s),
            "different seeds must differ"
        );
    }

    #[test]
    fn bursty_concentrates_arrivals_and_preserves_mean() {
        let (g, m) = cfgs();
        let ratio = 9.0;
        let trace = WorkloadGen::new(g, m, Dataset::Aime, 5)
            .online_trace_curve(20.0, 100.0, ArrivalCurve::Bursty { ratio });
        // mean rate preserved within Poisson noise
        let rate = trace.len() as f64 / 100.0;
        assert!((rate - 20.0).abs() < 3.0, "mean rate drifted: {rate}");
        // count arrivals in burst vs quiet half-cycles
        let cycles = ArrivalCurve::BURSTY_CYCLES;
        let (mut burst, mut quiet) = (0usize, 0usize);
        for r in &trace {
            let phase = (r.arrival_s / 100.0 * cycles).fract();
            if phase < 0.5 {
                burst += 1;
            } else {
                quiet += 1;
            }
        }
        let observed = burst as f64 / quiet.max(1) as f64;
        assert!(
            observed > ratio * 0.6 && observed < ratio * 1.6,
            "burst/quiet ratio {observed} should track {ratio}"
        );
    }

    #[test]
    fn diurnal_peaks_mid_cycle() {
        let (g, m) = cfgs();
        let trace = WorkloadGen::new(g, m, Dataset::Aime, 6)
            .online_trace_curve(20.0, 100.0, ArrivalCurve::Diurnal { ratio: 6.0 });
        // sin peaks in the first half of the horizon, troughs in the second
        let first: usize = trace.iter().filter(|r| r.arrival_s < 50.0).count();
        let second = trace.len() - first;
        assert!(
            first as f64 > 1.5 * second as f64,
            "diurnal first-half {first} should dominate second-half {second}"
        );
    }

    #[test]
    fn ids_unique() {
        let (g, m) = cfgs();
        let mut w = WorkloadGen::new(g, m, Dataset::Aime, 4);
        let reqs = w.offline_batch(100);
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 100);
    }

    #[test]
    fn shard_shape_parses() {
        assert_eq!(ShardShape::parse("even"), Some(ShardShape::Even));
        assert_eq!(ShardShape::parse("ByLength"), Some(ShardShape::ByLength));
        assert_eq!(ShardShape::parse("skewed:0.75"), Some(ShardShape::Skewed { hot: 0.75 }));
        assert_eq!(ShardShape::parse("skewed:1.5"), None, "fraction must be <= 1");
        assert_eq!(ShardShape::parse("skewed:-0.1"), None);
        assert_eq!(ShardShape::parse("skewed"), None);
        assert_eq!(ShardShape::parse("hotcold:0.5"), None);
    }

    #[test]
    fn shard_even_round_robins_and_partitions() {
        let (g, m) = cfgs();
        let reqs = WorkloadGen::new(g, m, Dataset::Aime, 3).offline_batch(20);
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let shards = shard_requests(reqs, 3, ShardShape::Even);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 20);
        // sizes differ by at most one, every id lands exactly once
        let (min, max) = (
            shards.iter().map(|s| s.len()).min().unwrap(),
            shards.iter().map(|s| s.len()).max().unwrap(),
        );
        assert!(max - min <= 1);
        let mut seen: Vec<u64> = shards.iter().flatten().map(|r| r.id).collect();
        seen.sort_unstable();
        let mut want = ids;
        want.sort_unstable();
        assert_eq!(seen, want);
    }

    #[test]
    fn shard_skewed_gives_shard0_the_hot_fraction() {
        let (g, m) = cfgs();
        let reqs = WorkloadGen::new(g, m, Dataset::Aime, 8).offline_batch(200);
        let shards = shard_requests(reqs, 4, ShardShape::Skewed { hot: 0.6 });
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 200);
        // largest-remainder: shard 0 gets floor/ceil of hot * n
        let hot_n = shards[0].len();
        assert!((119..=121).contains(&hot_n), "hot shard got {hot_n} of 200 at 0.6");
        // cold remainder spreads evenly over the other shards
        let (cmin, cmax) = (
            shards[1..].iter().map(|s| s.len()).min().unwrap(),
            shards[1..].iter().map(|s| s.len()).max().unwrap(),
        );
        assert!(cmax - cmin <= 1, "cold shards uneven: {cmin}..{cmax}");
    }

    #[test]
    fn shard_by_length_orders_quantiles() {
        let (g, m) = cfgs();
        let reqs = WorkloadGen::new(g, m, Dataset::Aime, 13).offline_batch(120);
        let shards = shard_requests(reqs, 3, ShardShape::ByLength);
        assert!(shards.iter().all(|s| s.len() == 40));
        let mean = |s: &[Request]| {
            s.iter().map(|r| (r.prompt.len() + r.max_new) as f64).sum::<f64>() / s.len() as f64
        };
        assert!(mean(&shards[0]) < mean(&shards[1]));
        assert!(mean(&shards[1]) < mean(&shards[2]));
        // arrival order preserved within each shard
        for s in &shards {
            assert!(s.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        }
    }

    #[test]
    fn shard_degenerate_cases() {
        let (g, m) = cfgs();
        let reqs = WorkloadGen::new(g, m, Dataset::Aime, 2).offline_batch(7);
        // n = 1 keeps the whole trace in order regardless of shape
        for shape in [ShardShape::Even, ShardShape::Skewed { hot: 0.9 }, ShardShape::ByLength] {
            let shards = shard_requests(reqs.clone(), 1, shape);
            assert_eq!(shards.len(), 1);
            let ids: Vec<u64> = shards[0].iter().map(|r| r.id).collect();
            let want: Vec<u64> = reqs.iter().map(|r| r.id).collect();
            assert_eq!(ids, want);
        }
        // empty input yields n empty shards
        let empty = shard_requests(Vec::new(), 3, ShardShape::ByLength);
        assert_eq!(empty.len(), 3);
        assert!(empty.iter().all(|s| s.is_empty()));
    }
}
