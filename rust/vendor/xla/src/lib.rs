// Placeholder crate: the `pjrt` feature needs the real patched `xla`
// sources vendored at rust/vendor/xla (xla_extension 0.5.1 with the
// untuple_result patch applied to xla_rs/xla_rs.cc).  See
// rust/src/runtime/pjrt.rs for the API surface the runtime consumes.
compile_error!(
    "rust/vendor/xla is a placeholder. Vendor the patched xla crate here \
     (see rust/vendor/xla/Cargo.toml) before building with --features pjrt; \
     the default (no-feature) build uses the deterministic CPU fallback \
     runtime and does not need it."
);
