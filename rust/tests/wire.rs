//! Wire-protocol codec property tests (seeded, deterministic — no
//! external fuzzing deps).
//!
//! Properties pinned here, with a byte-layout twin in
//! `python/tests/test_wire_port.py`:
//!   1. encode → decode round-trips every frame kind, arbitrary values;
//!   2. decoding is *canonical*: any body that decodes re-encodes to the
//!      identical bytes (no two wire representations of one frame);
//!   3. truncated / mutated / garbage inputs never panic and never
//!      silently succeed where the layout is violated;
//!   4. golden byte strings (shared verbatim with the Python twin) pin
//!      the layout across languages.

use sparsespec::serving::wire::{self, Frame, WireError};
use sparsespec::serving::ErrorCode;
use sparsespec::util::rng::Xoshiro256;

fn rand_string(rng: &mut Xoshiro256, max: usize) -> String {
    let n = (rng.next_u64() as usize) % (max + 1);
    (0..n)
        .map(|_| {
            // mixed ASCII + multibyte to exercise UTF-8 handling
            match rng.next_u64() % 4 {
                0 => 'é',
                1 => '→',
                _ => (b'a' + (rng.next_u64() % 26) as u8) as char,
            }
        })
        .collect()
}

fn rand_frame(rng: &mut Xoshiro256) -> Frame {
    match rng.next_u64() % 11 {
        0 => Frame::Submit {
            req_id: rng.next_u64(),
            seed: rng.next_u64(),
            max_new: rng.next_u64() as u32,
            tenant: rand_string(rng, 12),
            drafter: rand_string(rng, 12),
            prompt: (0..(rng.next_u64() % 64)).map(|_| rng.next_u64() as i32).collect(),
        },
        1 => Frame::Cancel { session: rng.next_u64() },
        2 => Frame::Credit { n: rng.next_u64() as u32 },
        3 => Frame::Shutdown { abort: rng.next_u64() % 2 == 1 },
        4 => Frame::Ping { nonce: rng.next_u64() },
        5 => Frame::Hello { version: rng.next_u64() as u8, window: rng.next_u64() as u32 },
        6 => Frame::Accepted {
            req_id: rng.next_u64(),
            session: rng.next_u64(),
            replica: if rng.next_u64() % 2 == 0 { None } else { Some(rng.next_u64() as u16) },
        },
        7 => Frame::Token {
            session: rng.next_u64(),
            index: rng.next_u64() as u32,
            token: rng.next_u64() as i32,
        },
        8 => Frame::Finished {
            session: rng.next_u64(),
            reason: (rng.next_u64() % 4) as u8,
            tokens: rng.next_u64() as u32,
        },
        9 => Frame::Error {
            req_id: rng.next_u64(),
            code: ErrorCode::from_u8((rng.next_u64() % 9 + 1) as u8).unwrap(),
            detail: rand_string(rng, 40),
        },
        _ => Frame::Pong { nonce: rng.next_u64() },
    }
}

#[test]
fn fuzz_roundtrip_random_frames() {
    let mut rng = Xoshiro256::new(0xC0DEC);
    for i in 0..2000 {
        let f = rand_frame(&mut rng);
        let bytes = f.encode();
        let mut cur = std::io::Cursor::new(&bytes);
        let back = wire::read_frame(&mut cur).unwrap_or_else(|e| panic!("iter {i}: {e} on {f:?}"));
        assert_eq!(back, Some(f), "iter {i}");
    }
}

#[test]
fn fuzz_decode_is_canonical() {
    // any body that decodes must re-encode to the identical bytes —
    // there is exactly one wire representation per frame
    let mut rng = Xoshiro256::new(0xBEEF);
    for _ in 0..2000 {
        let body = rand_frame(&mut rng).encode_body();
        let decoded = wire::decode_body(&body).expect("valid body decodes");
        assert_eq!(decoded.encode_body(), body, "canonical re-encode");
    }
}

#[test]
fn fuzz_truncations_always_error() {
    let mut rng = Xoshiro256::new(0x7A7A);
    for _ in 0..200 {
        let f = rand_frame(&mut rng);
        let body = f.encode_body();
        for cut in 0..body.len() {
            // The one sanctioned exception: Accepted's optional trailing
            // replica id means cutting exactly that field yields the
            // (equally canonical) replica-less form.
            if matches!(f, Frame::Accepted { replica: Some(_), .. }) && cut == body.len() - 2 {
                let r = wire::decode_body(&body[..cut]).unwrap();
                assert!(matches!(r, Frame::Accepted { replica: None, .. }));
                continue;
            }
            let r = wire::decode_body(&body[..cut]);
            assert!(r.is_err(), "strict prefix (len {cut}/{}) decoded: {r:?}", body.len());
        }
    }
}

#[test]
fn fuzz_mutations_never_panic() {
    // single-byte mutations: any outcome is fine except a panic or an
    // over-allocation; run a bounded number per frame
    let mut rng = Xoshiro256::new(0xF00D);
    for _ in 0..400 {
        let mut body = rand_frame(&mut rng).encode_body();
        let at = (rng.next_u64() as usize) % body.len();
        body[at] ^= (rng.next_u64() as u8) | 1;
        let _ = wire::decode_body(&body);
    }
}

#[test]
fn fuzz_garbage_never_panics() {
    let mut rng = Xoshiro256::new(0x6A6B);
    for _ in 0..2000 {
        let n = (rng.next_u64() as usize) % 96;
        let garbage: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = wire::decode_body(&garbage);
        // and through the stream reader (garbage length prefixes included)
        let mut cur = std::io::Cursor::new(&garbage);
        let _ = wire::read_frame(&mut cur);
    }
}

#[test]
fn oversized_and_zero_lengths_rejected_before_allocation() {
    for len in [0u32, (wire::MAX_FRAME as u32) + 1, u32::MAX] {
        let mut bytes = len.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 8]);
        let mut cur = std::io::Cursor::new(&bytes);
        assert!(
            matches!(wire::read_frame(&mut cur), Err(WireError::Oversized { .. })),
            "len {len}"
        );
    }
}

/// Golden byte pins, shared verbatim with python/tests/test_wire_port.py.
/// If these change, the wire protocol changed: bump PROTOCOL_VERSION and
/// update both twins.
#[test]
fn golden_bytes_pin_the_layout() {
    let cases: Vec<(Frame, &str)> = vec![
        (
            Frame::Submit {
                req_id: 1,
                seed: 2,
                max_new: 3,
                tenant: "t".into(),
                drafter: "d".into(),
                prompt: vec![5, -1],
            },
            "270000000101000000000000000200000000000000030000000100740100640200000005000000ffffffff",
        ),
        (
            Frame::Hello { version: 1, window: 1024 },
            "06000000100100040000",
        ),
        (
            Frame::Error { req_id: 7, code: ErrorCode::KvShed, detail: "x".into() },
            "0d00000014070000000000000002010078",
        ),
        (
            Frame::Token { session: 9, index: 4, token: -7 },
            "1100000012090000000000000004000000f9ffffff",
        ),
        (
            Frame::Accepted { req_id: 7, session: 3, replica: None },
            "110000001107000000000000000300000000000000",
        ),
        (
            Frame::Accepted { req_id: 7, session: 3, replica: Some(1) },
            "1300000011070000000000000003000000000000000100",
        ),
    ];
    for (frame, hex) in cases {
        let got: String = frame.encode().iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(got, hex, "{frame:?}");
        let raw: Vec<u8> = (0..hex.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&hex[i..i + 2], 16).unwrap())
            .collect();
        let mut cur = std::io::Cursor::new(&raw);
        assert_eq!(wire::read_frame(&mut cur).unwrap(), Some(frame));
    }
}

/// Negative path of the Hello version handshake (the wire-hardening
/// contract): a `Hello` carrying any version other than PROTOCOL_VERSION
/// must be a typed refusal, and non-Hello opening frames likewise.
#[test]
fn hello_version_mismatch_is_a_typed_refusal() {
    for v in [0u8, wire::PROTOCOL_VERSION + 1, u8::MAX] {
        let f = Frame::Hello { version: v, window: 256 };
        assert_eq!(
            wire::expect_hello(&f),
            Err(WireError::BadValue("protocol version")),
            "version {v}"
        );
    }
    assert_eq!(
        wire::expect_hello(&Frame::Hello { version: wire::PROTOCOL_VERSION, window: 256 }),
        Ok(256)
    );
    assert_eq!(
        wire::expect_hello(&Frame::Ping { nonce: 0 }),
        Err(WireError::BadValue("expected hello"))
    );
}

#[test]
fn multiple_frames_stream_back_to_back() {
    let frames = vec![
        Frame::Hello { version: wire::PROTOCOL_VERSION, window: 64 },
        Frame::Accepted { req_id: 1, session: 10, replica: None },
        Frame::Token { session: 10, index: 0, token: 42 },
        Frame::Finished { session: 10, reason: 0, tokens: 1 },
    ];
    let mut bytes = Vec::new();
    for f in &frames {
        bytes.extend_from_slice(&f.encode());
    }
    let mut cur = std::io::Cursor::new(&bytes);
    for f in &frames {
        assert_eq!(wire::read_frame(&mut cur).unwrap().as_ref(), Some(f));
    }
    assert_eq!(wire::read_frame(&mut cur).unwrap(), None);
}
