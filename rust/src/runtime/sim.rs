//! Deterministic CPU fallback runtime (the default backend).
//!
//! Replaces the PJRT artifact executor with a seeded **hash surrogate
//! model** while preserving every contract the serving layer depends on,
//! so engine, scheduler, KV-manager and session logic are exercised
//! end-to-end with zero native dependencies:
//!
//! * **KV pool layout** `[L, S, T, Hkv, D]` is identical to the artifacts,
//!   so offload row extraction (`Engine::extract_slot_rows`), `kv_dump` /
//!   `kv_load` round-trips and slot reuse behave exactly like the real
//!   path.  A token write stores `token + 1` at `d = 0` of every (layer,
//!   head) row; `0.0` means "empty".
//! * **Causal visibility**: the logits for a query at position `p` are a
//!   deterministic hash of the tokens at the last [`CTX`] positions
//!   `(p-CTX, p]` — read back *from the KV pool*, not from any shadow
//!   state — plus, for `p >= LONG_MIN`, the token at the long-range
//!   position `p/2`.  Rollback correctness therefore falls out the same
//!   way it does on device: stale rows beyond the frontier are rewritten
//!   before they are ever read.
//! * **Sparse visibility**: draft / sparse-verify steps only see positions
//!   present in their `[L, Hkv, W]` index sets, so drafter quality is
//!   real: a policy whose window covers the last `CTX` positions *and*
//!   whose selected pillars cover `p/2` reproduces the dense logits
//!   (high acceptance); one that misses them diverges (rejections).
//! * **Score dumps**: dense verification emits an attention-mass dump
//!   peaked at the sinks, the recent window, and a band around the
//!   long-range position `len/2` — exactly the signal PillarAttn selection
//!   needs to beat a pure sliding window, mirroring the paper's Fig. 3
//!   oracle-vs-window gap in miniature.
//! * **Greedy losslessness**: logits depend only on the visible token
//!   sequence, so speculative decoding reproduces vanilla outputs
//!   token-for-token for every drafter — the paper's core invariant stays
//!   testable without artifacts.
//!
//! Everything is integer hashing (`f32` values are exact 24-bit scaled
//! ints), so runs are bit-identical across platforms and runs.  The Python
//! cross-check of this model lives in
//! `python/tests/test_sim_runtime_port.py` and
//! `python/tests/test_arena_port.py`.
//!
//! ## Raw-speed structure (arena + slot-parallel kernels)
//!
//! Steps write into a [`StepArena`] owned by the runner instead of
//! returning fresh `Vec`s; callers read `ModelRunner::logits()` /
//! `ModelRunner::dump()` views afterwards.  Each step runs in two phases:
//!
//! 1. **Token writes** (serial): KV rows for one slot are strided across
//!    layers in the `[L, S, T, Hkv, D]` pool, so slot chunks are not
//!    disjoint — but writes are O(tokens × L × Hkv) scalar stores, a tiny
//!    fraction of a step.
//! 2. **Logit/dump fill** (slot-parallel): per-slot outputs are disjoint
//!    `chunks_mut` of the arena and only *read* the KV pool, so the loop
//!    fans out over `ThreadPool::scope`.  Every chunk is a pure function
//!    of its inputs, so outputs are bit-identical to the serial path
//!    regardless of worker count.  The fan-out boxes one closure per
//!    worker chunk; the serial path (`set_parallel(false)`) is
//!    zero-allocation in steady state and is what the `engine_iteration`
//!    allocation gate measures.
//!
//! The verify dump is filled **once** per slot and `copy_from_slice`d
//! across the remaining `L × Hkv − 1` rows (all heads receive the same
//! dump in this backend), and sparse steps test visibility against a
//! per-slot bitmask built once per call instead of scanning the index row
//! per position.  The seed-era kernels are kept verbatim in
//! [`reference`] as the executable specification: the bit-identity tests
//! and the `engine_iteration` bench baseline both run against that single
//! copy.

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use super::{ArtifactNames, StepArena, StepStats};
use crate::model::{ModelConfig, SystemConfig};
use crate::util::threadpool::ThreadPool;

/// Tokens of trailing causal context each logit row depends on.
pub const CTX: usize = 8;
/// Query positions `p >= LONG_MIN` additionally depend on the token at
/// position `p / 2` (the "long-range pillar" the dump advertises).
pub const LONG_MIN: usize = 24;
/// Half-width of the dump's high-mass band around `len / 2`.
pub const LONG_BAND: usize = 5;

#[inline]
fn mix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fill one vocab row of logits from a context hash.  Each value is a
/// 24-bit integer scaled by 2^-21 (exact in f32), spread over [0, 8).
fn fill_logits(h: u64, out: &mut [f32]) {
    for (v, o) in out.iter_mut().enumerate() {
        let x = mix64(h ^ (v as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93));
        *o = (x >> 40) as f32 * (8.0 / (1u64 << 24) as f32);
    }
}

#[inline]
fn pool_off(m: &ModelConfig, l: usize, s: usize, t: usize, h: usize, d: usize) -> usize {
    (((l * m.slots + s) * m.max_seq + t) * m.kv_heads + h) * m.head_dim + d
}

/// Write `token` into slot `s` position `t` of both pools (every layer and
/// head carries it, so any row subset survives offload round-trips).
fn write_token(kv_k: &mut [f32], kv_v: &mut [f32], m: &ModelConfig, s: usize, t: usize, token: i32) {
    let enc = (token + 1) as f32;
    for l in 0..m.layers {
        for h in 0..m.kv_heads {
            let off = pool_off(m, l, s, t, h, 0);
            kv_k[off] = enc;
            kv_v[off] = enc;
        }
    }
}

/// Read the token stored at slot `s` position `t` (-1 when empty).
#[inline]
fn read_token(kv_k: &[f32], m: &ModelConfig, s: usize, t: usize) -> i32 {
    kv_k[pool_off(m, 0, s, t, 0, 0)] as i32 - 1
}

/// Dense context hash for a query at position `p`: folds the long-range
/// token (if any) then the trailing window, in position order.
fn ctx_hash(kv_k: &[f32], m: &ModelConfig, s: usize, p: usize) -> u64 {
    let mut h = 0xC0FF_EE00_5EED_1234u64;
    if p >= LONG_MIN {
        let lp = p / 2;
        h = mix64(h ^ (read_token(kv_k, m, s, lp) + 1) as u64);
    }
    let start = (p + 1).saturating_sub(CTX);
    for t in start..=p {
        h = mix64(h ^ (read_token(kv_k, m, s, t) + 1) as u64);
    }
    h
}

/// Sparse context hash: identical fold, but a position contributes only if
/// it appears in `idx_row` (one (layer, head) row of the `[L, Hkv, W]`
/// index sets: ascending valid prefix, -1 tail).  All heads receive the
/// same dump in this backend, so row (0, 0) is representative.
///
/// This is the seed-era O(CTX·W) linear-scan form, kept as the executable
/// specification of [`sparse_ctx_hash_vis`] (equivalence is unit-tested
/// and re-pinned by `python/tests/test_arena_port.py`).
fn sparse_ctx_hash(kv_k: &[f32], m: &ModelConfig, s: usize, p: usize, idx_row: &[i32]) -> u64 {
    let visible = |t: usize| -> bool {
        idx_row
            .iter()
            .take_while(|&&x| x >= 0)
            .any(|&x| x == t as i32)
    };
    let mut h = 0xC0FF_EE00_5EED_1234u64;
    if p >= LONG_MIN {
        let lp = p / 2;
        if visible(lp) {
            h = mix64(h ^ (read_token(kv_k, m, s, lp) + 1) as u64);
        }
    }
    let start = (p + 1).saturating_sub(CTX);
    for t in start..=p {
        if visible(t) {
            h = mix64(h ^ (read_token(kv_k, m, s, t) + 1) as u64);
        }
    }
    h
}

/// Build the visibility bitmask for one slot from its (0, 0) index row:
/// bit `t` set ⇔ position `t` appears in the ascending valid prefix.
/// Out-of-range indices are ignored, exactly as the linear scan never
/// matched them against any position `t < max_seq`.
fn build_vis(idx_row: &[i32], words: &mut [u64]) {
    words.fill(0);
    let cap = words.len() * 64;
    for &x in idx_row {
        if x < 0 {
            break;
        }
        let t = x as usize;
        if t < cap {
            words[t >> 6] |= 1u64 << (t & 63);
        }
    }
}

#[inline]
fn vis_test(words: &[u64], t: usize) -> bool {
    (words[t >> 6] >> (t & 63)) & 1 == 1
}

/// [`sparse_ctx_hash`] with the membership scan replaced by O(1) bitmask
/// tests (`words` built once per call by [`build_vis`]).
fn sparse_ctx_hash_vis(kv_k: &[f32], m: &ModelConfig, s: usize, p: usize, words: &[u64]) -> u64 {
    let mut h = 0xC0FF_EE00_5EED_1234u64;
    if p >= LONG_MIN {
        let lp = p / 2;
        if vis_test(words, lp) {
            h = mix64(h ^ (read_token(kv_k, m, s, lp) + 1) as u64);
        }
    }
    let start = (p + 1).saturating_sub(CTX);
    for t in start..=p {
        if vis_test(words, t) {
            h = mix64(h ^ (read_token(kv_k, m, s, t) + 1) as u64);
        }
    }
    h
}

/// The attention-mass dump row for a context of length `len`: recency
/// decay + sink boost + a band around the long-range position `len/2`.
fn dump_mass(t: usize, len: usize) -> f32 {
    let mut mass = 1.0 / (1.0 + (len - 1 - t) as f32);
    if t < 4 {
        mass += 3.0;
    }
    if t.abs_diff(len / 2) <= LONG_BAND {
        mass += 2.0;
    }
    mass
}

/// What an artifact name resolves to in this backend (validation only —
/// there is nothing to compile).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
}

fn validate_artifact(m: &ModelConfig, name: &str) -> Result<()> {
    if let Some(q) = name.strip_prefix("verify_q") {
        let q: usize = q.parse().map_err(|_| anyhow!("bad artifact name '{name}'"))?;
        if m.verify_q_variants.contains(&q) {
            return Ok(());
        }
        return Err(anyhow!(
            "no verify_q{q} variant (have {:?}) — pick k so that k+1 is compiled",
            m.verify_q_variants
        ));
    }
    if let Some(w) = name.strip_prefix("draft_w") {
        let w: usize = w.parse().map_err(|_| anyhow!("bad artifact name '{name}'"))?;
        if m.draft_w_variants.contains(&w) {
            return Ok(());
        }
        return Err(anyhow!(
            "no draft_w{w} variant (have {:?})",
            m.draft_w_variants
        ));
    }
    match name {
        "prefill" | "sparse_verify" | "eagle" | "kv_load" | "draft_pallas" => Ok(()),
        other => Err(anyhow!("unknown artifact '{other}'")),
    }
}

/// Host buffer stand-in for `xla::PjRtBuffer` (API parity for upload/fetch
/// call sites; raw `execute` is a `pjrt`-only capability).
#[derive(Clone, Debug)]
pub enum Buffer {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

/// Deterministic fallback `Runtime`: carries the system configuration and
/// validates artifact names; the actual step math lives in `ModelRunner`.
pub struct Runtime {
    pub cfg: SystemConfig,
    /// (artifact name, "compile" seconds) log — kept for API parity with
    /// the PJRT backend (entries are all ~0 here).
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    /// Load `config.json` from `artifacts_dir` when present; otherwise fall
    /// back to the built-in testbed configuration so a fresh checkout
    /// serves without running `make artifacts`.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let cfg = if Path::new(artifacts_dir).join("config.json").exists() {
            SystemConfig::load(artifacts_dir)?
        } else {
            SystemConfig::synthetic(artifacts_dir)
        };
        Ok(Runtime { cfg, compile_log: RefCell::new(Vec::new()) })
    }

    /// Human-readable backend identifier (for banners and `info`).
    pub fn platform_name(&self) -> String {
        "sim-cpu (deterministic fallback; build with --features pjrt for XLA artifacts)".into()
    }

    /// Validate that `name` is an artifact this configuration could serve.
    pub fn executable(&self, name: &str) -> Result<Artifact> {
        validate_artifact(&self.cfg.model, name)?;
        self.compile_log.borrow_mut().push((name.to_string(), 0.0));
        Ok(Artifact { name: name.to_string() })
    }

    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    // ---- host <-> "device" marshalling (API parity) -------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        Ok(Buffer::F32(data.to_vec(), dims.to_vec()))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        Ok(Buffer::I32(data.to_vec(), dims.to_vec()))
    }

    /// Borrow the host view of a buffer.  This backend's buffers already
    /// live on the host, so readback is zero-copy — callers that need
    /// ownership call `.to_vec()` themselves, making the copy count
    /// explicit (the seed version cloned here *and* at most call sites).
    pub fn fetch_f32<'b>(&self, buf: &'b Buffer) -> Result<&'b [f32]> {
        match buf {
            Buffer::F32(d, _) => Ok(d),
            Buffer::I32(..) => Err(anyhow!("buffer holds i32, asked for f32")),
        }
    }

    pub fn fetch_i32<'b>(&self, buf: &'b Buffer) -> Result<&'b [i32]> {
        match buf {
            Buffer::I32(d, _) => Ok(d),
            Buffer::F32(..) => Err(anyhow!("buffer holds f32, asked for i32")),
        }
    }

    /// Raw artifact execution is a PJRT capability (the compose-proof and
    /// Pallas comparison paths); the fallback serves only through
    /// `ModelRunner`'s typed step functions.
    pub fn execute(&self, name: &str, _args: &[&Buffer]) -> Result<Vec<Buffer>> {
        Err(anyhow!(
            "raw execution of artifact '{name}' requires the `pjrt` feature \
             (the deterministic fallback serves via ModelRunner only)"
        ))
    }

    /// Read a raw little-endian f32 file (weights.bin / eagle.bin).
    pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{path:?} is not a multiple of 4 bytes"));
        }
        let mut out = Vec::with_capacity(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }
}

fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

/// Typed step-function runner over the hash surrogate model.  Signatures
/// and KV semantics mirror the PJRT `ModelRunner` exactly: every step
/// fills the [`StepArena`] and the caller reads [`Self::logits`] /
/// [`Self::dump`] before the next step overwrites them.
pub struct ModelRunner {
    pub rt: Rc<Runtime>,
    /// Copied out of `rt.cfg` once: step methods borrow this field
    /// directly so the hot loop never clones the config (the Vec-bearing
    /// `ModelConfig` clone per call would otherwise churn the allocator).
    mcfg: ModelConfig,
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    arena: StepArena,
    names: ArtifactNames,
    /// Lazily-created pool for the slot-parallel fill phase (so
    /// serial-only runners never spawn threads).
    pool: Option<ThreadPool>,
    parallel: bool,
    pub stats: StepStats,
}

impl ModelRunner {
    pub fn new(rt: Rc<Runtime>) -> Result<Self> {
        let mcfg = rt.cfg.model.clone();
        let n = mcfg.kv_pool_elems();
        let arena = StepArena::new(&mcfg);
        let names = ArtifactNames::new(&mcfg);
        Ok(Self {
            rt,
            mcfg,
            kv_k: vec![0.0; n],
            kv_v: vec![0.0; n],
            arena,
            names,
            pool: None,
            parallel: true,
            stats: StepStats::default(),
        })
    }

    /// Owned config snapshot (cold paths / tests).
    #[cfg(test)]
    fn m(&self) -> ModelConfig {
        self.mcfg.clone()
    }

    /// Toggle the slot-parallel fill phase.  Off ⇒ strictly serial and
    /// zero-allocation in steady state; on ⇒ same bits, fanned out over
    /// the worker pool (boxes one closure per worker chunk per step).
    pub fn set_parallel(&mut self, on: bool) {
        self.parallel = on;
    }

    pub fn parallel(&self) -> bool {
        self.parallel
    }

    /// The logits written by the most recent step: `[S, V]` for
    /// prefill/draft/eagle, `[S, Q, V]` for (sparse-)verify.
    pub fn logits(&self) -> &[f32] {
        self.arena.logits()
    }

    /// The `[S, L, Hkv, T]` attention-mass dump of the most recent dense
    /// verify.
    pub fn dump(&self) -> &[f32] {
        self.arena.dump()
    }

    /// Zero both KV pools (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv_k.fill(0.0);
        self.kv_v.fill(0.0);
        Ok(())
    }

    /// Fan `fill(slot, chunk)` out over per-slot `chunks_mut` of `buf`
    /// (chunk = `per_slot` elements), or run it serially — bit-identical
    /// either way.  `aux` is a second per-slot buffer handed to `fill`
    /// (the sparse steps' visibility bitmask; empty slice chunks when
    /// unused).
    fn fill_slots<F>(
        pool: &Option<ThreadPool>,
        go_par: bool,
        s_n: usize,
        buf: &mut [f32],
        per_slot: usize,
        aux: &mut [u64],
        aux_per_slot: usize,
        fill: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [u64]) + Sync,
    {
        debug_assert_eq!(buf.len(), s_n * per_slot);
        match pool {
            Some(pool) if go_par && s_n > 1 => {
                let nc = pool.workers().min(s_n);
                let spc = s_n.div_ceil(nc);
                let fill = &fill;
                // Split chunks by hand rather than zipping `chunks_mut`
                // iterators: an empty `aux` (prefill / eagle) yields zero
                // aux chunks, and a zip would silently drop every job.
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nc);
                let (mut buf_rest, mut aux_rest) = (buf, aux);
                let mut base = 0usize;
                while !buf_rest.is_empty() {
                    // `mem::take` reborrow: the split halves must outlive
                    // this loop iteration (they move into boxed jobs), so
                    // the rest-slices are carried by value, not reborrowed.
                    let n = (spc * per_slot).min(buf_rest.len());
                    let (bch, rest) = std::mem::take(&mut buf_rest).split_at_mut(n);
                    buf_rest = rest;
                    let n = (spc * aux_per_slot).min(aux_rest.len());
                    let (ach, rest) = std::mem::take(&mut aux_rest).split_at_mut(n);
                    aux_rest = rest;
                    let first = base;
                    jobs.push(Box::new(move || {
                        for (r, out) in bch.chunks_mut(per_slot).enumerate() {
                            // promoted &'static mut [] when no aux is used
                            let a: &mut [u64] = if aux_per_slot == 0 {
                                &mut []
                            } else {
                                &mut ach[r * aux_per_slot..(r + 1) * aux_per_slot]
                            };
                            fill(first + r, out, a);
                        }
                    }) as Box<dyn FnOnce() + Send + '_>);
                    base += spc;
                }
                pool.scope(jobs);
            }
            _ => {
                let mut aux_rest = aux;
                for (s, out) in buf.chunks_mut(per_slot).enumerate() {
                    let n = aux_per_slot.min(aux_rest.len());
                    let (a, rest) = std::mem::take(&mut aux_rest).split_at_mut(n);
                    aux_rest = rest;
                    fill(s, out, a);
                }
            }
        }
    }

    /// Prefill the prompt chunk for newly-admitted slots.
    /// tokens: [S*P], plen/active: [S].  Fills last-token logits [S*V].
    pub fn prefill(&mut self, tokens: &[i32], plen: &[i32], active: &[i32]) -> Result<()> {
        let (s_n, pad, v) = (self.mcfg.slots, self.mcfg.prompt_pad, self.mcfg.vocab);
        debug_assert_eq!(tokens.len(), s_n * pad);
        let t0 = Instant::now();
        // Phase 1: serial token writes (KV slot rows are strided).
        {
            let m = &self.mcfg;
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let p = (plen[s].max(1) as usize).min(pad);
                for (j, &t) in tokens[s * pad..s * pad + p].iter().enumerate() {
                    write_token(&mut self.kv_k, &mut self.kv_v, m, s, j, t);
                }
            }
        }
        // Phase 2: slot-parallel last-token logits.
        let go_par = self.parallel && s_n > 1;
        if go_par && self.pool.is_none() {
            self.pool = Some(ThreadPool::new(default_workers()));
        }
        let m = &self.mcfg;
        let kv_k = &self.kv_k;
        let arena = &mut self.arena;
        arena.logits_len = s_n * v;
        Self::fill_slots(
            &self.pool,
            go_par,
            s_n,
            &mut arena.logits[..s_n * v],
            v,
            &mut [],
            0,
            |s, out, _| {
                if active[s] == 0 {
                    out.fill(0.0);
                    return;
                }
                let p = (plen[s].max(1) as usize).min(pad);
                let h = ctx_hash(kv_k, m, s, p - 1);
                fill_logits(h, out);
            },
        );
        self.stats.add("prefill", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }

    /// One sparse draft step (budget `w` must be a compiled variant).
    /// token/pos/active: [S]; idx: [S*L*Hkv*w] (-1 holes).  Fills [S*V].
    pub fn draft(
        &mut self,
        w: usize,
        token: &[i32],
        pos: &[i32],
        idx: &[i32],
        active: &[i32],
    ) -> Result<()> {
        if !self.mcfg.draft_w_variants.contains(&w) {
            return Err(anyhow!(
                "no draft_w{w} variant (have {:?})",
                self.mcfg.draft_w_variants
            ));
        }
        let (s_n, v) = (self.mcfg.slots, self.mcfg.vocab);
        let per_idx = self.mcfg.layers * self.mcfg.kv_heads * w;
        debug_assert_eq!(idx.len(), s_n * per_idx);
        let t0 = Instant::now();
        {
            let m = &self.mcfg;
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let p = pos[s].max(0) as usize;
                if p >= m.max_seq {
                    continue;
                }
                write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, token[s]);
            }
        }
        let go_par = self.parallel && s_n > 1;
        if go_par && self.pool.is_none() {
            self.pool = Some(ThreadPool::new(default_workers()));
        }
        let m = &self.mcfg;
        let kv_k = &self.kv_k;
        let arena = &mut self.arena;
        arena.logits_len = s_n * v;
        let wps = arena.words_per_slot;
        Self::fill_slots(
            &self.pool,
            go_par,
            s_n,
            &mut arena.logits[..s_n * v],
            v,
            &mut arena.vis,
            wps,
            |s, out, vis| {
                if active[s] == 0 {
                    out.fill(0.0);
                    return;
                }
                let p = pos[s].max(0) as usize;
                if p >= m.max_seq {
                    out.fill(0.0);
                    return;
                }
                build_vis(&idx[s * per_idx..s * per_idx + w], vis);
                let h = sparse_ctx_hash_vis(kv_k, m, s, p, vis);
                fill_logits(h, out);
            },
        );
        let name = self.names.draft(w).expect("validated against draft_w_variants above");
        self.stats.add(name, 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }

    /// One dense verification step over q query tokens (compiled variant).
    /// tokens: [S*q]; pos/q_valid/active: [S].  Fills logits [S*q*V] and
    /// the dump [S*L*Hkv*T].
    pub fn verify(
        &mut self,
        q: usize,
        tokens: &[i32],
        pos: &[i32],
        q_valid: &[i32],
        active: &[i32],
    ) -> Result<()> {
        if !self.mcfg.verify_q_variants.contains(&q) {
            return Err(anyhow!(
                "no verify_q{q} variant (have {:?}) — pick k so that k+1 is compiled",
                self.mcfg.verify_q_variants
            ));
        }
        let (s_n, v, t_dim) = (self.mcfg.slots, self.mcfg.vocab, self.mcfg.max_seq);
        debug_assert_eq!(tokens.len(), s_n * q);
        let per_dump = self.mcfg.layers * self.mcfg.kv_heads * t_dim;
        let t0 = Instant::now();
        {
            let m = &self.mcfg;
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let qv = (q_valid[s].max(1) as usize).min(q);
                let base = pos[s].max(0) as usize;
                for j in 0..qv {
                    let p = base + j;
                    if p >= t_dim {
                        break;
                    }
                    write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, tokens[s * q + j]);
                }
            }
        }
        let go_par = self.parallel && s_n > 1;
        if go_par && self.pool.is_none() {
            self.pool = Some(ThreadPool::new(default_workers()));
        }
        let m = &self.mcfg;
        let kv_k = &self.kv_k;
        let arena = &mut self.arena;
        arena.logits_len = s_n * q * v;
        arena.dump_len = s_n * per_dump;
        let (logits, dump) = (&mut arena.logits[..s_n * q * v], &mut arena.dump[..s_n * per_dump]);
        let fill = |s: usize, lout: &mut [f32], dout: &mut [f32]| {
            if active[s] == 0 {
                lout.fill(0.0);
                dout.fill(0.0);
                return;
            }
            let qv = (q_valid[s].max(1) as usize).min(q);
            let base = pos[s].max(0) as usize;
            let mut filled = 0;
            for j in 0..qv {
                let p = base + j;
                if p >= t_dim {
                    break;
                }
                let h = ctx_hash(kv_k, m, s, p);
                fill_logits(h, &mut lout[j * v..(j + 1) * v]);
                filled = j + 1;
            }
            lout[filled * v..].fill(0.0);
            // Dump once into the representative (layer 0, head 0) row,
            // then replicate: all heads carry the same mass in this
            // backend (the seed kernels recomputed it L×Hkv times).
            let end = (base + qv).min(t_dim);
            let (row0, rest) = dout.split_at_mut(t_dim);
            for (t, x) in row0.iter_mut().enumerate() {
                *x = if t < end { dump_mass(t, end) } else { 0.0 };
            }
            for r in rest.chunks_mut(t_dim) {
                r.copy_from_slice(row0);
            }
        };
        match &self.pool {
            Some(pool) if go_par && s_n > 1 => {
                let nc = pool.workers().min(s_n);
                let spc = s_n.div_ceil(nc);
                let fill = &fill;
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = logits
                    .chunks_mut(spc * q * v)
                    .zip(dump.chunks_mut(spc * per_dump))
                    .enumerate()
                    .map(|(ci, (lch, dch))| {
                        Box::new(move || {
                            for (r, (lout, dout)) in lch
                                .chunks_mut(q * v)
                                .zip(dch.chunks_mut(per_dump))
                                .enumerate()
                            {
                                fill(ci * spc + r, lout, dout);
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.scope(jobs);
            }
            _ => {
                for (s, (lout, dout)) in logits
                    .chunks_mut(q * v)
                    .zip(dump.chunks_mut(per_dump))
                    .enumerate()
                {
                    fill(s, lout, dout);
                }
            }
        }
        let name = self.names.verify(q).expect("validated against verify_q_variants above");
        self.stats.add(name, 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }

    /// TriForce middle layer: verify q tokens under the sparse draft
    /// model.  Fills logits [S*(spec_k+1)*V].
    pub fn sparse_verify(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        q_valid: &[i32],
        idx: &[i32],
        active: &[i32],
    ) -> Result<()> {
        let (s_n, v, w) = (self.mcfg.slots, self.mcfg.vocab, self.mcfg.draft_budget);
        let q = self.mcfg.spec_k + 1;
        let per_idx = self.mcfg.layers * self.mcfg.kv_heads * w;
        debug_assert_eq!(tokens.len(), s_n * q);
        debug_assert_eq!(idx.len(), s_n * per_idx);
        let t0 = Instant::now();
        {
            let m = &self.mcfg;
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let qv = (q_valid[s].max(1) as usize).min(q);
                let base = pos[s].max(0) as usize;
                for j in 0..qv {
                    let p = base + j;
                    if p >= m.max_seq {
                        break;
                    }
                    write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, tokens[s * q + j]);
                }
            }
        }
        let go_par = self.parallel && s_n > 1;
        if go_par && self.pool.is_none() {
            self.pool = Some(ThreadPool::new(default_workers()));
        }
        let m = &self.mcfg;
        let kv_k = &self.kv_k;
        let arena = &mut self.arena;
        arena.logits_len = s_n * q * v;
        let wps = arena.words_per_slot;
        Self::fill_slots(
            &self.pool,
            go_par,
            s_n,
            &mut arena.logits[..s_n * q * v],
            q * v,
            &mut arena.vis,
            wps,
            |s, out, vis| {
                if active[s] == 0 {
                    out.fill(0.0);
                    return;
                }
                let qv = (q_valid[s].max(1) as usize).min(q);
                let base = pos[s].max(0) as usize;
                build_vis(&idx[s * per_idx..s * per_idx + w], vis);
                let mut filled = 0;
                for j in 0..qv {
                    let p = base + j;
                    if p >= m.max_seq {
                        break;
                    }
                    let h = sparse_ctx_hash_vis(kv_k, m, s, p, vis);
                    fill_logits(h, &mut out[j * v..(j + 1) * v]);
                    filled = j + 1;
                }
                out[filled * v..].fill(0.0);
            },
        );
        self.stats
            .add("sparse_verify", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }

    /// EAGLE-like draft head: ctx [S*ECTX] -> logits [S*V].  The head sees
    /// only its short context window, so (as with an untrained head on the
    /// real path) its proposals are weaker than self-speculation.
    pub fn eagle(&mut self, ctx: &[i32]) -> Result<()> {
        let ectx = self.rt.cfg.eagle.ctx;
        let (s_n, v) = (self.mcfg.slots, self.mcfg.vocab);
        debug_assert_eq!(ctx.len(), s_n * ectx);
        let t0 = Instant::now();
        let go_par = self.parallel && s_n > 1;
        if go_par && self.pool.is_none() {
            self.pool = Some(ThreadPool::new(default_workers()));
        }
        let arena = &mut self.arena;
        arena.logits_len = s_n * v;
        Self::fill_slots(
            &self.pool,
            go_par,
            s_n,
            &mut arena.logits[..s_n * v],
            v,
            &mut [],
            0,
            |s, out, _| {
                let mut h = 0xEA91_E000_0000_0001u64;
                for &t in &ctx[s * ectx..(s + 1) * ectx] {
                    h = mix64(h ^ (t + 1) as u64);
                }
                fill_logits(h, out);
            },
        );
        self.stats.add("eagle", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }

    /// Make both KV pools readable on the host via [`Self::kv_pools`]
    /// (offload path).  A no-op copy-wise in this backend — the pools
    /// already live on the host — so the dump is zero-copy; the PJRT
    /// backend fetches into its staging buffers here.
    pub fn kv_dump_prepare(&mut self) -> Result<()> {
        let t0 = Instant::now();
        self.stats
            .add("kv_dump", 0.0, 0.0, t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Host views of (k, v), each [L*S*T*Hkv*D].  Valid after
    /// [`Self::kv_dump_prepare`].
    pub fn kv_pools(&self) -> (&[f32], &[f32]) {
        (&self.kv_k, &self.kv_v)
    }

    /// Write one slot's KV rows back into the device pools (onload path).
    /// rows_k/rows_v: [L*T*Hkv*D].
    pub fn kv_load(&mut self, slot: usize, rows_k: &[f32], rows_v: &[f32]) -> Result<()> {
        let m = &self.mcfg;
        debug_assert_eq!(rows_k.len(), m.kv_slot_elems());
        let t0 = Instant::now();
        let row = m.max_seq * m.kv_heads * m.head_dim;
        let per_l = m.slots * row;
        for l in 0..m.layers {
            let dst = l * per_l + slot * row;
            self.kv_k[dst..dst + row].copy_from_slice(&rows_k[l * row..(l + 1) * row]);
            self.kv_v[dst..dst + row].copy_from_slice(&rows_v[l * row..(l + 1) * row]);
        }
        self.stats
            .add("kv_load", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }
}

/// Seed-era step kernels, kept verbatim as the *executable specification*:
/// fresh output `Vec`s per call, the dump recomputed per (layer, head)
/// row, sparse visibility via the O(CTX·W) linear scan, strictly serial.
/// The `engine_iteration` bench baseline and the arena bit-identity tests
/// (`rust/tests/arena.rs`, `python/tests/test_arena_port.py`) all run
/// against this single copy, so spec and optimised kernels cannot drift
/// apart.  Not for production use.
#[doc(hidden)]
pub mod reference {
    use super::*;

    /// Minimal seed-era runner: same KV semantics, allocating step
    /// functions, no stats/arena/threadpool.
    pub struct Runner {
        m: ModelConfig,
        eagle_ctx: usize,
        kv_k: Vec<f32>,
        kv_v: Vec<f32>,
    }

    impl Runner {
        pub fn new(m: ModelConfig, eagle_ctx: usize) -> Self {
            let n = m.kv_pool_elems();
            Runner { m, eagle_ctx, kv_k: vec![0.0; n], kv_v: vec![0.0; n] }
        }

        pub fn reset_kv(&mut self) {
            self.kv_k.fill(0.0);
            self.kv_v.fill(0.0);
        }

        pub fn prefill(&mut self, tokens: &[i32], plen: &[i32], active: &[i32]) -> Vec<f32> {
            let m = &self.m;
            let (s_n, pad, v) = (m.slots, m.prompt_pad, m.vocab);
            let mut logits = vec![0.0f32; s_n * v];
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let p = (plen[s].max(1) as usize).min(pad);
                for (j, &t) in tokens[s * pad..s * pad + p].iter().enumerate() {
                    write_token(&mut self.kv_k, &mut self.kv_v, m, s, j, t);
                }
                let h = ctx_hash(&self.kv_k, m, s, p - 1);
                fill_logits(h, &mut logits[s * v..(s + 1) * v]);
            }
            logits
        }

        pub fn draft(
            &mut self,
            w: usize,
            token: &[i32],
            pos: &[i32],
            idx: &[i32],
            active: &[i32],
        ) -> Vec<f32> {
            let m = &self.m;
            let (s_n, v) = (m.slots, m.vocab);
            let per_slot = m.layers * m.kv_heads * w;
            let mut logits = vec![0.0f32; s_n * v];
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let p = pos[s].max(0) as usize;
                if p >= m.max_seq {
                    continue;
                }
                write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, token[s]);
                let idx_row = &idx[s * per_slot..s * per_slot + w];
                let h = sparse_ctx_hash(&self.kv_k, m, s, p, idx_row);
                fill_logits(h, &mut logits[s * v..(s + 1) * v]);
            }
            logits
        }

        pub fn verify(
            &mut self,
            q: usize,
            tokens: &[i32],
            pos: &[i32],
            q_valid: &[i32],
            active: &[i32],
        ) -> (Vec<f32>, Vec<f32>) {
            let m = &self.m;
            let (s_n, v, t_dim) = (m.slots, m.vocab, m.max_seq);
            let per_dump = m.layers * m.kv_heads * t_dim;
            let mut logits = vec![0.0f32; s_n * q * v];
            let mut dump = vec![0.0f32; s_n * per_dump];
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let qv = (q_valid[s].max(1) as usize).min(q);
                let base = pos[s].max(0) as usize;
                for j in 0..qv {
                    let p = base + j;
                    if p >= t_dim {
                        break;
                    }
                    write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, tokens[s * q + j]);
                    let h = ctx_hash(&self.kv_k, m, s, p);
                    fill_logits(h, &mut logits[(s * q + j) * v..(s * q + j + 1) * v]);
                }
                let end = (base + qv).min(t_dim);
                for lh in 0..m.layers * m.kv_heads {
                    let row =
                        &mut dump[s * per_dump + lh * t_dim..s * per_dump + (lh + 1) * t_dim];
                    for (t, x) in row.iter_mut().enumerate().take(end) {
                        *x = dump_mass(t, end);
                    }
                }
            }
            (logits, dump)
        }

        pub fn sparse_verify(
            &mut self,
            tokens: &[i32],
            pos: &[i32],
            q_valid: &[i32],
            idx: &[i32],
            active: &[i32],
        ) -> Vec<f32> {
            let m = &self.m;
            let (s_n, v, w) = (m.slots, m.vocab, m.draft_budget);
            let q = m.spec_k + 1;
            let per_slot = m.layers * m.kv_heads * w;
            let mut logits = vec![0.0f32; s_n * q * v];
            for s in 0..s_n {
                if active[s] == 0 {
                    continue;
                }
                let qv = (q_valid[s].max(1) as usize).min(q);
                let base = pos[s].max(0) as usize;
                let idx_row = &idx[s * per_slot..s * per_slot + w];
                for j in 0..qv {
                    let p = base + j;
                    if p >= m.max_seq {
                        break;
                    }
                    write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, tokens[s * q + j]);
                    let h = sparse_ctx_hash(&self.kv_k, m, s, p, idx_row);
                    fill_logits(h, &mut logits[(s * q + j) * v..(s * q + j + 1) * v]);
                }
            }
            logits
        }

        pub fn eagle(&mut self, ctx: &[i32]) -> Vec<f32> {
            let m = &self.m;
            let ectx = self.eagle_ctx;
            let (s_n, v) = (m.slots, m.vocab);
            let mut logits = vec![0.0f32; s_n * v];
            for s in 0..s_n {
                let mut h = 0xEA91_E000_0000_0001u64;
                for &t in &ctx[s * ectx..(s + 1) * ectx] {
                    h = mix64(h ^ (t + 1) as u64);
                }
                fill_logits(h, &mut logits[s * v..(s + 1) * v]);
            }
            logits
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runner() -> ModelRunner {
        let rt = Rc::new(Runtime {
            cfg: SystemConfig::synthetic("artifacts"),
            compile_log: RefCell::new(Vec::new()),
        });
        ModelRunner::new(rt).unwrap()
    }

    fn ref_runner() -> reference::Runner {
        let cfg = SystemConfig::synthetic("artifacts");
        reference::Runner::new(cfg.model.clone(), cfg.eagle.ctx)
    }

    #[test]
    fn logits_are_deterministic_and_in_range() {
        let mut row = vec![0.0f32; 64];
        fill_logits(1234, &mut row);
        let mut row2 = vec![0.0f32; 64];
        fill_logits(1234, &mut row2);
        assert_eq!(row, row2);
        assert!(row.iter().all(|&x| (0.0..8.0).contains(&x)));
        let mut row3 = vec![0.0f32; 64];
        fill_logits(1235, &mut row3);
        assert_ne!(row, row3);
    }

    #[test]
    fn prefill_then_verify_chain_is_causal() {
        let mut r = runner();
        let m = r.m();
        let mut tokens = vec![0i32; m.slots * m.prompt_pad];
        for j in 0..6 {
            tokens[j] = 16 + j as i32;
        }
        let mut plen = vec![1i32; m.slots];
        plen[0] = 6;
        let mut active = vec![0i32; m.slots];
        active[0] = 1;
        r.prefill(&tokens, &plen, &active).unwrap();
        let l0 = r.logits().to_vec();
        assert_eq!(l0.len(), m.slots * m.vocab);
        // one greedy verify step: writes position 6, logits differ from
        // the prefill row (context changed)
        let mut tok = vec![0i32; m.slots];
        tok[0] = 99;
        let mut pos = vec![0i32; m.slots];
        pos[0] = 6;
        let qv = vec![1i32; m.slots];
        r.verify(1, &tok, &pos, &qv, &active).unwrap();
        assert_ne!(&r.logits()[..m.vocab], &l0[..m.vocab]);
        // and the dump covers exactly [0, 7)
        assert!(r.dump()[6] > 0.0);
        assert_eq!(r.dump()[7], 0.0);
    }

    #[test]
    fn sparse_draft_matches_dense_when_window_covered() {
        let mut r = runner();
        let m = r.m();
        let mut tokens = vec![0i32; m.slots * m.prompt_pad];
        for j in 0..10 {
            tokens[j] = 20 + j as i32;
        }
        let mut plen = vec![1i32; m.slots];
        plen[0] = 10;
        let mut active = vec![0i32; m.slots];
        active[0] = 1;
        r.prefill(&tokens, &plen, &active).unwrap();

        // dense reference at position 10
        let mut tok = vec![0i32; m.slots];
        tok[0] = 7;
        let mut pos = vec![0i32; m.slots];
        pos[0] = 10;
        let qv = vec![1i32; m.slots];
        r.verify(1, &tok, &pos, &qv, &active).unwrap();
        let dense = r.logits().to_vec();

        // sparse with an index set covering every position <= 10
        let w = 16usize;
        let per_slot = m.layers * m.kv_heads * w;
        let mut idx = vec![-1i32; m.slots * per_slot];
        for lh in 0..m.layers * m.kv_heads {
            for j in 0..11 {
                idx[lh * w + j] = j as i32;
            }
        }
        r.draft(w, &tok, &pos, &idx, &active).unwrap();
        assert_eq!(&r.logits()[..m.vocab], &dense[..m.vocab]);

        // drop position 10 (the fed token) from the set: logits diverge
        let mut idx2 = vec![-1i32; m.slots * per_slot];
        for lh in 0..m.layers * m.kv_heads {
            for j in 0..10 {
                idx2[lh * w + j] = j as i32;
            }
        }
        r.draft(w, &tok, &pos, &idx2, &active).unwrap();
        assert_ne!(&r.logits()[..m.vocab], &dense[..m.vocab]);
    }

    #[test]
    fn kv_roundtrip_preserves_tokens() {
        let mut r = runner();
        let m = r.m();
        write_token(&mut r.kv_k, &mut r.kv_v, &m, 3, 17, 123);
        r.kv_dump_prepare().unwrap();
        let (k, v) = r.kv_pools();
        // extract slot 3 rows the way the engine does
        let row = m.max_seq * m.kv_heads * m.head_dim;
        let per_l = m.slots * row;
        let mut rows_k = Vec::new();
        let mut rows_v = Vec::new();
        for l in 0..m.layers {
            let off = l * per_l + 3 * row;
            rows_k.extend_from_slice(&k[off..off + row]);
            rows_v.extend_from_slice(&v[off..off + row]);
        }
        r.reset_kv().unwrap();
        assert_eq!(read_token(&r.kv_k, &m, 3, 17), -1);
        r.kv_load(5, &rows_k, &rows_v).unwrap();
        assert_eq!(read_token(&r.kv_k, &m, 5, 17), 123);
    }

    #[test]
    fn artifact_validation() {
        let m = SystemConfig::synthetic("a").model;
        assert!(validate_artifact(&m, "prefill").is_ok());
        assert!(validate_artifact(&m, "verify_q9").is_ok());
        assert!(validate_artifact(&m, "verify_q7").is_err());
        assert!(validate_artifact(&m, "draft_w64").is_ok());
        assert!(validate_artifact(&m, "draft_w63").is_err());
        assert!(validate_artifact(&m, "bogus").is_err());
    }

    #[test]
    fn visibility_bitmask_matches_linear_scan() {
        let m = SystemConfig::synthetic("a").model;
        let words = m.max_seq.div_ceil(64);
        let mut vis = vec![0u64; words];
        // index rows exercising: empty, dense prefix, sparse scatter,
        // -1-terminated tails, out-of-range entries
        let rows: Vec<Vec<i32>> = vec![
            vec![-1; 16],
            (0..16).collect(),
            vec![0, 3, 12, 40, 41, 200, 511, -1, 7, 9, -1, -1, -1, -1, -1, -1],
            vec![5, 63, 64, 65, 127, 128, 510, 511, -1, -1, -1, -1, -1, -1, -1, -1],
            vec![1000, -1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0],
        ];
        for row in &rows {
            build_vis(row, &mut vis);
            let visible = |t: usize| {
                row.iter().take_while(|&&x| x >= 0).any(|&x| x == t as i32)
            };
            for t in 0..m.max_seq {
                assert_eq!(vis_test(&vis, t), visible(t), "row {row:?} t={t}");
            }
        }
    }

    /// Arena kernels (serial AND parallel) must be bit-identical to the
    /// seed-era reference kernels across a mixed prefill → draft →
    /// verify → sparse_verify → eagle round with partially-active slots.
    #[test]
    fn arena_kernels_match_reference_bit_for_bit() {
        let cfg = SystemConfig::synthetic("artifacts");
        let m = cfg.model.clone();
        let ectx = cfg.eagle.ctx;
        for par in [false, true] {
            let mut r = runner();
            r.set_parallel(par);
            let mut rr = ref_runner();

            let s_n = m.slots;
            let mut tokens = vec![0i32; s_n * m.prompt_pad];
            let mut plen = vec![1i32; s_n];
            let mut active = vec![0i32; s_n];
            for s in 0..s_n {
                active[s] = if s % 3 == 2 { 0 } else { 1 };
                plen[s] = (4 + (s % 5)) as i32;
                for j in 0..plen[s] as usize {
                    tokens[s * m.prompt_pad + j] = (10 + s * 7 + j) as i32 % m.vocab as i32;
                }
            }
            r.prefill(&tokens, &plen, &active).unwrap();
            assert_eq!(r.logits(), &rr.prefill(&tokens, &plen, &active)[..], "prefill par={par}");

            let w = m.draft_budget;
            let per_slot = m.layers * m.kv_heads * w;
            let mut idx = vec![-1i32; s_n * per_slot];
            for s in 0..s_n {
                for lh in 0..m.layers * m.kv_heads {
                    for j in 0..((plen[s] as usize) + 1).min(w) {
                        idx[s * per_slot + lh * w + j] = j as i32;
                    }
                }
            }
            let tok: Vec<i32> = (0..s_n).map(|s| (s as i32 * 3 + 1) % m.vocab as i32).collect();
            let pos: Vec<i32> = plen.clone();
            r.draft(w, &tok, &pos, &idx, &active).unwrap();
            assert_eq!(r.logits(), &rr.draft(w, &tok, &pos, &idx, &active)[..], "draft par={par}");

            let q = m.spec_k + 1;
            let mut vtok = vec![0i32; s_n * q];
            let mut qv = vec![1i32; s_n];
            for s in 0..s_n {
                qv[s] = (1 + (s % q)) as i32;
                for j in 0..q {
                    vtok[s * q + j] = ((s * 11 + j * 5) % m.vocab) as i32;
                }
            }
            let vpos: Vec<i32> = pos.iter().map(|p| p + 1).collect();
            r.verify(q, &vtok, &vpos, &qv, &active).unwrap();
            let (ref_l, ref_d) = rr.verify(q, &vtok, &vpos, &qv, &active);
            assert_eq!(r.logits(), &ref_l[..], "verify logits par={par}");
            assert_eq!(r.dump(), &ref_d[..], "verify dump par={par}");

            r.sparse_verify(&vtok, &vpos, &qv, &idx, &active).unwrap();
            assert_eq!(
                r.logits(),
                &rr.sparse_verify(&vtok, &vpos, &qv, &idx, &active)[..],
                "sparse_verify par={par}"
            );

            let ctx: Vec<i32> = (0..s_n * ectx).map(|i| (i % 97) as i32).collect();
            r.eagle(&ctx).unwrap();
            assert_eq!(r.logits(), &rr.eagle(&ctx)[..], "eagle par={par}");
        }
    }

    /// After warm-up, repeated steps must not grow the arena (the
    /// `engine_iteration` zero-allocation gate measures the same thing
    /// with a counting allocator; this pins the capacity invariant in
    /// plain `cargo test`).
    #[test]
    fn steady_state_arena_capacity_is_stable() {
        let mut r = runner();
        r.set_parallel(false);
        let m = r.m();
        let s_n = m.slots;
        let tokens = vec![3i32; s_n * m.prompt_pad];
        let plen = vec![4i32; s_n];
        let active = vec![1i32; s_n];
        let w = m.draft_budget;
        let idx = vec![-1i32; s_n * m.layers * m.kv_heads * w];
        let tok = vec![1i32; s_n];
        let q = m.spec_k + 1;
        let vtok = vec![2i32; s_n * q];
        let qv = vec![q as i32; s_n];
        r.prefill(&tokens, &plen, &active).unwrap();
        let cap = r.arena.capacity_elems();
        for i in 0..32 {
            let pos = vec![4 + i; s_n];
            r.draft(w, &tok, &pos, &idx, &active).unwrap();
            r.verify(q, &vtok, &pos, &qv, &active).unwrap();
            r.sparse_verify(&vtok, &pos, &qv, &idx, &active).unwrap();
            assert_eq!(r.arena.capacity_elems(), cap, "arena realloc at step {i}");
        }
    }
}
