//! Feedback-adaptive speculation length (the Vegas-style controller).
//!
//! Static speculation wastes draft work whenever acceptance dips: a slot
//! drafting k = 8 tokens at a 30% per-token acceptance rate burns ~5.6
//! sparse steps per round that verification then rolls back.  The
//! speculative-decoding survey (Xia et al.) calls dynamic draft-length
//! control the main lever beyond drafter quality itself; Vegas shows the
//! verifier's own feedback is enough signal to steer it online.
//!
//! [`AdaptiveK`] is that controller in its simplest robust form — AIMD
//! over a windowed acceptance-rate estimate:
//!
//! * every verification round feeds `observe(drafted, accepted)`;
//! * the estimate is `Σ accepted / Σ drafted` over the last
//!   [`AdaptiveKCfg::window`] rounds (per-token acceptance α, the same
//!   quantity Fig. 12 reports);
//! * α ≥ `widen_at`  → k grows additively (+1, up to `k_max`);
//! * α <  `narrow_at` → k halves (down to `k_min`) — rollback waste is
//!   quadratic-ish in overshoot, so narrowing is multiplicative.
//!
//! [`AdaptiveDrafter`] lifts the controller onto any [`Drafter`]: it
//! keeps one `AdaptiveK` per live request (created in `on_admit`, fed by
//! `on_verify`, dropped in `on_finish`) and clamps the inner drafter's
//! [`DraftPlan`] to the per-slot target.  Under greedy decoding the
//! output tokens are invariant to k (losslessness), so adaptation changes
//! *scheduling* — rounds, draft launches, wasted steps — never content.
//!
//! Note on the unified schedule: bucket alignment (Fig. 8) assumes every
//! round spans `k + 1` iterations; a slot narrowed below `k` verifies
//! early and drifts off its bucket phase, fragmenting verify launches.
//! That trade is deliberate (see `EngineConfigBuilder::adaptive_k`).
//!
//! Methodology and measured behaviour: EXPERIMENTS.md §AdaptiveK.

use std::collections::{HashMap, VecDeque};

use anyhow::Result;

use super::drafter::{DraftCtx, DraftHost, DraftMode, DraftPlan, Drafter, VerifyFeedback};
use super::{DrafterKind, IndexPolicy};
use crate::engine::Slot;
use crate::model::ModelConfig;

/// Controller tuning (defaults match the EXPERIMENTS.md methodology).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveKCfg {
    /// Never narrow below this (1 keeps speculation alive so the
    /// estimate can recover).
    pub k_min: usize,
    /// Rounds in the acceptance window.
    pub window: usize,
    /// Widen (+1) when the windowed α reaches this.
    pub widen_at: f64,
    /// Halve when the windowed α falls below this.
    pub narrow_at: f64,
}

impl Default for AdaptiveKCfg {
    fn default() -> Self {
        AdaptiveKCfg { k_min: 1, window: 8, widen_at: 0.8, narrow_at: 0.4 }
    }
}

/// Per-slot AIMD speculation-length controller (see module docs).
#[derive(Clone, Debug)]
pub struct AdaptiveK {
    cfg: AdaptiveKCfg,
    k_max: usize,
    k: usize,
    /// (drafted, accepted) per round, newest last.
    hist: VecDeque<(u32, u32)>,
}

impl AdaptiveK {
    /// Start optimistic at `k_max` (identical to the static drafter until
    /// feedback says otherwise).
    pub fn new(k_max: usize) -> AdaptiveK {
        AdaptiveK::with_cfg(k_max, AdaptiveKCfg::default())
    }

    pub fn with_cfg(k_max: usize, cfg: AdaptiveKCfg) -> AdaptiveK {
        let k_max = k_max.max(1);
        AdaptiveK {
            cfg: AdaptiveKCfg { k_min: cfg.k_min.clamp(1, k_max), ..cfg },
            k_max,
            k: k_max,
            hist: VecDeque::new(),
        }
    }

    /// Current speculation-length target, always in `[k_min, k_max]`.
    pub fn target(&self) -> usize {
        self.k
    }

    /// Windowed per-token acceptance rate α, if any tokens were drafted
    /// in the window.
    pub fn rate(&self) -> Option<f64> {
        let (d, a) = self
            .hist
            .iter()
            .fold((0u64, 0u64), |(d, a), &(dr, ac)| (d + dr as u64, a + ac as u64));
        if d == 0 {
            None
        } else {
            Some(a as f64 / d as f64)
        }
    }

    /// Feed one verification round and adjust the target.
    pub fn observe(&mut self, drafted: usize, accepted: usize) {
        self.hist.push_back((drafted as u32, accepted as u32));
        while self.hist.len() > self.cfg.window {
            self.hist.pop_front();
        }
        let Some(rate) = self.rate() else { return };
        if rate >= self.cfg.widen_at {
            self.k = (self.k + 1).min(self.k_max);
        } else if rate < self.cfg.narrow_at {
            self.k = (self.k / 2).max(self.cfg.k_min);
        }
    }
}

/// Wrap any drafter with per-session adaptive speculation length.
///
/// Enabled engine-wide by `EngineConfig::adaptive_k` (every resolved
/// drafter gets wrapped), or construct directly and register under a
/// custom name.  Capabilities (mode, artifacts, index policy) delegate to
/// the inner drafter; only the round-size decision is intercepted.
pub struct AdaptiveDrafter {
    inner: Box<dyn Drafter>,
    k_max: usize,
    cfg: AdaptiveKCfg,
    ctl: HashMap<u64, AdaptiveK>,
}

impl AdaptiveDrafter {
    pub fn new(inner: Box<dyn Drafter>, k_max: usize) -> AdaptiveDrafter {
        AdaptiveDrafter::with_cfg(inner, k_max, AdaptiveKCfg::default())
    }

    pub fn with_cfg(inner: Box<dyn Drafter>, k_max: usize, cfg: AdaptiveKCfg) -> AdaptiveDrafter {
        AdaptiveDrafter { inner, k_max, cfg, ctl: HashMap::new() }
    }

    fn target_for(&self, req_id: u64) -> usize {
        self.ctl
            .get(&req_id)
            .map(|c| c.target())
            .unwrap_or(self.k_max.max(1))
    }

    /// The live controller for a request (introspection/tests).
    pub fn controller(&self, req_id: u64) -> Option<&AdaptiveK> {
        self.ctl.get(&req_id)
    }
}

impl Drafter for AdaptiveDrafter {
    fn kind(&self) -> DrafterKind {
        self.inner.kind()
    }

    fn name(&self) -> String {
        format!("adaptive-{}", self.inner.name())
    }

    fn mode(&self) -> DraftMode {
        self.inner.mode()
    }

    fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
        self.inner.index_policy(m)
    }

    fn draft_budget(&self, m: &ModelConfig) -> usize {
        self.inner.draft_budget(m)
    }

    fn artifacts(&self, k: usize) -> Vec<String> {
        self.inner.artifacts(k)
    }

    fn ngram_order(&self) -> usize {
        self.inner.ngram_order()
    }

    fn wants_dump_refresh(&self) -> bool {
        self.inner.wants_dump_refresh()
    }

    fn validate_engine(&self, m: &ModelConfig, k: usize) -> Result<()> {
        self.inner.validate_engine(m, k)
    }

    fn on_admit(&mut self, req_id: u64, resumed: bool) {
        // Fresh admissions (and preempt restarts) reset the controller;
        // a host-tier reload keeps the learned estimate.
        if !resumed || !self.ctl.contains_key(&req_id) {
            self.ctl
                .insert(req_id, AdaptiveK::with_cfg(self.k_max, self.cfg));
        }
        self.inner.on_admit(req_id, resumed);
    }

    fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
        let cap = self.target_for(ctx.req_id);
        let mut plan = self.inner.plan(ctx);
        plan.target = plan.target.min(cap);
        plan.tokens.truncate(cap.max(1));
        plan
    }

    fn propose_batch(
        &mut self,
        host: &mut DraftHost,
        slots: &mut [Option<Slot>],
        idxs: &[usize],
    ) -> Result<u32> {
        let launches = self.inner.propose_batch(host, slots, idxs)?;
        // Inner drafters with custom batch hooks (EAGLE, TriForce) size
        // proposals at host.k; clamp them to the per-slot target after
        // the fact (draft_probs rows must stay in lockstep with drafts).
        let vocab = host.m.vocab;
        for &i in idxs {
            let Some(slot) = slots[i].as_mut() else { continue };
            let cap = self.target_for(slot.req.id).max(1);
            if slot.drafts.len() > cap {
                slot.drafts.truncate(cap);
                slot.draft_probs.truncate(cap * vocab);
            }
        }
        Ok(launches)
    }

    fn after_draft(
        &mut self,
        host: &mut DraftHost,
        slots: &mut [Option<Slot>],
        idxs: &[usize],
    ) -> Result<u32> {
        self.inner.after_draft(host, slots, idxs)
    }

    fn on_verify(&mut self, fb: &VerifyFeedback) {
        self.ctl
            .entry(fb.req_id)
            .or_insert_with(|| AdaptiveK::with_cfg(self.k_max, self.cfg))
            .observe(fb.drafted, fb.accepted);
        self.inner.on_verify(fb);
    }

    fn current_k(&self, req_id: u64) -> Option<usize> {
        self.ctl.get(&req_id).map(|c| c.target())
    }

    fn on_finish(&mut self, req_id: u64) {
        self.ctl.remove(&req_id);
        self.inner.on_finish(req_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::drafter::PillarDrafter;

    #[test]
    fn high_acceptance_converges_to_k_max() {
        let mut c = AdaptiveK::new(8);
        assert_eq!(c.target(), 8, "starts optimistic");
        // knock it down first
        for _ in 0..6 {
            c.observe(8, 0);
        }
        assert!(c.target() < 8);
        for _ in 0..32 {
            let k = c.target();
            c.observe(k, k); // perfect acceptance
        }
        assert_eq!(c.target(), 8, "full acceptance must recover k_max");
    }

    #[test]
    fn low_acceptance_converges_to_k_min() {
        let mut c = AdaptiveK::new(8);
        for _ in 0..12 {
            let k = c.target();
            c.observe(k, 0);
        }
        assert_eq!(c.target(), 1, "zero acceptance must reach k_min");
        // and it never leaves the bounds on any stream
        let mut c = AdaptiveK::new(8);
        for i in 0..200 {
            let k = c.target();
            c.observe(k, if i % 3 == 0 { k } else { 0 });
            assert!(c.target() >= 1 && c.target() <= 8, "i={i}");
        }
    }

    #[test]
    fn window_forgets_old_rounds() {
        let mut c = AdaptiveK::with_cfg(
            8,
            AdaptiveKCfg { window: 4, ..AdaptiveKCfg::default() },
        );
        for _ in 0..8 {
            c.observe(8, 0);
        }
        let low = c.rate().unwrap();
        assert_eq!(low, 0.0);
        for _ in 0..4 {
            c.observe(8, 8);
        }
        assert_eq!(c.rate().unwrap(), 1.0, "window must have dropped the zeros");
    }

    #[test]
    fn rate_is_windowed_alpha() {
        let mut c = AdaptiveK::new(8);
        assert!(c.rate().is_none());
        c.observe(8, 4);
        c.observe(8, 8);
        assert!((c.rate().unwrap() - 12.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn adaptive_wrapper_tracks_per_request_state() {
        let mut d = AdaptiveDrafter::new(Box::new(PillarDrafter { w: 64 }), 8);
        assert_eq!(d.name(), "adaptive-pillar_w64");
        assert_eq!(d.mode(), DraftMode::SelfSpec);
        assert!(d.wants_dump_refresh());
        d.on_admit(1, false);
        d.on_admit(2, false);
        // request 1 collapses, request 2 stays perfect
        for _ in 0..12 {
            d.on_verify(&VerifyFeedback {
                req_id: 1,
                slot_idx: 0,
                drafted: 8,
                accepted: 0,
                bonus_token: 0,
                context_len: 10,
            });
            d.on_verify(&VerifyFeedback {
                req_id: 2,
                slot_idx: 1,
                drafted: 8,
                accepted: 8,
                bonus_token: 0,
                context_len: 10,
            });
        }
        let ctx = |id| DraftCtx {
            req_id: id,
            slot_idx: 0,
            k: 8,
            sched_cap: 8,
            len: 10,
            remaining: 100,
            pending: 0,
            first_round: false,
            ngram: None,
        };
        assert_eq!(d.plan(&ctx(1)).target, 1, "collapsed request narrows");
        assert_eq!(d.plan(&ctx(2)).target, 8, "healthy request keeps k");
        d.on_finish(1);
        assert!(d.controller(1).is_none(), "state dropped at finish");
        // unknown request falls back to k_max (defensive)
        assert_eq!(d.plan(&ctx(99)).target, 8);
    }
}
