//! Typed step-function wrapper over the raw artifact executables.
//!
//! Owns the device-resident state: the weights buffer (uploaded once) and
//! the two KV pools, which are threaded functionally through every step —
//! each execute returns fresh pool buffers that replace the old ones, so
//! the KV-cache never crosses the host boundary on the request path
//! (offloading uses `kv_dump_prepare`/`kv_pools`, which is the deliberate,
//! bandwidth-modelled host transfer).
//!
//! Signatures mirror the sim backend's arena API: steps fill the
//! [`StepArena`] and callers read `logits()` / `dump()` views.  On this
//! backend the fetch from device already materialises a host `Vec`, which
//! lands in the arena so the view lifetimes and zeroing semantics are
//! identical to the fallback.

use anyhow::{anyhow, Result};
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use super::{ArtifactNames, Runtime, StepArena, StepStats};

pub struct ModelRunner {
    pub rt: Rc<Runtime>,
    weights: xla::PjRtBuffer,
    eagle_weights: Option<xla::PjRtBuffer>,
    kv_k: xla::PjRtBuffer,
    kv_v: xla::PjRtBuffer,
    arena: StepArena,
    names: ArtifactNames,
    /// Host staging for `kv_dump_prepare` → `kv_pools` (offload path).
    host_k: Vec<f32>,
    host_v: Vec<f32>,
    pub stats: StepStats,
}

impl ModelRunner {
    pub fn new(rt: Rc<Runtime>) -> Result<Self> {
        let m = &rt.cfg.model;
        let dir = Path::new(&rt.cfg.dir);
        let w = Runtime::read_f32_file(&dir.join("weights.bin"))?;
        if w.len() != rt.cfg.n_params {
            return Err(anyhow!(
                "weights.bin has {} params, config says {}",
                w.len(),
                rt.cfg.n_params
            ));
        }
        let weights = rt.upload_f32(&w, &[w.len()])?;
        let zeros = vec![0f32; m.kv_pool_elems()];
        let dims = [m.layers, m.slots, m.max_seq, m.kv_heads, m.head_dim];
        let kv_k = rt.upload_f32(&zeros, &dims)?;
        let kv_v = rt.upload_f32(&zeros, &dims)?;
        let arena = StepArena::new(m);
        let names = ArtifactNames::new(m);
        Ok(Self {
            rt,
            weights,
            eagle_weights: None,
            kv_k,
            kv_v,
            arena,
            names,
            host_k: Vec::new(),
            host_v: Vec::new(),
            stats: StepStats::default(),
        })
    }

    fn m(&self) -> &crate::model::ModelConfig {
        &self.rt.cfg.model
    }

    /// No-op on this backend: per-slot parallelism happens inside the XLA
    /// executable, not in host code.  Kept so engine code toggling the
    /// fallback's slot-parallel fill compiles unchanged.
    pub fn set_parallel(&mut self, _on: bool) {}

    pub fn parallel(&self) -> bool {
        true
    }

    /// The logits written by the most recent step: `[S, V]` for
    /// prefill/draft/eagle, `[S, Q, V]` for (sparse-)verify.
    pub fn logits(&self) -> &[f32] {
        self.arena.logits()
    }

    /// The `[S, L, Hkv, T]` attention-mass dump of the most recent dense
    /// verify.
    pub fn dump(&self) -> &[f32] {
        self.arena.dump()
    }

    fn stash_logits(&mut self, logits: Vec<f32>) {
        self.arena.logits[..logits.len()].copy_from_slice(&logits);
        self.arena.logits_len = logits.len();
    }

    /// Zero both KV pools (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        let m = self.m();
        let zeros = vec![0f32; m.kv_pool_elems()];
        let dims = [m.layers, m.slots, m.max_seq, m.kv_heads, m.head_dim];
        self.kv_k = self.rt.upload_f32(&zeros, &dims)?;
        self.kv_v = self.rt.upload_f32(&zeros, &dims)?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Step functions (argument order == python/compile/model.py contracts)
    // ------------------------------------------------------------------

    /// Prefill the prompt chunk for newly-admitted slots.
    /// tokens: [S*P], plen/active: [S].  Fills last-token logits [S*V].
    pub fn prefill(&mut self, tokens: &[i32], plen: &[i32], active: &[i32]) -> Result<()> {
        let m = self.m();
        let (s, p) = (m.slots, m.prompt_pad);
        debug_assert_eq!(tokens.len(), s * p);
        let t0 = Instant::now();
        let tok = self.rt.upload_i32(tokens, &[s, p])?;
        let pl = self.rt.upload_i32(plen, &[s])?;
        let ac = self.rt.upload_i32(active, &[s])?;
        let t1 = Instant::now();
        let mut out = self.rt.execute(
            "prefill",
            &[&self.weights, &self.kv_k, &self.kv_v, &tok, &pl, &ac],
        )?;
        let t2 = Instant::now();
        if out.len() != 3 {
            return Err(anyhow!("prefill: expected 3 outputs, got {}", out.len()));
        }
        self.kv_v = out.pop().expect("output arity checked above");
        self.kv_k = out.pop().expect("output arity checked above");
        let logits = self.rt.fetch_f32(&out[0])?;
        self.stash_logits(logits);
        let t3 = Instant::now();
        self.stats.add(
            "prefill",
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
        );
        Ok(())
    }

    /// One sparse draft step (budget `w` must be a compiled variant).
    /// token/pos/active: [S]; idx: [S*L*Hkv*w] (-1 holes).  Fills [S*V].
    pub fn draft(
        &mut self,
        w: usize,
        token: &[i32],
        pos: &[i32],
        idx: &[i32],
        active: &[i32],
    ) -> Result<()> {
        let m = self.m();
        let (s, l, hkv) = (m.slots, m.layers, m.kv_heads);
        debug_assert_eq!(idx.len(), s * l * hkv * w);
        let name = self
            .names
            .draft(w)
            .ok_or_else(|| anyhow!("no draft_w{w} variant"))?
            .to_string();
        let t0 = Instant::now();
        let tok = self.rt.upload_i32(token, &[s])?;
        let po = self.rt.upload_i32(pos, &[s])?;
        let ix = self.rt.upload_i32(idx, &[s, l, hkv, w])?;
        let ac = self.rt.upload_i32(active, &[s])?;
        let t1 = Instant::now();
        let mut out = self.rt.execute(
            &name,
            &[&self.weights, &self.kv_k, &self.kv_v, &tok, &po, &ix, &ac],
        )?;
        let t2 = Instant::now();
        if out.len() != 3 {
            return Err(anyhow!("{name}: expected 3 outputs, got {}", out.len()));
        }
        self.kv_v = out.pop().expect("output arity checked above");
        self.kv_k = out.pop().expect("output arity checked above");
        let logits = self.rt.fetch_f32(&out[0])?;
        self.stash_logits(logits);
        let t3 = Instant::now();
        self.stats.add(
            &name,
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
        );
        Ok(())
    }

    /// One dense verification step over q query tokens (compiled variant).
    /// tokens: [S*q]; pos/q_valid/active: [S].  Fills logits [S*q*V] and
    /// the dump [S*L*Hkv*T].
    pub fn verify(
        &mut self,
        q: usize,
        tokens: &[i32],
        pos: &[i32],
        q_valid: &[i32],
        active: &[i32],
    ) -> Result<()> {
        let m = self.m();
        let s = m.slots;
        debug_assert_eq!(tokens.len(), s * q);
        let name = self
            .names
            .verify(q)
            .ok_or_else(|| anyhow!("no verify_q{q} variant"))?
            .to_string();
        let t0 = Instant::now();
        let tok = self.rt.upload_i32(tokens, &[s, q])?;
        let po = self.rt.upload_i32(pos, &[s])?;
        let qv = self.rt.upload_i32(q_valid, &[s])?;
        let ac = self.rt.upload_i32(active, &[s])?;
        let t1 = Instant::now();
        let mut out = self.rt.execute(
            &name,
            &[&self.weights, &self.kv_k, &self.kv_v, &tok, &po, &qv, &ac],
        )?;
        let t2 = Instant::now();
        if out.len() != 4 {
            return Err(anyhow!("{name}: expected 4 outputs, got {}", out.len()));
        }
        let dump_buf = out.pop().expect("output arity checked above");
        self.kv_v = out.pop().expect("output arity checked above");
        self.kv_k = out.pop().expect("output arity checked above");
        let logits = self.rt.fetch_f32(&out[0])?;
        self.stash_logits(logits);
        let dump = self.rt.fetch_f32(&dump_buf)?;
        self.arena.dump[..dump.len()].copy_from_slice(&dump);
        self.arena.dump_len = dump.len();
        let t3 = Instant::now();
        self.stats.add(
            &name,
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
        );
        Ok(())
    }

    /// TriForce middle layer: verify q tokens under the sparse draft
    /// model.  Fills logits [S*(spec_k+1)*V].
    pub fn sparse_verify(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        q_valid: &[i32],
        idx: &[i32],
        active: &[i32],
    ) -> Result<()> {
        let m = self.m();
        let (s, l, hkv, w) = (m.slots, m.layers, m.kv_heads, m.draft_budget);
        let q = m.spec_k + 1;
        debug_assert_eq!(tokens.len(), s * q);
        debug_assert_eq!(idx.len(), s * l * hkv * w);
        let t0 = Instant::now();
        let tok = self.rt.upload_i32(tokens, &[s, q])?;
        let po = self.rt.upload_i32(pos, &[s])?;
        let qv = self.rt.upload_i32(q_valid, &[s])?;
        let ix = self.rt.upload_i32(idx, &[s, l, hkv, w])?;
        let ac = self.rt.upload_i32(active, &[s])?;
        let t1 = Instant::now();
        let mut out = self.rt.execute(
            "sparse_verify",
            &[&self.weights, &self.kv_k, &self.kv_v, &tok, &po, &qv, &ix, &ac],
        )?;
        let t2 = Instant::now();
        if out.len() != 3 {
            return Err(anyhow!("sparse_verify: expected 3 outputs"));
        }
        self.kv_v = out.pop().expect("output arity checked above");
        self.kv_k = out.pop().expect("output arity checked above");
        let logits = self.rt.fetch_f32(&out[0])?;
        self.stash_logits(logits);
        let t3 = Instant::now();
        self.stats.add(
            "sparse_verify",
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
        );
        Ok(())
    }

    /// EAGLE-like draft head: ctx [S*ECTX] -> logits [S*V].
    pub fn eagle(&mut self, ctx: &[i32]) -> Result<()> {
        let m = self.m();
        let (s, ectx) = (m.slots, self.rt.cfg.eagle.ctx);
        debug_assert_eq!(ctx.len(), s * ectx);
        if self.eagle_weights.is_none() {
            let dir = Path::new(&self.rt.cfg.dir);
            let w = Runtime::read_f32_file(&dir.join("eagle.bin"))?;
            if w.len() != self.rt.cfg.eagle_n_params {
                return Err(anyhow!("eagle.bin size mismatch"));
            }
            self.eagle_weights = Some(self.rt.upload_f32(&w, &[w.len()])?);
        }
        let t0 = Instant::now();
        let cx = self.rt.upload_i32(ctx, &[s, ectx])?;
        let t1 = Instant::now();
        let out = self
            .rt
            .execute("eagle", &[self.eagle_weights.as_ref().expect("lazily loaded above"), &cx])?;
        let t2 = Instant::now();
        let logits = self.rt.fetch_f32(&out[0])?;
        self.stash_logits(logits);
        let t3 = Instant::now();
        self.stats.add(
            "eagle",
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
        );
        Ok(())
    }

    /// Pull both KV pools to the host (offload path); read them back with
    /// [`Self::kv_pools`].  One device→host copy per pool, landing in
    /// reused staging buffers.
    pub fn kv_dump_prepare(&mut self) -> Result<()> {
        let t0 = Instant::now();
        self.host_k = self.rt.fetch_f32(&self.kv_k)?;
        self.host_v = self.rt.fetch_f32(&self.kv_v)?;
        let t1 = Instant::now();
        self.stats
            .add("kv_dump", 0.0, 0.0, (t1 - t0).as_secs_f64());
        Ok(())
    }

    /// Host views of (k, v), each [L*S*T*Hkv*D].  Valid after
    /// [`Self::kv_dump_prepare`].
    pub fn kv_pools(&self) -> (&[f32], &[f32]) {
        (&self.host_k, &self.host_v)
    }

    /// Write one slot's KV rows back into the device pools (onload path).
    /// rows_k/rows_v: [L*T*Hkv*D].
    pub fn kv_load(&mut self, slot: usize, rows_k: &[f32], rows_v: &[f32]) -> Result<()> {
        let m = self.m();
        debug_assert_eq!(rows_k.len(), m.kv_slot_elems());
        let dims = [m.layers, m.max_seq, m.kv_heads, m.head_dim];
        let t0 = Instant::now();
        let sl = self.rt.upload_i32(&[slot as i32], &[1])?;
        let rk = self.rt.upload_f32(rows_k, &dims)?;
        let rv = self.rt.upload_f32(rows_v, &dims)?;
        let t1 = Instant::now();
        let mut out = self
            .rt
            .execute("kv_load", &[&self.kv_k, &self.kv_v, &sl, &rk, &rv])?;
        let t2 = Instant::now();
        if out.len() != 2 {
            return Err(anyhow!("kv_load: expected 2 outputs"));
        }
        self.kv_v = out.pop().expect("output arity checked above");
        self.kv_k = out.pop().expect("output arity checked above");
        self.stats.add(
            "kv_load",
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            0.0,
        );
        Ok(())
    }
}
