//! Quickstart: serve a small batch of reasoning requests with SparseSpec
//! (PillarAttn self-speculation), compare against vanilla decoding, then
//! stream one session token-by-token through the serving API.
//!
//!   cargo run --release --example quickstart
//!   cargo run --release --example quickstart -- --trace-out trace.json
//!   (add `make artifacts` + `--features pjrt` for the real XLA path; the
//!    default build serves on the deterministic CPU fallback runtime)
//!
//! With `--trace-out` the final section saves a Chrome/Perfetto trace of a
//! mixed-drafter batch under KV pressure — load it at ui.perfetto.dev.
//! The robustness section at the end replays the comparison under a seeded
//! fault plan and checks that chaos never changes the greedy outputs.


use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig, EngineHandle};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::runtime::Runtime;
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::trace::{names, TraceConfig};
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Rc::new(Runtime::load(&dir)?);
    println!(
        "loaded {} artifacts on {} (model: {} params, trained={})",
        rt.cfg.artifacts.len(),
        rt.platform_name(),
        rt.cfg.n_params,
        rt.cfg.trained
    );

    let n_req = 8;
    let mk_reqs = || {
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, 42)
            .offline_batch(n_req)
    };

    // Vanilla autoregressive baseline.
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla))?;
    let rv = vanilla.run(mk_reqs())?;
    println!("{}", rv.summary());

    // SparseSpec: PillarAttn self-speculation, k=8, W=128 (the acceptance-
    // saturation knee of the fig12 sensitivity sweep).
    let mut ours = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 128 }).with_k(8),
    )?;
    let ro = ours.run(mk_reqs())?;
    println!("{}", ro.summary());

    // Losslessness: greedy speculative decoding must reproduce the
    // vanilla outputs token-for-token.
    let mut same = 0usize;
    let mut total = 0usize;
    for (id, out_v) in &rv.outputs {
        let out_o = &ro.outputs[id];
        total += out_v.len().max(out_o.len());
        same += out_v.iter().zip(out_o.iter()).filter(|(a, b)| a == b).count();
    }
    println!(
        "losslessness: {}/{} tokens identical ({:.2}%)",
        same, total, 100.0 * same as f64 / total as f64
    );
    println!(
        "wallclock speedup: {:.2}x | simulated-H100 speedup: {:.2}x",
        rv.wall_s / ro.wall_s,
        rv.sim_s / ro.sim_s
    );

    // ------------------------------------------------------------------
    // Streaming quickstart: submit one session and consume its tokens as
    // verification accepts them (see engine::api for the full surface —
    // EngineDriver adds live arrival processes, TokenSink adds push-style
    // delivery, SessionHandle::cancel stops a generation mid-flight).
    // ------------------------------------------------------------------
    let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
        .k(8)
        .build(&rt.cfg.model)?;
    let mut handle = EngineHandle::new(rt.clone(), cfg)?;
    let req = mk_reqs().remove(0);
    let expect = req.max_new;
    let session = handle.submit(req);
    print!("streaming session {} ({expect} tokens):", session.id());
    let mut chunks = 0usize;
    while handle.step()? {
        let batch = session.drain();
        if !batch.is_empty() {
            chunks += 1;
            print!(" +{}", batch.len());
        }
    }
    let stats = session.stats();
    println!(
        "\n  done: {} tokens in {chunks} increments, ttft={:.4}s, {:.2} accepted/round",
        stats.tokens,
        stats.ttft_s.unwrap_or(0.0),
        stats.mean_accepted_per_round()
    );

    // ------------------------------------------------------------------
    // Observability quickstart: trace a mixed-drafter batch under KV
    // pressure and export the span journal as Chrome/Perfetto JSON
    // (EXPERIMENTS.md §Observability walks through the resulting view).
    // ------------------------------------------------------------------
    let m = &rt.cfg.model;
    // Tight dynamic budget (25% of worst case) forces offload/reload
    // traffic, so the Kv track has something to show.
    let kv_budget = m.slots * m.max_seq / 4;
    let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
        .k(8)
        .schedule(Schedule::Unified)
        .delayed_verify(true)
        .kv(KvPolicy::Dynamic, kv_budget)
        .adaptive_k(true)
        .allow_drafter(DrafterKind::NGram { n: 3 })
        .allow_drafter(DrafterKind::Vanilla)
        .tracing(TraceConfig::on())
        .ttft_slo(0.5)
        .build(m)?;
    let mut traced = Engine::new(rt.clone(), cfg)?;
    // Oversubscribe the 12 slots so admission queueing shows up too.
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, 43)
            .offline_batch(16);
    // Mixed batch: a third of the sessions override the engine default.
    for (i, r) in reqs.iter_mut().enumerate() {
        r.drafter = match i % 3 {
            1 => Some(DrafterKind::NGram { n: 3 }),
            2 => Some(DrafterKind::Vanilla),
            _ => None, // engine default (PillarAttn)
        };
    }
    let rt_report = traced.run(reqs)?;
    println!("\ntraced mixed-drafter run: {}", rt_report.summary());
    println!("{}", rt_report.slo.to_markdown());
    let chrome = traced.export_trace_chrome();
    // The trace must carry the full iteration anatomy: draft + verify
    // spans, the delayed-verification overlap window, and KV evictions.
    for span in [
        names::ITERATION,
        names::DRAFT,
        names::VERIFY,
        names::DELAYED_VERIFY_OVERLAP,
        names::KV_OFFLOAD,
    ] {
        assert!(
            chrome.contains(&format!("\"{span}\"")),
            "trace export is missing `{span}` spans"
        );
    }
    println!(
        "trace journal: {} events ({} dropped)",
        traced.tracer().len(),
        traced.tracer().dropped()
    );
    // ------------------------------------------------------------------
    // Robustness quickstart: replay the very first comparison under a
    // seeded chaos plan (transient runtime/KV faults + a drafter that
    // "panics" 10% of the time).  Transient faults retry with sim-clock
    // backoff, drafter faults demote only the affected slot to vanilla
    // decoding — so every session completes and, at temperature 0, the
    // generated tokens are bit-identical to the fault-free run above.
    // (`sparsespec serve --fault-plan ... --fault-seed N` is the CLI
    // spelling; EXPERIMENTS.md §Robustness has the full sweep.)
    // ------------------------------------------------------------------
    let plan = sparsespec::fault::FaultPlan::parse(
        "runtime:0.02,kv_reload:0.05,drafter_panic:0.1",
    )?;
    let cfg = EngineConfig::new(DrafterKind::Pillar { w: 128 })
        .with_k(8)
        .with_faults(sparsespec::fault::FaultConfig::new(plan, 7));
    let mut chaos = Engine::new(rt.clone(), cfg)?;
    let rchaos = chaos.run(mk_reqs())?;
    println!("\nchaos run: {}", rchaos.summary());
    println!(
        "chaos: {} faults injected, {} retries, {} slot degradations, {} failed — \
         outputs identical to fault-free run: {}",
        rchaos.faults_injected,
        rchaos.fault_retries,
        rchaos.slot_degradations,
        rchaos.requests_failed,
        rchaos.outputs == ro.outputs
    );
    assert_eq!(rchaos.outputs, ro.outputs, "chaos perturbed greedy outputs");

    let mut trace_path = None;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        if a == "--trace-out" {
            trace_path = argv.next();
        }
    }
    if let Some(path) = trace_path {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&path, &chrome)?;
        println!("perfetto trace saved to {path} — load it at ui.perfetto.dev");
    } else {
        println!("(pass `-- --trace-out trace.json` to save the Perfetto trace)");
    }
    Ok(())
}
