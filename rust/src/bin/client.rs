//! sparsespec-client — open-loop load generator for sparsespec-server.
//!
//! Generates per-tenant `workload` traffic (Poisson or bursty/diurnal
//! arrival curves), replays it over the wire protocol, and reports
//! client-side TTFT / inter-token latency / goodput plus typed refusal
//! counts.
//!
//! Examples:
//!   sparsespec-client --addr 127.0.0.1:7433 --tenants acme,hobby \
//!       --requests 16 --rate 4 --horizon 20 --arrival bursty:4 \
//!       --dataset aime --seed 7 --shutdown

use sparsespec::serving::{run_load, ClientConfig, TenantLoad};
use sparsespec::util::cli::Args;
use sparsespec::workload::{shard_requests, ArrivalCurve, Dataset, ShardShape, WorkloadGen};

fn usage() -> ! {
    eprintln!(
        "usage: sparsespec-client [flags]\n\
         \x20 --addr ADDR         server wire address (default 127.0.0.1:7433)\n\
         \x20 --tenants LIST      comma-separated tenant names (default 'default')\n\
         \x20 --drafters LIST     per-tenant wire drafter names, parallel to --tenants ('' = engine default)\n\
         \x20 --requests N        requests per tenant for offline mode (default 8)\n\
         \x20 --rate R            arrivals/s per tenant — switches to online arrivals\n\
         \x20 --horizon SECS      online horizon in trace seconds (default 20)\n\
         \x20 --arrival CURVE     uniform | bursty:<ratio> | diurnal:<ratio> (default uniform)\n\
         \x20 --dataset NAME      aime|olympiad|livecode|short|long (default aime)\n\
         \x20 --seed S            workload seed (default 7; tenant index is mixed in)\n\
         \x20 --shards N          split each tenant's trace into N connection shards (default 1)\n\
         \x20 --shape SHAPE       shard shape: even | skewed:<hot> | bylength (default even)\n\
         \x20 --time-scale F      trace-seconds compressed per wall second (default 50)\n\
         \x20 --credit-every N    return token credit every N tokens (default 32)\n\
         \x20 --timeout SECS      client deadline (default 60)\n\
         \x20 --artifacts DIR     artifact dir for workload model/grammar config\n\
         \x20 --shutdown          drain the server after the run\n\
         \x20 --report-out FILE   save the Prometheus exposition of client metrics\n\
         \x20 --outputs-out FILE  save per-session JSONL (tenant, req, replica, outcome, tokens)\n\
         \x20 --allow-failed      exit 0 even when sessions failed (deliberate-failover runs)"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.bool("help", false) {
        usage();
    }
    let rt = sparsespec::runtime::Runtime::load(&args.str("artifacts", "artifacts"))?;
    let dataset = Dataset::parse(&args.str("dataset", "aime")).unwrap_or_else(|| usage());
    let curve = ArrivalCurve::parse(&args.str("arrival", "uniform")).unwrap_or_else(|| usage());
    let tenants: Vec<String> = args
        .str("tenants", "default")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.to_string())
        .collect();
    let drafters: Vec<String> = args
        .str("drafters", "")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let seed = args.u64("seed", 7);
    let horizon = args.f64("horizon", 20.0);
    let shards = args.usize("shards", 1).max(1);
    let shape = ShardShape::parse(&args.str("shape", "even")).unwrap_or_else(|| usage());

    let mut cfg = ClientConfig::new(&args.str("addr", "127.0.0.1:7433"));
    cfg.credit_every = args.u64("credit-every", 32) as u32;
    cfg.time_scale = args.f64("time-scale", 50.0);
    cfg.timeout_s = args.f64("timeout", 60.0);
    cfg.shutdown_after = args.bool("shutdown", false);

    for (i, name) in tenants.iter().enumerate() {
        let mut gen = WorkloadGen::new(
            rt.cfg.grammar.clone(),
            rt.cfg.model.clone(),
            dataset,
            // distinct per-tenant streams, deterministic per --seed
            seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let requests = match args.opt("rate") {
            Some(r) => {
                let rate: f64 = r.parse().unwrap_or(2.0);
                gen.online_trace_curve(rate, horizon, curve)
            }
            None => gen.offline_batch(args.usize("requests", 8)),
        };
        println!("tenant {name}: {} requests ({})", requests.len(), dataset.name());
        if shards == 1 {
            cfg.tenants.push(TenantLoad {
                name: name.clone(),
                requests,
                drafter: drafters.get(i).cloned().unwrap_or_default(),
            });
        } else {
            // one connection per shard: `name/K` streams its own slice of
            // the trace, so the router sees `shards` concurrent tenants
            // with the chosen load shape
            for (k, part) in shard_requests(requests, shards, shape).into_iter().enumerate() {
                cfg.tenants.push(TenantLoad {
                    name: format!("{name}/{k}"),
                    requests: part,
                    drafter: drafters.get(i).cloned().unwrap_or_default(),
                });
            }
        }
    }

    let report = run_load(cfg)?;
    print!("{}", report.render());
    if let Some(path) = args.opt("report-out") {
        std::fs::write(path, report.metrics.expose_prometheus("sparsespec_client"))?;
        println!("client metrics saved to {path}");
    }
    if let Some(path) = args.opt("outputs-out") {
        // one JSON object per session — machine-checkable bit-identity and
        // replica attribution for the CI fleet smoke
        use std::fmt::Write as _;
        let mut out = String::new();
        for ((tenant, req), d) in &report.sessions {
            let tokens = report.outputs.get(&(tenant.clone(), *req)).cloned().unwrap_or_default();
            let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
            let replica = d.replica.map(|r| r.to_string()).unwrap_or_else(|| "null".to_string());
            let _ = writeln!(
                out,
                "{{\"tenant\":\"{tenant}\",\"req\":{req},\"replica\":{replica},\"outcome\":\"{}\",\"tokens\":[{}]}}",
                d.outcome,
                toks.join(",")
            );
        }
        std::fs::write(path, out)?;
        println!("session outputs saved to {path}");
    }
    // Non-zero exit when anything failed outright (refusals are expected
    // under deliberate overload and do not fail the run).
    if report.failed > 0 && !args.bool("allow-failed", false) {
        std::process::exit(1);
    }
    Ok(())
}
