//! Engine implementation: the per-iteration serving loop.
//!
//! The engine is a **plugin host**: it owns batching, scheduling,
//! verification and the KV tiers, while every draft policy lives behind
//! the object-safe [`Drafter`] trait (`spec::drafter`), resolved by name
//! through a [`DrafterRegistry`].  Slots carry their own drafter index, so
//! sessions with different policies (per-session override via
//! `Request::drafter`) share one batch: draft steps are grouped by sparse
//! budget W, proposal generation is grouped per drafter (one batched hook
//! call each), and a single dense verification serves everyone.

use anyhow::Result;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::rc::Rc;
use std::time::Instant;

use super::api::{FinishReason, SessionHandle, SessionShared, TokenSink};
use super::slot::{Phase, Slot};
use super::{EngineConfig, RunReport, SloReport};
use crate::fault::{self, EngineError, FaultInjector, FaultSite};
use crate::kv_cache::{HostKv, KvManager, OffloadEngine, OffloadJob, PressureAction};
use crate::metrics::Histogram;
use crate::perfmodel::{DeviceModel, SimScale};
use crate::runtime::{ArtifactNames, ModelRunner, Runtime};
use crate::sampling;
use crate::scheduler::{BucketScheduler, IterComposition, Schedule, ScheduleTrace};
use crate::spec::{
    AcceptStats, AdaptiveDrafter, DraftCtx, DraftHost, DraftMode, Drafter, DrafterKind,
    DrafterRegistry, NGramIndex, PillarState, VerifyFeedback,
};
use crate::trace::{names, Tracer, Track};
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::{Promise, ThreadPool};
use crate::workload::Request;

/// State parked on the host while a request's KV lives in the host tier.
struct Suspended {
    req: Request,
    len: usize,
    gen_count: usize,
    pending: i32,
    output: Vec<i32>,
    pillar: PillarState,
    ngram_hist: Vec<i32>,
    /// Drafter-table index (per-session policy survives suspension).
    drafter: usize,
    admitted_at: Instant,
    sim_admitted_at: f64,
}

/// Engine-owned staging buffers, cleared and refilled in place each
/// iteration so steady-state batch composition (admit / draft / verify)
/// allocates nothing: `clear()` + `resize()` is a memset over retained
/// capacity.  Fields are shared across phases (the phases run
/// sequentially), so capacity converges to the largest shape touched.
#[derive(Default)]
struct Scratch {
    /// Token staging: `slots × prompt_pad` (admit), `slots` (draft) or
    /// `slots × q` (verify).
    tokens: Vec<i32>,
    plen: Vec<i32>,
    pos: Vec<i32>,
    qv: Vec<i32>,
    active: Vec<i32>,
    /// Flattened per-slot sparse index rows for grouped draft launches.
    idxs: Vec<i32>,
    /// Slot indices admitted this iteration.
    newly: Vec<usize>,
    /// Slot indices that drafted this iteration (across all W groups).
    stepped: Vec<usize>,
    /// Slot indices in this verification launch.
    participating: Vec<usize>,
    /// One vocab-row copy of the arena logits view — ends the runner
    /// borrow before sampling mutates the engine.
    row: Vec<f32>,
    /// Draft-distribution staging (`softmax_into` target).
    probs: Vec<f32>,
}

/// Result of the off-thread verification processing (delayed mode).
struct VerifyWork {
    slot_idx: usize,
    accepted: usize,
    next_token: i32,
    /// Refreshed pillar state (top-k over the dump) — the expensive part.
    pillar: Option<PillarState>,
    cpu_s: f64,
    /// Portion of `cpu_s` spent in critical-token selection (refresh).
    select_s: f64,
}

/// Always-on SLO accounting on the **simulated** serving clock.
///
/// Token events are queued while an iteration runs and flushed only after
/// the clock has advanced past that iteration (mirroring `stamp_pending`),
/// so TTFT/ITL include the cost of the iteration that produced them.
struct SloTracker {
    target_s: f64,
    ttft: Histogram,
    itl: Histogram,
    within_target: usize,
    completed: usize,
    submit_sim: HashMap<u64, f64>,
    /// First-token latency per live request; first admission wins, so a
    /// preempt-restart's second prefill never rewrites TTFT.
    ttft_by: HashMap<u64, f64>,
    last_emit: HashMap<u64, f64>,
    ttft_pending: Vec<u64>,
    /// (req_id, tokens emitted this round) — ITL spreads the round gap
    /// evenly over the tokens it delivered.
    itl_pending: Vec<(u64, usize)>,
    completed_pending: Vec<u64>,
}

impl SloTracker {
    fn new(target_s: f64) -> SloTracker {
        SloTracker {
            target_s,
            ttft: Histogram::default(),
            itl: Histogram::default(),
            within_target: 0,
            completed: 0,
            submit_sim: HashMap::new(),
            ttft_by: HashMap::new(),
            last_emit: HashMap::new(),
            ttft_pending: Vec::new(),
            itl_pending: Vec::new(),
            completed_pending: Vec::new(),
        }
    }

    fn on_submit(&mut self, id: u64, sim_s: f64) {
        self.submit_sim.insert(id, sim_s);
    }

    /// Stamp queued events with the end-of-iteration clock.  Order
    /// matters: first tokens before ITL (initialises `last_emit`), and
    /// completions last, so a same-iteration retire still records TTFT.
    fn flush(&mut self, now: f64) {
        // Iterate + clear (never drop the `Vec`s) so the pending queues
        // keep their capacity across iterations — flush runs every step.
        for &id in &self.ttft_pending {
            if self.ttft_by.contains_key(&id) {
                continue; // preempt restart: the original TTFT stands
            }
            let Some(&t0) = self.submit_sim.get(&id) else { continue };
            let ttft = (now - t0).max(0.0);
            self.ttft_by.insert(id, ttft);
            self.ttft.record(ttft);
            self.last_emit.insert(id, now);
        }
        self.ttft_pending.clear();
        for &(id, n) in &self.itl_pending {
            if n == 0 {
                continue;
            }
            let Some(last) = self.last_emit.get_mut(&id) else { continue };
            let gap = ((now - *last) / n as f64).max(0.0);
            for _ in 0..n {
                self.itl.record(gap);
            }
            *last = now;
        }
        self.itl_pending.clear();
        // `forget` needs `&mut self`, so this queue is taken out for the
        // walk and handed back (same buffer, capacity retained).
        let done = std::mem::take(&mut self.completed_pending);
        for &id in &done {
            self.completed += 1;
            if self.ttft_by.get(&id).is_some_and(|t| *t <= self.target_s) {
                self.within_target += 1;
            }
            self.forget(id);
        }
        self.completed_pending = done;
        self.completed_pending.clear();
    }

    /// Drop per-request state (cancellation or completion).
    fn forget(&mut self, id: u64) {
        self.submit_sim.remove(&id);
        self.ttft_by.remove(&id);
        self.last_emit.remove(&id);
    }
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub runner: ModelRunner,
    rt: Rc<Runtime>,
    /// Pre-rendered `draft_w{W}` / `verify_q{Q}` labels for retry/trace
    /// call sites — the serving loop never formats an artifact name.
    names: ArtifactNames,
    /// Reusable staging buffers (see [`Scratch`]).
    scratch: Scratch,
    queue: VecDeque<Request>,
    slots: Vec<Option<Slot>>,
    buckets: BucketScheduler,
    kv: KvManager,
    offload: OffloadEngine,
    suspended: HashMap<u64, Suspended>,
    pool: ThreadPool,
    delayed: Vec<Promise<VerifyWork>>,
    rng: Xoshiro256,
    device: DeviceModel,
    sim_scale: SimScale,
    /// Name → constructor table every drafter resolves through.
    registry: DrafterRegistry,
    /// Resolved drafter instances; index 0 is the engine default, the
    /// rest arrive through `EngineConfig::extra_drafters` or per-session
    /// overrides.  Slots reference entries by index.
    drafters: Vec<Box<dyn Drafter>>,
    /// Parse-layer kind per table entry (submit-time resolution key).
    drafter_kinds: Vec<DrafterKind>,
    /// Display name per table entry (reports/metrics keys).
    drafter_names: Vec<String>,
    /// Per-drafter acceptance accounting (RunReport::accept_by).
    accept_by: Vec<AcceptStats>,
    // accounting
    iter: u64,
    sim_s: f64,
    sim_cpu_s: f64,
    accept: AcceptStats,
    trace: ScheduleTrace,
    kv_util_sum: f64,
    tokens_generated: u64,
    outputs: BTreeMap<u64, Vec<i32>>,
    latency: Histogram,
    requests_done: usize,
    requests_cancelled: usize,
    requests_rejected: usize,
    /// Live session state per request id (submit-created; `run` goes
    /// through the same path, so streaming is uniform).  Entries are
    /// removed at finish (complete/cancel), so the map only ever holds
    /// in-flight work — a long-lived server does not accumulate history.
    sessions: BTreeMap<u64, Rc<RefCell<SessionShared>>>,
    /// Sessions that produced events this iteration; their sim timestamps
    /// are stamped with the *end-of-iteration* clock in `step`.
    stamp_pending: Vec<Rc<RefCell<SessionShared>>>,
    /// Span/counter journal (config-gated; near-free when disabled).
    tracer: Tracer,
    /// SLO accounting on the simulated clock (always on — it is two map
    /// inserts per round, not a tracing feature).
    slo: SloTracker,
    /// Open delayed-verification overlap window (async-span id == the
    /// iteration that launched it), closed at the next delayed drain.
    overlap_open: Option<u64>,
    /// Deterministic fault source (`EngineConfig::fault`; disabled by
    /// default — one branch per check site, CI-gated by `fault_overhead`).
    injector: FaultInjector,
    /// Transient-fault recoveries: runtime-step retries plus skipped
    /// (naturally retried) KV offload/reload actions.
    fault_retries: u64,
    /// Consecutive injected reload faults per suspended request; cleared
    /// on a clean check, a session fails at `fault::RELOAD_FAULT_BUDGET`.
    reload_faults: HashMap<u64, u32>,
    requests_failed: usize,
    slot_degradations: u64,
    slot_promotions: u64,
}

impl Engine {
    pub fn new(rt: Rc<Runtime>, cfg: EngineConfig) -> Result<Engine> {
        Self::with_registry(rt, cfg, DrafterRegistry::with_builtins())
    }

    /// Build an engine whose drafters resolve through `registry` — the
    /// out-of-crate extension point: register a constructor, submit
    /// requests naming it (`DrafterKind::Custom`), never touch this file.
    pub fn with_registry(
        rt: Rc<Runtime>,
        cfg: EngineConfig,
        registry: DrafterRegistry,
    ) -> Result<Engine> {
        let mut runner = ModelRunner::new(rt.clone())?;
        let m = rt.cfg.model.clone();
        let default_drafter = registry.create(&cfg.drafter, &m)?;
        // A no-speculation default forces k = 0 (verify_q1, no drafting).
        let k = if default_drafter.mode() == DraftMode::Off { 0 } else { cfg.k };
        let mut cfg = cfg;
        cfg.k = k;
        // Slot-parallel sim kernels follow the engine knob (bit-identical
        // either way; serial is the zero-allocation reference mode).
        runner.set_parallel(cfg.parallel);
        let default_drafter: Box<dyn Drafter> =
            if cfg.adaptive_k && default_drafter.mode() != DraftMode::Off {
                Box::new(AdaptiveDrafter::new(default_drafter, k))
            } else {
                default_drafter
            };
        default_drafter.validate_engine(&m, k)?;
        let worst_case = m.max_seq;
        let device = DeviceModel::default();
        let sim_scale = cfg
            .sim_scale
            .unwrap_or_else(|| SimScale::paper_scale(m.slots, m.kv_bytes_per_token()));
        let chunk = 256 * 1024;
        // Precompile every artifact the default configuration can touch,
        // so first-call XLA compilation (~2 s each) never lands inside the
        // serving loop's wallclock.  Statically declared extras precompile
        // right below; an UNdeclared per-session override instead pays its
        // first-call compilation synchronously inside the `submit` that
        // introduces it, stalling in-flight sessions on the real PJRT
        // backend — latency-sensitive servers should declare the drafters
        // they serve via `EngineConfig::extra_drafters`/`allow_drafter`.
        {
            let mut names: Vec<String> = vec!["prefill".into()];
            names.push(format!("verify_q{}", k + 1));
            names.extend(default_drafter.artifacts(k));
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            rt.precompile(&refs)?;
        }
        let drafter_names = vec![default_drafter.name()];
        let drafter_kinds = vec![cfg.drafter];
        let mut eng = Engine {
            runner,
            names: ArtifactNames::new(&m),
            scratch: Scratch::default(),
            queue: VecDeque::new(),
            slots: (0..m.slots).map(|_| None).collect(),
            buckets: BucketScheduler::new(k.max(1)),
            kv: KvManager::new(cfg.kv_policy, cfg.kv_budget, worst_case),
            offload: OffloadEngine::new(chunk, device.pcie_bw),
            suspended: HashMap::new(),
            // Sized to the host: verify workers (one per slot round) and
            // the (layer, head)-parallel pillar refresh both fan out here.
            pool: ThreadPool::new(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(2)
                    .clamp(2, 8),
            ),
            // Pre-sized to the slot ceiling (one promise per participating
            // slot per round) so the steady-state drain never grows it —
            // collect_delayed drains in place and keeps the capacity.
            delayed: Vec::with_capacity(m.slots),
            rng: Xoshiro256::new(cfg.seed),
            device,
            sim_scale,
            registry,
            drafters: vec![default_drafter],
            drafter_kinds,
            drafter_names,
            accept_by: vec![AcceptStats::new(k.max(1))],
            iter: 0,
            sim_s: 0.0,
            sim_cpu_s: 0.0,
            accept: AcceptStats::new(k.max(1)),
            trace: ScheduleTrace::default(),
            kv_util_sum: 0.0,
            tokens_generated: 0,
            outputs: BTreeMap::new(),
            latency: Histogram::default(),
            requests_done: 0,
            requests_cancelled: 0,
            requests_rejected: 0,
            sessions: BTreeMap::new(),
            stamp_pending: Vec::new(),
            tracer: Tracer::new(cfg.trace.clone()),
            slo: SloTracker::new(cfg.ttft_slo_s),
            overlap_open: None,
            injector: FaultInjector::new(&cfg.fault),
            fault_retries: 0,
            reload_faults: HashMap::new(),
            requests_failed: 0,
            slot_degradations: 0,
            slot_promotions: 0,
            rt,
            cfg,
        };
        // Statically declared extra drafters resolve (and precompile) up
        // front, exactly like the default.
        let extras = eng.cfg.extra_drafters.clone();
        for kind in extras {
            eng.drafter_index(kind)?;
        }
        Ok(eng)
    }

    fn mcfg(&self) -> &crate::model::ModelConfig {
        &self.rt.cfg.model
    }

    // ------------------------------------------------------------------
    // drafter table
    // ------------------------------------------------------------------

    /// Resolve a kind to a drafter-table index, instantiating (and
    /// precompiling) it through the registry on first use.
    fn drafter_index(&mut self, kind: DrafterKind) -> Result<usize> {
        if let Some(i) = self.drafter_kinds.iter().position(|x| *x == kind) {
            return Ok(i);
        }
        let rt = self.rt.clone();
        let m = &rt.cfg.model;
        let d = self.registry.create(&kind, m)?;
        let d: Box<dyn Drafter> = if self.cfg.adaptive_k && d.mode() != DraftMode::Off {
            Box::new(AdaptiveDrafter::new(d, self.cfg.k))
        } else {
            d
        };
        d.validate_engine(m, self.cfg.k)?;
        let arts = d.artifacts(self.cfg.k);
        if !arts.is_empty() {
            let refs: Vec<&str> = arts.iter().map(|s| s.as_str()).collect();
            self.rt.precompile(&refs)?;
        }
        self.drafter_names.push(d.name());
        self.drafter_kinds.push(kind);
        self.drafters.push(d);
        self.accept_by.push(AcceptStats::new(self.cfg.k.max(1)));
        Ok(self.drafters.len() - 1)
    }

    /// Read-only resolution for requests already validated at submit
    /// time; unknown kinds fall back to the engine default.
    fn lookup_drafter(&self, kind: Option<DrafterKind>) -> usize {
        match kind {
            None => 0,
            Some(k) => self
                .drafter_kinds
                .iter()
                .position(|x| *x == k)
                .unwrap_or(0),
        }
    }

    /// Resolved drafter names, in table order (index 0 = engine default).
    pub fn drafter_names(&self) -> &[String] {
        &self.drafter_names
    }

    /// Batch-compatibility wrapper over the session API: submits every
    /// request (same queue order as the pre-session engine), drives the
    /// loop to idle and assembles the report — `RunReport.outputs` is
    /// bit-identical to the historical behaviour on a fixed seed.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<RunReport> {
        for r in requests {
            self.submit(r);
        }
        let t0 = Instant::now();
        while self.iter < self.cfg.max_iterations {
            let busy = self.step()?;
            if !busy {
                break;
            }
        }
        Ok(self.take_report(t0.elapsed().as_secs_f64()))
    }

    // ------------------------------------------------------------------
    // session API (consumed through engine::api)
    // ------------------------------------------------------------------

    /// Admit a request into the serving queue mid-run; returns its live
    /// session.  Latest submission wins: if the same id is already in
    /// flight, the old request is cancelled first (through the normal
    /// cancellation path), so two generations never feed one stream.
    ///
    /// A request naming a drafter (`Request::drafter`) that fails to
    /// resolve — unknown registry name, degenerate parameters, missing
    /// artifact variant — is **rejected**: the returned session finishes
    /// immediately with [`FinishReason::Rejected`] (the reason readable
    /// via `SessionHandle::reject_reason`) and nothing enters the queue,
    /// so one bad submission never disturbs service.
    pub fn submit(&mut self, req: Request) -> SessionHandle {
        self.submit_inner(req, None)
    }

    /// `submit` with a push-style sink receiving every token event.
    pub fn submit_with_sink(&mut self, req: Request, sink: Box<dyn TokenSink>) -> SessionHandle {
        self.submit_inner(req, Some(sink))
    }

    fn submit_inner(&mut self, req: Request, sink: Option<Box<dyn TokenSink>>) -> SessionHandle {
        if self.sessions.contains_key(&req.id) {
            self.cancel_session(req.id);
        }
        let resolved = match req.drafter {
            None => Ok(0usize),
            Some(kind) => self.drafter_index(kind),
        };
        let name = match &resolved {
            Ok(i) => self.drafter_names[*i].clone(),
            Err(_) => req.drafter.map(|k| k.name()).unwrap_or_default(),
        };
        let trace_name = if self.tracer.enabled() { name.clone() } else { String::new() };
        let mut shared = SessionShared::new(req.id, self.sim_s, name);
        if let Some(s) = sink {
            shared.set_sink(s);
        }
        let rc = Rc::new(RefCell::new(shared));
        match resolved {
            Ok(_) => {
                self.slo.on_submit(req.id, self.sim_s);
                if self.tracer.enabled() {
                    self.tracer.instant(
                        names::SESSION_SUBMIT,
                        Track::Session,
                        self.sim_s,
                        vec![("req", req.id.into()), ("drafter", trace_name.into())],
                    );
                }
                self.sessions.insert(req.id, rc.clone());
                self.queue.push_back(req);
            }
            Err(e) => {
                self.requests_rejected += 1;
                if self.cfg.verbose {
                    eprintln!("rejected request {}: {e:#}", req.id);
                }
                let mut s = rc.borrow_mut();
                s.set_reject_reason(format!("{e:#}"));
                s.finish(FinishReason::Rejected);
                s.stamp_sim(self.sim_s);
            }
        }
        SessionHandle::new(rc)
    }

    /// The live session for a request id (finished sessions are dropped
    /// from the engine; their handles stay readable on the consumer side).
    pub fn session(&self, id: u64) -> Option<SessionHandle> {
        self.sessions.get(&id).map(|rc| SessionHandle::new(rc.clone()))
    }

    /// Iterations executed so far.
    pub fn iterations(&self) -> u64 {
        self.iter
    }

    /// The simulated serving clock (seconds).
    pub fn clock_s(&self) -> f64 {
        self.sim_s
    }

    /// Jump the simulated clock forward (the driver uses this to model
    /// idle waiting for the next arrival; never moves backwards).
    pub fn advance_clock(&mut self, t: f64) {
        if t > self.sim_s {
            self.sim_s = t;
        }
    }

    /// Device-tier KV tokens currently accounted (introspection/tests).
    pub fn kv_used_tokens(&self) -> usize {
        self.kv.used_tokens()
    }

    /// The engine's trace journal (spans, instants, counters).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Chrome/Perfetto trace-event JSON of everything journaled so far.
    /// Load it at `ui.perfetto.dev` or `chrome://tracing`.
    pub fn export_trace_chrome(&self) -> String {
        self.tracer.export_chrome_string()
    }

    /// One JSON object per line — the journal for ad-hoc `jq` analysis.
    pub fn export_trace_jsonl(&self) -> String {
        self.tracer.export_jsonl()
    }

    /// Deliver any new output tokens of `slot` to its session.  Sessions
    /// with no observer — no consumer handle alive (the engine's map Rc
    /// is the only one) and no sink — skip token delivery and per-token
    /// wallclock reads; only the two-integer acceptance accounting runs,
    /// so batch `Engine::run` keeps its pre-session cost profile.
    fn notify_session(
        sessions: &BTreeMap<u64, Rc<RefCell<SessionShared>>>,
        stamp_pending: &mut Vec<Rc<RefCell<SessionShared>>>,
        slot: &Slot,
        round_accept: Option<usize>,
    ) {
        if let Some(sess) = sessions.get(&slot.req.id) {
            let observed = Rc::strong_count(sess) > 1 || sess.borrow().has_sink();
            if observed {
                sess.borrow_mut().on_progress(&slot.output, round_accept);
                stamp_pending.push(sess.clone());
            } else {
                sess.borrow_mut().note_round(round_accept);
            }
        }
    }

    /// Mark a session finished and drop it from the live map (consumer
    /// handles keep the shared state readable).
    fn finish_session(&mut self, id: u64, reason: FinishReason) {
        if let Some(sess) = self.sessions.remove(&id) {
            sess.borrow_mut().finish(reason);
            self.stamp_pending.push(sess);
            if self.tracer.enabled() {
                self.tracer.instant(
                    names::SESSION_FINISH,
                    Track::Session,
                    self.sim_s,
                    vec![("req", id.into()), ("reason", reason.label().into())],
                );
            }
        }
    }

    /// Apply pending cancellations.  Runs right after the delayed-verify
    /// drain, so no in-flight work can target a freed slot; releases go
    /// through the same bucket/KV paths retirement uses.  The map only
    /// holds in-flight sessions, so this scan is bounded by live work.
    fn process_cancellations(&mut self) {
        if self.sessions.is_empty() {
            return;
        }
        let ids: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.borrow().wants_cancel())
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            self.cancel_session(id);
        }
    }

    /// Cancel one session wherever its request currently lives: the
    /// admission queue, a device slot, or the suspended/offloaded tier.
    fn cancel_session(&mut self, id: u64) {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            if let Some(req) = self.queue.remove(pos) {
                let di = self.lookup_drafter(req.drafter);
                self.drafter_on_finish(di, id);
            }
        } else if let Some(idx) = self.slot_of(id) {
            let slot = self.slots[idx]
                .take()
                .expect("slot_of returned a live slot index");
            self.buckets
                .release(slot.bucket.min(self.buckets.n_buckets() - 1));
            self.kv.release(id);
            self.drafter_on_finish(slot.drafter, id);
        } else if let Some(sus) = self.suspended.remove(&id) {
            // Covers both host-resident KV and rows still in offload
            // transit (the orphaned transfer is dropped at harvest time).
            self.kv.forget(id);
            if self.tracer.enabled() {
                self.tracer.instant(
                    names::KV_FORGET,
                    Track::Kv,
                    self.sim_s,
                    vec![("req", id.into()), ("tokens", sus.len.into())],
                );
            }
            self.drafter_on_finish(sus.drafter, id);
        }
        self.slo.forget(id);
        self.requests_cancelled += 1;
        self.finish_session(id, FinishReason::Cancelled);
    }

    // ------------------------------------------------------------------
    // fault handling (taxonomy and policy live in `crate::fault`)
    // ------------------------------------------------------------------

    /// Run one fallible runtime step under the injector with bounded retry
    /// + exponential backoff charged to the **sim clock**.  Transient
    /// errors (injected faults, and unclassified runner errors — a bounded
    /// retry is harmless, a deterministic failure just exhausts the budget)
    /// retry up to [`fault::MAX_STEP_RETRIES`] attempts; exhaustion
    /// surfaces as the fatal [`EngineError::RetriesExhausted`] out of
    /// [`Engine::step`].  Free-function shape (disjoint field borrows) so
    /// the closure can hold `&mut self.runner`.
    fn step_with_retry<T>(
        injector: &mut FaultInjector,
        sim_s: &mut f64,
        fault_retries: &mut u64,
        tracer: &mut Tracer,
        artifact: &str,
        mut f: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let mut attempt = 0u32;
        loop {
            let res = if injector.check(FaultSite::RuntimeStep) {
                if tracer.enabled() {
                    tracer.instant(
                        names::FAULT,
                        Track::Engine,
                        *sim_s,
                        vec![
                            ("site", FaultSite::RuntimeStep.label().into()),
                            ("artifact", artifact.to_string().into()),
                        ],
                    );
                }
                Err(EngineError::RuntimeStep {
                    artifact: artifact.to_string(),
                    detail: "injected fault".into(),
                }
                .into())
            } else {
                f()
            };
            match res {
                Ok(v) => return Ok(v),
                Err(e) => {
                    attempt += 1;
                    let transient = e
                        .downcast_ref::<EngineError>()
                        .map(EngineError::is_transient)
                        .unwrap_or(true);
                    if !transient || attempt >= fault::MAX_STEP_RETRIES {
                        return Err(EngineError::RetriesExhausted {
                            site: FaultSite::RuntimeStep,
                            attempts: attempt,
                            last: format!("{e:#}"),
                        }
                        .into());
                    }
                    let backoff = fault::backoff_s(attempt - 1);
                    *sim_s += backoff;
                    *fault_retries += 1;
                    if tracer.enabled() {
                        tracer.instant(
                            names::FAULT_RETRY,
                            Track::Engine,
                            *sim_s,
                            vec![
                                ("site", FaultSite::RuntimeStep.label().into()),
                                ("artifact", artifact.to_string().into()),
                                ("attempt", (attempt as u64).into()),
                                ("backoff_us", (backoff * 1e6).into()),
                            ],
                        );
                    }
                }
            }
        }
    }

    /// Run a drafter lifecycle hook inside the panic sandbox.  Plugin
    /// drafters are third-party code; a panic must cost the slot its
    /// speculation, never the process or the co-batched sessions.
    fn sandboxed<T>(
        drafter: &str,
        hook: &'static str,
        f: impl FnOnce() -> T,
    ) -> std::result::Result<T, EngineError> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
            Ok(v) => Ok(v),
            Err(p) => Err(EngineError::DrafterPanic {
                drafter: drafter.to_string(),
                hook,
                detail: fault::panic_detail(&*p),
            }),
        }
    }

    /// `on_finish` never blocks retirement: the session is already ending,
    /// so a panicking drafter is logged and ignored.
    fn drafter_on_finish(&mut self, di: usize, id: u64) {
        if let Err(e) =
            Self::sandboxed(&self.drafter_names[di], "on_finish", || {
                self.drafters[di].on_finish(id)
            })
        {
            if self.cfg.verbose {
                eprintln!("ignored drafter fault at retire of {id}: {e}");
            }
        }
    }

    /// Record a drafter fault against a slot: trace it, and demote the
    /// slot to vanilla (k=1) decoding once it crosses
    /// [`fault::DEGRADE_FAULT_THRESHOLD`].  The session keeps running —
    /// degraded slots still finish `Completed`, just without speculation.
    fn note_drafter_fault(&mut self, slot_idx: usize, err: &EngineError) {
        if self.cfg.verbose {
            eprintln!("drafter fault (slot {slot_idx}): {err}");
        }
        let Some((req_id, demote)) = self.slots[slot_idx].as_mut().map(|slot| {
            let demote = slot.note_fault();
            if demote {
                slot.demote();
            }
            (slot.req.id, demote)
        }) else {
            return;
        };
        if self.tracer.enabled() {
            self.tracer.instant(
                names::FAULT,
                Track::Drafter,
                self.sim_s,
                vec![("req", req_id.into()), ("kind", err.kind_label().into())],
            );
        }
        if demote {
            self.note_degradation(req_id, err.kind_label());
        }
    }

    /// Count + trace one slot demotion to vanilla decoding.
    fn note_degradation(&mut self, req_id: u64, reason: &'static str) {
        self.slot_degradations += 1;
        if self.cfg.verbose {
            eprintln!("request {req_id} degraded to vanilla decoding ({reason})");
        }
        if self.tracer.enabled() {
            self.tracer.instant(
                names::SLOT_DEGRADE,
                Track::Drafter,
                self.sim_s,
                vec![("req", req_id.into()), ("reason", reason.into())],
            );
        }
    }

    /// Poison one session with a fatal error: record the detail on its
    /// handle, count it, and finish it `Failed`.  Resource teardown (slot
    /// / KV / bucket / drafter state) is the caller's job — it knows which
    /// tier the request currently lives in.  Blast radius is exactly this
    /// session: co-batched sessions' outputs are untouched.
    fn fail_session(&mut self, id: u64, err: &EngineError) {
        if let Some(sess) = self.sessions.get(&id) {
            sess.borrow_mut().set_failure_reason(err.to_string());
        }
        if self.cfg.verbose {
            eprintln!("session {id} failed: {err}");
        }
        self.slo.forget(id);
        self.requests_failed += 1;
        if self.tracer.enabled() {
            self.tracer.instant(
                names::SESSION_FAIL,
                Track::Session,
                self.sim_s,
                vec![("req", id.into()), ("kind", err.kind_label().into())],
            );
        }
        self.finish_session(id, FinishReason::Failed);
    }

    /// Assemble the run report and drain per-run aggregates (`outputs`
    /// moves out; in-flight offload transfers are drained first).
    pub(crate) fn take_report(&mut self, wall_s: f64) -> RunReport {
        // Drain any in-flight offloads (their requests will never resume).
        for (id, kv, _transfer_s) in self.offload.drain() {
            if self.suspended.contains_key(&id) {
                self.kv.host.insert(id, kv);
            }
        }
        // Consumed-once aggregates MOVE into the report (`mem::take` /
        // `mem::replace`) instead of deep-cloning histograms and trace
        // journals; the engine keeps fresh zeroed accounting so a server
        // that reports mid-flight continues recording cleanly.
        let slo = SloReport {
            ttft_target_s: self.cfg.ttft_slo_s,
            ttft_sim_s: std::mem::take(&mut self.slo.ttft),
            itl_sim_s: std::mem::take(&mut self.slo.itl),
            completed_within_ttft: self.slo.within_target,
            completed: self.slo.completed,
            goodput_rps: self.slo.within_target as f64 / self.sim_s.max(1e-9),
            kv_evictions: self.kv.stats.recompute_events,
            kv_offloads: self.kv.stats.offload_events,
            kv_reloads: self.kv.stats.reload_events,
        };
        let accept_by: BTreeMap<String, AcceptStats> = {
            let taken = std::mem::take(&mut self.accept_by);
            self.accept_by = (0..taken.len())
                .map(|_| AcceptStats::new(self.cfg.k.max(1)))
                .collect();
            self.drafter_names.iter().cloned().zip(taken).collect()
        };
        RunReport {
            name: self.drafter_names[0].clone(),
            iterations: self.iter,
            wall_s,
            sim_s: self.sim_s,
            sim_cpu_s: self.sim_cpu_s,
            requests_done: self.requests_done,
            requests_cancelled: self.requests_cancelled,
            requests_rejected: self.requests_rejected,
            requests_failed: self.requests_failed,
            faults_injected: self.injector.total_fired(),
            fault_retries: self.fault_retries,
            slot_degradations: self.slot_degradations,
            slot_promotions: self.slot_promotions,
            tokens_generated: self.tokens_generated,
            accept: std::mem::replace(&mut self.accept, AcceptStats::new(self.cfg.k.max(1))),
            accept_by,
            kv: std::mem::take(&mut self.kv.stats),
            offload: self.offload.stats(),
            trace: std::mem::take(&mut self.trace),
            step_stats: std::mem::take(&mut self.runner.stats),
            mean_kv_util: self.kv_util_sum / self.iter.max(1) as f64,
            outputs: std::mem::take(&mut self.outputs),
            request_latency_s: std::mem::take(&mut self.latency),
            slo,
        }
    }

    /// One engine iteration.  Returns false when fully idle.
    pub fn step(&mut self) -> Result<bool> {
        let any_slot = self.slots.iter().any(|s| s.is_some());
        if self.queue.is_empty()
            && !any_slot
            && self.suspended.is_empty()
            && self.delayed.is_empty()
        {
            return Ok(false);
        }
        self.iter += 1;
        let sim0 = self.sim_s;
        self.tracer.iter_begin(self.iter, sim0);
        // Snapshot device-phase stats so per-artifact spans can be carved
        // out of this iteration's delta after the clock advances.
        let dev_base = if self.tracer.hot() {
            Some((self.runner.stats.clone(), self.tracer.now_us()))
        } else {
            None
        };
        let mut comp = IterComposition::default();
        let mut launches = 0u32;
        let mut cpu_s = 0.0;

        // 0. consume delayed verification results from the previous iter.
        cpu_s += self.collect_delayed()?;

        // 0b. apply session cancellations (after the delayed drain, so no
        //     pending verify work can land in a slot freed here; before
        //     admission, so the freed capacity is reusable this iteration).
        self.process_cancellations();

        // 1. reload offloaded requests when capacity allows.
        self.try_reloads()?;

        // 2. admission (prefill newly accepted requests).
        let admitted = self.admit(&mut comp)?;
        if admitted > 0 {
            launches += 1;
        }

        // 3. proposal generation, grouped per proposal drafter (ngram/
        //    eagle/triforce/custom): fills `drafts`, slots stay
        //    ReadyVerify.
        launches += self.generate_proposals(&mut comp, &mut cpu_s)?;

        // 4. sparse draft step for self-spec slots in Drafting phase,
        //    grouped by draft budget W (one artifact launch per group).
        launches += self.draft_step(&mut comp, &mut cpu_s)?;

        // 5. verification for ReadyVerify slots.
        launches += self.verify_step(&mut comp, &mut cpu_s)?;

        // 6. memory pressure + retirement bookkeeping happen inside the
        //    processing paths; record the iteration.
        self.kv_util_sum += self.kv.utilization().min(1.0);
        let t_dev = self.device.t_iteration(
            comp.gemm_rows as f64 * self.sim_scale.gemm_rows,
            comp.attn_bytes as f64 * self.sim_scale.kv_bytes,
            launches,
        );
        let cpu_charge = if self.cfg.delayed_verify {
            (cpu_s - t_dev).max(0.0) // overlapped; only the overshoot stalls
        } else {
            cpu_s
        };
        self.sim_s += t_dev + cpu_charge;
        self.sim_cpu_s += cpu_charge;
        // Stamp this iteration's session events with the clock *including*
        // the iteration that produced them (idempotent per session).
        if !self.stamp_pending.is_empty() {
            for sess in self.stamp_pending.drain(..) {
                sess.borrow_mut().stamp_sim(self.sim_s);
            }
        }
        self.slo.flush(self.sim_s);
        if let Some((base, dev_t0)) = dev_base {
            // Device-track spans: one per artifact touched this iteration,
            // laid end to end from the snapshot point (the modelled device
            // is serial, so concatenation is the honest picture).
            let mut cursor = dev_t0;
            for (name, d) in self.runner.stats.delta_since(&base) {
                let dur_us = d.total_s() * 1e6;
                self.tracer.complete_at(
                    &name,
                    Track::Device,
                    cursor,
                    dur_us,
                    sim0,
                    vec![
                        ("calls", (d.calls as f64).into()),
                        ("upload_us", (d.upload_s * 1e6).into()),
                        ("exec_us", (d.exec_s * 1e6).into()),
                        ("fetch_us", (d.fetch_s * 1e6).into()),
                    ],
                );
                cursor += dur_us;
            }
            self.tracer.counter("queue_depth", self.sim_s, self.queue.len() as f64);
            self.tracer
                .counter("delayed_verify_depth", self.sim_s, self.delayed.len() as f64);
            self.tracer
                .counter("kv_used_tokens", self.sim_s, self.kv.used_tokens() as f64);
            self.tracer
                .counter("live_sessions", self.sim_s, self.sessions.len() as f64);
            let mut args = comp.trace_args();
            args.push(("launches", (launches as f64).into()));
            args.push(("t_dev_us", (t_dev * 1e6).into()));
            args.push(("cpu_charge_us", (cpu_charge * 1e6).into()));
            self.tracer.iter_end(self.sim_s, args);
        }
        self.trace.push(comp);
        Ok(true)
    }

    // ------------------------------------------------------------------
    // admission / suspension
    // ------------------------------------------------------------------

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    fn admit(&mut self, comp: &mut IterComposition) -> Result<usize> {
        // Cheap gates first: most iterations have an empty queue, no free
        // slot, or no KV headroom — don't build the slots × prompt_pad
        // staging buffers just to find that out.
        if self.queue.is_empty() || self.free_slot().is_none() {
            return Ok(0);
        }
        {
            let req = self
                .queue
                .front()
                .expect("queue non-empty: checked by the gate above");
            let p = req.prompt.len().min(self.mcfg().prompt_pad);
            if !self.kv.can_admit(p) {
                return Ok(0);
            }
        }
        let rt = self.rt.clone();
        let m = &rt.cfg.model;
        self.tracer.begin(names::ADMIT, Track::Engine, self.sim_s);
        self.scratch.tokens.clear();
        self.scratch.tokens.resize(m.slots * m.prompt_pad, 0);
        self.scratch.plen.clear();
        self.scratch.plen.resize(m.slots, 1);
        self.scratch.active.clear();
        self.scratch.active.resize(m.slots, 0);
        self.scratch.newly.clear();

        while let Some(req) = self.queue.front() {
            let p = req.prompt.len().min(m.prompt_pad);
            if self.free_slot().is_none() || !self.kv.can_admit(p) {
                break;
            }
            let req = self
                .queue
                .pop_front()
                .expect("queue front checked in the loop condition");
            let rid = req.id;
            let di = self.lookup_drafter(req.drafter);
            let idx = self
                .free_slot()
                .expect("free slot checked in the loop condition");
            let bucket = match self.cfg.schedule {
                Schedule::Unified => self.buckets.assign(),
                // Everyone lives in bucket 0; counted there so release()
                // stays balanced.
                Schedule::Lockstep => self.buckets.assign_to(0),
            };
            for (j, &t) in req.prompt.iter().take(p).enumerate() {
                self.scratch.tokens[idx * m.prompt_pad + j] = t;
            }
            self.scratch.plen[idx] = p as i32;
            self.scratch.active[idx] = 1;
            self.kv.admit(rid, p);
            if self.tracer.enabled() {
                self.tracer.instant(
                    names::BUCKET_ASSIGN,
                    Track::Scheduler,
                    self.sim_s,
                    vec![("req", rid.into()), ("bucket", bucket.into())],
                );
                self.tracer.instant(
                    names::KV_ADMIT,
                    Track::Kv,
                    self.sim_s,
                    vec![("req", rid.into()), ("tokens", p.into())],
                );
            }
            let pol = self.drafters[di].index_policy(m);
            let mode = self.drafters[di].mode();
            let draft_w = self.drafters[di].draft_budget(m);
            let refresh_dump = self.drafters[di].wants_dump_refresh();
            let nord = self.drafters[di].ngram_order();
            let slot = Slot {
                len: p,
                gen_count: 0,
                pending: 0,
                anchor: 0,
                round_start_len: p,
                drafts: Vec::new(),
                draft_probs: Vec::new(),
                draft_target: 0,
                phase: Phase::ReadyVerify,
                bucket,
                drafter: di,
                mode,
                draft_w,
                refresh_dump,
                pillar: PillarState::new(m.layers, m.kv_heads, pol),
                ngram: NGramIndex::new(nord),
                output: Vec::new(),
                admitted_at: Instant::now(),
                sim_admitted_at: self.sim_s,
                faults: 0,
                zero_accept_rounds: 0,
                degraded: false,
                probation: 0,
                req,
            };
            self.slots[idx] = Some(slot);
            if let Err(e) = Self::sandboxed(&self.drafter_names[di], "on_admit", || {
                self.drafters[di].on_admit(rid, false)
            }) {
                self.note_drafter_fault(idx, &e);
            }
            self.scratch.newly.push(idx);
        }
        if self.scratch.newly.is_empty() {
            if self.tracer.hot() {
                self.tracer
                    .end(names::ADMIT, Track::Engine, self.sim_s, vec![("admitted", 0usize.into())]);
            }
            return Ok(0);
        }
        comp.prefilling = self.scratch.newly.len();
        comp.gemm_rows += self.scratch.newly.len() * m.prompt_pad;
        comp.attn_bytes += self.scratch.newly.len() * m.prompt_pad * m.kv_bytes_per_token();

        {
            let runner = &mut self.runner;
            let sc = &self.scratch;
            Self::step_with_retry(
                &mut self.injector,
                &mut self.sim_s,
                &mut self.fault_retries,
                &mut self.tracer,
                "prefill",
                || runner.prefill(&sc.tokens, &sc.plen, &sc.active),
            )?;
        }
        let v = m.vocab;
        // `start_round` below needs `&mut self`, so walk a taken copy of
        // the admit list and hand the staging buffer back after.
        let newly = std::mem::take(&mut self.scratch.newly);
        for &idx in &newly {
            // Copy this slot's logits row out of the arena view so the
            // runner borrow ends before sampling/session mutation below.
            self.scratch.row.clear();
            self.scratch
                .row
                .extend_from_slice(&self.runner.logits()[idx * v..(idx + 1) * v]);
            let t0 = sampling::sample_logits(&self.scratch.row, self.cfg.temperature, &mut self.rng)
                as i32;
            let slot = self.slots[idx]
                .as_mut()
                .expect("newly admitted slot is live");
            slot.output.push(t0);
            slot.gen_count = 1;
            slot.pending = t0;
            self.tokens_generated += 1;
            if slot.ngram.max_n > 0 {
                // Only n-gram-consuming drafters pay the history build.
                let mut hist = slot.req.prompt.clone();
                hist.push(t0);
                slot.ngram.extend(&hist);
            }
            // Begin the first round, aligned to the slot's bucket.
            self.start_round(idx, true);
            // The sampled first token streams out immediately (TTFT).
            let slot = self.slots[idx]
                .as_ref()
                .expect("newly admitted slot is live");
            self.slo.ttft_pending.push(slot.req.id);
            if self.tracer.enabled() {
                self.tracer.instant(
                    names::SESSION_FIRST_TOKEN,
                    Track::Session,
                    self.sim_s,
                    vec![("req", slot.req.id.into())],
                );
            }
            Self::notify_session(&self.sessions, &mut self.stamp_pending, slot, None);
        }
        self.scratch.newly = newly;
        if self.tracer.hot() {
            self.tracer.end(
                names::ADMIT,
                Track::Engine,
                self.sim_s,
                vec![("admitted", self.scratch.newly.len().into())],
            );
        }
        Ok(self.scratch.newly.len())
    }

    /// Start a speculation round on slot `idx`: ask the slot's drafter to
    /// size it (`Drafter::plan`), clamp to the scheduler's cap (bucket
    /// alignment can shorten a first round — Fig. 8) and the remaining
    /// generation budget, then arm the slot.
    fn start_round(&mut self, idx: usize, first: bool) {
        // Probation bookkeeping: demoted slots decode vanilla rounds until
        // the window expires, then re-promote back to speculation.
        let promoted = self.slots[idx]
            .as_mut()
            .expect("start_round targets a live slot")
            .tick_probation();
        if promoted {
            self.slot_promotions += 1;
            if self.tracer.enabled() {
                let rid = self.slots[idx]
                    .as_ref()
                    .expect("slot checked above")
                    .req
                    .id;
                self.tracer.instant(
                    names::SLOT_PROMOTE,
                    Track::Drafter,
                    self.sim_s,
                    vec![("req", rid.into())],
                );
            }
        }
        let (di, mode, bucket, remaining, len, pending, req_id, degraded) = {
            let s = self.slots[idx].as_ref().expect("slot checked above");
            (
                s.drafter, s.mode, s.bucket, s.remaining(), s.len, s.pending, s.req.id,
                s.degraded,
            )
        };
        if degraded || mode != DraftMode::SelfSpec {
            // Degraded slots decode vanilla (target 0 → verify q=1, one
            // bonus token per round); proposal drafters fill drafts
            // through their batch hook; no-speculation slots go straight
            // to verification.
            self.slots[idx]
                .as_mut()
                .expect("slot checked above")
                .begin_round(0);
            return;
        }
        let sched_cap = if first {
            match self.cfg.schedule {
                Schedule::Lockstep => self.cfg.k,
                Schedule::Unified => self.buckets.first_draft_len(self.iter, bucket),
            }
        } else {
            self.cfg.k
        };
        let ctx = DraftCtx {
            req_id,
            slot_idx: idx,
            k: self.cfg.k,
            sched_cap,
            len,
            remaining,
            pending,
            first_round: first,
            ngram: None,
        };
        let plan = if self.injector.check(FaultSite::DrafterPanic) {
            Err(EngineError::DrafterPanic {
                drafter: self.drafter_names[di].clone(),
                hook: "plan",
                detail: "injected fault".into(),
            })
        } else {
            Self::sandboxed(&self.drafter_names[di], "plan", || {
                self.drafters[di].plan(&ctx)
            })
        };
        match plan {
            Ok(plan) => {
                let target = plan.target.min(sched_cap).min(remaining.max(1));
                self.slots[idx]
                    .as_mut()
                    .expect("slot checked above")
                    .begin_round(target);
            }
            Err(e) => {
                // A faulting planner costs this slot its speculation, not
                // the batch: fall back to a vanilla round.
                self.note_drafter_fault(idx, &e);
                self.slots[idx]
                    .as_mut()
                    .expect("slot checked above")
                    .begin_round(0);
            }
        }
    }

    fn try_reloads(&mut self) -> Result<()> {
        let rt = self.rt.clone();
        let m = &rt.cfg.model;
        loop {
            if self.free_slot().is_none() {
                return Ok(());
            }
            // harvest finished offload transfers into the host tier
            // (transfers whose request was cancelled mid-flight are
            // orphans — drop them instead of stranding host KV)
            for (id, kv, transfer_s) in self.offload.poll() {
                if self.tracer.enabled() {
                    self.tracer.async_end(
                        names::KV_OFFLOAD,
                        Track::Kv,
                        id,
                        self.sim_s,
                        vec![("req", id.into()), ("transfer_us", (transfer_s * 1e6).into())],
                    );
                }
                if self.suspended.contains_key(&id) {
                    self.kv.host.insert(id, kv);
                }
            }
            // Injected host-tier read fault, checked BEFORE `try_reload`
            // mutates queue/host state: skipping the iteration retries the
            // same reload naturally later.  A request that keeps faulting
            // past its patience budget can never come back — tear it down
            // and fail exactly that session.
            if let Some(rid) = self.kv.peek_reload() {
                if self.injector.check(FaultSite::KvReload) {
                    let io =
                        EngineError::KvReloadIo { req_id: rid, detail: "injected fault".into() };
                    self.fault_retries += 1;
                    if self.tracer.enabled() {
                        self.tracer.instant(
                            names::FAULT,
                            Track::Kv,
                            self.sim_s,
                            vec![
                                ("req", rid.into()),
                                ("site", FaultSite::KvReload.label().into()),
                            ],
                        );
                    }
                    let n = {
                        let n = self.reload_faults.entry(rid).or_insert(0);
                        *n += 1;
                        *n
                    };
                    if n >= fault::RELOAD_FAULT_BUDGET {
                        self.reload_faults.remove(&rid);
                        let err = EngineError::RetriesExhausted {
                            site: FaultSite::KvReload,
                            attempts: n,
                            last: io.to_string(),
                        };
                        if let Some(sus) = self.suspended.remove(&rid) {
                            self.kv.forget(rid);
                            self.drafter_on_finish(sus.drafter, rid);
                        } else {
                            self.kv.forget(rid);
                        }
                        self.fail_session(rid, &err);
                        continue; // the queue head changed; keep reloading
                    }
                    return Ok(());
                }
                self.reload_faults.remove(&rid);
            }
            let Some((id, host_kv)) = self.kv.try_reload() else {
                return Ok(());
            };
            let Some(sus) = self.suspended.remove(&id) else {
                continue;
            };
            let idx = self
                .free_slot()
                .expect("free slot checked at the loop top");
            {
                let runner = &mut self.runner;
                Self::step_with_retry(
                    &mut self.injector,
                    &mut self.sim_s,
                    &mut self.fault_retries,
                    &mut self.tracer,
                    "kv_load",
                    || runner.kv_load(idx, &host_kv.k, &host_kv.v),
                )?;
            }
            self.kv.admit(id, sus.len);
            if self.tracer.enabled() {
                self.tracer.instant(
                    names::KV_RELOAD,
                    Track::Kv,
                    self.sim_s,
                    vec![("req", id.into()), ("tokens", sus.len.into())],
                );
            }
            let bucket = match self.cfg.schedule {
                Schedule::Unified => self.buckets.assign(),
                Schedule::Lockstep => self.buckets.assign_to(0),
            };
            let di = sus.drafter;
            let mode = self.drafters[di].mode();
            let draft_w = self.drafters[di].draft_budget(m);
            let refresh_dump = self.drafters[di].wants_dump_refresh();
            let mut ngram = NGramIndex::new(self.drafters[di].ngram_order());
            ngram.extend(&sus.ngram_hist);
            let slot = Slot {
                len: sus.len,
                gen_count: sus.gen_count,
                pending: sus.pending,
                anchor: sus.pending,
                round_start_len: sus.len,
                drafts: Vec::new(),
                draft_probs: Vec::new(),
                draft_target: 0,
                phase: Phase::ReadyVerify,
                bucket,
                drafter: di,
                mode,
                draft_w,
                refresh_dump,
                pillar: sus.pillar,
                ngram,
                output: sus.output,
                admitted_at: sus.admitted_at,
                sim_admitted_at: sus.sim_admitted_at,
                faults: 0,
                zero_accept_rounds: 0,
                degraded: false,
                probation: 0,
                req: sus.req,
            };
            self.slots[idx] = Some(slot);
            if let Err(e) = Self::sandboxed(&self.drafter_names[di], "on_admit", || {
                self.drafters[di].on_admit(id, true)
            }) {
                self.note_drafter_fault(idx, &e);
            }
            self.start_round(idx, true);
        }
    }

    /// Handle KV pressure after frontier growth.  Only round-boundary
    /// slots (just verified) are eligible victims.
    fn handle_pressure(&mut self, boundary: &[usize]) -> Result<()> {
        let boundary_ids: Vec<u64> = boundary
            .iter()
            .filter_map(|&i| self.slots[i].as_ref().map(|s| s.req.id))
            .collect();
        let protect: Vec<u64> = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.req.id)
            .filter(|id| !boundary_ids.contains(id))
            .collect();
        let actions = self.kv.check_pressure(&protect);
        if actions.is_empty() {
            return Ok(());
        }
        // One pool preparation serves all victims this iteration; the
        // rows are then borrowed straight out of the runner's host-side
        // pools (`kv_pools`) — no full-pool copy.
        let mut pool_ready = false;
        for act in actions {
            match act {
                PressureAction::Offload { req_id } => {
                    let Some(idx) = self.slot_of(req_id) else { continue };
                    if self.injector.check(FaultSite::KvOffload) {
                        // Injected offload-write fault: keep the victim
                        // resident this iteration (no state has moved
                        // yet); pressure re-fires on a later step, which
                        // is the natural retry.
                        self.fault_retries += 1;
                        if self.tracer.enabled() {
                            self.tracer.instant(
                                names::FAULT,
                                Track::Kv,
                                self.sim_s,
                                vec![
                                    ("req", req_id.into()),
                                    ("site", FaultSite::KvOffload.label().into()),
                                ],
                            );
                        }
                        continue;
                    }
                    if !pool_ready {
                        let runner = &mut self.runner;
                        Self::step_with_retry(
                            &mut self.injector,
                            &mut self.sim_s,
                            &mut self.fault_retries,
                            &mut self.tracer,
                            "kv_dump",
                            || runner.kv_dump_prepare(),
                        )?;
                        pool_ready = true;
                    }
                    let (rows_k, rows_v) = {
                        let (pk, pv) = self.runner.kv_pools();
                        self.extract_slot_rows(pk, pv, idx)
                    };
                    let slot = self.slots[idx]
                        .take()
                        .expect("slot_of returned a live slot index");
                    self.buckets.release(slot.bucket.min(self.buckets.n_buckets() - 1));
                    let len = slot.len;
                    let bytes = (rows_k.len() + rows_v.len()) * 4;
                    // `full_context` reads prompt + output, so build it
                    // before the owned fields MOVE into `Suspended` (the
                    // slot was taken — no reason to clone them).
                    let ngram_hist = slot.full_context();
                    self.suspended.insert(
                        req_id,
                        Suspended {
                            len,
                            gen_count: slot.gen_count,
                            pending: slot.pending,
                            output: slot.output,
                            pillar: slot.pillar,
                            ngram_hist,
                            drafter: slot.drafter,
                            admitted_at: slot.admitted_at,
                            sim_admitted_at: slot.sim_admitted_at,
                            req: slot.req,
                        },
                    );
                    self.kv.complete_offload(
                        req_id,
                        HostKv { k: vec![], v: vec![], len },
                    );
                    // the actual rows travel through the async copier
                    self.kv.host.remove(&req_id);
                    self.offload.submit(OffloadJob {
                        req_id,
                        kv: HostKv { k: rows_k, v: rows_v, len },
                        bytes,
                    });
                    if self.tracer.enabled() {
                        self.tracer.async_begin(
                            names::KV_OFFLOAD,
                            Track::Kv,
                            req_id,
                            self.sim_s,
                            vec![("req", req_id.into()), ("bytes", bytes.into()), ("tokens", len.into())],
                        );
                    }
                }
                PressureAction::Preempt { req_id } => {
                    let Some(idx) = self.slot_of(req_id) else { continue };
                    let slot = self.slots[idx]
                        .take()
                        .expect("slot_of returned a live slot index");
                    self.buckets.release(slot.bucket.min(self.buckets.n_buckets() - 1));
                    self.kv.complete_preempt(req_id);
                    // Restart from scratch (greedy decode regenerates the
                    // same tokens; they count as recomputed, not new).
                    // CAVEAT at temperature > 0: the engine RNG has
                    // advanced, so the regenerated prefix can differ from
                    // what an observed session already streamed (the
                    // delivered watermark cannot retract tokens).
                    // RunReport.outputs always holds the final generation;
                    // prefer KvPolicy::Dynamic when streaming
                    // stochastically.  (Per-request reseeding would fix
                    // this but change legacy bit-compat outputs.)
                    self.tokens_generated -= slot.gen_count.min(slot.output.len()) as u64;
                    if self.tracer.enabled() {
                        self.tracer.instant(
                            names::KV_PREEMPT,
                            Track::Kv,
                            self.sim_s,
                            vec![("req", req_id.into()), ("tokens", slot.len.into())],
                        );
                    }
                    self.queue.push_back(slot.req);
                }
            }
        }
        Ok(())
    }

    fn slot_of(&self, req_id: u64) -> Option<usize> {
        self.slots
            .iter()
            .position(|s| s.as_ref().map(|x| x.req.id) == Some(req_id))
    }

    fn extract_slot_rows(&self, pk: &[f32], pv: &[f32], idx: usize) -> (Vec<f32>, Vec<f32>) {
        // pool layout [L, S, T, Hkv, D] -> rows [L, T, Hkv, D] for slot idx
        let m = self.mcfg();
        let row = m.max_seq * m.kv_heads * m.head_dim;
        let per_l = m.slots * row;
        let mut k = Vec::with_capacity(m.layers * row);
        let mut v = Vec::with_capacity(m.layers * row);
        for l in 0..m.layers {
            let off = l * per_l + idx * row;
            k.extend_from_slice(&pk[off..off + row]);
            v.extend_from_slice(&pv[off..off + row]);
        }
        (k, v)
    }

    // ------------------------------------------------------------------
    // draft / proposal / verify phases
    // ------------------------------------------------------------------

    /// One sparse draft step for all Drafting self-spec slots, grouped by
    /// draft budget W (each group is one `draft_w{W}` launch); then each
    /// drafter's `after_draft` hook runs over its slots (the oracle's
    /// exact-score refresh lives there).
    fn draft_step(&mut self, comp: &mut IterComposition, cpu_s: &mut f64) -> Result<u32> {
        // Group Drafting slots by artifact budget (only self-spec slots
        // ever enter the Drafting phase).
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(slot) = s {
                if slot.phase == Phase::Drafting {
                    groups.entry(slot.draft_w).or_default().push(i);
                }
            }
        }
        if groups.is_empty() {
            return Ok(0);
        }
        let rt = self.rt.clone();
        let m = &rt.cfg.model;
        let mut launches = 0u32;
        self.scratch.stepped.clear();
        for (&w, participating) in &groups {
            self.tracer.begin(names::DRAFT, Track::Engine, self.sim_s);
            let t_cpu = Instant::now();
            let per_slot = m.layers * m.kv_heads * w;
            self.scratch.tokens.clear();
            self.scratch.tokens.resize(m.slots, 0);
            self.scratch.pos.clear();
            self.scratch.pos.resize(m.slots, 0);
            self.scratch.idxs.clear();
            self.scratch.idxs.resize(m.slots * per_slot, 0);
            self.scratch.active.clear();
            self.scratch.active.resize(m.slots, 0);
            let mut sel_s = 0.0;
            for &i in participating {
                let slot = self.slots[i].as_ref().expect("grouped above from live slots");
                self.scratch.tokens[i] = slot.pending;
                self.scratch.pos[i] = slot.len as i32;
                // Compose straight into the flattened index buffer — no
                // intermediate Vec + copy.
                let base = i * per_slot;
                let t_sel = Instant::now();
                slot.pillar
                    .compose_into(&mut self.scratch.idxs[base..base + per_slot], slot.len + 1);
                sel_s += t_sel.elapsed().as_secs_f64();
                self.scratch.active[i] = 1;
            }
            self.runner.stats.note_host("pillar_select", sel_s);
            comp.drafting += participating.len();
            comp.gemm_rows += participating.len();
            comp.attn_bytes += participating.len() * w * m.kv_bytes_per_token();
            *cpu_s += t_cpu.elapsed().as_secs_f64();

            {
                let runner = &mut self.runner;
                let sc = &self.scratch;
                let artifact = self
                    .names
                    .draft(w)
                    .expect("slot draft_w comes from a validated variant");
                Self::step_with_retry(
                    &mut self.injector,
                    &mut self.sim_s,
                    &mut self.fault_retries,
                    &mut self.tracer,
                    artifact,
                    || runner.draft(w, &sc.tokens, &sc.pos, &sc.idxs, &sc.active),
                )?;
            }
            launches += 1;

            let t_cpu = Instant::now();
            let v = m.vocab;
            let temp = self.cfg.temperature;
            for &i in participating {
                // Row copy ends the arena borrow before engine mutation;
                // softmax refills the scratch distribution in place.
                self.scratch.row.clear();
                self.scratch
                    .row
                    .extend_from_slice(&self.runner.logits()[i * v..(i + 1) * v]);
                if temp > 0.0 {
                    let Scratch { row, probs, .. } = &mut self.scratch;
                    sampling::softmax_into(row, temp, probs);
                }
                let d = sampling::sample_logits(&self.scratch.row, temp, &mut self.rng) as i32;
                let slot = self.slots[i].as_mut().expect("grouped above from live slots");
                slot.drafts.push(d);
                if temp > 0.0 {
                    slot.draft_probs.extend_from_slice(&self.scratch.probs);
                } else {
                    // One-hot written straight into the slot's buffer.
                    let base = slot.draft_probs.len();
                    slot.draft_probs.resize(base + v, 0.0);
                    slot.draft_probs[base + d as usize] = 1.0;
                }
                slot.pending = d;
                slot.len += 1; // the fed token's KV row was written
                let id = slot.req.id;
                let full = slot.drafts.len() >= slot.draft_target;
                if full {
                    slot.phase = Phase::ReadyVerify;
                }
                self.kv.grow(id, 1);
            }
            *cpu_s += t_cpu.elapsed().as_secs_f64();
            if self.tracer.hot() {
                self.tracer.end(
                    names::DRAFT,
                    Track::Engine,
                    self.sim_s,
                    vec![("w", w.into()), ("slots", participating.len().into())],
                );
            }
            self.scratch.stepped.extend_from_slice(participating);
        }

        // Per-drafter post-step hooks over the slots that just drafted
        // (oracle: dense q=1 pass + exact-score refresh).
        let mut by_drafter: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &i in &self.scratch.stepped {
            if let Some(slot) = self.slots[i].as_ref() {
                by_drafter.entry(slot.drafter).or_default().push(i);
            }
        }
        let eagle_ctx = self.rt.cfg.eagle.ctx;
        for (di, idxs) in by_drafter {
            let mut host = DraftHost {
                runner: &mut self.runner,
                m,
                k: self.cfg.k,
                temperature: self.cfg.temperature,
                eagle_ctx,
                rng: &mut self.rng,
                comp: &mut *comp,
                cpu_s: &mut *cpu_s,
                pool: &self.pool,
            };
            let res = Self::sandboxed(&self.drafter_names[di], "after_draft", || {
                self.drafters[di].after_draft(&mut host, &mut self.slots, &idxs)
            });
            match res {
                // Real runner errors inside the hook keep propagating —
                // only panics are absorbed into the degrade path.
                Ok(r) => launches += r?,
                Err(e) => {
                    for &i in &idxs {
                        self.note_drafter_fault(i, &e);
                    }
                }
            }
        }
        Ok(launches)
    }

    /// Proposal generation, one batched `propose_batch` hook call per
    /// proposal drafter over its empty-drafted ReadyVerify slots.
    fn generate_proposals(
        &mut self,
        comp: &mut IterComposition,
        cpu_s: &mut f64,
    ) -> Result<u32> {
        let rt = self.rt.clone();
        let m = &rt.cfg.model;
        let eagle_ctx = rt.cfg.eagle.ctx;
        let mut launches = 0u32;
        for di in 0..self.drafters.len() {
            if self.drafters[di].mode() != DraftMode::Proposal {
                continue;
            }
            let idxs: Vec<usize> = (0..self.slots.len())
                .filter(|&i| {
                    self.slots[i]
                        .as_ref()
                        .map(|s| {
                            s.drafter == di
                                && s.phase == Phase::ReadyVerify
                                && s.drafts.is_empty()
                                && !s.degraded // demoted slots verify q=1
                        })
                        .unwrap_or(false)
                })
                .collect();
            if idxs.is_empty() {
                continue;
            }
            self.tracer.begin(names::PROPOSE, Track::Engine, self.sim_s);
            let res = if self.injector.check(FaultSite::DrafterPanic) {
                Err(EngineError::DrafterPanic {
                    drafter: self.drafter_names[di].clone(),
                    hook: "propose_batch",
                    detail: "injected fault".into(),
                })
            } else {
                let mut host = DraftHost {
                    runner: &mut self.runner,
                    m,
                    k: self.cfg.k,
                    temperature: self.cfg.temperature,
                    eagle_ctx,
                    rng: &mut self.rng,
                    comp: &mut *comp,
                    cpu_s: &mut *cpu_s,
                    pool: &self.pool,
                };
                Self::sandboxed(&self.drafter_names[di], "propose_batch", || {
                    self.drafters[di].propose_batch(&mut host, &mut self.slots, &idxs)
                })
            };
            match res {
                // Real runner errors inside the hook keep propagating.
                Ok(r) => launches += r?,
                Err(e) => {
                    // The whole batch loses its proposals (the faulting
                    // drafter owns every one of these slots); each slot
                    // verifies as a vanilla round instead.
                    for &i in &idxs {
                        if let Some(slot) = self.slots[i].as_mut() {
                            slot.drafts.clear();
                            slot.draft_probs.clear();
                        }
                        self.note_drafter_fault(i, &e);
                    }
                }
            }
            // Injected malformed batch: corrupt one slot's proposals so
            // the validation below is exercised end-to-end.
            if self.injector.check(FaultSite::DrafterMalformed) {
                if let Some(slot) = idxs.first().and_then(|&i| self.slots[i].as_mut()) {
                    slot.drafts.push(m.vocab as i32); // out-of-vocab token
                    let grown = slot.draft_probs.len() + m.vocab;
                    slot.draft_probs.resize(grown, 0.0);
                }
            }
            // Defensive shape validation: sandboxing catches panics, but a
            // *returned* bad batch (token ids outside the vocab, more
            // drafts than k, inconsistent prob rows) would corrupt the
            // shared verify launch.  Never feed one to the verifier.
            self.validate_proposals(di, &idxs, m.vocab);
            if self.tracer.hot() {
                let dname = self.drafter_names[di].clone();
                self.tracer.end(
                    names::PROPOSE,
                    Track::Engine,
                    self.sim_s,
                    vec![("drafter", dname.into()), ("slots", idxs.len().into())],
                );
            }
        }
        Ok(launches)
    }

    /// Shape-validate the proposal batch a drafter just produced: at most
    /// `k` drafts, every token id inside the vocab, prob rows consistent
    /// with the draft count.  A malformed slot loses its proposals (it
    /// verifies as a vanilla round) and counts a drafter fault toward
    /// demotion — the engine never feeds a bad token id to the verifier.
    fn validate_proposals(&mut self, di: usize, idxs: &[usize], vocab: usize) {
        let k = self.cfg.k;
        for &i in idxs {
            let bad = {
                let Some(slot) = self.slots[i].as_ref() else { continue };
                let over_len = slot.drafts.len() > k;
                let oov = slot.drafts.iter().any(|&t| t < 0 || t as usize >= vocab);
                let probs_bad = slot.draft_probs.len() != slot.drafts.len() * vocab;
                if over_len || oov || probs_bad {
                    Some(format!(
                        "{} drafts (k={k}), out_of_vocab={oov}, {} prob rows",
                        slot.drafts.len(),
                        slot.draft_probs.len() / vocab.max(1),
                    ))
                } else {
                    None
                }
            };
            if let Some(detail) = bad {
                let err = EngineError::MalformedProposal {
                    drafter: self.drafter_names[di].clone(),
                    detail,
                };
                if let Some(slot) = self.slots[i].as_mut() {
                    slot.drafts.clear();
                    slot.draft_probs.clear();
                }
                self.note_drafter_fault(i, &err);
            }
        }
    }

    /// Dense verification for all ReadyVerify slots — one launch serves
    /// every drafter (per-slot `qv` covers mixed speculation lengths).
    fn verify_step(&mut self, comp: &mut IterComposition, cpu_s: &mut f64) -> Result<u32> {
        let rt = self.rt.clone();
        let m = &rt.cfg.model;
        let q = self.cfg.k + 1;
        let t_cpu = Instant::now();
        self.scratch.tokens.clear();
        self.scratch.tokens.resize(m.slots * q, 0);
        self.scratch.pos.clear();
        self.scratch.pos.resize(m.slots, 0);
        self.scratch.qv.clear();
        self.scratch.qv.resize(m.slots, 1);
        self.scratch.active.clear();
        self.scratch.active.resize(m.slots, 0);
        self.scratch.participating.clear();
        for i in 0..m.slots {
            let Some(slot) = self.slots[i].as_ref() else { continue };
            if slot.phase != Phase::ReadyVerify {
                continue;
            }
            self.scratch.participating.push(i);
            self.scratch.tokens[i * q] = slot.anchor;
            for (j, &d) in slot.drafts.iter().enumerate().take(q - 1) {
                self.scratch.tokens[i * q + 1 + j] = d;
            }
            self.scratch.qv[i] = (1 + slot.drafts.len()) as i32;
            self.scratch.pos[i] = slot.round_start_len as i32;
            self.scratch.active[i] = 1;
        }
        if self.scratch.participating.is_empty() {
            return Ok(0);
        }
        self.tracer.begin(names::VERIFY, Track::Engine, self.sim_s);
        comp.verifying = self.scratch.participating.len();
        for &i in &self.scratch.participating {
            let slot = self.slots[i].as_ref().expect("collected above from live slots");
            comp.gemm_rows += 1 + slot.drafts.len();
            comp.attn_bytes +=
                (slot.round_start_len + 1 + slot.drafts.len()) * m.kv_bytes_per_token();
        }
        *cpu_s += t_cpu.elapsed().as_secs_f64();

        {
            let runner = &mut self.runner;
            let sc = &self.scratch;
            // k+1 is builder-validated against the compiled variants; the
            // permissive constructor path falls back to a generic label
            // and lets `verify` surface the artifact error as before.
            let artifact = self.names.verify(q).unwrap_or("verify");
            Self::step_with_retry(
                &mut self.injector,
                &mut self.sim_s,
                &mut self.fault_retries,
                &mut self.tracer,
                artifact,
                || runner.verify(q, &sc.tokens, &sc.pos, &sc.qv, &sc.active),
            )?;
        }

        // Process: acceptance + pillar refresh.  In delayed mode the CPU
        // part runs on the worker pool and is consumed next iteration.
        let v = m.vocab;
        let t_dim = m.max_seq;
        let per_dump = m.layers * m.kv_heads * t_dim;
        let temp = self.cfg.temperature;

        let mut inline: Vec<Promise<VerifyWork>> = Vec::new();
        let mut serial: Vec<VerifyWork> = Vec::new();
        for &i in &self.scratch.participating {
            let slot = self.slots[i].as_ref().expect("collected above from live slots");
            let drafts = slot.drafts.clone();
            let dprobs = slot.draft_probs.clone();
            // Off-thread jobs need owned rows (the arena view cannot cross
            // the pool); the copies are the price of the overlap.
            let logits = self.runner.logits()[i * q * v..(i + 1) * q * v].to_vec();
            // Whether the score dump feeds selection is the slot's
            // drafter's call (PillarAttn: yes; windows/proposals: no).
            let dump = if slot.refresh_dump {
                Some(self.runner.dump()[i * per_dump..(i + 1) * per_dump].to_vec())
            } else {
                None
            };
            let rsl = slot.round_start_len;
            let mut pillar = slot.pillar.clone();
            let seed = self.rng.next_u64();
            let job = move || {
                let t0 = Instant::now();
                let res = if temp > 0.0 {
                    let mut rng = Xoshiro256::new(seed);
                    sampling::verify_stochastic(&drafts, &dprobs, &logits, v, temp, &mut rng)
                } else {
                    sampling::verify_greedy(&drafts, &logits, v)
                };
                let new_len = rsl + res.accepted + 1;
                let (pillar_out, select_s) = match dump {
                    Some(d) => {
                        let t_sel = Instant::now();
                        pillar.refresh_from(&d, t_dim, new_len);
                        (Some(pillar), t_sel.elapsed().as_secs_f64())
                    }
                    None => (None, 0.0),
                };
                VerifyWork {
                    slot_idx: i,
                    accepted: res.accepted,
                    next_token: res.next_token,
                    pillar: pillar_out,
                    cpu_s: t0.elapsed().as_secs_f64(),
                    select_s,
                }
            };
            if self.cfg.delayed_verify {
                self.slots[i]
                    .as_mut()
                    .expect("collected above from live slots")
                    .phase = Phase::AwaitVerify;
                self.delayed.push(Promise::spawn_on(&self.pool, job));
            } else if self.cfg.parallel {
                // Immediate mode still fans the per-slot acceptance +
                // refresh work out across the pool; results are collected
                // (in deterministic slot order) right below.
                inline.push(Promise::spawn_on(&self.pool, job));
            } else {
                // Serial mode runs the identical closure synchronously —
                // bit-identical results without touching the pool (the
                // RNG seed was drawn in the same per-slot order above).
                serial.push(job());
            }
        }
        if !inline.is_empty() || !serial.is_empty() {
            let mut c = 0.0;
            let mut sel = 0.0;
            for w in serial {
                c += w.cpu_s;
                sel += w.select_s;
                self.apply_verify(w)?;
            }
            for p in inline {
                let w = p.get();
                c += w.cpu_s;
                sel += w.select_s;
                self.apply_verify(w)?;
            }
            if sel > 0.0 {
                self.runner.stats.note_host("pillar_select", sel);
            }
            *cpu_s += c;
            // `post_verify` needs `&mut self`; lend it the boundary list
            // and put the staging buffer back after (it never touches the
            // verify scratch).
            let participating = std::mem::take(&mut self.scratch.participating);
            self.post_verify(&participating)?;
            self.scratch.participating = participating;
        }
        if self.cfg.delayed_verify && self.tracer.enabled() && self.overlap_open.is_none() {
            // The CPU-side acceptance/refresh work now runs concurrently
            // with whatever the device does next; the window closes at the
            // next delayed drain (possibly several iterations later).
            self.overlap_open = Some(self.iter);
            self.tracer.async_begin(
                names::DELAYED_VERIFY_OVERLAP,
                Track::Overlap,
                self.iter,
                self.sim_s,
                vec![("jobs", self.scratch.participating.len().into())],
            );
        }
        if self.tracer.hot() {
            let delayed: u64 = if self.cfg.delayed_verify { 1 } else { 0 };
            self.tracer.end(
                names::VERIFY,
                Track::Engine,
                self.sim_s,
                vec![
                    ("slots", self.scratch.participating.len().into()),
                    ("delayed", delayed.into()),
                ],
            );
        }
        Ok(1)
    }

    fn collect_delayed(&mut self) -> Result<f64> {
        if self.delayed.is_empty() {
            return Ok(0.0);
        }
        // Take the queue out to appease the borrow checker, drain it in
        // place, and hand the (now empty) Vec back so its capacity — sized
        // to the slot ceiling at construction — survives every round.
        let mut promises = std::mem::take(&mut self.delayed);
        let n_jobs = promises.len();
        let mut boundary = Vec::new();
        let mut stall = 0.0;
        let mut sel = 0.0;
        if self.injector.check(FaultSite::VerifyStall) {
            // Injected CPU-side stall: the delayed acceptance work took
            // longer than the overlap window.  The overshoot is charged as
            // stall time and absorbed — nothing else changes.
            let extra = 4.0 * fault::STEP_BACKOFF_BASE_S;
            stall += extra;
            if self.tracer.enabled() {
                self.tracer.instant(
                    names::FAULT,
                    Track::Engine,
                    self.sim_s,
                    vec![
                        ("site", FaultSite::VerifyStall.label().into()),
                        ("stall_us", (extra * 1e6).into()),
                    ],
                );
            }
        }
        for p in promises.drain(..) {
            let t0 = Instant::now();
            let w = p.get(); // usually already done: ran during GPU work
            stall += t0.elapsed().as_secs_f64();
            sel += w.select_s;
            boundary.push(w.slot_idx);
            self.apply_verify(w)?;
        }
        self.delayed = promises;
        if sel > 0.0 {
            // Selection ran overlapped with GPU work, but the Table-2
            // breakdown (and the overlap model's observers) still want to
            // see its true cost.
            self.runner.stats.note_host("pillar_select", sel);
        }
        if let Some(id) = self.overlap_open.take() {
            self.tracer.async_end(
                names::DELAYED_VERIFY_OVERLAP,
                Track::Overlap,
                id,
                self.sim_s,
                vec![("jobs", n_jobs.into()), ("stall_us", (stall * 1e6).into())],
            );
        }
        self.post_verify(&boundary)?;
        Ok(stall)
    }

    fn apply_verify(&mut self, w: VerifyWork) -> Result<()> {
        let Some(slot) = self.slots[w.slot_idx].as_mut() else {
            return Ok(());
        };
        let di = slot.drafter;
        let drafted = slot.drafts.len();
        self.accept.record(drafted, w.accepted);
        self.accept_by[di].record(drafted, w.accepted);
        // Acceptance-collapse tracking: a slot that keeps speculating
        // without ever landing a draft token wastes every verify round —
        // past the window it demotes to vanilla decoding (handled below,
        // once the slot borrow ends).
        let collapse = slot.note_round_accept(w.accepted, drafted > 0);
        let old_len = slot.len;
        let new_len = slot.round_start_len + w.accepted + 1;

        // Accepted tokens + correction/bonus token enter the output.
        let take = w.accepted.min(slot.remaining());
        let out_base = slot.output.len();
        for j in 0..take {
            slot.output.push(slot.drafts[j]);
        }
        slot.gen_count += take;
        if slot.remaining() > 0 {
            slot.output.push(w.next_token);
            slot.gen_count += 1;
        }
        let n_new = slot.output.len() - out_base;
        self.tokens_generated += n_new as u64;
        // The n-gram index reads the new tokens straight off the output
        // tail — no staging Vec (order-0 indexes skip even the hashing).
        slot.ngram.extend(&slot.output[out_base..]);
        slot.pending = w.next_token;
        slot.len = new_len;
        if let Some(p) = w.pillar {
            slot.pillar = p;
        }
        let id = slot.req.id;
        if new_len > old_len {
            self.kv.grow(id, new_len - old_len);
        } else {
            self.kv.shrink(id, old_len - new_len);
        }
        if collapse {
            if let Some(s) = self.slots[w.slot_idx].as_mut() {
                s.demote();
            }
            self.note_degradation(id, "acceptance_collapse");
        }
        // Close the feedback loop: the drafter steers its next plan from
        // this round's acceptance (AdaptiveK lives on exactly this hook).
        let fb = VerifyFeedback {
            req_id: id,
            slot_idx: w.slot_idx,
            drafted,
            accepted: w.accepted,
            bonus_token: w.next_token,
            context_len: new_len,
        };
        if let Err(e) = Self::sandboxed(&self.drafter_names[di], "on_verify", || {
            self.drafters[di].on_verify(&fb)
        }) {
            self.note_drafter_fault(w.slot_idx, &e);
        }
        if self.tracer.enabled() {
            // AdaptiveK (or any feedback-adaptive wrapper) may have just
            // moved this session's speculation length.
            if let Some(kc) = self.drafters[di].current_k(id) {
                self.tracer.instant(
                    names::ADAPTIVE_K,
                    Track::Drafter,
                    self.sim_s,
                    vec![("req", id.into()), ("k", kc.into())],
                );
            }
        }
        if n_new > 0 {
            self.slo.itl_pending.push((id, n_new));
        }
        // Stream the accepted tokens out before retirement/pressure run.
        Self::notify_session(
            &self.sessions,
            &mut self.stamp_pending,
            self.slots[w.slot_idx]
                .as_ref()
                .expect("verified slot is live (checked at entry)"),
            Some(w.accepted),
        );
        Ok(())
    }

    /// Retirement, pressure and round restart for slots that just finished
    /// verification.
    fn post_verify(&mut self, indices: &[usize]) -> Result<()> {
        for &i in indices {
            let Some(slot) = self.slots[i].as_ref() else { continue };
            if slot.done() {
                let slot = self.slots[i]
                    .take()
                    .expect("done() was just read from this slot");
                self.buckets.release(slot.bucket.min(self.buckets.n_buckets() - 1));
                self.kv.release(slot.req.id);
                self.drafter_on_finish(slot.drafter, slot.req.id);
                let mut out = slot.output;
                out.truncate(slot.req.max_new);
                self.outputs.insert(slot.req.id, out);
                self.latency
                    .record(slot.admitted_at.elapsed().as_secs_f64());
                self.requests_done += 1;
                self.slo.completed_pending.push(slot.req.id);
                self.finish_session(slot.req.id, FinishReason::Completed);
            }
        }
        self.handle_pressure(indices)?;
        for &i in indices {
            let restart = matches!(
                self.slots[i].as_ref().map(|s| s.phase),
                Some(Phase::ReadyVerify) | Some(Phase::AwaitVerify)
            );
            if restart {
                self.start_round(i, false);
            }
        }
        Ok(())
    }
}
