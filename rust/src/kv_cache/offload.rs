//! Asynchronous chunk-wise KV offload engine (§4.4 "Overhead analysis").
//!
//! A dedicated copier thread receives offload jobs and streams them to the
//! host tier chunk by chunk at a modelled PCIe bandwidth, so the engine can
//! verify the paper's claim that offload overlaps with compute: the copier
//! records, per job, how much of its transfer time fit inside compute time
//! vs stalled the pipeline.  The *data* movement is real (the engine pulls
//! the rows out of the device pool); the *pacing* models PCIe.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::HostKv;

pub struct OffloadJob {
    pub req_id: u64,
    pub kv: HostKv,
    pub bytes: usize,
}

#[derive(Clone, Debug, Default)]
pub struct OffloadStats {
    pub jobs: u64,
    pub bytes: u64,
    pub chunks: u64,
    /// Total modelled transfer seconds.
    pub transfer_s: f64,
    /// Seconds the engine actually had to wait on `drain()` — transfer
    /// time that did NOT hide behind compute.
    pub stall_s: f64,
}

/// A finished transfer as harvested by `poll`/`drain`: request id, the
/// host-tier KV payload, and the modelled transfer seconds for that job
/// (feeds the per-job KV_OFFLOAD trace span).
pub type Done = (u64, HostKv, f64);

enum Msg {
    Job(OffloadJob, mpsc::Sender<Done>),
    Quit,
}

/// Copier thread handle.
pub struct OffloadEngine {
    tx: mpsc::Sender<Msg>,
    done_rx: mpsc::Receiver<Done>,
    done_tx: mpsc::Sender<Done>,
    stats: Arc<Mutex<OffloadStats>>,
    handle: Option<thread::JoinHandle<()>>,
    pending: usize,
}

impl OffloadEngine {
    /// `chunk_bytes`: transfer granularity; `pcie_bw`: modelled bytes/s.
    pub fn new(chunk_bytes: usize, pcie_bw: f64) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (done_tx, done_rx) = mpsc::channel();
        let stats = Arc::new(Mutex::new(OffloadStats::default()));
        let st = stats.clone();
        let handle = thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Quit => return,
                    Msg::Job(job, reply) => {
                        let t0 = Instant::now();
                        let n_chunks = job.bytes.div_ceil(chunk_bytes).max(1);
                        let per_chunk = job.bytes as f64 / n_chunks as f64 / pcie_bw;
                        for _ in 0..n_chunks {
                            // Model the PCIe pacing of one chunk.
                            thread::sleep(Duration::from_secs_f64(per_chunk));
                        }
                        let took = t0.elapsed().as_secs_f64();
                        {
                            let mut s = st.lock().unwrap();
                            s.jobs += 1;
                            s.bytes += job.bytes as u64;
                            s.chunks += n_chunks as u64;
                            s.transfer_s += took;
                        }
                        let _ = reply.send((job.req_id, job.kv, took));
                    }
                }
            }
        });
        OffloadEngine {
            tx,
            done_rx,
            done_tx,
            stats,
            handle: Some(handle),
            pending: 0,
        }
    }

    /// Submit an offload; returns immediately (the transfer overlaps with
    /// whatever the engine does next).
    ///
    /// If the copier thread has died (its receiver is gone), the job
    /// completes synchronously through the done channel instead — the KV
    /// payload is never lost and the engine's harvest path is unchanged;
    /// only the PCIe pacing model is skipped.
    pub fn submit(&mut self, job: OffloadJob) {
        self.pending += 1;
        if let Err(mpsc::SendError(Msg::Job(job, reply))) =
            self.tx.send(Msg::Job(job, self.done_tx.clone()))
        {
            let _ = reply.send((job.req_id, job.kv, 0.0));
        }
    }

    /// Harvest finished transfers without blocking.
    pub fn poll(&mut self) -> Vec<Done> {
        let mut out = Vec::new();
        while let Ok(x) = self.done_rx.try_recv() {
            self.pending -= 1;
            out.push(x);
        }
        out
    }

    /// Block until all submitted transfers are done (end of run, or the
    /// rare case where the engine needs the slot *now*).  Stall time is
    /// charged to `stats.stall_s` — this is the non-overlapped remainder.
    pub fn drain(&mut self) -> Vec<Done> {
        let t0 = Instant::now();
        let mut out = Vec::new();
        while self.pending > 0 {
            match self.done_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(x) => {
                    self.pending -= 1;
                    out.push(x);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // A dead copier can never deliver the remaining jobs;
                    // give up instead of spinning forever (`submit` keeps
                    // new jobs lossless, this bounds the old ones).
                    if self.handle.as_ref().map(|h| h.is_finished()).unwrap_or(true) {
                        self.pending = 0;
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.pending = 0;
                    break;
                }
            }
        }
        self.lock_stats().stall_s += t0.elapsed().as_secs_f64();
        out
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn stats(&self) -> OffloadStats {
        self.lock_stats().clone()
    }

    /// Poison-proof stats lock: a copier that panicked mid-update leaves
    /// numbers that are at worst slightly stale — not worth taking the
    /// engine down over.
    fn lock_stats(&self) -> std::sync::MutexGuard<'_, OffloadStats> {
        self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Drop for OffloadEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Quit);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, bytes: usize) -> OffloadJob {
        OffloadJob {
            req_id: id,
            kv: HostKv { k: vec![0.0; 4], v: vec![0.0; 4], len: 4 },
            bytes,
        }
    }

    #[test]
    fn transfers_complete_and_stats_accumulate() {
        let mut eng = OffloadEngine::new(1 << 20, 10e9);
        eng.submit(job(1, 4 << 20));
        eng.submit(job(2, 2 << 20));
        let done = eng.drain();
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|(_, _, t)| *t > 0.0), "per-job transfer time");
        let st = eng.stats();
        assert_eq!(st.jobs, 2);
        assert_eq!(st.bytes, (6 << 20) as u64);
        assert!(st.chunks >= 6);
        // 6 MiB at 10 GB/s ~ 0.6 ms of modelled transfer
        assert!(st.transfer_s > 0.0004, "transfer_s={}", st.transfer_s);
    }

    #[test]
    fn overlap_hides_transfer_behind_compute() {
        let mut eng = OffloadEngine::new(256 << 10, 50e9);
        eng.submit(job(7, 1 << 20)); // ~20 us modelled
        std::thread::sleep(Duration::from_millis(20)); // "compute"
        let done = eng.poll(); // should already be finished: no stall
        assert_eq!(done.len(), 1);
        assert_eq!(eng.pending(), 0);
        let st = eng.stats();
        assert!(st.stall_s < 1e-3);
    }
}
