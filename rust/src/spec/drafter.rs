//! The pluggable `Drafter` API — the engine as a plugin host.
//!
//! The paper's central systems claim is that *one* engine (dense
//! verification, unified scheduling, dynamic KV) can host *many* draft
//! policies: PillarAttn self-speculation, sliding windows, n-gram lookup,
//! TriForce-style hierarchies, trained heads, oracles.  This module makes
//! that claim an API instead of an enum interpreter: every draft policy is
//! an object-safe [`Drafter`] the engine drives through lifecycle hooks,
//! and a [`DrafterRegistry`] maps names to constructors so out-of-crate
//! drafters plug in without touching `engine/core.rs`.
//!
//! # Lifecycle
//!
//! ```text
//!   admission            round start          draft phase        verification
//!   on_admit(id)  ──►  plan(&DraftCtx)  ──►  (engine-run sparse  ──►  on_verify(
//!                        -> DraftPlan          steps, or                &VerifyFeedback)
//!                                              propose_batch /          ... next round
//!   retire/cancel: on_finish(id)               after_draft hooks)
//! ```
//!
//! * **Capabilities** ([`Drafter::mode`], [`Drafter::index_policy`],
//!   [`Drafter::artifacts`], [`Drafter::wants_dump_refresh`]) are read at
//!   admission and engine construction: they tell the engine which
//!   compiled artifact variants the drafter touches, how its per-slot
//!   sparse index sets are composed, and whether verification's attention
//!   score dump feeds back into selection.
//! * **[`Drafter::plan`]** is the host-free per-round hook: it sizes the
//!   speculation (`DraftPlan::target`, clamped by the engine to the
//!   schedule cap and the request's remaining budget) and, for proposal
//!   drafters, returns the draft tokens themselves.
//! * **[`Drafter::propose_batch`] / [`Drafter::after_draft`]** are the
//!   batch hooks for drafters that need model access (EAGLE's head calls,
//!   TriForce's sparse middle-layer verify, the oracle's exact-score
//!   refresh).  The engine groups slots by drafter and hands over a
//!   [`DraftHost`] with the runner, RNG and accounting — one call per
//!   drafter per iteration, so batching across slots is preserved.
//! * **[`Drafter::on_verify`]** closes the loop with per-round acceptance
//!   feedback; adaptive policies (see [`crate::spec::adaptive`]) use it to
//!   widen/narrow their speculation length online.
//!
//! # Write your own drafter
//!
//! A drafter that just re-proposes the pending token (a "parrot") needs
//! ~20 lines and zero engine changes:
//!
//! ```no_run
//! use std::rc::Rc;
//! use sparsespec::engine::{Engine, EngineConfig};
//! use sparsespec::model::ModelConfig;
//! use sparsespec::runtime::Runtime;
//! use sparsespec::spec::{
//!     DraftCtx, DraftMode, DraftPlan, Drafter, DrafterKind, DrafterRegistry, IndexPolicy,
//! };
//!
//! struct Parrot;
//!
//! impl Drafter for Parrot {
//!     fn kind(&self) -> DrafterKind {
//!         DrafterKind::Custom { name: "parrot" }
//!     }
//!     fn mode(&self) -> DraftMode {
//!         DraftMode::Proposal
//!     }
//!     fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
//!         IndexPolicy::pillar(m.draft_budget) // unused: no sparse steps
//!     }
//!     fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
//!         // Guess the pending token keeps repeating; dense verification
//!         // keeps this lossless no matter how wrong the guess is.
//!         DraftPlan::proposals(vec![ctx.pending; ctx.k.min(ctx.remaining.max(1))])
//!     }
//! }
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut reg = DrafterRegistry::with_builtins();
//! reg.register("parrot", |_kind, _m| Ok(Box::new(Parrot)));
//! let rt = Rc::new(Runtime::load("artifacts")?);
//! let cfg = EngineConfig::new(DrafterKind::Custom { name: "parrot" }).with_k(8);
//! let _engine = Engine::with_registry(rt, cfg, reg)?;
//! # Ok(())
//! # }
//! ```
//!
//! Per-session selection: set [`crate::workload::Request::drafter`] and the
//! engine resolves it through the same registry at submit time — sessions
//! with different drafters share one batch, one verification artifact and
//! one KV pool (validated at `EngineConfig::builder` time for statically
//! declared drafters, at submit time for dynamic ones).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use super::{DrafterKind, IndexPolicy, NGramIndex};
use crate::engine::{Phase, Slot};
use crate::model::ModelConfig;
use crate::runtime::ModelRunner;
use crate::sampling;
use crate::scheduler::IterComposition;
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::ThreadPool;

/// What class of engine execution a drafter needs each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DraftMode {
    /// No speculation at all: every round is a single-query dense
    /// verification (the vanilla baseline).  The engine compiles
    /// `verify_q1` and forces `k = 0` when this is the default drafter.
    Off,
    /// Sparse self-speculative draft steps on the target model: the
    /// engine runs `DraftPlan::target` sparse steps through the
    /// `draft_w{W}` artifact, composing index sets from the slot's
    /// [`crate::spec::PillarState`].
    SelfSpec,
    /// Host/auxiliary proposal generation (n-gram lookup, trained heads,
    /// hierarchical drafts): the engine fills the slot's draft buffer
    /// from [`Drafter::plan`] tokens or a [`Drafter::propose_batch`]
    /// override, then verifies densely as usual.
    Proposal,
}

/// Per-round planning context handed to [`Drafter::plan`].
///
/// Everything here is a value snapshot of the slot (plus a read-only view
/// of its n-gram history), so `plan` never borrows engine internals.
pub struct DraftCtx<'a> {
    /// Request id (the per-session key for adaptive state).
    pub req_id: u64,
    /// Engine slot index.
    pub slot_idx: usize,
    /// The engine's configured speculation ceiling (`EngineConfig::k`).
    pub k: usize,
    /// Scheduler cap for this round (bucket alignment can shorten a
    /// first round under the unified schedule).  The engine clamps the
    /// returned target to this.
    pub sched_cap: usize,
    /// Current KV frontier (valid context length).
    pub len: usize,
    /// Generation budget left for this request.
    pub remaining: usize,
    /// The pending (sampled, not yet KV-written) token — the round's
    /// anchor.
    pub pending: i32,
    /// True for the first round after admission/reload.
    pub first_round: bool,
    /// The slot's n-gram history index (prompt + accepted output).
    pub ngram: Option<&'a NGramIndex>,
}

/// What a drafter wants to do this round (see [`Drafter::plan`]).
#[derive(Clone, Debug, Default)]
pub struct DraftPlan {
    /// Speculation length for this round, before the engine clamps it to
    /// the schedule cap and the remaining generation budget.  `0` means a
    /// verify-only round.
    pub target: usize,
    /// Host-proposed draft tokens (proposal drafters).  Self-spec
    /// drafters leave this empty: the engine runs `target` sparse draft
    /// steps instead.
    pub tokens: Vec<i32>,
}

impl DraftPlan {
    /// Plan `n` engine-run sparse draft steps (self-spec drafters).
    pub fn steps(n: usize) -> DraftPlan {
        DraftPlan { target: n, tokens: Vec::new() }
    }

    /// Plan with concrete proposal tokens (proposal drafters).
    pub fn proposals(tokens: Vec<i32>) -> DraftPlan {
        DraftPlan { target: tokens.len(), tokens }
    }
}

/// Verification feedback delivered to [`Drafter::on_verify`] after every
/// round that touched one of the drafter's slots.
///
/// The attention score dump itself is not carried here: drafters that
/// consume it declare [`Drafter::wants_dump_refresh`] and the engine
/// refreshes the slot's `PillarState` on its worker pool, overlapped with
/// device work — the zero-copy fast path of §4.1/§4.3.
#[derive(Clone, Copy, Debug)]
pub struct VerifyFeedback {
    pub req_id: u64,
    pub slot_idx: usize,
    /// Tokens drafted this round.
    pub drafted: usize,
    /// Drafted tokens accepted (bonus token not counted, per §5.3).
    pub accepted: usize,
    /// The correction/bonus token verification sampled.
    pub bonus_token: i32,
    /// KV frontier after rollback (round start + accepted + 1).
    pub context_len: usize,
}

/// Engine-side services handed to the batch hooks
/// ([`Drafter::propose_batch`], [`Drafter::after_draft`]): the model
/// runner, configuration, RNG and the iteration's accounting sinks.
pub struct DraftHost<'a> {
    pub runner: &'a mut ModelRunner,
    pub m: &'a ModelConfig,
    /// Engine speculation ceiling.
    pub k: usize,
    pub temperature: f32,
    /// EAGLE head context length (from the runtime config).
    pub eagle_ctx: usize,
    pub rng: &'a mut Xoshiro256,
    /// Per-iteration batch composition (feeds the simulated clock).
    pub comp: &'a mut IterComposition,
    /// Host CPU seconds consumed this iteration.
    pub cpu_s: &'a mut f64,
    pub pool: &'a ThreadPool,
}

/// An object-safe draft policy.  See the module docs for the lifecycle
/// and a complete out-of-crate example.
pub trait Drafter {
    /// The parse/CLI-layer tag this instance answers to (`DrafterKind`
    /// survives as the serialisable surface; the trait is the behaviour).
    fn kind(&self) -> DrafterKind;

    /// Display/metrics name (defaults to `kind().name()`); keys the
    /// per-drafter acceptance breakdowns in `RunReport::accept_by`.
    fn name(&self) -> String {
        self.kind().name()
    }

    /// Execution class the engine must provide (see [`DraftMode`]).
    fn mode(&self) -> DraftMode;

    /// How this drafter's per-(layer, head) sparse index sets are
    /// composed (sinks / recent window / score-selected split).
    fn index_policy(&self, m: &ModelConfig) -> IndexPolicy;

    /// Sparse budget W — selects the `draft_w{W}` artifact variant for
    /// self-spec drafters and sizes the slot's index state.
    fn draft_budget(&self, m: &ModelConfig) -> usize {
        self.kind().budget().unwrap_or(m.draft_budget)
    }

    /// Artifact names (beyond `prefill` / the engine's dense verify) this
    /// drafter can touch, for up-front precompilation.
    fn artifacts(&self, _k: usize) -> Vec<String> {
        Vec::new()
    }

    /// n-gram history order kept per slot.  NGram/TriForce consume it;
    /// builtin drafters that never call `propose` return 0 so accepted
    /// tokens cost neither hashing nor history growth on the hot path.
    /// The default stays 3 for out-of-crate drafters, whose `plan` may
    /// read `DraftCtx::ngram`.
    fn ngram_order(&self) -> usize {
        3
    }

    /// Should verification's attention score dump refresh the slot's
    /// critical-token state?  (PillarAttn: yes; pure windows: no.)
    fn wants_dump_refresh(&self) -> bool {
        false
    }

    /// Engine-level compatibility check at resolve time (e.g. TriForce's
    /// `sparse_verify` artifact is compiled for exactly one (W, k)).
    fn validate_engine(&self, _m: &ModelConfig, _k: usize) -> Result<()> {
        Ok(())
    }

    /// A request using this drafter entered a device slot (`resumed` when
    /// reloading from the host KV tier rather than a fresh admission).
    fn on_admit(&mut self, _req_id: u64, _resumed: bool) {}

    /// Size the next speculation round / produce proposal tokens.  Called
    /// at round start for self-spec drafters and per proposal-fill for
    /// proposal drafters (via the default [`Drafter::propose_batch`]).
    fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan;

    /// Batched proposal generation over this drafter's slots (`idxs` are
    /// the slots owned by this drafter that need drafts this iteration).
    /// The default loops [`Drafter::plan`] per slot — override it when
    /// proposals need model access so calls stay batched.  Returns the
    /// number of device launches performed.
    fn propose_batch(
        &mut self,
        host: &mut DraftHost,
        slots: &mut [Option<Slot>],
        idxs: &[usize],
    ) -> Result<u32> {
        let t = Instant::now();
        for &i in idxs {
            let plan = {
                let slot = slots[i].as_ref().expect("proposal slot vanished");
                let ctx = DraftCtx {
                    req_id: slot.req.id,
                    slot_idx: i,
                    k: host.k,
                    sched_cap: host.k,
                    len: slot.len,
                    remaining: slot.remaining(),
                    pending: slot.pending,
                    first_round: false,
                    ngram: Some(&slot.ngram),
                };
                self.plan(&ctx)
            };
            let slot = slots[i].as_mut().unwrap();
            // The drafter sizes its own proposal (plan.tokens); the engine
            // clamp only enforces the k ceiling and the remaining budget.
            let cap = host.k.min(slot.remaining().max(1));
            let mut props = plan.tokens;
            props.truncate(cap);
            set_proposals(slot, props, host.m.vocab);
        }
        *host.cpu_s += t.elapsed().as_secs_f64();
        Ok(0)
    }

    /// Hook after the engine ran a sparse draft step for this drafter's
    /// slots (the oracle refreshes critical tokens from exact scores
    /// here).  Returns the number of device launches performed.
    fn after_draft(
        &mut self,
        _host: &mut DraftHost,
        _slots: &mut [Option<Slot>],
        _idxs: &[usize],
    ) -> Result<u32> {
        Ok(0)
    }

    /// Per-round verification feedback (acceptance, bonus token, new
    /// frontier).  Adaptive policies steer their next `plan` from this.
    fn on_verify(&mut self, _fb: &VerifyFeedback) {}

    /// The live per-request speculation-length target, if this drafter
    /// adapts one (see [`crate::spec::adaptive`]).  Static drafters return
    /// `None`; the engine uses this to emit `adaptive_k` trace instants
    /// without downcasting.
    fn current_k(&self, _req_id: u64) -> Option<usize> {
        None
    }

    /// The request finished (completed or cancelled): drop per-session
    /// state.
    fn on_finish(&mut self, _req_id: u64) {}
}

/// Install proposal tokens as the slot's drafts (with one-hot q rows for
/// the stochastic verifier, since proposals are deterministic).
///
/// Defensive: a token id outside `[0, vocab)` truncates the proposal at
/// that point instead of panicking on the one-hot write — a buggy
/// third-party drafter loses the tail of its speculation, never the
/// process.  (The engine additionally shape-validates every proposal
/// batch before it can enter the shared verify launch; see
/// [`crate::fault`] for the full robustness contract.)
pub fn set_proposals(slot: &mut Slot, mut props: Vec<i32>, vocab: usize) {
    if let Some(bad) = props.iter().position(|&p| p < 0 || p as usize >= vocab) {
        props.truncate(bad);
    }
    slot.draft_probs.clear();
    for &p in &props {
        let mut onehot = vec![0.0f32; vocab];
        onehot[p as usize] = 1.0;
        slot.draft_probs.extend(onehot);
    }
    slot.drafts = props;
    slot.phase = Phase::ReadyVerify;
}

/// Construction-time validation of a drafter configuration against the
/// compiled artifact shape — shared by `EngineConfig::builder`, the
/// builtin registry constructors and the engine's submit-time resolution,
/// so degenerate parameters (`NGram { n: 0 }`, zero/tiny budgets) fail
/// with an actionable error instead of a mid-run index underflow.
pub fn validate_drafter(kind: &DrafterKind, m: &ModelConfig) -> Result<()> {
    match *kind {
        DrafterKind::Vanilla | DrafterKind::Eagle | DrafterKind::Custom { .. } => Ok(()),
        DrafterKind::NGram { n } => {
            if n == 0 {
                bail!(
                    "NGram drafter needs n >= 1: an empty suffix can never match \
                     and n = 0 underflows draft composition"
                );
            }
            if n > 4 {
                bail!("NGram drafter packs keys into a u64: n must be <= 4 (got {n})");
            }
            Ok(())
        }
        DrafterKind::Pillar { w } | DrafterKind::Window { w } | DrafterKind::OracleTopK { w } => {
            validate_budget(kind, w, m)?;
            if !m.has_draft_w(w) {
                bail!(
                    "draft budget W={w} has no draft_w{w} artifact (variants: {:?})",
                    m.draft_w_variants
                );
            }
            Ok(())
        }
        DrafterKind::TriForce { w } => {
            validate_budget(kind, w, m)?;
            // sparse_verify is compiled for exactly (draft_budget, spec_k).
            if w != m.draft_budget {
                bail!(
                    "TriForce W={w} must match the sparse_verify artifact's W={}",
                    m.draft_budget
                );
            }
            Ok(())
        }
    }
}

fn validate_budget(kind: &DrafterKind, w: usize, _m: &ModelConfig) -> Result<()> {
    // The sinks + recent-window split needs room: below 8 the policy
    // degenerates (no sinks, window == budget) and W = 0 would compose
    // empty index sets that the draft kernels reject as all-holes.
    if w < 8 {
        bail!(
            "{} has a degenerate draft budget W={w}: the sinks/recent/top-k \
             split needs W >= 8",
            kind.name()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// builtin drafters — the seven DrafterKind variants, ported onto the trait
// ---------------------------------------------------------------------

/// No speculation: dense autoregressive decode (the vLLM baseline).
pub struct VanillaDrafter;

impl Drafter for VanillaDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::Vanilla
    }

    fn mode(&self) -> DraftMode {
        DraftMode::Off
    }

    fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::pillar(m.draft_budget) // constructed, never composed
    }

    fn ngram_order(&self) -> usize {
        0
    }

    fn plan(&mut self, _ctx: &DraftCtx) -> DraftPlan {
        DraftPlan::steps(0)
    }
}

/// SparseSpec: PillarAttn self-speculation — critical tokens re-selected
/// from the verification score dump every round (§4.1).
pub struct PillarDrafter {
    pub w: usize,
}

impl Drafter for PillarDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::Pillar { w: self.w }
    }

    fn mode(&self) -> DraftMode {
        DraftMode::SelfSpec
    }

    fn index_policy(&self, _m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::pillar(self.w)
    }

    fn artifacts(&self, _k: usize) -> Vec<String> {
        vec![format!("draft_w{}", self.w)]
    }

    fn wants_dump_refresh(&self) -> bool {
        true
    }

    fn ngram_order(&self) -> usize {
        0
    }

    fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
        DraftPlan::steps(ctx.k)
    }
}

/// MagicDec / StreamingLLM-style: attention sinks + sliding window, no
/// score feedback at all.
pub struct WindowDrafter {
    pub w: usize,
}

impl Drafter for WindowDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::Window { w: self.w }
    }

    fn mode(&self) -> DraftMode {
        DraftMode::SelfSpec
    }

    fn index_policy(&self, _m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::window(self.w)
    }

    fn artifacts(&self, _k: usize) -> Vec<String> {
        vec![format!("draft_w{}", self.w)]
    }

    fn ngram_order(&self) -> usize {
        0
    }

    fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
        DraftPlan::steps(ctx.k)
    }
}

/// Oracle top-k (Fig. 3): critical tokens refreshed from *exact* scores
/// after every draft step via a dense q=1 pass — the upper bound for
/// dynamic sparse selection (acceptance comparisons only; not a
/// wallclock-fair system).
pub struct OracleDrafter {
    pub w: usize,
}

impl Drafter for OracleDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::OracleTopK { w: self.w }
    }

    fn mode(&self) -> DraftMode {
        DraftMode::SelfSpec
    }

    fn index_policy(&self, _m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::pillar(self.w)
    }

    fn artifacts(&self, _k: usize) -> Vec<String> {
        vec![format!("draft_w{}", self.w), "verify_q1".into()]
    }

    fn ngram_order(&self) -> usize {
        0
    }

    fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
        DraftPlan::steps(ctx.k)
    }

    /// One dense q=1 pass over the slots that just drafted, then refresh
    /// each slot's critical tokens from the exact score dump.
    fn after_draft(
        &mut self,
        host: &mut DraftHost,
        slots: &mut [Option<Slot>],
        idxs: &[usize],
    ) -> Result<u32> {
        if idxs.is_empty() {
            return Ok(0);
        }
        let m = host.m;
        let mut toks = vec![0i32; m.slots];
        let mut opos = vec![0i32; m.slots];
        let qv = vec![1i32; m.slots];
        let mut act = vec![0i32; m.slots];
        for &i in idxs {
            let slot = slots[i].as_ref().expect("oracle slot vanished");
            // re-feed the token we just wrote, at its own position
            toks[i] = slot.pending;
            opos[i] = (slot.len - 1) as i32;
            act[i] = 1;
        }
        host.runner.verify(1, &toks, &opos, &qv, &act)?;
        let t_dim = m.max_seq;
        let per = m.layers * m.kv_heads * t_dim;
        let t_sel = Instant::now();
        let pool = host.pool;
        for &i in idxs {
            let slot = slots[i].as_mut().unwrap();
            let dump = &host.runner.dump()[i * per..(i + 1) * per];
            let len = slot.len;
            slot.pillar.refresh_parallel(dump, t_dim, len, pool);
        }
        host.runner
            .stats
            .note_host("pillar_select", t_sel.elapsed().as_secs_f64());
        host.comp.attn_bytes +=
            idxs.len() * slots[idxs[0]].as_ref().map(|s| s.len).unwrap_or(0) * m.kv_bytes_per_token();
        Ok(1)
    }
}

/// vLLM-NGram: longest-suffix n-gram lookup over the request's own
/// history — host-only, no draft-model pass at all.
pub struct NGramDrafter {
    pub n: usize,
}

impl Drafter for NGramDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::NGram { n: self.n }
    }

    fn mode(&self) -> DraftMode {
        DraftMode::Proposal
    }

    fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::pillar(m.draft_budget) // constructed, never composed
    }

    fn ngram_order(&self) -> usize {
        self.n
    }

    fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
        let kk = ctx.k.min(ctx.remaining.max(1));
        let props = ctx.ngram.map(|ix| ix.propose(kk)).unwrap_or_default();
        DraftPlan::proposals(props)
    }
}

/// EAGLE-like trained draft head (Fig. 11): k sequential head calls,
/// batched across every slot that needs proposals.
pub struct EagleDrafter;

impl Drafter for EagleDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::Eagle
    }

    fn mode(&self) -> DraftMode {
        DraftMode::Proposal
    }

    fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::pillar(m.draft_budget) // constructed, never composed
    }

    fn artifacts(&self, _k: usize) -> Vec<String> {
        vec!["eagle".into()]
    }

    fn ngram_order(&self) -> usize {
        0
    }

    /// Drafts through `propose_batch` (needs the head artifact); the
    /// host-free path proposes nothing.
    fn plan(&mut self, _ctx: &DraftCtx) -> DraftPlan {
        DraftPlan::proposals(Vec::new())
    }

    fn propose_batch(
        &mut self,
        host: &mut DraftHost,
        slots: &mut [Option<Slot>],
        idxs: &[usize],
    ) -> Result<u32> {
        if idxs.is_empty() {
            return Ok(0);
        }
        let m = host.m;
        let ectx = host.eagle_ctx;
        let k = host.k;
        // k sequential head calls, batched across slots.
        let mut ctxs: Vec<Vec<i32>> = vec![vec![0; ectx]; m.slots];
        for &i in idxs {
            let slot = slots[i].as_ref().expect("eagle slot vanished");
            let full = slot.full_context();
            let tail = &full[full.len().saturating_sub(ectx)..];
            let mut c = vec![0i32; ectx];
            c[ectx - tail.len()..].copy_from_slice(tail);
            ctxs[i] = c;
        }
        let mut proposals: Vec<Vec<i32>> = vec![Vec::new(); m.slots];
        let mut launches = 0u32;
        for _ in 0..k {
            let flat: Vec<i32> = ctxs.iter().flatten().copied().collect();
            host.runner.eagle(&flat)?;
            launches += 1;
            for &i in idxs {
                let row = &host.runner.logits()[i * m.vocab..(i + 1) * m.vocab];
                let t = sampling::argmax(row) as i32;
                proposals[i].push(t);
                ctxs[i].rotate_left(1);
                let last = ctxs[i].len() - 1;
                ctxs[i][last] = t;
            }
        }
        host.comp.gemm_rows += idxs.len(); // head rows are tiny
        let t = Instant::now();
        for &i in idxs {
            let slot = slots[i].as_mut().unwrap();
            let kk = k.min(slot.remaining().max(1));
            let props = proposals[i][..kk].to_vec();
            set_proposals(slot, props, m.vocab);
        }
        *host.cpu_s += t.elapsed().as_secs_f64();
        Ok(launches)
    }
}

/// TriForce-like hierarchy: n-gram chunk proposals corrected by the
/// sparse-window model (`sparse_verify` artifact), then verified densely
/// like everyone else.
pub struct TriForceDrafter {
    pub w: usize,
}

impl Drafter for TriForceDrafter {
    fn kind(&self) -> DrafterKind {
        DrafterKind::TriForce { w: self.w }
    }

    fn mode(&self) -> DraftMode {
        DraftMode::Proposal
    }

    fn index_policy(&self, _m: &ModelConfig) -> IndexPolicy {
        IndexPolicy::window(self.w)
    }

    fn artifacts(&self, _k: usize) -> Vec<String> {
        vec!["sparse_verify".into()]
    }

    fn validate_engine(&self, m: &ModelConfig, k: usize) -> Result<()> {
        // sparse_verify is compiled for exactly (draft_budget, spec_k).
        if k != m.spec_k {
            bail!(
                "TriForce k={k} must match the sparse_verify artifact's k={}",
                m.spec_k
            );
        }
        Ok(())
    }

    /// Drafts through `propose_batch` (needs the sparse middle layer);
    /// the host-free path proposes nothing.
    fn plan(&mut self, _ctx: &DraftCtx) -> DraftPlan {
        DraftPlan::proposals(Vec::new())
    }

    fn propose_batch(
        &mut self,
        host: &mut DraftHost,
        slots: &mut [Option<Slot>],
        idxs: &[usize],
    ) -> Result<u32> {
        if idxs.is_empty() {
            return Ok(0);
        }
        let m = host.m;
        let w = self.w;
        let k = host.k;
        let q = k + 1;
        let t = Instant::now();
        let mut tokens = vec![0i32; m.slots * q];
        let mut pos = vec![0i32; m.slots];
        let mut qv = vec![1i32; m.slots];
        let mut idx_buf = vec![0i32; m.slots * m.layers * m.kv_heads * w];
        let mut active = vec![0i32; m.slots];
        let mut props: Vec<Vec<i32>> = vec![Vec::new(); m.slots];
        for &i in idxs {
            let slot = slots[i].as_ref().expect("triforce slot vanished");
            // level-1: n-gram chunk proposal
            let mut p = slot.ngram.propose(k);
            if p.is_empty() {
                // no match: degenerate to the window model's own
                // prediction chain (propose anchor continuation)
                p = vec![slot.pending; 1];
            }
            p.truncate(k);
            tokens[i * q] = slot.pending;
            for (j, &pt) in p.iter().enumerate() {
                tokens[i * q + 1 + j] = pt;
            }
            qv[i] = (1 + p.len()) as i32;
            pos[i] = slot.len as i32;
            let per_slot = m.layers * m.kv_heads * w;
            let base = i * per_slot;
            slot.pillar
                .compose_into(&mut idx_buf[base..base + per_slot], slot.len + q);
            active[i] = 1;
            props[i] = p;
        }
        *host.cpu_s += t.elapsed().as_secs_f64();
        host.comp.gemm_rows += idxs.len() * q;
        host.comp.attn_bytes += idxs.len() * w * m.kv_bytes_per_token();
        host.runner
            .sparse_verify(&tokens, &pos, &qv, &idx_buf, &active)?;

        let t = Instant::now();
        for &i in idxs {
            let slot = slots[i].as_mut().unwrap();
            // middle layer: greedy-match proposals under the window
            // model; corrected draft = matched prefix + window pick.
            let v = m.vocab;
            let rows = &host.runner.logits()[i * q * v..(i + 1) * q * v];
            let mut mid: Vec<i32> = Vec::new();
            for (j, &pt) in props[i].iter().enumerate() {
                let e = sampling::argmax(&rows[j * v..(j + 1) * v]) as i32;
                if e == pt {
                    mid.push(pt);
                } else {
                    mid.push(e);
                    break;
                }
            }
            // KV frontier: sparse_verify wrote qv rows, but only the
            // anchor row (and later the verified rows) matter — dense
            // verification overwrites everything it validates.
            let kk = k.min(slot.remaining().max(1));
            mid.truncate(kk);
            set_proposals(slot, mid, m.vocab);
        }
        *host.cpu_s += t.elapsed().as_secs_f64();
        Ok(1)
    }
}

// ---------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------

/// Constructor signature stored in the registry: build a drafter from its
/// parse-layer [`DrafterKind`] against the model/artifact shape.
pub type DrafterCtor = Box<dyn Fn(&DrafterKind, &ModelConfig) -> Result<Box<dyn Drafter>>>;

/// Name → constructor table the engine resolves every drafter through —
/// the engine's plugin point.  [`DrafterRegistry::with_builtins`] carries
/// the seven paper drafters; [`DrafterRegistry::register`] adds
/// out-of-crate policies reachable via [`DrafterKind::Custom`] (or by
/// shadowing a builtin name).
pub struct DrafterRegistry {
    ctors: BTreeMap<String, DrafterCtor>,
}

impl Default for DrafterRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

impl DrafterRegistry {
    /// An empty registry (no names resolve).
    pub fn empty() -> DrafterRegistry {
        DrafterRegistry { ctors: BTreeMap::new() }
    }

    /// The seven builtin drafters under their canonical root names.
    pub fn with_builtins() -> DrafterRegistry {
        let mut r = DrafterRegistry::empty();
        r.register("vanilla", |_, _| Ok(Box::new(VanillaDrafter)));
        r.register("pillar", |kind, _| match *kind {
            DrafterKind::Pillar { w } => Ok(Box::new(PillarDrafter { w })),
            _ => bail!("pillar constructor got {kind:?}"),
        });
        r.register("window", |kind, _| match *kind {
            DrafterKind::Window { w } => Ok(Box::new(WindowDrafter { w })),
            _ => bail!("window constructor got {kind:?}"),
        });
        r.register("oracle", |kind, _| match *kind {
            DrafterKind::OracleTopK { w } => Ok(Box::new(OracleDrafter { w })),
            _ => bail!("oracle constructor got {kind:?}"),
        });
        r.register("ngram", |kind, _| match *kind {
            DrafterKind::NGram { n } => Ok(Box::new(NGramDrafter { n })),
            _ => bail!("ngram constructor got {kind:?}"),
        });
        r.register("eagle", |_, _| Ok(Box::new(EagleDrafter)));
        r.register("triforce", |kind, _| match *kind {
            DrafterKind::TriForce { w } => Ok(Box::new(TriForceDrafter { w })),
            _ => bail!("triforce constructor got {kind:?}"),
        });
        r
    }

    /// Register (or shadow) a constructor under `name`.
    pub fn register<F>(&mut self, name: &str, ctor: F)
    where
        F: Fn(&DrafterKind, &ModelConfig) -> Result<Box<dyn Drafter>> + 'static,
    {
        self.ctors.insert(name.to_string(), Box::new(ctor));
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.ctors.keys().map(|s| s.as_str()).collect()
    }

    /// Resolve a kind to a live drafter: validate its parameters against
    /// the model/artifact shape, then run the registered constructor.
    pub fn create(&self, kind: &DrafterKind, m: &ModelConfig) -> Result<Box<dyn Drafter>> {
        validate_drafter(kind, m)?;
        let key = kind.registry_key();
        let Some(ctor) = self.ctors.get(key) else {
            bail!(
                "no drafter registered under '{key}' (registered: {:?})",
                self.names()
            );
        };
        ctor(kind, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    fn model() -> ModelConfig {
        SystemConfig::synthetic("artifacts").model
    }

    #[test]
    fn builtins_resolve_all_seven_kinds() {
        let r = DrafterRegistry::with_builtins();
        let m = model();
        for (kind, mode) in [
            (DrafterKind::Vanilla, DraftMode::Off),
            (DrafterKind::Pillar { w: 64 }, DraftMode::SelfSpec),
            (DrafterKind::Window { w: 64 }, DraftMode::SelfSpec),
            (DrafterKind::OracleTopK { w: 64 }, DraftMode::SelfSpec),
            (DrafterKind::NGram { n: 3 }, DraftMode::Proposal),
            (DrafterKind::Eagle, DraftMode::Proposal),
            (DrafterKind::TriForce { w: 64 }, DraftMode::Proposal),
        ] {
            let d = r.create(&kind, &m).unwrap();
            assert_eq!(d.kind(), kind);
            assert_eq!(d.mode(), mode, "{kind:?}");
            assert_eq!(d.name(), kind.name());
        }
    }

    #[test]
    fn capabilities_match_the_enum_interpreter() {
        // The capability surface must reproduce exactly what the old
        // match-on-DrafterKind engine hardwired.
        let r = DrafterRegistry::with_builtins();
        let m = model();
        let d = r.create(&DrafterKind::Pillar { w: 64 }, &m).unwrap();
        assert!(d.wants_dump_refresh());
        assert_eq!(d.artifacts(8), vec!["draft_w64".to_string()]);
        assert_eq!(d.index_policy(&m).recent, IndexPolicy::pillar(64).recent);

        let d = r.create(&DrafterKind::Window { w: 128 }, &m).unwrap();
        assert!(!d.wants_dump_refresh());
        let p = d.index_policy(&m);
        assert_eq!(p.sinks + p.recent, 128, "window policy must be pure window");

        let d = r.create(&DrafterKind::OracleTopK { w: 32 }, &m).unwrap();
        assert_eq!(
            d.artifacts(8),
            vec!["draft_w32".to_string(), "verify_q1".to_string()]
        );

        let d = r.create(&DrafterKind::TriForce { w: 64 }, &m).unwrap();
        assert_eq!(d.artifacts(8), vec!["sparse_verify".to_string()]);
        assert!(d.validate_engine(&m, 8).is_ok());
        assert!(d.validate_engine(&m, 4).is_err(), "k must match spec_k");

        let d = r.create(&DrafterKind::NGram { n: 2 }, &m).unwrap();
        assert_eq!(d.ngram_order(), 2);

        // drafters that never propose from history keep order-0 (inert)
        // n-gram state, so accepted tokens don't pay indexing costs
        for kind in [
            DrafterKind::Vanilla,
            DrafterKind::Pillar { w: 64 },
            DrafterKind::Window { w: 64 },
            DrafterKind::OracleTopK { w: 64 },
            DrafterKind::Eagle,
        ] {
            assert_eq!(r.create(&kind, &m).unwrap().ngram_order(), 0, "{kind:?}");
        }
        let d = r.create(&DrafterKind::TriForce { w: 64 }, &m).unwrap();
        assert!(d.ngram_order() >= 1, "TriForce consumes n-gram history");
    }

    #[test]
    fn degenerate_params_rejected_with_actionable_errors() {
        let m = model();
        // one assertion per rejection class (satellite contract)
        let e = validate_drafter(&DrafterKind::NGram { n: 0 }, &m).unwrap_err();
        assert!(e.to_string().contains("n >= 1"), "{e}");
        let e = validate_drafter(&DrafterKind::NGram { n: 9 }, &m).unwrap_err();
        assert!(e.to_string().contains("<= 4"), "{e}");
        let e = validate_drafter(&DrafterKind::Window { w: 0 }, &m).unwrap_err();
        assert!(e.to_string().contains("degenerate"), "{e}");
        let e = validate_drafter(&DrafterKind::Pillar { w: 4 }, &m).unwrap_err();
        assert!(e.to_string().contains("W >= 8"), "{e}");
        let e = validate_drafter(&DrafterKind::Pillar { w: 100 }, &m).unwrap_err();
        assert!(e.to_string().contains("draft_w100"), "{e}");
        let e = validate_drafter(&DrafterKind::TriForce { w: 128 }, &m).unwrap_err();
        assert!(e.to_string().contains("sparse_verify"), "{e}");
        let e = validate_drafter(&DrafterKind::TriForce { w: 0 }, &m).unwrap_err();
        assert!(e.to_string().contains("degenerate"), "{e}");
        // registry create runs the same validation
        let r = DrafterRegistry::with_builtins();
        assert!(r.create(&DrafterKind::NGram { n: 0 }, &m).is_err());
    }

    #[test]
    fn unknown_names_fail_with_the_registered_list() {
        let r = DrafterRegistry::with_builtins();
        let m = model();
        let e = r
            .create(&DrafterKind::Custom { name: "nope" }, &m)
            .unwrap_err();
        assert!(e.to_string().contains("nope"));
        assert!(e.to_string().contains("pillar"), "error should list names");
    }

    #[test]
    fn custom_registration_resolves() {
        struct Fixed;
        impl Drafter for Fixed {
            fn kind(&self) -> DrafterKind {
                DrafterKind::Custom { name: "fixed" }
            }
            fn mode(&self) -> DraftMode {
                DraftMode::Proposal
            }
            fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
                IndexPolicy::pillar(m.draft_budget)
            }
            fn plan(&mut self, _ctx: &DraftCtx) -> DraftPlan {
                DraftPlan::proposals(vec![7, 7])
            }
        }
        let mut r = DrafterRegistry::with_builtins();
        r.register("fixed", |_, _| Ok(Box::new(Fixed)));
        let m = model();
        let mut d = r.create(&DrafterKind::Custom { name: "fixed" }, &m).unwrap();
        assert_eq!(d.name(), "fixed");
        let ctx = DraftCtx {
            req_id: 1,
            slot_idx: 0,
            k: 8,
            sched_cap: 8,
            len: 10,
            remaining: 5,
            pending: 3,
            first_round: true,
            ngram: None,
        };
        assert_eq!(d.plan(&ctx).tokens, vec![7, 7]);
    }

    #[test]
    fn plan_sizes_static_drafters_at_k() {
        let m = model();
        let r = DrafterRegistry::with_builtins();
        let ctx = DraftCtx {
            req_id: 0,
            slot_idx: 0,
            k: 8,
            sched_cap: 3,
            len: 40,
            remaining: 100,
            pending: 5,
            first_round: true,
            ngram: None,
        };
        for kind in [
            DrafterKind::Pillar { w: 64 },
            DrafterKind::Window { w: 64 },
            DrafterKind::OracleTopK { w: 64 },
        ] {
            let mut d = r.create(&kind, &m).unwrap();
            // static self-spec drafters always ask for the ceiling; the
            // engine clamps to sched_cap (bucket alignment) afterwards
            assert_eq!(d.plan(&ctx).target, 8, "{kind:?}");
        }
        let mut v = r.create(&DrafterKind::Vanilla, &m).unwrap();
        assert_eq!(v.plan(&ctx).target, 0);
    }
}
