//! Rust port of the synthetic reasoning-trace grammar
//! (`python/compile/data.py`) — MUST stay bit-identical to the Python
//! generator; `python/tests/test_data.py` and `grammar_golden` below pin
//! both sides to the same token stream for the same seed.

use crate::model::GrammarConfig;
use crate::util::rng::SplitMix64;
use std::collections::BTreeMap;

/// Stateful generator of one reasoning trace (header of definitions, then
/// query / redefinition / filler blocks).
pub struct TraceGen {
    pub g: GrammarConfig,
    rng: SplitMix64,
    slots: BTreeMap<i32, i32>,
    focus: Option<i32>,
    buf: std::collections::VecDeque<i32>,
}

impl TraceGen {
    pub fn new(seed: u64, g: GrammarConfig) -> Self {
        let mut t = TraceGen {
            g,
            rng: SplitMix64::new(seed),
            slots: BTreeMap::new(),
            focus: None,
            buf: Default::default(),
        };
        t.emit_header();
        t
    }

    fn slot_tok(&self, i: i32) -> i32 {
        self.g.slot_base + i
    }

    fn val_tok(&self, i: i32) -> i32 {
        self.g.value_base + i
    }

    /// Successor of filler `t` at position `j` inside a mode-`mode` run.
    /// The j-dependence forces a local (mode + run-start) circuit rather
    /// than induction-style copying — see python GrammarConfig.filler_next.
    pub fn filler_next(g: &GrammarConfig, t: i32, mode: usize, j: i32) -> i32 {
        let i = t - g.filler_base;
        g.filler_base + (i + g.mode_mul[mode] + j).rem_euclid(g.n_filler)
    }

    fn pick_focus(&mut self) {
        let keys: Vec<i32> = self.slots.keys().copied().collect();
        self.focus = Some(keys[self.rng.below(keys.len() as u64) as usize]);
    }

    fn emit_header(&mut self) {
        self.buf.push_back(self.g.bos);
        for _ in 0..self.g.n_defs {
            let s = self.rng.below(self.g.n_slots as u64) as i32;
            let v = self.rng.below(self.g.n_values as u64) as i32;
            self.slots.insert(s, v);
            let (st, vt) = (self.slot_tok(s), self.val_tok(v));
            self.buf.extend([self.g.def_tok, st, vt, self.g.sep]);
        }
    }

    fn emit_block(&mut self) {
        let r = self.rng.unit();
        if r < self.g.query_prob && !self.slots.is_empty() {
            // Queries dwell on the focus slot (temporal locality of the
            // critical definition), occasionally probing another slot.
            // Python iterates sorted(slots.keys()); BTreeMap is sorted too.
            if self.focus.map(|f| !self.slots.contains_key(&f)).unwrap_or(true) {
                self.pick_focus();
            }
            let s = if self.rng.unit() < self.g.focus_query_prob {
                self.focus.unwrap()
            } else {
                let keys: Vec<i32> = self.slots.keys().copied().collect();
                keys[self.rng.below(keys.len() as u64) as usize]
            };
            let v = self.slots[&s];
            let (qt, st, et, vt, sep) = (
                self.g.qry,
                self.slot_tok(s),
                self.g.eq,
                self.val_tok(v),
                self.g.sep,
            );
            self.buf.extend([qt, st, et, vt, sep]);
            if self.rng.unit() < self.g.focus_switch_prob {
                self.pick_focus();
            }
        } else if r < self.g.query_prob + self.g.redefine_prob {
            let s = self.rng.below(self.g.n_slots as u64) as i32;
            let v = self.rng.below(self.g.n_values as u64) as i32;
            self.slots.insert(s, v);
            let (dt, st, vt, sep) =
                (self.g.def_tok, self.slot_tok(s), self.val_tok(v), self.g.sep);
            self.buf.extend([dt, st, vt, sep]);
        } else {
            let m = self.rng.below(self.g.n_modes as u64) as usize;
            let mut f = self.g.filler_base + self.rng.below(self.g.n_filler as u64) as i32;
            let run = 3 + self.rng.below(6);
            self.buf.push_back(self.g.mode_base + m as i32);
            for j in 0..run {
                self.buf.push_back(f);
                f = Self::filler_next(&self.g, f, m, j as i32);
            }
        }
    }

    /// Next `n` tokens of the trace.
    pub fn take(&mut self, n: usize) -> Vec<i32> {
        while self.buf.len() < n {
            self.emit_block();
        }
        self.buf.drain(..n).collect()
    }

    /// A serving prompt (definition header + a couple of body blocks),
    /// capped at 32 tokens.  Mirrors python `data.prompt`.
    pub fn prompt(seed: u64, g: GrammarConfig) -> Vec<i32> {
        let mut gen = TraceGen::new(seed, g);
        let n = (1 + 4 * gen.g.n_defs) as usize;
        while gen.buf.len() < n + 8 {
            gen.emit_block();
        }
        let take = gen.buf.len().min(32);
        gen.take(take)
    }
}

/// Grammar-aware next-token predictability classes, used by analysis
/// benches (Fig. 4 companion) to label positions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenClass {
    /// Deterministic given local context (filler chain, EQ after slot, ...).
    Local,
    /// Requires a long-range lookup (value after `QRY slot EQ`).
    Lookup,
    /// Genuinely random (new slot choices, new values, block starts).
    Random,
}

/// Classify the next-token prediction problem at position i of `toks`
/// (predicting toks[i+1]) — a grammar-level oracle used in tests/benches.
pub fn classify_next(g: &GrammarConfig, toks: &[i32], i: usize) -> TokenClass {
    let t = toks[i];
    let is_filler = |x: i32| x >= g.filler_base && x < g.filler_base + g.n_filler;
    if t >= g.mode_base && t < g.mode_base + g.n_modes {
        return TokenClass::Random; // chain start is a free choice
    }
    if t == g.eq {
        // value after EQ: if preceding is QRY slot -> lookup; DEF -> random
        if i >= 2 && toks[i - 2] == g.qry {
            return TokenClass::Lookup;
        }
        return TokenClass::Random;
    }
    if is_filler(t) {
        return TokenClass::Local; // chain step is deterministic given mode
    }
    if t == g.qry || t == g.def_tok {
        return TokenClass::Random; // which slot — random
    }
    if t >= g.slot_base && t < g.slot_base + g.n_slots {
        return TokenClass::Local; // after slot comes EQ (qry) or value (def)
    }
    TokenClass::Random
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grammar() -> GrammarConfig {
        GrammarConfig {
            pad: 0,
            bos: 1,
            eos: 2,
            def_tok: 3,
            qry: 4,
            eq: 5,
            sep: 6,
            slot_base: 16,
            n_slots: 48,
            value_base: 80,
            n_values: 256,
            filler_base: 336,
            n_filler: 120,
            mode_base: 456,
            n_modes: 12,
            n_defs: 8,
            redefine_prob: 0.08,
            query_prob: 0.30,
            focus_query_prob: 0.85,
            focus_switch_prob: 0.18,
            mode_mul: vec![1, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43],
            mode_add: vec![3, 8, 1, 14, 5, 11, 2, 7, 9, 4, 13, 6],
        }
    }

    #[test]
    fn header_shape() {
        let mut t = TraceGen::new(7, grammar());
        let toks = t.take(33);
        assert_eq!(toks[0], 1); // BOS
        // 8 defs of the form DEF slot value SEP
        for d in 0..8 {
            let b = 1 + d * 4;
            assert_eq!(toks[b], 3, "def tok at block {d}");
            assert!(toks[b + 1] >= 16 && toks[b + 1] < 64);
            assert!(toks[b + 2] >= 80 && toks[b + 2] < 336);
            assert_eq!(toks[b + 3], 6);
        }
    }

    /// Golden traces pinned against python/compile/data.py (see
    /// python/tests/test_data.py which asserts the same values).
    #[test]
    fn grammar_golden_cross_language() {
        let mut t = TraceGen::new(7, grammar());
        assert_eq!(
            t.take(24),
            vec![
                1, 3, 55, 108, 6, 3, 34, 283, 6, 3, 26, 97, 6, 3, 38, 334, 6, 3,
                33, 185, 6, 3, 59, 124
            ]
        );
        let mut t = TraceGen::new(123, grammar());
        assert_eq!(
            t.take(12),
            vec![1, 3, 59, 204, 6, 3, 56, 335, 6, 3, 18, 96]
        );
    }

    #[test]
    fn queries_reference_defined_values() {
        let g = grammar();
        let mut t = TraceGen::new(123, g.clone());
        let toks = t.take(400);
        // Scan QRY slot EQ value SEP patterns; the value must equal the
        // most recent definition of that slot.
        let mut defs = std::collections::HashMap::new();
        let mut i = 0;
        let mut queries = 0;
        while i + 4 < toks.len() {
            if toks[i] == g.def_tok {
                defs.insert(toks[i + 1], toks[i + 2]);
                i += 4;
            } else if toks[i] == g.qry {
                let (slot, val) = (toks[i + 1], toks[i + 3]);
                assert_eq!(toks[i + 2], g.eq);
                if let Some(&v) = defs.get(&slot) {
                    assert_eq!(val, v, "query must return latest definition");
                    queries += 1;
                }
                i += 5;
            } else {
                i += 1;
            }
        }
        assert!(queries >= 3, "trace should contain several queries");
    }

    #[test]
    fn filler_chain_deterministic_per_mode_and_position() {
        let g = grammar();
        let f0 = 336;
        assert_eq!(TraceGen::filler_next(&g, f0, 0, 0), 336 + 1);
        // different modes give different successors
        let succ: std::collections::HashSet<i32> = (0..g.n_modes as usize)
            .map(|m| TraceGen::filler_next(&g, f0 + 5, m, 0))
            .collect();
        assert!(succ.len() > 8, "modes should induce distinct chains");
        // the position inside the run matters (anti-induction property)
        assert_ne!(
            TraceGen::filler_next(&g, f0, 0, 0),
            TraceGen::filler_next(&g, f0, 0, 1)
        );
        for &f in &succ {
            assert!(f >= g.filler_base && f < g.filler_base + g.n_filler);
        }
    }

    #[test]
    fn prompt_is_bounded_and_deterministic() {
        let g = grammar();
        let p1 = TraceGen::prompt(5, g.clone());
        let p2 = TraceGen::prompt(5, g.clone());
        assert_eq!(p1, p2);
        assert!(p1.len() <= 32 && p1.len() >= 16);
        assert_eq!(p1[0], g.bos);
    }

    #[test]
    fn classifier_labels_filler_local() {
        let g = grammar();
        let toks = vec![336, TraceGen::filler_next(&g, 336, 0, 0)];
        assert_eq!(classify_next(&g, &toks, 0), TokenClass::Local);
        let toks2 = vec![g.mode_base + 2, 340];
        assert_eq!(classify_next(&g, &toks2, 0), TokenClass::Random);
    }

    #[test]
    fn queries_dwell_on_focus() {
        // With focus_query_prob=0.85, consecutive queries should mostly
        // target the same slot (the temporal-locality property PillarAttn
        // relies on).
        let g = grammar();
        let mut t = TraceGen::new(5, g.clone());
        let toks = t.take(3000);
        let mut qslots = Vec::new();
        let mut i = 0;
        while i + 4 < toks.len() {
            if toks[i] == g.qry {
                qslots.push(toks[i + 1]);
                i += 5;
            } else {
                i += 1;
            }
        }
        assert!(qslots.len() > 20);
        let same: usize = qslots.windows(2).filter(|w| w[0] == w[1]).count();
        let frac = same as f64 / (qslots.len() - 1) as f64;
        assert!(frac > 0.5, "focus locality too weak: {frac}");
    }
}
