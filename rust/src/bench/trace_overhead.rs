//! `trace_overhead` — span-journal cost on the engine iteration path.
//!
//! The tentpole promise of the trace module is that it is effectively free
//! when disabled and cheap when enabled.  Two measurements:
//!
//! 1. **Micro**: per-callsite-group latency (one begin/end pair plus one
//!    gated instant — the shape a phase emits) against a disabled tracer
//!    (config-flag branch only) and an enabled one (ring push + wall-clock
//!    read + arg vec).
//! 2. **End-to-end**: paired engine runs over the identical workload with
//!    tracing off and on.  Outputs must be bit-identical (observability
//!    never perturbs generation), and the off-run's per-iteration
//!    wallclock anchors the extrapolated ratios.
//!
//! Gates (enforced after saving, like `drafter_dispatch`): the tracer
//! cost extrapolated to a full iteration must stay under **1%** of an
//! engine iteration when disabled and under **5%** when enabled.  Emits
//! `reports/BENCH_trace_overhead.json`.

use super::BenchCtx;
use crate::engine::{Engine, EngineConfig};
use crate::spec::DrafterKind;
use crate::trace::{names, TraceConfig, Tracer, Track};
use crate::util::json::{num, obj, s as jstr};
use crate::workload::{Dataset, WorkloadGen};
use anyhow::Result;
use std::hint::black_box;
use std::time::Instant;

pub fn trace_overhead(ctx: &mut BenchCtx) -> Result<()> {
    println!("trace_overhead: span journal cost, disabled vs enabled");
    let reps = 200_000 * ctx.n_requests.max(1);

    // Micro: disabled tracer — what every engine callsite pays when
    // tracing is off (branch on the config flag; arg vecs are guarded at
    // the call sites, mirrored by the `enabled()` guard here).
    let mut off = Tracer::new(TraceConfig::default());
    off.iter_begin(1, 0.0);
    let t0 = Instant::now();
    for i in 0..reps {
        let sim = black_box(i as f64 * 1e-6);
        off.begin(names::DRAFT, Track::Engine, sim);
        off.end(names::DRAFT, Track::Engine, sim, Vec::new());
        if off.enabled() {
            off.instant(names::KV_ADMIT, Track::Kv, sim, vec![("req", (i as u64).into())]);
        }
    }
    let off_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
    anyhow::ensure!(off.is_empty(), "disabled tracer must journal nothing");

    // Micro: enabled tracer at full sampling (worst case: every event is
    // a ring push with a wall-clock read).
    let mut on = Tracer::new(TraceConfig::on());
    on.iter_begin(1, 0.0);
    let t0 = Instant::now();
    for i in 0..reps {
        let sim = black_box(i as f64 * 1e-6);
        on.begin(names::DRAFT, Track::Engine, sim);
        on.end(names::DRAFT, Track::Engine, sim, vec![("w", 64usize.into())]);
        if on.enabled() {
            on.instant(names::KV_ADMIT, Track::Kv, sim, vec![("req", (i as u64).into())]);
        }
    }
    let on_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
    println!(
        "  per callsite group (begin+end+instant): disabled {off_ns:.1}ns, \
         enabled {on_ns:.1}ns"
    );

    // End-to-end anchor: the same workload with tracing off and on.
    let rt = ctx.rt()?;
    let m = rt.cfg.model.clone();
    let n_req = ctx.n_requests.max(4);
    let mk_reqs = |seed: u64| {
        WorkloadGen::new(rt.cfg.grammar.clone(), m.clone(), Dataset::Aime, seed)
            .offline_batch(n_req)
    };
    let mut eng_off = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )?;
    let r_off = eng_off.run(mk_reqs(ctx.seed))?;
    let cfg_on = EngineConfig::builder(DrafterKind::Pillar { w: 64 })
        .k(8)
        .tracing(TraceConfig::on())
        .build(&m)?;
    let mut eng_on = Engine::new(rt.clone(), cfg_on)?;
    let r_on = eng_on.run(mk_reqs(ctx.seed))?;
    // Observability must never perturb generation.
    anyhow::ensure!(
        r_off.outputs == r_on.outputs,
        "tracing changed engine outputs (must be bit-identical)"
    );
    println!("  {}", r_off.summary());
    let iter_us = r_off.wall_s * 1e6 / r_off.iterations.max(1) as f64;
    let events_per_iter = eng_on.tracer().len() as f64 / r_on.iterations.max(1) as f64;

    // Callsite-group bound per iteration: the phase spans (iteration,
    // admit, one per draft-W group, one per proposal drafter, verify),
    // per-slot lifecycle/KV instants, four counters and the device-track
    // spans — comfortably under slots + 16 groups.
    let groups_per_iter = (m.slots + 16) as f64;
    let off_us_per_iter = off_ns * groups_per_iter / 1e3;
    let on_us_per_iter = on_ns * groups_per_iter / 1e3;
    let ratio_off = off_us_per_iter / iter_us.max(1e-9);
    let ratio_on = on_us_per_iter / iter_us.max(1e-9);
    println!(
        "  per-iteration: engine {iter_us:.1}us, tracer bound disabled \
         {off_us_per_iter:.4}us ({:.4}% — gate < 1%), enabled {on_us_per_iter:.3}us \
         ({:.3}% — gate < 5%), observed {events_per_iter:.1} events/iter",
        ratio_off * 100.0,
        ratio_on * 100.0
    );

    let json = obj(vec![
        ("experiment", jstr("trace_overhead")),
        ("harness", jstr("cargo bench -- trace_overhead")),
        ("group_disabled_ns", num(off_ns)),
        ("group_enabled_ns", num(on_ns)),
        ("engine_iter_us", num(iter_us)),
        ("groups_per_iter_bound", num(groups_per_iter)),
        ("events_per_iter_observed", num(events_per_iter)),
        ("overhead_ratio_disabled", num(ratio_off)),
        ("overhead_ratio_enabled", num(ratio_on)),
        ("outputs_bit_identical", num(1.0)),
    ]);
    ctx.save("BENCH_trace_overhead.json", &json.to_string())?;
    // Enforced after saving, so a regression still leaves evidence.
    anyhow::ensure!(
        ratio_off < 0.01,
        "trace_overhead gate failed: disabled tracing costs {:.3}% of an \
         engine iteration (need < 1%)",
        ratio_off * 100.0
    );
    anyhow::ensure!(
        ratio_on < 0.05,
        "trace_overhead gate failed: enabled tracing costs {:.3}% of an \
         engine iteration (need < 5%)",
        ratio_on * 100.0
    );
    Ok(())
}
