//! Step-function accounting types shared by every runtime backend.

use std::collections::BTreeMap;

/// Per-artifact cumulative timing, split into the three phases the paper's
/// Table 2 cares about: CPU marshalling (upload), device execution, fetch.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub per_artifact: BTreeMap<String, PhaseTimes>,
}

#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    pub calls: u64,
    pub upload_s: f64,
    pub exec_s: f64,
    pub fetch_s: f64,
}

impl StepStats {
    pub(crate) fn add(&mut self, name: &str, upload: f64, exec: f64, fetch: f64) {
        // Key interning happens once per artifact; the steady state takes
        // the `get_mut` path and never allocates the `String` key.  (Two
        // separate lookups rather than a `get_mut`-or-`entry` match — the
        // borrow checker rejects holding both mutable borrows.)
        if !self.per_artifact.contains_key(name) {
            self.per_artifact.insert(name.to_string(), PhaseTimes::default());
        }
        let e = self.per_artifact.get_mut(name).expect("inserted above");
        e.calls += 1;
        e.upload_s += upload;
        e.exec_s += exec;
        e.fetch_s += fetch;
    }

    /// Attribute host-side CPU work to a named pseudo-artifact (e.g.
    /// `pillar_select` for critical-token selection), so Table-2 style
    /// phase breakdowns and the delayed-verify overlap model see it.
    pub fn note_host(&mut self, name: &str, secs: f64) {
        self.add(name, secs, 0.0, 0.0);
    }

    pub fn total_exec(&self) -> f64 {
        self.per_artifact.values().map(|p| p.exec_s).sum()
    }

    pub fn total_cpu(&self) -> f64 {
        self.per_artifact
            .values()
            .map(|p| p.upload_s + p.fetch_s)
            .sum()
    }

    /// Per-artifact deltas against an earlier snapshot — the engine turns
    /// one iteration's worth of device activity into trace spans with
    /// this.  Artifacts untouched since `base` (zero new calls and no new
    /// host time) are omitted.
    pub fn delta_since(&self, base: &StepStats) -> Vec<(String, PhaseTimes)> {
        let mut out = Vec::new();
        for (name, cur) in &self.per_artifact {
            let zero = PhaseTimes::default();
            let b = base.per_artifact.get(name).unwrap_or(&zero);
            let d = PhaseTimes {
                calls: cur.calls.saturating_sub(b.calls),
                upload_s: (cur.upload_s - b.upload_s).max(0.0),
                exec_s: (cur.exec_s - b.exec_s).max(0.0),
                fetch_s: (cur.fetch_s - b.fetch_s).max(0.0),
            };
            if d.calls > 0 || d.upload_s + d.exec_s + d.fetch_s > 0.0 {
                out.push((name.clone(), d));
            }
        }
        out
    }
}

impl PhaseTimes {
    /// Total wall seconds across the three phases.
    pub fn total_s(&self) -> f64 {
        self.upload_s + self.exec_s + self.fetch_s
    }
}
