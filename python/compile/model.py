"""Layer-2: the Qwen3-shaped JAX model, calling the Layer-1 kernels.

Everything here is *build-time* Python.  `aot.py` lowers the step
functions below to HLO text once; the Rust coordinator then executes them
via PJRT with device-resident weights and KV buffers.  Python is never on
the request path.

Step-function contracts (argument order is the PJRT calling convention —
rust/src/runtime/artifacts.rs must match exactly):

  prefill(params, kv_k, kv_v, tokens[S,P], plen[S], active[S])
      -> (logits[S,V], kv_k', kv_v')
  draft(params, kv_k, kv_v, token[S], pos[S], idx[S,L,Hkv,W], active[S])
      -> (logits[S,V], kv_k', kv_v')
  verify(params, kv_k, kv_v, tokens[S,Q], pos[S], q_valid[S], active[S])
      -> (logits[S,Q,V], kv_k', kv_v', dump[S,L,Hkv,T])
  sparse_verify(params, kv_k, kv_v, tokens[S,Q], pos[S], q_valid[S],
                idx[S,L,Hkv,W], active[S])
      -> (logits[S,Q,V], kv_k', kv_v')            # TriForce middle layer
  kv_load(kv_k, kv_v, slot[1], rows_k[L,T,Hkv,D], rows_v[L,T,Hkv,D])
      -> (kv_k', kv_v')                           # host->device KV onload
  eagle(eparams, ctx[S,ECTX]) -> logits[S,V]      # EAGLE-like draft head

KV layout: kv_k/kv_v are f32[L, S, T, Hkv, D] — one device-resident pool
for all slots; the batch dimension IS the slot dimension (continuous
batching over fixed slots).  Inactive slots are masked via `active`.
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import kernels
from .config import MODEL, EAGLE


# --------------------------------------------------------------------------
# Parameter manifest: a single flat f32 vector (one device buffer in Rust).
# --------------------------------------------------------------------------

def param_shapes(cfg=MODEL):
    """Ordered (name, shape) list — the weights.bin layout contract."""
    shapes = [("embed", (cfg.vocab, cfg.hidden))]
    for l in range(cfg.layers):
        shapes += [
            (f"l{l}.ln1", (cfg.hidden,)),
            (f"l{l}.wq", (cfg.hidden, cfg.q_dim)),
            (f"l{l}.wk", (cfg.hidden, cfg.kv_dim)),
            (f"l{l}.wv", (cfg.hidden, cfg.kv_dim)),
            (f"l{l}.wo", (cfg.q_dim, cfg.hidden)),
            (f"l{l}.ln2", (cfg.hidden,)),
            (f"l{l}.wg", (cfg.hidden, cfg.ffn)),
            (f"l{l}.wu", (cfg.hidden, cfg.ffn)),
            (f"l{l}.wd", (cfg.ffn, cfg.hidden)),
        ]
    shapes.append(("ln_f", (cfg.hidden,)))
    return shapes


def n_params(cfg=MODEL):
    return sum(math.prod(s) for _, s in param_shapes(cfg))


def unpack(params, cfg=MODEL):
    """Flat f32[NP] -> dict of named arrays (static slicing; XLA folds it)."""
    out, off = {}, 0
    for name, shape in param_shapes(cfg):
        n = math.prod(shape)
        out[name] = params[off : off + n].reshape(shape)
        off += n
    return out


def init_params(key, cfg=MODEL):
    """He-style init, returned as the flat vector."""
    parts = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name == "embed" else 1.0 / math.sqrt(fan_in)
            parts.append((jax.random.normal(sub, shape) * std).reshape(-1))
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, w, eps=MODEL.rms_eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta=MODEL.rope_theta):
    """Rotary embedding. x: [..., P, H, D]; positions broadcastable [..., P]."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv        # [..., P, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape)


def _write_kv(cache, slot_rows, positions, active):
    """Scatter new KV rows into the per-layer cache.

    cache: [S, T, Hkv, D]; slot_rows: [S, Q, Hkv, D]; positions: [S, Q].
    Inactive slots / out-of-range positions are dropped (mode='drop').
    """
    S, Q = positions.shape
    T = cache.shape[1]
    pos_safe = jnp.where(active[:, None] > 0, positions, T)   # T => dropped
    s_ix = jnp.broadcast_to(jnp.arange(S)[:, None], (S, Q))
    return cache.at[s_ix, pos_safe].set(slot_rows, mode="drop")


def _mlp(pt, l, x):
    g = jax.nn.silu(x @ pt[f"l{l}.wg"])
    u = x @ pt[f"l{l}.wu"]
    return (g * u) @ pt[f"l{l}.wd"]


def _qkv(pt, l, x, positions, cfg):
    """x: [S, Q, H] -> q [S,Q,Hq,D] (roped), k/v [S,Q,Hkv,D] (k roped)."""
    S, Q, _ = x.shape
    q = (x @ pt[f"l{l}.wq"]).reshape(S, Q, cfg.q_heads, cfg.head_dim)
    k = (x @ pt[f"l{l}.wk"]).reshape(S, Q, cfg.kv_heads, cfg.head_dim)
    v = (x @ pt[f"l{l}.wv"]).reshape(S, Q, cfg.kv_heads, cfg.head_dim)
    q = rope(q, positions)
    k = rope(k, positions)
    return q, k, v


def _logits(pt, x):
    return rmsnorm(x, pt["ln_f"]) @ pt["embed"].T


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------

def _decode_core(pt, kv_k, kv_v, tokens, pos, active, attend, cfg, impl):
    """Shared trunk: embed -> L x (attn via `attend` + MLP) -> hidden.

    tokens: [S, Q]; pos: [S]; attend(l, q, kc, vc, positions) -> (out, extra).
    Returns (hidden [S,Q,H], kv_k', kv_v', extras list per layer).
    """
    S, Q = tokens.shape
    x = pt["embed"][tokens]                                    # [S, Q, H]
    positions = pos[:, None] + jnp.arange(Q)[None, :]          # [S, Q]
    extras = []
    for l in range(cfg.layers):
        h = rmsnorm(x, pt[f"l{l}.ln1"])
        q, k, v = _qkv(pt, l, h, positions, cfg)
        kc = _write_kv(kv_k[l], k, positions, active)
        vc = _write_kv(kv_v[l], v, positions, active)
        kv_k = kv_k.at[l].set(kc)
        kv_v = kv_v.at[l].set(vc)
        attn_out, extra = attend(l, q, kc, vc)
        extras.append(extra)
        x = x + attn_out.reshape(S, Q, cfg.q_dim) @ pt[f"l{l}.wo"]
        x = x + _mlp(pt, l, rmsnorm(x, pt[f"l{l}.ln2"]))
    return x, kv_k, kv_v, extras


def make_prefill(cfg=MODEL, impl="ref"):
    def prefill(params, kv_k, kv_v, tokens, plen, active):
        pt = unpack(params, cfg)
        S, P = tokens.shape
        pos0 = jnp.zeros((S,), jnp.int32)

        def attend(l, q, kc, vc):
            out, _, _ = kernels.full(q, kc, vc, pos0, plen, impl=impl)
            return out, None

        x, kv_k, kv_v, _ = _decode_core(
            pt, kv_k, kv_v, tokens, pos0, active, attend, cfg, impl
        )
        # logits at the last valid prompt position per slot
        last = jnp.clip(plen - 1, 0, P - 1)
        xl = x[jnp.arange(S), last]                            # [S, H]
        return _logits(pt, xl), kv_k, kv_v

    return prefill


def make_draft(cfg=MODEL, impl="ref"):
    def draft(params, kv_k, kv_v, token, pos, idx, active):
        pt = unpack(params, cfg)
        tokens = token[:, None]                                # [S, 1]

        def attend(l, q, kc, vc):
            return kernels.sparse(q, kc, vc, idx[:, l], pos, impl=impl), None

        x, kv_k, kv_v, _ = _decode_core(
            pt, kv_k, kv_v, tokens, pos, active, attend, cfg, impl
        )
        return _logits(pt, x[:, 0]), kv_k, kv_v

    return draft


def make_verify(cfg=MODEL, impl="ref"):
    def verify(params, kv_k, kv_v, tokens, pos, q_valid, active):
        pt = unpack(params, cfg)

        def attend(l, q, kc, vc):
            out, dump, _ = kernels.full(q, kc, vc, pos, q_valid, impl=impl)
            return out, dump

        x, kv_k, kv_v, dumps = _decode_core(
            pt, kv_k, kv_v, tokens, pos, active, attend, cfg, impl
        )
        dump = jnp.stack(dumps, axis=1)                        # [S, L, Hkv, T]
        return _logits(pt, x), kv_k, kv_v, dump

    return verify


def make_sparse_verify(cfg=MODEL, impl="ref"):
    """TriForce middle layer: verify candidate tokens *under the sparse
    (window) draft model* — multi-query sparse attention, no dump."""

    def sparse_verify(params, kv_k, kv_v, tokens, pos, q_valid, idx, active):
        pt = unpack(params, cfg)

        def attend(l, q, kc, vc):
            return kernels.sparse(q, kc, vc, idx[:, l], pos, impl=impl), None

        x, kv_k, kv_v, _ = _decode_core(
            pt, kv_k, kv_v, tokens, pos, active, attend, cfg, impl
        )
        return _logits(pt, x), kv_k, kv_v

    return sparse_verify


def make_kv_load(cfg=MODEL):
    def kv_load(kv_k, kv_v, slot, rows_k, rows_v):
        s = slot[0]
        kv_k = jax.lax.dynamic_update_slice(
            kv_k, rows_k[:, None], (0, s, 0, 0, 0)
        )
        kv_v = jax.lax.dynamic_update_slice(
            kv_v, rows_v[:, None], (0, s, 0, 0, 0)
        )
        return kv_k, kv_v

    return kv_load


# --------------------------------------------------------------------------
# EAGLE-like draft head (Fig. 11 baseline)
# --------------------------------------------------------------------------

def eagle_param_shapes(cfg=MODEL, e=EAGLE):
    return [
        ("emb", (cfg.vocab, e.embed)),
        ("w1", (e.ctx * e.embed, e.hidden)),
        ("b1", (e.hidden,)),
        ("w2", (e.hidden, e.hidden)),
        ("b2", (e.hidden,)),
        ("w3", (e.hidden, cfg.vocab)),
    ]


def eagle_n_params(cfg=MODEL, e=EAGLE):
    return sum(math.prod(s) for _, s in eagle_param_shapes(cfg, e))


def eagle_unpack(params, cfg=MODEL, e=EAGLE):
    out, off = {}, 0
    for name, shape in eagle_param_shapes(cfg, e):
        n = math.prod(shape)
        out[name] = params[off : off + n].reshape(shape)
        off += n
    return out


def eagle_init(key, cfg=MODEL, e=EAGLE):
    parts = []
    for name, shape in eagle_param_shapes(cfg, e):
        key, sub = jax.random.split(key)
        if name.startswith("b"):
            parts.append(jnp.zeros(shape, jnp.float32))
        else:
            std = 0.02 if name == "emb" else 1.0 / math.sqrt(shape[0])
            parts.append((jax.random.normal(sub, shape) * std).reshape(-1))
    return jnp.concatenate(parts)


def make_eagle(cfg=MODEL, e=EAGLE):
    def eagle(eparams, ctx):
        pt = eagle_unpack(eparams, cfg, e)
        S = ctx.shape[0]
        x = pt["emb"][ctx].reshape(S, e.ctx * e.embed)
        h = jax.nn.relu(x @ pt["w1"] + pt["b1"])
        h = jax.nn.relu(h @ pt["w2"] + pt["b2"])
        return h @ pt["w3"]

    return eagle


# --------------------------------------------------------------------------
# Training-time full forward (teacher forcing) — used only by train.py
# --------------------------------------------------------------------------

def make_train_forward(cfg=MODEL, with_attn_entropy=False):
    """Causal LM forward over [B, Lseq] without KV caches (dense training).

    When `with_attn_entropy` is set, also returns the mean attention
    entropy across layers/heads/queries.  Training penalises it lightly:
    large reasoning models concentrate attention mass on few tokens (the
    empirical basis of the paper's §3.2 sparsity claim); a ~0.7M-param
    model needs an explicit nudge to land in the same regime (DESIGN.md §1
    scale substitution).  Without it an occasional run learns a diffuse
    "averaging" layer whose output no small token budget can reproduce.
    """

    def fwd(params, tokens):
        pt = unpack(params, cfg)
        B, L = tokens.shape
        x = pt["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(L)[None, :], (B, L))
        mask = jnp.tril(jnp.ones((L, L), jnp.float32))
        neg = jnp.array(-1e30, jnp.float32)
        ent_sum = 0.0
        for l in range(cfg.layers):
            h = rmsnorm(x, pt[f"l{l}.ln1"])
            q, k, v = _qkv(pt, l, h, positions, cfg)
            kx = jnp.repeat(k, cfg.group, axis=2)
            vx = jnp.repeat(v, cfg.group, axis=2)
            lg = jnp.einsum("bqhd,bthd->bhqt", q, kx) / math.sqrt(cfg.head_dim)
            lg = jnp.where(mask[None, None] > 0, lg, neg)
            p = jax.nn.softmax(lg, axis=-1)
            if with_attn_entropy:
                ent = -jnp.sum(p * jnp.log(p + 1e-30), axis=-1)  # [B,H,Q]
                ent_sum = ent_sum + jnp.mean(ent)
            o = jnp.einsum("bhqt,bthd->bqhd", p, vx).reshape(B, L, cfg.q_dim)
            x = x + o @ pt[f"l{l}.wo"]
            x = x + _mlp(pt, l, rmsnorm(x, pt[f"l{l}.ln2"]))
        logits = _logits(pt, x)
        if with_attn_entropy:
            return logits, ent_sum / cfg.layers
        return logits

    return fwd
