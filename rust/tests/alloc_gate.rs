//! The zero-allocation invariant as a plain test: with the counting
//! allocator installed, a steady-state serial step loop (draft + dense
//! verify + sparse verify, every buffer arena-resident) must request no
//! new memory at all.  This is the same gate `cargo bench --
//! engine_iteration` enforces; having it as a test means plain `cargo
//! test` catches an allocation regression without running the bench.
//!
//! This file is its own test binary, so no other test binary can pollute
//! the allocation count; the tests *within* it serialise on [`GATE`]
//! because the counter is process-global.  Sim-backend only: the pjrt
//! runner allocates per device fetch by design.

#![cfg(not(feature = "pjrt"))]

#[global_allocator]
static ALLOC: sparsespec::util::alloc::CountingAlloc = sparsespec::util::alloc::CountingAlloc;

use std::rc::Rc;
use std::sync::Mutex;

use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::runtime::{ModelRunner, Runtime};
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::util::alloc;
use sparsespec::workload::Request;

/// Serialises the tests sharing the process-global allocation counter.
static GATE: Mutex<()> = Mutex::new(());

#[test]
fn serial_arena_step_loop_is_allocation_free() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Rc::new(Runtime::load(&dir).expect("runtime loads"));
    let m = rt.cfg.model.clone();
    let (s, pad) = (m.slots, m.prompt_pad);
    let q = m.spec_k + 1;
    let w = m.draft_budget;
    let per_head = m.layers * m.kv_heads;

    let active = vec![1i32; s];
    let ptokens: Vec<i32> = (0..s * pad).map(|i| (i % 97) as i32 + 1).collect();
    let plen = vec![pad as i32; s];
    let dtok: Vec<i32> = (0..s).map(|x| (x as i32 % 31) + 2).collect();
    let pos = vec![pad as i32; s];
    let vtok: Vec<i32> = (0..s * q).map(|i| (i % 89) as i32 + 1).collect();
    let qv = vec![q as i32; s];
    let idx: Vec<i32> = (0..s * per_head * w).map(|i| ((i * 13) % pad) as i32).collect();

    let mut r = ModelRunner::new(rt.clone()).unwrap();
    r.set_parallel(false);
    r.prefill(&ptokens, &plen, &active).unwrap();
    // Warmup: first calls may intern stats keys / size lazy state.
    for _ in 0..4 {
        r.draft(w, &dtok, &pos, &idx, &active).unwrap();
        r.verify(q, &vtok, &pos, &qv, &active).unwrap();
        r.sparse_verify(&vtok, &pos, &qv, &idx, &active).unwrap();
    }

    let base = alloc::allocations();
    assert!(base.is_some(), "counting allocator must be installed in this binary");
    for _ in 0..32 {
        r.draft(w, &dtok, &pos, &idx, &active).unwrap();
        r.verify(q, &vtok, &pos, &qv, &active).unwrap();
        r.sparse_verify(&vtok, &pos, &qv, &idx, &active).unwrap();
    }
    let n = alloc::allocations_since(base).expect("counter stays installed");
    assert_eq!(n, 0, "steady-state serial step loop allocated {n} time(s), expected 0");
}

/// The delayed-verify counterpart of the gate (ROADMAP item).  Delayed
/// mode cannot be allocation-*free*: each overlapped round spawns one
/// verify job per participating slot through `Promise::spawn_on` (a
/// channel, a boxed closure, a pool queue node) plus the job-owned input
/// copies — that is the price of the CPU/GPU overlap, and it is O(slots)
/// per round by construction, not per-token or per-context.  What this
/// test pins is exactly that bound: the deferred-verification queue
/// itself is pre-sized to the slot ceiling and drained capacity-
/// preserving (`collect_delayed`), so steady-state allocations stay under
/// a fixed per-job constant instead of growing with queue reallocation.
#[test]
fn delayed_verify_steady_state_allocations_are_bounded() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Rc::new(Runtime::load(&dir).expect("runtime loads"));
    let m = rt.cfg.model.clone();
    let slots = m.slots;

    let cfg = EngineConfig::new(DrafterKind::Pillar { w: m.draft_budget })
        .with_k(m.spec_k)
        .with_schedule(Schedule::parse("unified").expect("unified schedule parses"), true);
    let mut eng = Engine::new(rt.clone(), cfg).expect("engine builds");
    for i in 0..slots as u64 {
        eng.submit(Request {
            id: i,
            prompt: (0..16).map(|t| (t % 50) as i32 + 1).collect(),
            max_new: 400, // long enough that nobody retires mid-measurement
            arrival_s: 0.0,
            seed: 11 + i,
            drafter: None,
        });
    }
    // Warmup: fill the slots, run the first verify rounds, let lazy state
    // (stats keys, pool threads, scratch buffers) reach steady state.
    for _ in 0..12 {
        assert!(eng.step().expect("warmup step"), "work should remain during warmup");
    }

    let base = alloc::allocations();
    assert!(base.is_some(), "counting allocator must be installed in this binary");
    const STEPS: u64 = 20;
    for _ in 0..STEPS {
        assert!(eng.step().expect("measured step"), "work should remain while measuring");
    }
    let n = alloc::allocations_since(base).expect("counter stays installed");
    // Generous per-job constant (channel + closure + queue node + owned
    // input copies is well under this); what it must NOT absorb is any
    // per-round queue growth, which would scale with STEPS x reallocation.
    let bound = STEPS * slots as u64 * 64;
    assert!(
        n <= bound,
        "delayed-verify steady state allocated {n} times over {STEPS} steps \
         ({} slots); bound is {bound} — the deferred-verification queue is \
         likely growing instead of reusing its capacity",
        slots
    );
}
