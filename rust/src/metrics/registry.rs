//! Typed metrics registry: counters / gauges / histograms with labels,
//! snapshotable and mergeable across engine replicas.
//!
//! Replaces the ad-hoc `Metrics` string-map usage for serving-path
//! metrics.  Three series types with explicit merge semantics chosen so
//! that `merge_from` is **associative** (the fleet-rollup requirement,
//! pinned by `python/tests/test_trace_port.py`):
//!
//! * counters — sum
//! * gauges — last-write-wins (the merged-in value wins when present)
//! * histograms — sample concatenation
//!
//! Exports: Prometheus-style text exposition ([`MetricsRegistry::
//! expose_prometheus`]) and a deterministic markdown table
//! ([`MetricsRegistry::to_markdown`]) — both iterate `BTreeMap`s, so
//! output ordering is stable by construction.
//!
//! # Add your own metric
//!
//! ```
//! use sparsespec::metrics::MetricsRegistry;
//!
//! let mut reg = MetricsRegistry::new();
//! reg.inc("requests_done", &[], 1.0);
//! reg.inc("requests_done", &[("drafter", "pillar_w64")], 1.0);
//! reg.observe("ttft_s", &[("drafter", "pillar_w64")], 0.25);
//! reg.set_gauge("kv_used_tokens", &[], 4096.0);
//! let text = reg.expose_prometheus("sparsespec");
//! assert!(text.contains("sparsespec_requests_done{drafter=\"pillar_w64\"} 1"));
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::Histogram;

/// A metric identity: name + sorted label set.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl MetricKey {
    pub fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        MetricKey { name: name.to_string(), labels }
    }

    /// `name{k="v",...}` — the human/debug rendering (also used in
    /// markdown tables).
    pub fn render(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let mut out = format!("{}{{", self.name);
        for (i, (k, v)) in self.labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{k}=\"{}\"", escape_label(v));
        }
        out.push('}');
        out
    }
}

/// Sanitise a metric name to the Prometheus charset `[a-zA-Z0-9_:]`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect()
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Escape a string for the snapshot codec: backslash, the two structural
/// separators (tab, comma), `=`, and newline.
fn esc_field(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\t' => out.push_str("\\t"),
            '\n' => out.push_str("\\n"),
            ',' => out.push_str("\\c"),
            '=' => out.push_str("\\e"),
            c => out.push(c),
        }
    }
    out
}

fn unesc_field(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('t') => out.push('\t'),
            Some('n') => out.push('\n'),
            Some('c') => out.push(','),
            Some('e') => out.push('='),
            other => return Err(format!("bad escape \\{other:?}")),
        }
    }
    Ok(out)
}

/// Render `{k="v",...}` for exposition, with optional extra pairs
/// (the `quantile` label on summary lines).
fn label_block(labels: &[(String, String)], extra: &[(&str, &str)]) -> String {
    if labels.is_empty() && extra.is_empty() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", sanitize(k), escape_label(v)))
        .collect();
    parts.extend(extra.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))));
    format!("{{{}}}", parts.join(","))
}

fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Typed, labelled, mergeable metrics store.  See the module docs for
/// merge semantics; `snapshot()` is a deep copy safe to ship across
/// replica boundaries.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<MetricKey, f64>,
    gauges: BTreeMap<MetricKey, f64>,
    histograms: BTreeMap<MetricKey, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)], by: f64) {
        *self.counters.entry(MetricKey::new(name, labels)).or_insert(0.0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.gauges.insert(MetricKey::new(name, labels), v);
    }

    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.histograms
            .entry(MetricKey::new(name, labels))
            .or_default()
            .record(v);
    }

    /// Counter value for `name` with the given labels (0.0 if absent).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        self.counters
            .get(&MetricKey::new(name, labels))
            .copied()
            .unwrap_or(0.0)
    }

    /// Unlabelled-counter shorthand (the aggregate series).
    pub fn get(&self, name: &str) -> f64 {
        self.counter(name, &[])
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.gauges.get(&MetricKey::new(name, labels)).copied()
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<&Histogram> {
        self.histograms.get(&MetricKey::new(name, labels))
    }

    pub fn hist_mut(&mut self, name: &str, labels: &[(&str, &str)]) -> &mut Histogram {
        self.histograms
            .entry(MetricKey::new(name, labels))
            .or_default()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Deep copy of the current state (safe to merge elsewhere later).
    pub fn snapshot(&self) -> MetricsRegistry {
        self.clone()
    }

    /// Fold another registry in: counters sum, gauges last-write-wins
    /// (`other`'s value wins where present), histograms concatenate
    /// samples.  Associative by construction.
    pub fn merge_from(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Prometheus-style text exposition.  Counters and gauges one sample
    /// per key; histograms as summaries (p50/p99 quantiles + `_sum` /
    /// `_count`).  Deterministic: keys iterate in `BTreeMap` order.
    pub fn expose_prometheus(&self, prefix: &str) -> String {
        let mut out = String::new();
        let mut last_typed: Option<(String, &str)> = None;
        let mut type_line = |out: &mut String, full: &str, kind: &str| {
            if last_typed.as_ref().map(|(n, k)| (n.as_str(), *k)) != Some((full, kind)) {
                let _ = writeln!(out, "# TYPE {full} {kind}");
                last_typed = Some((full.to_string(), kind));
            }
        };
        for (k, v) in &self.counters {
            let full = format!("{}_{}", sanitize(prefix), sanitize(&k.name));
            type_line(&mut out, &full, "counter");
            let _ = writeln!(out, "{full}{} {}", label_block(&k.labels, &[]), fmt_value(*v));
        }
        for (k, v) in &self.gauges {
            let full = format!("{}_{}", sanitize(prefix), sanitize(&k.name));
            type_line(&mut out, &full, "gauge");
            let _ = writeln!(out, "{full}{} {}", label_block(&k.labels, &[]), fmt_value(*v));
        }
        for (k, h) in &self.histograms {
            let full = format!("{}_{}", sanitize(prefix), sanitize(&k.name));
            type_line(&mut out, &full, "summary");
            for (q, p) in [("0.5", 50.0), ("0.99", 99.0)] {
                let _ = writeln!(
                    out,
                    "{full}{} {}",
                    label_block(&k.labels, &[("quantile", q)]),
                    fmt_value(h.percentile(p))
                );
            }
            let _ = writeln!(out, "{full}_sum{} {}", label_block(&k.labels, &[]), fmt_value(h.sum()));
            let _ = writeln!(out, "{full}_count{} {}", label_block(&k.labels, &[]), h.len());
        }
        out
    }

    /// Lossless text serialisation of a snapshot, for shipping a
    /// registry across a replica boundary (`/snapshot` on the server's
    /// metrics endpoint, fetched by the router's fleet rollup).  The
    /// Prometheus exposition cannot serve this purpose: it renders
    /// histograms as quantile summaries, which do not merge.  This codec
    /// keeps the raw samples so `decode_text(encode_text(r))` is
    /// merge-equivalent to `r` — the router's one-merge rollup stays
    /// associative end to end.
    ///
    /// Line format (tab-separated, stable `BTreeMap` order):
    ///
    /// ```text
    /// sparsespec-metrics-snapshot v1
    /// c <name> <k=v,k2=v2|-> <value>
    /// g <name> <labels>      <value>
    /// h <name> <labels>      <s1,s2,...>
    /// ```
    ///
    /// Names, label keys and values are escaped (`\\`, tab, newline,
    /// `,`, `=`) so arbitrary tenant strings survive.  Floats use Rust's
    /// shortest round-trip `Display`.
    pub fn encode_text(&self) -> String {
        let mut out = String::from("sparsespec-metrics-snapshot v1\n");
        let labels = |k: &MetricKey| -> String {
            if k.labels.is_empty() {
                return "-".into();
            }
            k.labels
                .iter()
                .map(|(lk, lv)| format!("{}={}", esc_field(lk), esc_field(lv)))
                .collect::<Vec<_>>()
                .join(",")
        };
        for (k, v) in &self.counters {
            let _ = writeln!(out, "c\t{}\t{}\t{v}", esc_field(&k.name), labels(k));
        }
        for (k, v) in &self.gauges {
            let _ = writeln!(out, "g\t{}\t{}\t{v}", esc_field(&k.name), labels(k));
        }
        for (k, h) in &self.histograms {
            let samples: Vec<String> = h.samples().iter().map(|s| s.to_string()).collect();
            let _ = writeln!(
                out,
                "h\t{}\t{}\t{}",
                esc_field(&k.name),
                labels(k),
                samples.join(",")
            );
        }
        out
    }

    /// Inverse of [`encode_text`](Self::encode_text).  Total: malformed
    /// input returns a typed description, never panics.
    pub fn decode_text(text: &str) -> Result<MetricsRegistry, String> {
        let mut lines = text.lines();
        match lines.next() {
            Some("sparsespec-metrics-snapshot v1") => {}
            other => return Err(format!("bad snapshot header: {other:?}")),
        }
        let mut reg = MetricsRegistry::new();
        for (i, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let mut parts = line.splitn(4, '\t');
            let (kind, name, labels, payload) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(k), Some(n), Some(l), Some(p)) => (k, n, l, p),
                _ => return Err(format!("line {}: expected 4 tab-separated fields", i + 2)),
            };
            let name = unesc_field(name)?;
            let mut key = MetricKey { name, labels: Vec::new() };
            if labels != "-" {
                for pair in labels.split(',') {
                    let (lk, lv) = pair
                        .split_once('=')
                        .ok_or_else(|| format!("line {}: label without '='", i + 2))?;
                    key.labels.push((unesc_field(lk)?, unesc_field(lv)?));
                }
            }
            let parse = |s: &str| -> Result<f64, String> {
                s.parse::<f64>().map_err(|_| format!("line {}: bad float {s:?}", i + 2))
            };
            match kind {
                "c" => {
                    *reg.counters.entry(key).or_insert(0.0) += parse(payload)?;
                }
                "g" => {
                    reg.gauges.insert(key, parse(payload)?);
                }
                "h" => {
                    let h = reg.histograms.entry(key).or_default();
                    if !payload.is_empty() {
                        for s in payload.split(',') {
                            h.record(parse(s)?);
                        }
                    }
                }
                other => return Err(format!("line {}: unknown series kind {other:?}", i + 2)),
            }
        }
        Ok(reg)
    }

    /// Deterministic markdown rendering (sorted keys, fixed precision).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() || !self.gauges.is_empty() {
            let _ = writeln!(out, "| metric | type | value |\n|---|---|---|");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "| {} | counter | {:.4} |", k.render(), v);
            }
            for (k, v) in &self.gauges {
                let _ = writeln!(out, "| {} | gauge | {:.4} |", k.render(), v);
            }
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(
                out,
                "\n| histogram | n | mean | p50 | p99 | max |\n|---|---|---|---|---|---|"
            );
            for (k, h) in &self.histograms {
                let _ = writeln!(
                    out,
                    "| {} | {} | {:.4} | {:.4} | {:.4} | {:.4} |",
                    k.render(),
                    h.len(),
                    h.mean(),
                    h.percentile(50.0),
                    h.percentile(99.0),
                    h.max()
                );
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.inc("requests_done", &[], 3.0);
        r.inc("requests_done", &[("drafter", "pillar_w64")], 2.0);
        r.set_gauge("kv_used_tokens", &[], 128.0);
        r.observe("ttft_s", &[], 0.5);
        r.observe("ttft_s", &[], 1.5);
        r
    }

    #[test]
    fn labels_are_order_insensitive() {
        let a = MetricKey::new("x", &[("a", "1"), ("b", "2")]);
        let b = MetricKey::new("x", &[("b", "2"), ("a", "1")]);
        assert_eq!(a, b);
        let mut r = MetricsRegistry::new();
        r.inc("x", &[("a", "1"), ("b", "2")], 1.0);
        r.inc("x", &[("b", "2"), ("a", "1")], 1.0);
        assert_eq!(r.counter("x", &[("a", "1"), ("b", "2")]), 2.0);
    }

    #[test]
    fn merge_semantics_counter_gauge_histogram() {
        let mut a = sample();
        let mut b = MetricsRegistry::new();
        b.inc("requests_done", &[], 4.0);
        b.set_gauge("kv_used_tokens", &[], 64.0);
        b.observe("ttft_s", &[], 2.5);
        a.merge_from(&b);
        assert_eq!(a.get("requests_done"), 7.0);
        assert_eq!(a.gauge("kv_used_tokens", &[]), Some(64.0), "gauge LWW");
        assert_eq!(a.histogram("ttft_s", &[]).unwrap().len(), 3);
        // b untouched
        assert_eq!(b.get("requests_done"), 4.0);
    }

    #[test]
    fn merge_is_associative() {
        let mk = |c: f64, g: Option<f64>, h: &[f64]| {
            let mut r = MetricsRegistry::new();
            r.inc("c", &[], c);
            if let Some(g) = g {
                r.set_gauge("g", &[], g);
            }
            for &x in h {
                r.observe("h", &[], x);
            }
            r
        };
        let (a, b, c) = (mk(1.0, Some(10.0), &[1.0]), mk(2.0, None, &[2.0, 3.0]), mk(4.0, Some(30.0), &[]));
        // (a ⊕ b) ⊕ c
        let mut l = a.snapshot();
        l.merge_from(&b);
        l.merge_from(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.snapshot();
        bc.merge_from(&c);
        let mut r = a.snapshot();
        r.merge_from(&bc);
        assert_eq!(l.get("c"), r.get("c"));
        assert_eq!(l.gauge("g", &[]), r.gauge("g", &[]));
        assert_eq!(
            {
                let mut s = l.histogram("h", &[]).unwrap().samples();
                s.sort_by(f64::total_cmp);
                s
            },
            {
                let mut s = r.histogram("h", &[]).unwrap().samples();
                s.sort_by(f64::total_cmp);
                s
            }
        );
        assert_eq!(l.expose_prometheus("t"), r.expose_prometheus("t"));
    }

    #[test]
    fn prometheus_exposition_shape_and_determinism() {
        let r = sample();
        let text = r.expose_prometheus("sparsespec");
        assert!(text.contains("# TYPE sparsespec_requests_done counter"));
        assert!(text.contains("sparsespec_requests_done 3"));
        assert!(text.contains("sparsespec_requests_done{drafter=\"pillar_w64\"} 2"));
        assert!(text.contains("# TYPE sparsespec_kv_used_tokens gauge"));
        assert!(text.contains("sparsespec_ttft_s{quantile=\"0.5\"}"));
        assert!(text.contains("sparsespec_ttft_s_sum 2"));
        assert!(text.contains("sparsespec_ttft_s_count 2"));
        // deterministic across calls and across an equivalent rebuild
        assert_eq!(text, sample().expose_prometheus("sparsespec"));
    }

    #[test]
    fn name_sanitation_and_label_escaping() {
        let mut r = MetricsRegistry::new();
        r.inc("ttft_s[pillar]", &[("q", "a\"b")], 1.0);
        let text = r.expose_prometheus("x");
        assert!(text.contains("x_ttft_s_pillar_"), "bad chars mapped to _: {text}");
        assert!(text.contains("q=\"a\\\"b\""), "label value escaped: {text}");
    }

    #[test]
    fn snapshot_is_independent() {
        let mut r = sample();
        let snap = r.snapshot();
        r.inc("requests_done", &[], 100.0);
        r.observe("ttft_s", &[], 9.0);
        assert_eq!(snap.get("requests_done"), 3.0);
        assert_eq!(snap.histogram("ttft_s", &[]).unwrap().len(), 2);
    }

    #[test]
    fn snapshot_text_roundtrips_losslessly() {
        let mut r = sample();
        // hostile label values: the structural characters of the codec
        r.inc("evil", &[("k", "a,b=c\td\ne\\f")], 2.5);
        r.observe("empty_hist", &[], 1.0);
        let text = r.encode_text();
        let back = MetricsRegistry::decode_text(&text).unwrap();
        assert_eq!(back.encode_text(), text, "decode ∘ encode is identity");
        assert_eq!(back.get("requests_done"), 3.0);
        assert_eq!(back.counter("evil", &[("k", "a,b=c\td\ne\\f")]), 2.5);
        assert_eq!(back.gauge("kv_used_tokens", &[]), Some(128.0));
        assert_eq!(back.histogram("ttft_s", &[]).unwrap().samples(), vec![0.5, 1.5]);
    }

    #[test]
    fn snapshot_text_merge_equals_in_process_merge() {
        // the router's rollup path: encode on the replica, decode on the
        // router, merge — must equal merging the live registries
        let mut a = sample();
        let mut b = MetricsRegistry::new();
        b.inc("requests_done", &[], 4.0);
        b.observe("ttft_s", &[], 2.5);
        let mut via_wire = MetricsRegistry::decode_text(&a.encode_text()).unwrap();
        via_wire.merge_from(&MetricsRegistry::decode_text(&b.encode_text()).unwrap());
        a.merge_from(&b);
        assert_eq!(via_wire.encode_text(), a.encode_text());
        assert_eq!(via_wire.expose_prometheus("s"), a.expose_prometheus("s"));
    }

    #[test]
    fn snapshot_text_rejects_malformed() {
        assert!(MetricsRegistry::decode_text("").is_err(), "missing header");
        assert!(MetricsRegistry::decode_text("garbage v9\n").is_err());
        let hdr = "sparsespec-metrics-snapshot v1\n";
        assert!(MetricsRegistry::decode_text(&format!("{hdr}c\tx\t-")).is_err(), "3 fields");
        assert!(MetricsRegistry::decode_text(&format!("{hdr}q\tx\t-\t1")).is_err(), "bad kind");
        assert!(MetricsRegistry::decode_text(&format!("{hdr}c\tx\t-\tnope")).is_err(), "bad float");
        assert!(MetricsRegistry::decode_text(&format!("{hdr}c\tx\tk\t1")).is_err(), "label sans =");
        // trailing newline / empty lines are tolerated
        let ok = MetricsRegistry::decode_text(&format!("{hdr}c\tx\t-\t1\n\n")).unwrap();
        assert_eq!(ok.get("x"), 1.0);
    }

    #[test]
    fn markdown_is_deterministic_and_labelled() {
        let r = sample();
        let md = r.to_markdown();
        assert!(md.contains("| requests_done | counter | 3.0000 |"));
        assert!(md.contains("| requests_done{drafter=\"pillar_w64\"} | counter | 2.0000 |"));
        assert!(md.contains("| kv_used_tokens | gauge | 128.0000 |"));
        assert!(md.contains("| ttft_s | 2 |"));
        assert_eq!(md, sample().to_markdown());
    }
}
