//! Wire protocol: length-prefixed binary frames over TCP.
//!
//! The serving front-end speaks a deliberately small binary protocol —
//! small enough that the codec is exhaustively property-tested (round-trip
//! fuzz in `rust/tests/wire.rs`, byte-layout twin in
//! `python/tests/test_wire_port.py`) and that a load generator in any
//! language is an afternoon of work.
//!
//! # Frame layout (all integers little-endian)
//!
//! ```text
//! frame   := len:u32  body              len = |body|, 0 < len <= MAX_FRAME
//! body    := kind:u8  payload
//! str     := n:u16  utf8-bytes[n]
//! ```
//!
//! | kind | dir | frame     | payload |
//! |------|-----|-----------|---------|
//! | 0x01 | c→s | Submit    | req_id:u64 seed:u64 max_new:u32 tenant:str drafter:str n:u32 prompt:i32[n] |
//! | 0x02 | c→s | Cancel    | session:u64 |
//! | 0x03 | c→s | Credit    | n:u32 |
//! | 0x04 | c→s | Shutdown  | abort:u8 (0 = graceful drain, 1 = cancel live sessions first) |
//! | 0x05 | c→s | Ping      | nonce:u64 |
//! | 0x10 | s→c | Hello     | version:u8 window:u32 |
//! | 0x11 | s→c | Accepted  | req_id:u64 session:u64 [replica:u16] |
//! | 0x12 | s→c | Token     | session:u64 index:u32 token:i32 |
//! | 0x13 | s→c | Finished  | session:u64 reason:u8 tokens:u32 |
//! | 0x14 | s→c | Error     | req_id:u64 code:u8 detail:str |
//! | 0x15 | s→c | Pong      | nonce:u64 |
//!
//! `Hello` opens every connection and grants the initial **token credit
//! window**: the server decrements one credit per `Token` frame it queues
//! and stops sending tokens at zero; the client returns credit with
//! `Credit` frames as it consumes.  Receiver-driven flow control makes
//! slow-reader backpressure deterministic (no dependence on kernel socket
//! buffer sizes) — see `serving::server` for the stall → drop-to-cancel
//! policy.  Control frames (`Accepted`/`Finished`/`Error`/`Pong`) are
//! never credit-gated.
//!
//! Decoding is total: truncated, oversized, trailing-garbage and
//! unknown-kind inputs return a typed [`WireError`], never panic, and
//! never allocate more than the declared (bounds-checked) sizes.
//!
//! `Accepted` carries one **optional trailing field**: a `replica:u16`
//! appended by `sparsespec-router` so clients can attribute sessions to
//! the replica that served them.  Absence is encoded by absence (a bare
//! 17-byte body, what `sparsespec-server` has always sent), presence by
//! exactly two extra bytes; any other tail is `Trailing`.  This keeps
//! both forms canonical under PROTOCOL_VERSION 1 and leaves every other
//! frame's layout untouched.

use std::fmt;
use std::io::{Read, Write};

/// Protocol version announced in `Hello`.
pub const PROTOCOL_VERSION: u8 = 1;
/// Hard cap on the body length of a single frame (1 MiB).
pub const MAX_FRAME: usize = 1 << 20;
/// Hard cap on the prompt token count a `Submit` may carry (decode-time
/// bound; the model's `prompt_pad` is far smaller and enforced at
/// admission).
pub const MAX_PROMPT: usize = 4096;

// Frame kind bytes (pinned by python/tests/test_wire_port.py).
pub const K_SUBMIT: u8 = 0x01;
pub const K_CANCEL: u8 = 0x02;
pub const K_CREDIT: u8 = 0x03;
pub const K_SHUTDOWN: u8 = 0x04;
pub const K_PING: u8 = 0x05;
pub const K_HELLO: u8 = 0x10;
pub const K_ACCEPTED: u8 = 0x11;
pub const K_TOKEN: u8 = 0x12;
pub const K_FINISHED: u8 = 0x13;
pub const K_ERROR: u8 = 0x14;
pub const K_PONG: u8 = 0x15;

/// Typed refusal codes carried by `Error` frames (pinned by
/// python/tests/test_wire_port.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// The request can never fit the engine's KV budget (prompt too long
    /// or `prompt + max_new + k` beyond the device budget).
    AdmissionReject = 1,
    /// Load shed: device-KV pressure crossed the server's watermark.
    KvShed = 2,
    /// The tenant's admission queue is at capacity (bounded queueing).
    TenantQueueFull = 3,
    /// Backpressure drop-to-cancel: the connection stalled out of token
    /// credit for longer than the configured stall budget.
    SlowReader = 4,
    /// The named per-request drafter could not be resolved.
    DrafterRejected = 5,
    /// Malformed or out-of-protocol frame from the client.
    Protocol = 6,
    /// The server is draining and accepts no new work.
    Draining = 7,
    /// A fatal engine fault poisoned the session mid-run (the typed
    /// `EngineError` rendering rides in `detail`; the paired `Finished`
    /// frame carries reason `failed`).
    EngineFault = 8,
    /// The replica serving this session went down (router failover): no
    /// live replica was available to route to, or the session had already
    /// streamed tokens and cannot be transparently resubmitted.
    ReplicaDown = 9,
}

impl ErrorCode {
    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::AdmissionReject),
            2 => Some(ErrorCode::KvShed),
            3 => Some(ErrorCode::TenantQueueFull),
            4 => Some(ErrorCode::SlowReader),
            5 => Some(ErrorCode::DrafterRejected),
            6 => Some(ErrorCode::Protocol),
            7 => Some(ErrorCode::Draining),
            8 => Some(ErrorCode::EngineFault),
            9 => Some(ErrorCode::ReplicaDown),
            _ => None,
        }
    }

    /// Stable lowercase label (metric label values, client reports).
    pub fn label(self) -> &'static str {
        match self {
            ErrorCode::AdmissionReject => "admission_reject",
            ErrorCode::KvShed => "kv_shed",
            ErrorCode::TenantQueueFull => "tenant_queue_full",
            ErrorCode::SlowReader => "slow_reader",
            ErrorCode::DrafterRejected => "drafter_rejected",
            ErrorCode::Protocol => "protocol",
            ErrorCode::Draining => "draining",
            ErrorCode::EngineFault => "engine_fault",
            ErrorCode::ReplicaDown => "replica_down",
        }
    }
}

/// `FinishReason` ↔ wire byte (0 completed, 1 cancelled, 2 rejected,
/// 3 failed).
pub fn reason_to_wire(r: crate::engine::FinishReason) -> u8 {
    match r {
        crate::engine::FinishReason::Completed => 0,
        crate::engine::FinishReason::Cancelled => 1,
        crate::engine::FinishReason::Rejected => 2,
        crate::engine::FinishReason::Failed => 3,
    }
}

pub fn reason_from_wire(v: u8) -> Option<crate::engine::FinishReason> {
    match v {
        0 => Some(crate::engine::FinishReason::Completed),
        1 => Some(crate::engine::FinishReason::Cancelled),
        2 => Some(crate::engine::FinishReason::Rejected),
        3 => Some(crate::engine::FinishReason::Failed),
        _ => None,
    }
}

/// One protocol frame.  See the module docs for the byte layout.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Submit {
        req_id: u64,
        seed: u64,
        max_new: u32,
        tenant: String,
        drafter: String,
        prompt: Vec<i32>,
    },
    Cancel { session: u64 },
    Credit { n: u32 },
    Shutdown { abort: bool },
    Ping { nonce: u64 },
    Hello { version: u8, window: u32 },
    Accepted { req_id: u64, session: u64, replica: Option<u16> },
    Token { session: u64, index: u32, token: i32 },
    Finished { session: u64, reason: u8, tokens: u32 },
    Error { req_id: u64, code: ErrorCode, detail: String },
    Pong { nonce: u64 },
}

/// Typed decode/IO failures.  Every malformed input maps here — the codec
/// never panics.
#[derive(Clone, Debug, PartialEq)]
pub enum WireError {
    /// Body ended before the payload the kind requires.
    Truncated,
    /// Declared frame length of 0 or beyond [`MAX_FRAME`].
    Oversized { len: usize },
    /// Unknown frame-kind byte.
    UnknownKind(u8),
    /// A string field was not valid UTF-8.
    BadUtf8,
    /// Bytes left over after the payload was fully parsed.
    Trailing { extra: usize },
    /// A field value outside its domain (error code, finish reason,
    /// prompt length, shutdown mode).
    BadValue(&'static str),
    /// Underlying socket/IO failure.
    Io(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "frame truncated"),
            WireError::Oversized { len } => write!(f, "frame length {len} outside (0, {MAX_FRAME}]"),
            WireError::UnknownKind(k) => write!(f, "unknown frame kind 0x{k:02x}"),
            WireError::BadUtf8 => write!(f, "string field is not valid utf-8"),
            WireError::Trailing { extra } => write!(f, "{extra} trailing bytes after payload"),
            WireError::BadValue(what) => write!(f, "field out of domain: {what}"),
            WireError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    debug_assert!(bytes.len() <= u16::MAX as usize, "string field too long");
    let n = bytes.len().min(u16::MAX as usize) as u16;
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&bytes[..n as usize]);
}

impl Frame {
    /// The kind byte this frame encodes with.
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Submit { .. } => K_SUBMIT,
            Frame::Cancel { .. } => K_CANCEL,
            Frame::Credit { .. } => K_CREDIT,
            Frame::Shutdown { .. } => K_SHUTDOWN,
            Frame::Ping { .. } => K_PING,
            Frame::Hello { .. } => K_HELLO,
            Frame::Accepted { .. } => K_ACCEPTED,
            Frame::Token { .. } => K_TOKEN,
            Frame::Finished { .. } => K_FINISHED,
            Frame::Error { .. } => K_ERROR,
            Frame::Pong { .. } => K_PONG,
        }
    }

    /// Encode body (kind byte + payload), without the length prefix.
    pub fn encode_body(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        out.push(self.kind());
        match self {
            Frame::Submit { req_id, seed, max_new, tenant, drafter, prompt } => {
                out.extend_from_slice(&req_id.to_le_bytes());
                out.extend_from_slice(&seed.to_le_bytes());
                out.extend_from_slice(&max_new.to_le_bytes());
                put_str(&mut out, tenant);
                put_str(&mut out, drafter);
                out.extend_from_slice(&(prompt.len() as u32).to_le_bytes());
                for t in prompt {
                    out.extend_from_slice(&t.to_le_bytes());
                }
            }
            Frame::Cancel { session } => out.extend_from_slice(&session.to_le_bytes()),
            Frame::Credit { n } => out.extend_from_slice(&n.to_le_bytes()),
            Frame::Shutdown { abort } => out.push(*abort as u8),
            Frame::Ping { nonce } => out.extend_from_slice(&nonce.to_le_bytes()),
            Frame::Hello { version, window } => {
                out.push(*version);
                out.extend_from_slice(&window.to_le_bytes());
            }
            Frame::Accepted { req_id, session, replica } => {
                out.extend_from_slice(&req_id.to_le_bytes());
                out.extend_from_slice(&session.to_le_bytes());
                if let Some(r) = replica {
                    out.extend_from_slice(&r.to_le_bytes());
                }
            }
            Frame::Token { session, index, token } => {
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&index.to_le_bytes());
                out.extend_from_slice(&token.to_le_bytes());
            }
            Frame::Finished { session, reason, tokens } => {
                out.extend_from_slice(&session.to_le_bytes());
                out.push(*reason);
                out.extend_from_slice(&tokens.to_le_bytes());
            }
            Frame::Error { req_id, code, detail } => {
                out.extend_from_slice(&req_id.to_le_bytes());
                out.push(*code as u8);
                put_str(&mut out, detail);
            }
            Frame::Pong { nonce } => out.extend_from_slice(&nonce.to_le_bytes()),
        }
        out
    }

    /// Full on-wire bytes: u32 length prefix + body.
    pub fn encode(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }
}

// ---------------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over a frame body.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, WireError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn rest(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Decode one frame body (kind byte + payload, no length prefix).
/// Total: every malformed input returns a typed error, and the payload
/// must be consumed exactly (`Trailing` otherwise).
pub fn decode_body(body: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cur { buf: body, pos: 0 };
    let kind = c.u8().map_err(|_| WireError::Truncated)?;
    let frame = match kind {
        K_SUBMIT => {
            let req_id = c.u64()?;
            let seed = c.u64()?;
            let max_new = c.u32()?;
            let tenant = c.string()?;
            let drafter = c.string()?;
            let n = c.u32()? as usize;
            if n > MAX_PROMPT {
                return Err(WireError::BadValue("prompt length"));
            }
            // The cursor bounds-checks before allocating: a lying length
            // on a short body fails Truncated without reserving n*4 bytes.
            if c.rest() < n * 4 {
                return Err(WireError::Truncated);
            }
            let mut prompt = Vec::with_capacity(n);
            for _ in 0..n {
                prompt.push(c.i32()?);
            }
            Frame::Submit { req_id, seed, max_new, tenant, drafter, prompt }
        }
        K_CANCEL => Frame::Cancel { session: c.u64()? },
        K_CREDIT => Frame::Credit { n: c.u32()? },
        K_SHUTDOWN => {
            let mode = c.u8()?;
            if mode > 1 {
                return Err(WireError::BadValue("shutdown mode"));
            }
            Frame::Shutdown { abort: mode == 1 }
        }
        K_PING => Frame::Ping { nonce: c.u64()? },
        K_HELLO => Frame::Hello { version: c.u8()?, window: c.u32()? },
        K_ACCEPTED => {
            let req_id = c.u64()?;
            let session = c.u64()?;
            // Optional trailing replica id: either absent (legacy server
            // form) or exactly one u16.  Anything else falls through to
            // the Trailing check below.
            let replica = if c.rest() == 2 { Some(c.u16()?) } else { None };
            Frame::Accepted { req_id, session, replica }
        }
        K_TOKEN => Frame::Token { session: c.u64()?, index: c.u32()?, token: c.i32()? },
        K_FINISHED => {
            let session = c.u64()?;
            let reason = c.u8()?;
            if reason_from_wire(reason).is_none() {
                return Err(WireError::BadValue("finish reason"));
            }
            let tokens = c.u32()?;
            Frame::Finished { session, reason, tokens }
        }
        K_ERROR => {
            let req_id = c.u64()?;
            let code = ErrorCode::from_u8(c.u8()?).ok_or(WireError::BadValue("error code"))?;
            let detail = c.string()?;
            Frame::Error { req_id, code, detail }
        }
        K_PONG => Frame::Pong { nonce: c.u64()? },
        other => return Err(WireError::UnknownKind(other)),
    };
    if c.rest() != 0 {
        return Err(WireError::Trailing { extra: c.rest() });
    }
    Ok(frame)
}

/// Read one length-prefixed frame.  `Ok(None)` on clean EOF at a frame
/// boundary; `Err` on mid-frame EOF, oversized declared length, or a
/// malformed body.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, WireError> {
    let mut len_buf = [0u8; 4];
    // Clean EOF is only legal before the first length byte.
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(WireError::Truncated);
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(WireError::Oversized { len });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e.to_string())
        }
    })?;
    decode_body(&body)
}

/// Write one frame (length prefix + body).  Does not flush.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), WireError> {
    w.write_all(&f.encode()).map_err(|e| WireError::Io(e.to_string()))
}

/// Validate the connection-opening frame: it must be a `Hello` carrying
/// the one [`PROTOCOL_VERSION`] this build speaks.  Returns the granted
/// credit window.  Both `serving::client` and the router run every
/// server-side handshake through this instead of pattern-matching
/// `Hello` fields loosely — a version we don't understand must be a
/// typed refusal, never a silent best-effort decode.
pub fn expect_hello(f: &Frame) -> Result<u32, WireError> {
    match f {
        Frame::Hello { version, window } if *version == PROTOCOL_VERSION => Ok(*window),
        Frame::Hello { .. } => Err(WireError::BadValue("protocol version")),
        _ => Err(WireError::BadValue("expected hello")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_kind() {
        let frames = vec![
            Frame::Submit {
                req_id: 7,
                seed: u64::MAX,
                max_new: 40,
                tenant: "acme".into(),
                drafter: "pillar_w64".into(),
                prompt: vec![1, -2, 511],
            },
            Frame::Cancel { session: 9 },
            Frame::Credit { n: 128 },
            Frame::Shutdown { abort: false },
            Frame::Shutdown { abort: true },
            Frame::Ping { nonce: 0xDEAD },
            Frame::Hello { version: PROTOCOL_VERSION, window: 1024 },
            Frame::Accepted { req_id: 7, session: 3, replica: None },
            Frame::Accepted { req_id: 7, session: 3, replica: Some(1) },
            Frame::Token { session: 3, index: 0, token: -1 },
            Frame::Finished { session: 3, reason: 0, tokens: 40 },
            Frame::Error {
                req_id: 7,
                code: ErrorCode::KvShed,
                detail: "kv pressure 0.93 > watermark 0.85".into(),
            },
            Frame::Pong { nonce: 0xDEAD },
        ];
        for f in frames {
            let bytes = f.encode();
            let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
            assert_eq!(len, bytes.len() - 4);
            assert_eq!(decode_body(&bytes[4..]).unwrap(), f, "{f:?}");
            // and through the stream reader
            let mut cursor = std::io::Cursor::new(bytes);
            assert_eq!(read_frame(&mut cursor).unwrap(), Some(f));
            assert_eq!(read_frame(&mut cursor).unwrap(), None, "clean EOF after");
        }
    }

    #[test]
    fn rejects_malformed_without_panic() {
        // empty body
        assert_eq!(decode_body(&[]), Err(WireError::Truncated));
        // unknown kind
        assert_eq!(decode_body(&[0x7F]), Err(WireError::UnknownKind(0x7F)));
        // truncated payload
        assert_eq!(decode_body(&[K_CANCEL, 1, 2]), Err(WireError::Truncated));
        // trailing garbage
        let mut bytes = Frame::Credit { n: 1 }.encode_body();
        bytes.push(0xAA);
        assert_eq!(decode_body(&bytes), Err(WireError::Trailing { extra: 1 }));
        // bad error code / finish reason / shutdown mode
        let mut e = Frame::Error { req_id: 1, code: ErrorCode::KvShed, detail: "x".into() }
            .encode_body();
        e[9] = 99;
        assert_eq!(decode_body(&e), Err(WireError::BadValue("error code")));
        let mut fin = Frame::Finished { session: 1, reason: 0, tokens: 2 }.encode_body();
        fin[9] = 17;
        assert_eq!(decode_body(&fin), Err(WireError::BadValue("finish reason")));
        assert_eq!(decode_body(&[K_SHUTDOWN, 2]), Err(WireError::BadValue("shutdown mode")));
        // zero and oversized length prefixes
        let mut z = std::io::Cursor::new(vec![0, 0, 0, 0]);
        assert_eq!(read_frame(&mut z), Err(WireError::Oversized { len: 0 }));
        let big = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let mut b = std::io::Cursor::new(big);
        assert_eq!(read_frame(&mut b), Err(WireError::Oversized { len: MAX_FRAME + 1 }));
        // lying prompt count on a short body must not OOM or panic
        let mut s = Frame::Submit {
            req_id: 1,
            seed: 2,
            max_new: 3,
            tenant: "t".into(),
            drafter: String::new(),
            prompt: vec![],
        }
        .encode_body();
        let n = s.len();
        s[n - 4..].copy_from_slice(&(MAX_PROMPT as u32).to_le_bytes());
        assert_eq!(decode_body(&s), Err(WireError::Truncated));
        s[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(decode_body(&s), Err(WireError::BadValue("prompt length")));
    }

    #[test]
    fn accepted_optional_replica_is_canonical() {
        // absent → 17-byte body, present → exactly 19; a one-byte tail is
        // Trailing, not a half-read replica id.
        let bare = Frame::Accepted { req_id: 1, session: 2, replica: None }.encode_body();
        assert_eq!(bare.len(), 17);
        let tagged = Frame::Accepted { req_id: 1, session: 2, replica: Some(7) }.encode_body();
        assert_eq!(tagged.len(), 19);
        let mut odd = bare.clone();
        odd.push(0xFF);
        assert_eq!(decode_body(&odd), Err(WireError::Trailing { extra: 1 }));
        let mut long = tagged.clone();
        long.push(0xFF);
        assert_eq!(decode_body(&long), Err(WireError::Trailing { extra: 3 }));
    }

    #[test]
    fn expect_hello_pins_the_protocol_version() {
        let ok = Frame::Hello { version: PROTOCOL_VERSION, window: 64 };
        assert_eq!(expect_hello(&ok), Ok(64));
        let bad = Frame::Hello { version: PROTOCOL_VERSION + 1, window: 64 };
        assert_eq!(expect_hello(&bad), Err(WireError::BadValue("protocol version")));
        assert_eq!(
            expect_hello(&Frame::Pong { nonce: 1 }),
            Err(WireError::BadValue("expected hello"))
        );
    }

    #[test]
    fn mid_frame_eof_is_an_error_not_a_hang() {
        let bytes = Frame::Ping { nonce: 1 }.encode();
        for cut in 1..bytes.len() {
            let mut c = std::io::Cursor::new(bytes[..cut].to_vec());
            assert!(
                matches!(read_frame(&mut c), Err(WireError::Truncated)),
                "cut at {cut}"
            );
        }
    }
}
