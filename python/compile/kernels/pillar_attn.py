"""PillarAttn sparse draft attention as a Pallas kernel.

Hardware-adaptation notes (DESIGN.md §2).  The paper implements this as a
CUDA gather kernel over page-size-1 PagedAttention (threadblock per
(request, kv-head), selected pages staged HBM->SMEM).  The TPU/Pallas
mapping used here:

  * grid = (S,)  — one program per request row; within a row, the W
    selected tokens form a single VMEM tile (W <= 256, so the K/V gather
    tile is W x D = at most 256x32 f32 = 32 KiB per head: trivially
    VMEM-resident; the HBM->VMEM schedule is the BlockSpec).
  * On a real TPU the gather would be expressed with
    `pltpu.PrefetchScalarGridSpec`: the idx table is scalar-prefetched and
    drives the K/V BlockSpec index_map, so only the selected rows are DMAd
    (the SMEM-staging analogue).  Under interpret=True (mandatory on CPU —
    Mosaic custom-calls cannot execute on the CPU PJRT plugin) dynamic
    index_maps execute as gathers; we keep the gather inside the kernel
    body (`jnp.take`) which is numerically identical.
  * QK^T and PV products are `jnp.einsum` so the TPU lowering targets the
    MXU; head_dim 32 / W multiples of 8 keep tiles MXU-shaped.

Correctness oracle: kernels.ref.sparse_attn_ref (pytest + hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _kernel(q_ref, k_ref, v_ref, idx_ref, pos_ref, o_ref, *, group):
    """One request row: q [1,Q,Hq,D], caches [1,T,Hkv,D], idx [1,Hkv,W]."""
    q = q_ref[0]                       # [Q, Hq, D]
    k = k_ref[0]                       # [T, Hkv, D]
    v = v_ref[0]
    idx = idx_ref[0]                   # [Hkv, W]
    pos = pos_ref[0]

    Q, Hq, D = q.shape
    T = k.shape[0]
    Hkv, W = idx.shape
    scale = 1.0 / jnp.sqrt(jnp.array(D, dtype=q.dtype))

    safe = jnp.clip(idx, 0, T - 1)
    # Gather the W selected tokens per kv head: [Hkv, W, D].
    kg = jnp.take(k, safe.reshape(-1), axis=0).reshape(Hkv, W, Hkv, D)
    kg = kg[jnp.arange(Hkv), :, jnp.arange(Hkv)]            # [Hkv, W, D]
    vg = jnp.take(v, safe.reshape(-1), axis=0).reshape(Hkv, W, Hkv, D)
    vg = vg[jnp.arange(Hkv), :, jnp.arange(Hkv)]

    qh = q.reshape(Q, Hkv, group, D)
    logits = jnp.einsum("qhgd,hwd->qhgw", qh, kg) * scale    # [Q,Hkv,G,W]

    qpos = pos + jnp.arange(Q)
    vis = (idx[None, :, None, :] >= 0) & (
        idx[None, :, None, :] <= qpos[:, None, None, None]
    )
    logits = jnp.where(vis, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("qhgw,hwd->qhgd", p, vg)                # [Q,Hkv,G,D]
    o_ref[0] = out.reshape(Q, Hq, D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_attn(q, k_cache, v_cache, idx, pos, interpret=True):
    """Pallas PillarAttn. Same contract as ref.sparse_attn_ref."""
    S, Q, Hq, D = q.shape
    _, T, Hkv, _ = k_cache.shape
    W = idx.shape[-1]
    group = Hq // Hkv
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Q, Hq, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, T, Hkv, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, T, Hkv, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, Hkv, W), lambda s: (s, 0, 0)),
            pl.BlockSpec((1,), lambda s: (s,)),
        ],
        out_specs=pl.BlockSpec((1, Q, Hq, D), lambda s: (s, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, Q, Hq, D), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, idx, pos)


def vmem_bytes(Q, Hq, Hkv, D, W, T, dtype_bytes=4):
    """Estimated VMEM working set per grid step (real-TPU scalar-prefetch
    variant: only the gathered K/V tiles are resident, never the full cache).
    Used by the §Perf roofline estimate in EXPERIMENTS.md."""
    q = Q * Hq * D
    kv = 2 * Hkv * W * D
    logits = Q * Hq * W
    out = Q * Hq * D
    return (q + kv + logits + out) * dtype_bytes
