//! Benchmark harness: one entry per table/figure of the paper's
//! evaluation (DESIGN.md §4 experiment index).  Shared by the `sparsespec
//! bench` subcommand and `cargo bench` (rust/benches/bench_main.rs).
//!
//! Every function prints the regenerated rows/series and writes raw CSVs
//! under `reports/` so the markdown in EXPERIMENTS.md can cite them.

mod dispatch;
mod engine_iteration;
mod experiments;
mod fault_overhead;
mod kernels;
mod trace_overhead;

pub use dispatch::drafter_dispatch;
pub use engine_iteration::engine_iteration;
pub use experiments::*;
pub use fault_overhead::fault_overhead;
pub use kernels::{fig15_fused_kernel, pillar_select};
pub use trace_overhead::trace_overhead;

use crate::runtime::Runtime;
use std::rc::Rc;

pub struct BenchCtx {
    artifacts_dir: String,
    /// Loaded on first use: CPU-only experiments (e.g. `pillar_select`)
    /// run without any compiled artifacts on disk.
    rt_cell: Option<Rc<Runtime>>,
    pub out_dir: String,
    /// Requests per engine run (scaled-down stand-in for the paper's 2048).
    pub n_requests: usize,
    pub seed: u64,
}

impl BenchCtx {
    pub fn new(artifacts_dir: &str, out_dir: &str) -> anyhow::Result<Self> {
        std::fs::create_dir_all(out_dir)?;
        Ok(BenchCtx {
            artifacts_dir: artifacts_dir.to_string(),
            rt_cell: None,
            out_dir: out_dir.to_string(),
            n_requests: 12,
            seed: 42,
        })
    }

    /// The artifact runtime, loaded lazily and shared across experiments.
    pub fn rt(&mut self) -> anyhow::Result<Rc<Runtime>> {
        if self.rt_cell.is_none() {
            self.rt_cell = Some(Rc::new(Runtime::load(&self.artifacts_dir)?));
        }
        Ok(self.rt_cell.as_ref().unwrap().clone())
    }

    pub fn save(&self, name: &str, contents: &str) -> anyhow::Result<()> {
        let path = format!("{}/{}", self.out_dir, name);
        std::fs::write(&path, contents)?;
        println!("  [saved {path}]");
        Ok(())
    }
}

/// Registry: name -> runner.  `all` runs everything in paper order.
pub fn run_named(ctx: &mut BenchCtx, name: &str) -> anyhow::Result<()> {
    match name {
        "table1" => table1_dataset_stats(ctx),
        "fig2" => fig2_utilization(ctx),
        "fig3" => fig3_theory_vs_achieved(ctx),
        "fig4" => fig4_attention_dynamics(ctx),
        "fig5" => fig5_memory_policies(ctx),
        "table2" => table2_breakdown(ctx),
        "fig10" => fig10_training_free(ctx),
        "fig11" => fig11_draft_model(ctx),
        "fig12_accept" => fig12_acceptance(ctx),
        "fig12_sens" => fig12_sensitivity(ctx),
        "fig13" => fig13_ablation(ctx),
        "fig14" => fig14_schedule_trace(ctx),
        "fig15" => fig15_fused_kernel(ctx),
        "pillar_select" => pillar_select(ctx),
        "drafter_dispatch" => drafter_dispatch(ctx),
        "trace_overhead" => trace_overhead(ctx),
        "fault_overhead" => fault_overhead(ctx),
        "engine_iteration" => engine_iteration(ctx),
        "all" => {
            for n in [
                "table1", "fig2", "fig3", "fig4", "fig5", "table2", "fig10", "fig11",
                "fig12_accept", "fig12_sens", "fig13", "fig14", "fig15", "pillar_select",
                "drafter_dispatch", "trace_overhead", "fault_overhead", "engine_iteration",
            ] {
                println!("\n================ {n} ================");
                run_named(ctx, n)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown bench '{other}'"),
    }
}
