//! PJRT/XLA backend (`--features pjrt`): loads the AOT artifacts produced
//! by `python/compile/aot.py` and executes them on the PJRT CPU client
//! with device-resident weights and KV pools.
//!
//! Interchange format is HLO *text* (`HloModuleProto::from_text_file`) —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).
//!
//! The vendored `xla` crate is patched (vendor/xla/xla_rs/xla_rs.cc) to set
//! `ExecuteOptions::untuple_result = true`, so multi-output step functions
//! come back as one `PjRtBuffer` per output and the KV pools can be fed
//! into the next step via `execute_b` without ever leaving the device —
//! the request path does no host↔device KV copies except for offloading.

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::model::SystemConfig;

/// PJRT client + lazily-compiled executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub cfg: SystemConfig,
    exes: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// (artifact name, compile seconds) log — surfaced in metrics reports.
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let cfg = SystemConfig::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime {
            client,
            cfg,
            exes: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    /// Human-readable backend/platform identifier (for banners and `info`).
    pub fn platform_name(&self) -> String {
        format!("pjrt:{}", self.client.platform_name())
    }

    /// Fetch (compiling on first use) the named artifact's executable.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self
            .cfg
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' (not in config.json)"))?;
        let path = Path::new(&self.cfg.dir).join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(wrap)
        .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp).map_err(wrap)?);
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((name.to_string(), dt));
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    // ---- host <-> device marshalling ---------------------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(wrap)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(wrap)
    }

    pub fn fetch_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(wrap)?;
        lit.to_vec::<f32>().map_err(wrap)
    }

    pub fn fetch_i32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf.to_literal_sync().map_err(wrap)?;
        lit.to_vec::<i32>().map_err(wrap)
    }

    pub fn execute(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.executable(name)?;
        let mut out = exe.execute_b(args).map_err(wrap)?;
        if out.is_empty() || out[0].is_empty() {
            return Err(anyhow!("artifact '{name}' produced no outputs"));
        }
        Ok(out.remove(0))
    }

    /// Read a raw little-endian f32 file (weights.bin / eagle.bin).
    pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{path:?} is not a multiple of 4 bytes"));
        }
        let mut out = Vec::with_capacity(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }
}

/// The `xla` crate has its own error type; fold it into anyhow.
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
