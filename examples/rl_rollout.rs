//! RL-rollout generation (§2.2): throughput-oriented offline inference.
//!
//! Rollout generation can take >90% of RL post-training time; this driver
//! oversubscribes the device KV budget with a large offline batch so the
//! dynamic KV manager (offload, FIFO reload) is exercised, and reports
//! rollouts/s for vanilla vs SparseSpec.  Driven through the session API
//! so the KV budget is validated up front and completions are observable
//! as they land.
//!
//!   cargo run --release --example rl_rollout [-- --requests 32 --budget-frac 45]
//!   (add `--trace-out trace.json` to export a Perfetto trace of the
//!    sparsespec+dynamic run — offload/reload spans on the Kv track;
//!    add `--fault-plan kv_reload:0.05 --fault-seed 7` to chaos-test the
//!    offload/reload path under injected host-tier I/O faults)


use std::rc::Rc;

use sparsespec::engine::{EngineConfig, EngineDriver, EngineHandle, FinishReason};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::runtime::Runtime;
use sparsespec::spec::DrafterKind;
use sparsespec::util::cli::Args;
use sparsespec::workload::{Dataset, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rt = Rc::new(Runtime::load(&args.str("artifacts", "artifacts"))?);
    let n = args.usize("requests", 24);
    let frac = args.usize("budget-frac", 45);
    let trace_out = args.opt("trace-out").map(|s| s.to_string());
    let budget = rt.cfg.model.slots * rt.cfg.model.max_seq * frac / 100;
    println!(
        "rollout batch: {n} requests, device KV budget {budget} tokens ({frac}% of pool)"
    );

    for (name, drafter, policy) in [
        ("vanilla+preempt", DrafterKind::Vanilla, KvPolicy::Preempt),
        ("sparsespec+dynamic", DrafterKind::Pillar { w: 128 }, KvPolicy::Dynamic),
    ] {
        let reqs = WorkloadGen::new(
            rt.cfg.grammar.clone(),
            rt.cfg.model.clone(),
            Dataset::Aime,
            9,
        )
        .offline_batch(n);
        let traced = trace_out.is_some() && policy == KvPolicy::Dynamic;
        let mut b = EngineConfig::builder(drafter).k(8).kv(policy, budget);
        if traced {
            b = b.tracing(sparsespec::trace::TraceConfig::on());
        }
        if let Some(spec) = args.opt("fault-plan") {
            b = b.faults(sparsespec::fault::FaultConfig::new(
                sparsespec::fault::FaultPlan::parse(spec)?,
                args.u64("fault-seed", 0),
            ));
        }
        let cfg = b.build(&rt.cfg.model)?;
        let mut driver = EngineDriver::new(EngineHandle::new(rt.clone(), cfg)?);
        for req in reqs {
            driver.submit(req);
        }
        driver.drive()?;
        if traced {
            let path = trace_out.as_deref().unwrap();
            std::fs::write(path, driver.tracer().export_chrome_string())?;
            println!("    perfetto trace saved to {path}");
        }
        let done = driver
            .sessions()
            .iter()
            .filter(|s| s.finish_reason() == Some(FinishReason::Completed))
            .count();
        let r = driver.report();
        println!("{name:<20} {}", r.summary());
        println!(
            "    rollouts/s (wall): {:.2}   completed {done}/{n}, offloaded {} times, recomputed {} tokens",
            r.requests_done as f64 / r.wall_s,
            r.kv.offload_events,
            r.kv.recomputed_tokens
        );
    }
    Ok(())
}
