"""Build-time miniature training.

Why this exists (DESIGN.md §1): self-speculation acceptance measures the
agreement between sparse- and full-attention forward passes of the *same*
weights.  A randomly-initialised model has near-uniform attention, which is
not the regime the paper exploits; a model trained on the pointer-chasing
corpus concentrates attention on definition tokens (the "pillars"),
reproducing the concentrated-attention / peaked-logits regime of real
reasoning models.  Training runs once inside `make artifacts`, on CPU, in
a couple of minutes; the request path never sees Python.

Also distils the EAGLE-like draft head (Fig. 11 baseline).  Per the paper's
observation that EAGLE3's training distribution is OOD for reasoning
workloads, the head is trained on *filler-only* traces (no query blocks):
it learns the locally-predictable chains but misses the long-range lookups
— the same qualitative gap the paper reports.

Optimiser: hand-rolled Adam (optax is not available in this environment).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model
from .config import MODEL, EAGLE, GRAMMAR, TRAIN, GrammarConfig


def _adam_update(p, g, m, v, step, lr, cfg=TRAIN):
    m = cfg.adam_b1 * m + (1 - cfg.adam_b1) * g
    v = cfg.adam_b2 * v + (1 - cfg.adam_b2) * g * g
    mh = m / (1 - cfg.adam_b1 ** step)
    vh = v / (1 - cfg.adam_b2 ** step)
    return p - lr * mh / (jnp.sqrt(vh) + cfg.adam_eps), m, v


def _lr(step, cfg=TRAIN):
    warm = jnp.minimum(step / cfg.warmup, 1.0)
    decay = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(step / cfg.steps, 1.0)))
    return cfg.lr * warm * (0.1 + 0.9 * decay)


def _ce_loss(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def train_model(log=print):
    """Train the target model; returns (params, [(step, loss, acc)])."""
    fwd = model.make_train_forward(MODEL)
    fwd_ent = model.make_train_forward(MODEL, with_attn_entropy=True)

    def loss_fn(params, tokens):
        logits, ent = fwd_ent(params, tokens[:, :-1])
        # Attention-concentration pressure (see make_train_forward doc).
        return _ce_loss(logits, tokens[:, 1:]) + TRAIN.attn_entropy_lambda * ent

    @jax.jit
    def train_step(params, m, v, tokens, step):
        loss, g = jax.value_and_grad(loss_fn)(params, tokens)
        params, m, v = _adam_update(params, g, m, v, step, _lr(step))
        return params, m, v, loss

    @jax.jit
    def acc_fn(params, tokens):
        logits = fwd(params, tokens[:, :-1])
        return jnp.mean(jnp.argmax(logits, -1) == tokens[:, 1:])

    params = model.init_params(jax.random.PRNGKey(TRAIN.seed))
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    curve = []
    t0 = time.time()
    for step in range(1, TRAIN.steps + 1):
        batch = jnp.asarray(
            data.training_batch(TRAIN.seed + step, TRAIN.batch, TRAIN.seq)
        )
        params, m, v, loss = train_step(params, m, v, batch, step)
        if step % 25 == 0 or step == 1:
            acc = float(acc_fn(params, batch))
            curve.append((step, float(loss), acc))
            log(f"[train] step {step:4d} loss {float(loss):.4f} "
                f"acc {acc:.3f} ({time.time()-t0:.0f}s)")
    return params, curve


def _filler_only_grammar():
    """EAGLE training distribution: body is filler chains only (OOD for the
    query-heavy serving workload)."""
    return GrammarConfig(query_prob=0.0, redefine_prob=0.02)


def train_eagle(target_params, log=print):
    """Distil the draft head on filler-only traces against the corpus."""
    e_fwd = model.make_eagle(MODEL, EAGLE)

    def loss_fn(ep, ctx, tgt):
        return _ce_loss(e_fwd(ep, ctx), tgt)

    @jax.jit
    def train_step(ep, m, v, ctx, tgt, step):
        loss, g = jax.value_and_grad(loss_fn)(ep, ctx, tgt)
        ep, m, v = _adam_update(ep, g, m, v, step, TRAIN.eagle_lr)
        return ep, m, v, loss

    g = _filler_only_grammar()
    ep = model.eagle_init(jax.random.PRNGKey(TRAIN.seed + 777))
    m = jnp.zeros_like(ep)
    v = jnp.zeros_like(ep)
    ectx = EAGLE.ctx
    for step in range(1, TRAIN.eagle_steps + 1):
        gen = data.TraceGen(seed=TRAIN.seed * 31 + step, g=g)
        seq = np.array(gen.take(TRAIN.eagle_batch + ectx), dtype=np.int32)
        ctx = np.stack([seq[i : i + ectx] for i in range(TRAIN.eagle_batch)])
        tgt = seq[ectx : ectx + TRAIN.eagle_batch]
        ep, m, v, loss = train_step(ep, m, v, jnp.asarray(ctx),
                                    jnp.asarray(tgt), step)
        if step % 50 == 0:
            log(f"[eagle] step {step:4d} loss {float(loss):.4f}")
    return ep
