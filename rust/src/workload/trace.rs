//! Request-trace record/replay: serialise a workload to JSON so a run can
//! be reproduced exactly across machines (and so failing benchmark
//! configurations can be shared as artefacts).

use super::Request;
use crate::spec::DrafterKind;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::{anyhow, Result};

/// Serialise a request trace.  Per-session drafter overrides ride along
/// as their canonical `DrafterKind::name()` form (omitted when `None`, so
/// pre-override traces stay byte-identical).
pub fn to_json(reqs: &[Request]) -> String {
    arr(reqs.iter().map(|r| {
        let mut fields = vec![
            ("id", num(r.id as f64)),
            ("prompt", arr(r.prompt.iter().map(|&t| num(t as f64)))),
            ("max_new", num(r.max_new as f64)),
            ("arrival_s", num(r.arrival_s)),
            ("seed", s(&r.seed.to_string())), // u64-safe as string
        ];
        if let Some(d) = r.drafter {
            // Only kinds `DrafterKind::parse_name` can reconstruct are
            // recorded: a `Custom` drafter's constructor lives in a
            // registry, not in a string, so serialising its name would
            // poison the trace for `from_json`.  Replays of such traces
            // fall back to the serving engine's default drafter.
            if DrafterKind::parse_name(&d.name()).is_some() {
                fields.push(("drafter", s(&d.name())));
            }
        }
        obj(fields)
    }))
    .to_string()
}

/// Parse a request trace back.
pub fn from_json(text: &str) -> Result<Vec<Request>> {
    let j = Json::parse(text).map_err(|e| anyhow!("trace parse: {e}"))?;
    let items = j.as_arr().ok_or_else(|| anyhow!("trace must be an array"))?;
    items
        .iter()
        .map(|it| {
            let id = it
                .get("id")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("missing id"))? as u64;
            let prompt = it
                .get("prompt")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing prompt"))?
                .iter()
                .filter_map(|t| t.as_i64().map(|x| x as i32))
                .collect();
            let max_new = it
                .get("max_new")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("missing max_new"))?;
            let arrival_s = it.get("arrival_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let seed = it
                .get("seed")
                .and_then(|v| v.as_str())
                .and_then(|x| x.parse().ok())
                .unwrap_or(0);
            let drafter = match it.get("drafter").and_then(|v| v.as_str()) {
                None => None,
                Some(name) => Some(DrafterKind::parse_name(name).ok_or_else(|| {
                    anyhow!("request {id}: unknown drafter name '{name}' in trace")
                })?),
            };
            Ok(Request { id, prompt, max_new, arrival_s, seed, drafter })
        })
        .collect()
}

pub fn save(path: &str, reqs: &[Request]) -> Result<()> {
    std::fs::write(path, to_json(reqs))?;
    Ok(())
}

pub fn load(path: &str) -> Result<Vec<Request>> {
    from_json(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Request> {
        vec![
            Request {
                id: 3,
                prompt: vec![1, 3, 55, 108, 6],
                max_new: 120,
                arrival_s: 0.5,
                seed: u64::MAX - 7,
                drafter: None,
            },
            Request {
                id: 4,
                prompt: vec![1],
                max_new: 8,
                arrival_s: 1.25,
                seed: 42,
                drafter: Some(DrafterKind::NGram { n: 3 }),
            },
        ]
    }

    #[test]
    fn roundtrip_exact() {
        let reqs = sample();
        let text = to_json(&reqs);
        let back = from_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in reqs.iter().zip(back.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new, b.max_new);
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.seed, b.seed); // u64::MAX survives (string-coded)
            assert_eq!(a.drafter, b.drafter); // override survives by name
        }
        // requests without an override serialise exactly as before
        let plain = to_json(&reqs[..1]);
        assert!(!plain.contains("drafter"), "None override must be omitted");
        // custom overrides are non-reconstructible -> omitted, so the
        // emitted trace always loads back
        let mut custom = reqs[0].clone();
        custom.drafter = Some(DrafterKind::Custom { name: "parrot" });
        let text = to_json(&[custom]);
        assert!(!text.contains("parrot"), "custom kinds must not be recorded");
        assert_eq!(from_json(&text).unwrap()[0].drafter, None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_json("{}").is_err());
        assert!(from_json(r#"[{"id": 1}]"#).is_err());
        assert!(from_json("not json").is_err());
        // a trace naming an unparseable drafter is an error, not a silent
        // fall-through to the engine default
        let bad = r#"[{"id": 1, "prompt": [1], "max_new": 4, "drafter": "warp-drive"}]"#;
        assert!(from_json(bad).is_err());
    }
}
