//! Property tests over the public API (no artifacts needed).
//!
//! Complements the per-module #[cfg(test)] suites: these exercise
//! cross-module invariants the coordinator depends on.  Run with
//! PTEST_CASES=N to scale case counts; failures print a reproducing seed.


use sparsespec::kv_cache::{HostKv, KvManager, KvPolicy, PressureAction};
use sparsespec::metrics::Histogram;
use sparsespec::sampling::{sample_cat, softmax, verify_greedy, verify_stochastic};
use sparsespec::scheduler::BucketScheduler;
use sparsespec::spec::{select_into, topk_indices, IndexPolicy, NGramIndex, PillarState, SelectScratch};
use sparsespec::util::json::{arr, num, obj, Json};
use sparsespec::util::ptest::{run_named, Gen};
use sparsespec::util::rng::Xoshiro256;
use sparsespec::util::threadpool::ThreadPool;

// ---------------------------------------------------------------------
// json
// ---------------------------------------------------------------------

fn random_json(g: &mut Gen, depth: usize) -> Json {
    if depth == 0 || g.bool(0.4) {
        match g.usize(0, 3) {
            0 => Json::Null,
            1 => Json::Bool(g.bool(0.5)),
            2 => num((g.i64(-1_000_000, 1_000_000)) as f64),
            _ => Json::Str(
                (0..g.usize(0, 12))
                    .map(|_| char::from(g.usize(32, 126) as u8))
                    .collect(),
            ),
        }
    } else if g.bool(0.5) {
        arr((0..g.usize(0, 5)).map(|_| random_json(g, depth - 1)))
    } else {
        let n = g.usize(0, 5);
        obj((0..n)
            .map(|i| {
                let key: &str = Box::leak(format!("k{i}").into_boxed_str());
                (key, random_json(g, depth - 1))
            })
            .collect())
    }
}

#[test]
fn json_roundtrip_property() {
    run_named("json_roundtrip", |g| {
        let v = random_json(g, 4);
        let text = v.to_string();
        let back = Json::parse(&text).expect("serialised json must parse");
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    });
}

// ---------------------------------------------------------------------
// histogram
// ---------------------------------------------------------------------

#[test]
fn histogram_percentiles_bracket_samples() {
    run_named("hist_pct", |g| {
        let n = g.usize(1, 500);
        let mut h = Histogram::default();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..n {
            let x = g.f64(-100.0, 100.0);
            lo = lo.min(x);
            hi = hi.max(x);
            h.record(x);
        }
        let p0 = h.percentile(0.0);
        let p50 = h.percentile(50.0);
        let p100 = h.percentile(100.0);
        assert!(p0 >= lo - 1e-9 && p100 <= hi + 1e-9);
        assert!(p0 <= p50 && p50 <= p100);
        assert!(h.mean() >= lo - 1e-9 && h.mean() <= hi + 1e-9);
    });
}

/// Nearest-rank reference, mirroring `Histogram::percentile`.
fn ref_percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[test]
fn histogram_lazy_sort_cache_survives_interleaved_mutation() {
    // The lazy-sort cache (interior-mutability sort behind &self reads)
    // must be invalidated by BOTH mutation paths — `record` and `merge` —
    // in any interleaving with sorted reads.  A shadow Vec is the oracle.
    run_named("hist_cache", |g| {
        let mut h = Histogram::default();
        let mut shadow: Vec<f64> = Vec::new();
        for _ in 0..g.usize(1, 80) {
            match g.usize(0, 3) {
                0 => {
                    let x = g.f64(-50.0, 50.0);
                    h.record(x);
                    shadow.push(x);
                }
                1 => {
                    // merge a small batch (possibly empty, possibly with a
                    // clean cache from its own sorted read)
                    let mut other = Histogram::default();
                    let mut batch = Vec::new();
                    for _ in 0..g.usize(0, 6) {
                        let x = g.f64(-50.0, 50.0);
                        other.record(x);
                        batch.push(x);
                    }
                    if g.bool(0.5) {
                        other.percentile(50.0); // mark the source sorted
                    }
                    h.merge(&other);
                    shadow.extend_from_slice(&batch);
                }
                _ => {
                    // sorted read: must agree with the oracle even right
                    // after mutation, and must not perturb len/sum
                    let p = g.f64(0.0, 100.0);
                    assert_eq!(h.percentile(p), ref_percentile(&shadow, p));
                    assert_eq!(h.len(), shadow.len());
                }
            }
        }
        // final full sweep, including the cached re-read
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            let want = ref_percentile(&shadow, p);
            assert_eq!(h.percentile(p), want);
            assert_eq!(h.percentile(p), want, "cached re-read must agree");
        }
        assert!((h.sum() - shadow.iter().sum::<f64>()).abs() < 1e-9);
    });
}

#[test]
fn histogram_merge_is_ordering_invariant() {
    // Merging the same parts in any order yields the same distribution
    // (len/sum/mean and every percentile) — sorted reads interleaved
    // between merges must not change the outcome.
    run_named("hist_merge_order", |g| {
        let n_parts = g.usize(2, 5);
        let parts: Vec<Vec<f64>> = (0..n_parts)
            .map(|_| (0..g.usize(0, 20)).map(|_| g.f64(-10.0, 10.0)).collect())
            .collect();
        let mk = |v: &[f64]| {
            let mut h = Histogram::default();
            for &x in v {
                h.record(x);
            }
            h
        };
        let mut fwd = Histogram::default();
        for p in &parts {
            fwd.merge(&mk(p));
        }
        let mut rev = Histogram::default();
        for p in parts.iter().rev() {
            rev.merge(&mk(p));
            rev.percentile(50.0); // dirty-then-clean the cache between merges
        }
        assert_eq!(fwd.len(), rev.len());
        assert!((fwd.sum() - rev.sum()).abs() < 1e-9);
        assert!((fwd.mean() - rev.mean()).abs() < 1e-9);
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(fwd.percentile(p), rev.percentile(p), "p{p} diverged");
        }
    });
}

// ---------------------------------------------------------------------
// scheduler
// ---------------------------------------------------------------------

#[test]
fn bucket_first_draft_len_lands_on_bucket() {
    run_named("bucket_align", |g| {
        let k = g.usize(1, 16);
        let s = BucketScheduler::new(k);
        let iter = g.u64(0, 10_000);
        let bucket = g.usize(0, k);
        let d = s.first_draft_len(iter, bucket);
        assert!(d <= k);
        // After d draft iterations, the verify iteration index ≡ bucket.
        let verify_iter = iter + d as u64;
        assert_eq!(
            (verify_iter % (k as u64 + 1)) as usize,
            bucket,
            "iter={iter} bucket={bucket} d={d}"
        );
    });
}

// ---------------------------------------------------------------------
// pillar index selection
// ---------------------------------------------------------------------

#[test]
fn topk_respects_budget_split_property() {
    run_named("topk_budget", |g| {
        let budget = g.usize(8, 96);
        let policy = IndexPolicy::pillar(budget);
        assert!(policy.sinks + policy.recent <= policy.budget);
        let len = g.usize(0, 400);
        let scores: Vec<f32> = (0..512).map(|_| g.f64(0.0, 1.0) as f32).collect();
        let ids = topk_indices(&scores, len, &policy);
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        // sinks present
        for t in 0..policy.sinks.min(len) {
            assert!(valid.contains(&(t as i32)));
        }
        // full recent window present when budget allows
        let lo = len.saturating_sub(policy.recent);
        for t in lo..len {
            assert!(valid.contains(&(t as i32)), "recent {t} missing");
        }
    });
}

/// The seed-era selection + compose pipeline (full sort, HashSet dedup,
/// per-call Vecs): the single shared transcription lives in
/// `spec::pillar::reference` and doubles as the `pillar_select` bench
/// baseline, so oracle and baseline can't drift apart.
use sparsespec::spec::pillar::reference as legacy;

#[test]
fn select_into_matches_legacy_topk_property() {
    run_named("select_vs_legacy", |g| {
        let budget = g.usize(1, 48);
        // stress beyond the IndexPolicy constructors' invariants
        let sinks = g.usize(0, budget);
        let recent = g.usize(0, budget + 4);
        let policy = IndexPolicy { budget, sinks, recent };
        let t_dim = g.usize(1, 300);
        let len = g.usize(0, t_dim);
        // heavy ties exercise the lowest-index-wins rule
        let levels = *g.pick(&[1usize, 2, 4, 1024]);
        let scores: Vec<f32> = (0..t_dim)
            .map(|_| (g.usize(0, levels) as f32) / levels as f32)
            .collect();
        let want = legacy::topk_indices(&scores, len, &policy);
        let mut scratch = SelectScratch::default();
        let mut got = vec![0i32; budget];
        let n = select_into(&scores, len, &policy, &mut scratch, &mut got);
        assert_eq!(got, want, "b={budget} s={sinks} r={recent} len={len}");
        assert_eq!(n, got.iter().filter(|&&x| x >= 0).count());
        assert_eq!(got, topk_indices(&scores, len, &policy));
        // determinism: a second run over the same inputs is bit-identical
        // (tie rule is stable lowest-index-wins, as in ref.py::topk_ids_ref)
        let mut again = vec![0i32; budget];
        select_into(&scores, len, &policy, &mut scratch, &mut again);
        assert_eq!(got, again);
    });
}

#[test]
fn compose_into_matches_legacy_compose_property() {
    run_named("compose_vs_legacy", |g| {
        let layers = g.usize(1, 3);
        let kv_heads = g.usize(1, 2);
        let budget = g.usize(4, 32);
        let sinks = g.usize(0, budget / 4);
        let recent = g.usize(1, budget - sinks);
        let policy = IndexPolicy { budget, sinks, recent };
        let t_dim = g.usize(8, 160);
        let len = g.usize(0, t_dim);
        let dump: Vec<f32> = (0..layers * kv_heads * t_dim)
            .map(|_| g.f64(0.0, 1.0) as f32)
            .collect();
        let mut legacy_st = legacy::Pillar::new(layers, kv_heads, policy);
        legacy_st.refresh(&dump, t_dim, len);
        let mut st = PillarState::new(layers, kv_heads, policy);
        st.refresh_from(&dump, t_dim, len);
        // compose at the refresh length and at a grown context (drafted
        // tokens append between refreshes)
        for dlen in [0usize, 1, 5] {
            let at = len + dlen;
            let want = legacy_st.compose(at);
            let mut got = vec![7i32; layers * kv_heads * budget];
            st.compose_into(&mut got, at);
            assert_eq!(got, want, "layers={layers} heads={kv_heads} at={at}");
            assert_eq!(st.compose(at), want);
        }
    });
}

#[test]
fn parallel_refresh_matches_serial_property() {
    // Plain seeded loop (not run_named): the pool's JoinHandles would make
    // the closure's unwind-safety hinge on std internals.
    let pool = ThreadPool::new(3);
    for case in 0..64u64 {
        let g = &mut Gen::new(0x9A11_E7 + case);
        let layers = g.usize(1, 4);
        let kv_heads = g.usize(1, 3);
        let budget = g.usize(4, 24);
        let policy = IndexPolicy::pillar(budget);
        let t_dim = g.usize(4, 96);
        let len = g.usize(0, t_dim);
        let dump: Vec<f32> = (0..layers * kv_heads * t_dim)
            .map(|_| g.f64(0.0, 1.0) as f32)
            .collect();
        let mut serial = PillarState::new(layers, kv_heads, policy);
        serial.refresh_from(&dump, t_dim, len);
        let mut par = PillarState::new(layers, kv_heads, policy);
        par.refresh_parallel(&dump, t_dim, len, &pool);
        assert_eq!(serial.compose(len), par.compose(len), "case {case}");
        assert_eq!(serial.compose(len + 3), par.compose(len + 3), "case {case}");
    }
}

// ---------------------------------------------------------------------
// kv manager + offload interplay
// ---------------------------------------------------------------------

#[test]
fn dynamic_policy_never_recomputes_property() {
    run_named("kv_no_recompute", |g| {
        let budget = g.usize(200, 1500);
        let mut kv = KvManager::new(KvPolicy::Dynamic, budget, budget);
        let mut next_id = 0u64;
        for _ in 0..g.usize(20, 120) {
            if kv.can_admit(32) && g.bool(0.5) {
                kv.admit(next_id, g.usize(8, 64));
                next_id += 1;
            }
            // random growth on a random resident
            if next_id > 0 {
                let id = g.u64(0, next_id - 1);
                if kv.resident_len(id).is_some() {
                    kv.grow(id, g.usize(1, 24));
                }
            }
            for act in kv.check_pressure(&[]) {
                match act {
                    PressureAction::Offload { req_id } => {
                        let len = kv.resident_len(req_id).unwrap();
                        kv.complete_offload(req_id, HostKv { k: vec![], v: vec![], len });
                    }
                    PressureAction::Preempt { .. } => {
                        panic!("dynamic policy must never preempt");
                    }
                }
            }
            assert!(kv.used_tokens() <= budget + 64 + 24);
        }
        assert_eq!(kv.stats.recomputed_tokens, 0);
    });
}

#[test]
fn reload_order_is_fifo_property() {
    run_named("kv_fifo", |g| {
        let mut kv = KvManager::new(KvPolicy::Dynamic, 10_000, 100);
        let n = g.usize(2, 10);
        // offload n requests in order, then reload: order must match.
        for id in 0..n as u64 {
            kv.admit(id, 10);
        }
        for id in (0..n as u64).rev() {
            // emulate pressure victims arriving in some order
            kv.complete_offload(id, HostKv { k: vec![], v: vec![], len: 10 });
        }
        let mut seen = Vec::new();
        while let Some((id, _)) = kv.try_reload() {
            seen.push(id);
        }
        let mut expect: Vec<u64> = (0..n as u64).rev().collect();
        assert_eq!(seen, expect.drain(..).collect::<Vec<_>>());
    });
}

// ---------------------------------------------------------------------
// sampling: chained losslessness
// ---------------------------------------------------------------------

#[test]
fn greedy_verify_prefix_property() {
    run_named("greedy_prefix", |g| {
        // Accepted prefix length equals the longest match with target argmax.
        let vocab = 8;
        let k = g.usize(1, 8);
        let mut logits = vec![0.0f32; (k + 1) * vocab];
        let mut want: Vec<i32> = Vec::new();
        for j in 0..=k {
            let t = g.usize(0, vocab - 1);
            logits[j * vocab + t] = 5.0;
            if j < k {
                want.push(t as i32);
            }
        }
        // draft = target prefix of length m, then a guaranteed mismatch
        let m = g.usize(0, k);
        let mut draft = want.clone();
        if m < k {
            draft[m] = (want[m] + 1) % vocab as i32;
        }
        let r = verify_greedy(&draft, &logits, vocab);
        assert_eq!(r.accepted, m.min(k));
    });
}

#[test]
fn stochastic_never_accepts_zero_prob_token() {
    run_named("stoch_zero", |g| {
        let vocab = 6;
        let mut rng = Xoshiro256::new(g.u64(0, u64::MAX / 2));
        // target puts ~zero mass on token 0
        let mut t_logits = vec![0.0f32; 2 * vocab];
        t_logits[0] = -40.0;
        t_logits[vocab] = 0.0;
        // draft proposes token 0 with high prob
        let mut q = vec![0.01f32; vocab];
        q[0] = 0.95;
        let r = verify_stochastic(&[0], &q, &t_logits, vocab, 1.0, &mut rng);
        if r.accepted == 1 {
            panic!("accepted a ~zero-probability token");
        }
        assert_ne!(r.next_token, 0);
    });
}

#[test]
fn softmax_sampling_matches_distribution() {
    // chi-square-ish sanity: empirical freq tracks softmax probs
    let logits = vec![0.0f32, 1.0, 2.0, 0.5];
    let p = softmax(&logits, 0.8);
    let mut rng = Xoshiro256::new(11);
    let n = 100_000;
    let mut c = vec![0usize; 4];
    for _ in 0..n {
        c[sample_cat(&p, &mut rng)] += 1;
    }
    for i in 0..4 {
        let emp = c[i] as f32 / n as f32;
        assert!((emp - p[i]).abs() < 0.01, "tok {i}: {emp} vs {}", p[i]);
    }
}

// ---------------------------------------------------------------------
// ngram drafting on grammar-like streams
// ---------------------------------------------------------------------

#[test]
fn ngram_never_panics_on_random_streams() {
    run_named("ngram_fuzz", |g| {
        let mut ix = NGramIndex::new(g.usize(1, 4));
        for _ in 0..g.usize(1, 30) {
            let chunk: Vec<i32> = (0..g.usize(1, 20))
                .map(|_| g.i64(0, 511) as i32)
                .collect();
            ix.extend(&chunk);
            let k = g.usize(1, 10);
            let p = ix.propose(k);
            assert!(p.len() <= k);
            assert!(p.iter().all(|&t| (0..512).contains(&t)));
        }
    });
}
