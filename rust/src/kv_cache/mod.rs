//! Dynamic KV-cache management (§4.4) — the two-tier (device/host) pool.
//!
//! Design: the engine owns S device *slots* (the compute batch dimension);
//! this module owns the *capacity policy* over a token budget that models
//! HBM (the budget is deliberately smaller than S×T so the policies are
//! exercised, exactly like real HBM runs out before slots do on long
//! reasoning outputs).  Three policies reproduce Fig. 5:
//!
//! * `Conservative` — reserve worst-case length at admission; never
//!   offloads, never recomputes, *underutilises*.
//! * `Preempt` — admit optimistically; on pressure, evict a victim and
//!   restart it later (recomputation).
//! * `Dynamic` (SparseSpec) — admit optimistically; on pressure, offload
//!   the *newest-admitted* resident's KV to host RAM chunk-by-chunk via the
//!   async copier, reload FIFO when space frees: full utilisation, zero
//!   recomputation.

pub mod offload;

pub use offload::{OffloadEngine, OffloadJob, OffloadStats};

use std::collections::{BTreeMap, VecDeque};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPolicy {
    Conservative,
    Preempt,
    Dynamic,
}

impl KvPolicy {
    pub fn parse(s: &str) -> Option<KvPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "conservative" => Some(KvPolicy::Conservative),
            "preempt" | "preemption" => Some(KvPolicy::Preempt),
            "dynamic" | "sparsespec" => Some(KvPolicy::Dynamic),
            _ => None,
        }
    }
}

/// A request's KV rows pulled to the host tier: [L, T, Hkv, D] each,
/// padded beyond `len` (only `len` positions are meaningful).
#[derive(Clone)]
pub struct HostKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
}

/// What the engine must do about memory pressure this iteration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PressureAction {
    /// Move this resident request's KV to host and free its slot.
    Offload { req_id: u64 },
    /// Drop this resident request's KV and re-enqueue it (recompute).
    Preempt { req_id: u64 },
}

#[derive(Clone, Debug, Default)]
pub struct KvStats {
    pub offload_events: u64,
    pub offloaded_tokens: u64,
    pub reload_events: u64,
    pub recompute_events: u64,
    pub recomputed_tokens: u64,
    pub peak_used_tokens: usize,
    pub admitted: u64,
    pub rejected_conservative: u64,
}

/// Token-budget accounting + policy.  The engine reports growth/release;
/// `check_pressure` returns actions; `host` holds offloaded KV.
pub struct KvManager {
    pub policy: KvPolicy,
    /// Device token capacity (the modelled HBM size).
    pub budget: usize,
    /// Worst-case length used by the conservative reservation.
    pub worst_case: usize,
    used: usize,
    reserved: usize,
    /// Resident request lengths, in admission order (FIFO for fairness —
    /// §4.4 "both offloading and loading follow the FIFO order").
    resident: BTreeMap<u64, usize>,
    admission_order: VecDeque<u64>,
    /// Offloaded requests, FIFO for reload priority.
    pub host: BTreeMap<u64, HostKv>,
    reload_queue: VecDeque<u64>,
    pub stats: KvStats,
}

impl KvManager {
    pub fn new(policy: KvPolicy, budget: usize, worst_case: usize) -> Self {
        KvManager {
            policy,
            budget,
            worst_case,
            used: 0,
            reserved: 0,
            resident: BTreeMap::new(),
            admission_order: VecDeque::new(),
            host: BTreeMap::new(),
            reload_queue: VecDeque::new(),
            stats: KvStats::default(),
        }
    }

    pub fn used_tokens(&self) -> usize {
        self.used
    }

    pub fn utilization(&self) -> f64 {
        self.used as f64 / self.budget as f64
    }

    /// Can a new request with `initial` tokens be admitted now?
    pub fn can_admit(&mut self, initial: usize) -> bool {
        match self.policy {
            KvPolicy::Conservative => {
                // Reserve the worst case; reject if it would not fit.
                if self.reserved + self.worst_case <= self.budget {
                    true
                } else {
                    self.stats.rejected_conservative += 1;
                    false
                }
            }
            // Optimistic: admit whenever current usage + prompt fits.
            KvPolicy::Preempt | KvPolicy::Dynamic => self.used + initial <= self.budget,
        }
    }

    pub fn admit(&mut self, req_id: u64, initial: usize) {
        self.resident.insert(req_id, initial);
        self.admission_order.push_back(req_id);
        self.used += initial;
        if self.policy == KvPolicy::Conservative {
            self.reserved += self.worst_case;
        }
        self.stats.admitted += 1;
        self.stats.peak_used_tokens = self.stats.peak_used_tokens.max(self.used);
    }

    /// A resident request grew by `n` tokens.
    pub fn grow(&mut self, req_id: u64, n: usize) {
        if let Some(len) = self.resident.get_mut(&req_id) {
            *len += n;
            self.used += n;
            self.stats.peak_used_tokens = self.stats.peak_used_tokens.max(self.used);
        }
    }

    /// Rollback: a verification rejected drafted tokens, shrinking the
    /// valid KV frontier by `n`.
    pub fn shrink(&mut self, req_id: u64, n: usize) {
        if let Some(len) = self.resident.get_mut(&req_id) {
            let d = n.min(*len);
            *len -= d;
            self.used -= d;
        }
    }

    /// A resident request finished; free its tokens.
    pub fn release(&mut self, req_id: u64) {
        if let Some(len) = self.resident.remove(&req_id) {
            self.used -= len;
            self.admission_order.retain(|&id| id != req_id);
            if self.policy == KvPolicy::Conservative {
                self.reserved -= self.worst_case;
            }
        }
    }

    /// Over budget? Return the actions to take (possibly several).
    /// Victim choice: the *most recently admitted* resident (LIFO victim /
    /// FIFO service): the oldest requests keep running to completion, which
    /// is the starvation-free order of §4.4.
    pub fn check_pressure(&mut self, protect: &[u64]) -> Vec<PressureAction> {
        let mut actions = Vec::new();
        if self.policy == KvPolicy::Conservative {
            return actions; // reservations make pressure impossible
        }
        let mut projected = self.used;
        let mut order = self.admission_order.clone();
        while projected > self.budget {
            // Scan newest-first, skipping protected (e.g. mid-verification).
            let victim = order
                .iter()
                .rev()
                .find(|id| !protect.contains(id))
                .copied();
            let Some(victim) = victim else { break };
            order.retain(|&id| id != victim);
            let len = self.resident.get(&victim).copied().unwrap_or(0);
            projected -= len;
            actions.push(match self.policy {
                KvPolicy::Preempt => PressureAction::Preempt { req_id: victim },
                KvPolicy::Dynamic => PressureAction::Offload { req_id: victim },
                KvPolicy::Conservative => unreachable!(),
            });
        }
        actions
    }

    /// Engine completed an offload: store the host copy.
    pub fn complete_offload(&mut self, req_id: u64, kv: HostKv) {
        let len = self.resident.remove(&req_id).unwrap_or(kv.len);
        self.used -= len;
        self.admission_order.retain(|&id| id != req_id);
        self.stats.offload_events += 1;
        self.stats.offloaded_tokens += len as u64;
        self.host.insert(req_id, kv);
        self.reload_queue.push_back(req_id);
    }

    /// Engine completed a preemption: account the recompute.
    pub fn complete_preempt(&mut self, req_id: u64) {
        if let Some(len) = self.resident.remove(&req_id) {
            self.used -= len;
            self.admission_order.retain(|&id| id != req_id);
            self.stats.recompute_events += 1;
            self.stats.recomputed_tokens += len as u64;
        }
    }

    /// Drop every trace of a request across both tiers — device
    /// accounting, host copies, and the reload queue.  The cancellation
    /// path uses this for requests that will never resume (a plain
    /// `release` only covers the device tier).
    pub fn forget(&mut self, req_id: u64) {
        if let Some(len) = self.resident.remove(&req_id) {
            self.used -= len;
            self.admission_order.retain(|&id| id != req_id);
            if self.policy == KvPolicy::Conservative {
                self.reserved -= self.worst_case;
            }
        }
        self.host.remove(&req_id);
        self.reload_queue.retain(|&id| id != req_id);
    }

    /// If capacity allows, pop the next offloaded request to reload
    /// (§4.4: "prioritizes scheduling the offloaded requests whenever GPU
    /// has available memory").
    pub fn try_reload(&mut self) -> Option<(u64, HostKv)> {
        let id = *self.reload_queue.front()?;
        let len = self.host.get(&id)?.len;
        if self.used + len + 16 > self.budget {
            return None;
        }
        self.reload_queue.pop_front();
        let kv = self.host.remove(&id)?;
        self.stats.reload_events += 1;
        Some((id, kv))
    }

    /// The request id [`Self::try_reload`] would pop next, without popping
    /// it (the engine's fault-injection hook checks reload I/O faults
    /// *before* the reload mutates queue/host state, so a skipped reload
    /// retries naturally on a later iteration).
    pub fn peek_reload(&self) -> Option<u64> {
        self.reload_queue.front().copied()
    }

    pub fn has_offloaded(&self) -> bool {
        !self.host.is_empty()
    }

    pub fn resident_len(&self, req_id: u64) -> Option<usize> {
        self.resident.get(&req_id).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest;

    #[test]
    fn conservative_reserves_worst_case() {
        let mut kv = KvManager::new(KvPolicy::Conservative, 1000, 400);
        assert!(kv.can_admit(50));
        kv.admit(1, 50);
        assert!(kv.can_admit(50));
        kv.admit(2, 50);
        // Two reservations of 400 leave no room for a third.
        assert!(!kv.can_admit(50));
        assert_eq!(kv.stats.rejected_conservative, 1);
        // Utilisation stays low even though budget is mostly unused.
        assert!(kv.utilization() < 0.2);
        // Conservative never produces pressure actions.
        kv.grow(1, 300);
        assert!(kv.check_pressure(&[]).is_empty());
    }

    #[test]
    fn dynamic_offloads_newest_first() {
        let mut kv = KvManager::new(KvPolicy::Dynamic, 300, 400);
        kv.admit(1, 100);
        kv.admit(2, 100);
        kv.admit(3, 80);
        kv.grow(1, 50); // used = 330 > 300
        let a = kv.check_pressure(&[]);
        assert_eq!(a, vec![PressureAction::Offload { req_id: 3 }]);
        kv.complete_offload(3, HostKv { k: vec![], v: vec![], len: 80 });
        assert_eq!(kv.used_tokens(), 250);
        assert!(kv.has_offloaded());
    }

    #[test]
    fn preempt_counts_recompute() {
        let mut kv = KvManager::new(KvPolicy::Preempt, 200, 400);
        kv.admit(1, 150);
        kv.admit(2, 60); // 210 > 200
        let a = kv.check_pressure(&[]);
        assert_eq!(a, vec![PressureAction::Preempt { req_id: 2 }]);
        kv.complete_preempt(2);
        assert_eq!(kv.stats.recomputed_tokens, 60);
        assert_eq!(kv.used_tokens(), 150);
    }

    #[test]
    fn reload_fifo_and_capacity_gated() {
        let mut kv = KvManager::new(KvPolicy::Dynamic, 300, 400);
        kv.admit(1, 280);
        kv.admit(2, 10);
        kv.admit(3, 20); // 310 > 300
        for act in kv.check_pressure(&[]) {
            if let PressureAction::Offload { req_id } = act {
                let len = kv.resident_len(req_id).unwrap();
                kv.complete_offload(req_id, HostKv { k: vec![], v: vec![], len });
            }
        }
        // No room to reload while request 1 occupies 280 of 300.
        assert!(kv.try_reload().is_none());
        kv.release(1);
        let (id, _) = kv.try_reload().expect("reload after release");
        assert_eq!(id, 3); // FIFO: 3 was offloaded first
    }

    #[test]
    fn forget_clears_both_tiers_and_reload_queue() {
        let mut kv = KvManager::new(KvPolicy::Dynamic, 300, 400);
        kv.admit(1, 100);
        kv.admit(2, 100);
        // 2 offloaded to host, then forgotten (cancelled)
        kv.complete_offload(2, HostKv { k: vec![], v: vec![], len: 100 });
        assert!(kv.has_offloaded());
        kv.forget(2);
        assert!(!kv.has_offloaded());
        assert!(kv.try_reload().is_none(), "forgotten id must not reload");
        // resident forget releases device accounting too
        kv.forget(1);
        assert_eq!(kv.used_tokens(), 0);
        // idempotent on unknown ids
        kv.forget(99);
    }

    #[test]
    fn protected_requests_not_victimised() {
        let mut kv = KvManager::new(KvPolicy::Dynamic, 100, 400);
        kv.admit(1, 60);
        kv.admit(2, 60);
        let a = kv.check_pressure(&[2]);
        assert_eq!(a, vec![PressureAction::Offload { req_id: 1 }]);
    }

    ptest!(accounting_never_negative_and_conserves, |g| {
        let policy = *g.pick(&[KvPolicy::Preempt, KvPolicy::Dynamic]);
        let budget = g.usize(100, 2000);
        let mut kv = KvManager::new(policy, budget, budget / 2);
        let mut live: Vec<u64> = Vec::new();
        let mut expected: i64 = 0;
        for step in 0..g.usize(10, 200) {
            let id = step as u64;
            let n = g.usize(1, 80);
            if kv.can_admit(n) && g.bool(0.6) {
                kv.admit(id, n);
                live.push(id);
                expected += n as i64;
            } else if !live.is_empty() && g.bool(0.5) {
                let idx = g.usize(0, live.len() - 1);
                let victim = live[idx];
                let grow = g.usize(1, 30);
                kv.grow(victim, grow);
                expected += grow as i64;
            } else if !live.is_empty() {
                let idx = g.usize(0, live.len() - 1);
                let victim = live.remove(idx);
                expected -= kv.resident_len(victim).unwrap_or(0) as i64;
                kv.release(victim);
            }
            for act in kv.check_pressure(&[]) {
                match act {
                    PressureAction::Offload { req_id } => {
                        let len = kv.resident_len(req_id).unwrap();
                        expected -= len as i64;
                        kv.complete_offload(
                            req_id,
                            HostKv { k: vec![], v: vec![], len },
                        );
                        live.retain(|&x| x != req_id);
                    }
                    PressureAction::Preempt { req_id } => {
                        expected -= kv.resident_len(req_id).unwrap() as i64;
                        kv.complete_preempt(req_id);
                        live.retain(|&x| x != req_id);
                    }
                }
            }
            assert_eq!(kv.used_tokens() as i64, expected, "accounting drift");
            assert!(kv.used_tokens() <= budget + 80 + 30, "unbounded overshoot");
        }
    });
}
