//! Deterministic CPU fallback runtime (the default backend).
//!
//! Replaces the PJRT artifact executor with a seeded **hash surrogate
//! model** while preserving every contract the serving layer depends on,
//! so engine, scheduler, KV-manager and session logic are exercised
//! end-to-end with zero native dependencies:
//!
//! * **KV pool layout** `[L, S, T, Hkv, D]` is identical to the artifacts,
//!   so offload row extraction (`Engine::extract_slot_rows`), `kv_dump` /
//!   `kv_load` round-trips and slot reuse behave exactly like the real
//!   path.  A token write stores `token + 1` at `d = 0` of every (layer,
//!   head) row; `0.0` means "empty".
//! * **Causal visibility**: the logits for a query at position `p` are a
//!   deterministic hash of the tokens at the last [`CTX`] positions
//!   `(p-CTX, p]` — read back *from the KV pool*, not from any shadow
//!   state — plus, for `p >= LONG_MIN`, the token at the long-range
//!   position `p/2`.  Rollback correctness therefore falls out the same
//!   way it does on device: stale rows beyond the frontier are rewritten
//!   before they are ever read.
//! * **Sparse visibility**: draft / sparse-verify steps only see positions
//!   present in their `[L, Hkv, W]` index sets, so drafter quality is
//!   real: a policy whose window covers the last `CTX` positions *and*
//!   whose selected pillars cover `p/2` reproduces the dense logits
//!   (high acceptance); one that misses them diverges (rejections).
//! * **Score dumps**: dense verification emits an attention-mass dump
//!   peaked at the sinks, the recent window, and a band around the
//!   long-range position `len/2` — exactly the signal PillarAttn selection
//!   needs to beat a pure sliding window, mirroring the paper's Fig. 3
//!   oracle-vs-window gap in miniature.
//! * **Greedy losslessness**: logits depend only on the visible token
//!   sequence, so speculative decoding reproduces vanilla outputs
//!   token-for-token for every drafter — the paper's core invariant stays
//!   testable without artifacts.
//!
//! Everything is integer hashing (`f32` values are exact 24-bit scaled
//! ints), so runs are bit-identical across platforms and runs.  The Python
//! cross-check of this model lives in
//! `python/tests/test_sim_runtime_port.py`.

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use super::{DraftOut, StepStats, VerifyOut};
use crate::model::{ModelConfig, SystemConfig};

/// Tokens of trailing causal context each logit row depends on.
pub const CTX: usize = 8;
/// Query positions `p >= LONG_MIN` additionally depend on the token at
/// position `p / 2` (the "long-range pillar" the dump advertises).
pub const LONG_MIN: usize = 24;
/// Half-width of the dump's high-mass band around `len / 2`.
pub const LONG_BAND: usize = 5;

#[inline]
fn mix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fill one vocab row of logits from a context hash.  Each value is a
/// 24-bit integer scaled by 2^-21 (exact in f32), spread over [0, 8).
fn fill_logits(h: u64, out: &mut [f32]) {
    for (v, o) in out.iter_mut().enumerate() {
        let x = mix64(h ^ (v as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93));
        *o = (x >> 40) as f32 * (8.0 / (1u64 << 24) as f32);
    }
}

#[inline]
fn pool_off(m: &ModelConfig, l: usize, s: usize, t: usize, h: usize, d: usize) -> usize {
    (((l * m.slots + s) * m.max_seq + t) * m.kv_heads + h) * m.head_dim + d
}

/// Write `token` into slot `s` position `t` of both pools (every layer and
/// head carries it, so any row subset survives offload round-trips).
fn write_token(kv_k: &mut [f32], kv_v: &mut [f32], m: &ModelConfig, s: usize, t: usize, token: i32) {
    let enc = (token + 1) as f32;
    for l in 0..m.layers {
        for h in 0..m.kv_heads {
            let off = pool_off(m, l, s, t, h, 0);
            kv_k[off] = enc;
            kv_v[off] = enc;
        }
    }
}

/// Read the token stored at slot `s` position `t` (-1 when empty).
#[inline]
fn read_token(kv_k: &[f32], m: &ModelConfig, s: usize, t: usize) -> i32 {
    kv_k[pool_off(m, 0, s, t, 0, 0)] as i32 - 1
}

/// Dense context hash for a query at position `p`: folds the long-range
/// token (if any) then the trailing window, in position order.
fn ctx_hash(kv_k: &[f32], m: &ModelConfig, s: usize, p: usize) -> u64 {
    let mut h = 0xC0FF_EE00_5EED_1234u64;
    if p >= LONG_MIN {
        let lp = p / 2;
        h = mix64(h ^ (read_token(kv_k, m, s, lp) + 1) as u64);
    }
    let start = (p + 1).saturating_sub(CTX);
    for t in start..=p {
        h = mix64(h ^ (read_token(kv_k, m, s, t) + 1) as u64);
    }
    h
}

/// Sparse context hash: identical fold, but a position contributes only if
/// it appears in `idx_row` (one (layer, head) row of the `[L, Hkv, W]`
/// index sets: ascending valid prefix, -1 tail).  All heads receive the
/// same dump in this backend, so row (0, 0) is representative.
fn sparse_ctx_hash(kv_k: &[f32], m: &ModelConfig, s: usize, p: usize, idx_row: &[i32]) -> u64 {
    let visible = |t: usize| -> bool {
        idx_row
            .iter()
            .take_while(|&&x| x >= 0)
            .any(|&x| x == t as i32)
    };
    let mut h = 0xC0FF_EE00_5EED_1234u64;
    if p >= LONG_MIN {
        let lp = p / 2;
        if visible(lp) {
            h = mix64(h ^ (read_token(kv_k, m, s, lp) + 1) as u64);
        }
    }
    let start = (p + 1).saturating_sub(CTX);
    for t in start..=p {
        if visible(t) {
            h = mix64(h ^ (read_token(kv_k, m, s, t) + 1) as u64);
        }
    }
    h
}

/// The attention-mass dump row for a context of length `len`: recency
/// decay + sink boost + a band around the long-range position `len/2`.
fn dump_mass(t: usize, len: usize) -> f32 {
    let mut mass = 1.0 / (1.0 + (len - 1 - t) as f32);
    if t < 4 {
        mass += 3.0;
    }
    if t.abs_diff(len / 2) <= LONG_BAND {
        mass += 2.0;
    }
    mass
}

/// What an artifact name resolves to in this backend (validation only —
/// there is nothing to compile).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
}

fn validate_artifact(m: &ModelConfig, name: &str) -> Result<()> {
    if let Some(q) = name.strip_prefix("verify_q") {
        let q: usize = q.parse().map_err(|_| anyhow!("bad artifact name '{name}'"))?;
        if m.verify_q_variants.contains(&q) {
            return Ok(());
        }
        return Err(anyhow!(
            "no verify_q{q} variant (have {:?}) — pick k so that k+1 is compiled",
            m.verify_q_variants
        ));
    }
    if let Some(w) = name.strip_prefix("draft_w") {
        let w: usize = w.parse().map_err(|_| anyhow!("bad artifact name '{name}'"))?;
        if m.draft_w_variants.contains(&w) {
            return Ok(());
        }
        return Err(anyhow!(
            "no draft_w{w} variant (have {:?})",
            m.draft_w_variants
        ));
    }
    match name {
        "prefill" | "sparse_verify" | "eagle" | "kv_load" | "draft_pallas" => Ok(()),
        other => Err(anyhow!("unknown artifact '{other}'")),
    }
}

/// Host buffer stand-in for `xla::PjRtBuffer` (API parity for upload/fetch
/// call sites; raw `execute` is a `pjrt`-only capability).
#[derive(Clone, Debug)]
pub enum Buffer {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

/// Deterministic fallback `Runtime`: carries the system configuration and
/// validates artifact names; the actual step math lives in `ModelRunner`.
pub struct Runtime {
    pub cfg: SystemConfig,
    /// (artifact name, "compile" seconds) log — kept for API parity with
    /// the PJRT backend (entries are all ~0 here).
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    /// Load `config.json` from `artifacts_dir` when present; otherwise fall
    /// back to the built-in testbed configuration so a fresh checkout
    /// serves without running `make artifacts`.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let cfg = if Path::new(artifacts_dir).join("config.json").exists() {
            SystemConfig::load(artifacts_dir)?
        } else {
            SystemConfig::synthetic(artifacts_dir)
        };
        Ok(Runtime { cfg, compile_log: RefCell::new(Vec::new()) })
    }

    /// Human-readable backend identifier (for banners and `info`).
    pub fn platform_name(&self) -> String {
        "sim-cpu (deterministic fallback; build with --features pjrt for XLA artifacts)".into()
    }

    /// Validate that `name` is an artifact this configuration could serve.
    pub fn executable(&self, name: &str) -> Result<Artifact> {
        validate_artifact(&self.cfg.model, name)?;
        self.compile_log.borrow_mut().push((name.to_string(), 0.0));
        Ok(Artifact { name: name.to_string() })
    }

    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    // ---- host <-> "device" marshalling (API parity) -------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        Ok(Buffer::F32(data.to_vec(), dims.to_vec()))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        Ok(Buffer::I32(data.to_vec(), dims.to_vec()))
    }

    pub fn fetch_f32(&self, buf: &Buffer) -> Result<Vec<f32>> {
        match buf {
            Buffer::F32(d, _) => Ok(d.clone()),
            Buffer::I32(..) => Err(anyhow!("buffer holds i32, asked for f32")),
        }
    }

    pub fn fetch_i32(&self, buf: &Buffer) -> Result<Vec<i32>> {
        match buf {
            Buffer::I32(d, _) => Ok(d.clone()),
            Buffer::F32(..) => Err(anyhow!("buffer holds f32, asked for i32")),
        }
    }

    /// Raw artifact execution is a PJRT capability (the compose-proof and
    /// Pallas comparison paths); the fallback serves only through
    /// `ModelRunner`'s typed step functions.
    pub fn execute(&self, name: &str, _args: &[&Buffer]) -> Result<Vec<Buffer>> {
        Err(anyhow!(
            "raw execution of artifact '{name}' requires the `pjrt` feature \
             (the deterministic fallback serves via ModelRunner only)"
        ))
    }

    /// Read a raw little-endian f32 file (weights.bin / eagle.bin).
    pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{path:?} is not a multiple of 4 bytes"));
        }
        let mut out = Vec::with_capacity(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }
}

/// Typed step-function runner over the hash surrogate model.  Signatures
/// and KV semantics mirror the PJRT `ModelRunner` exactly.
pub struct ModelRunner {
    pub rt: Rc<Runtime>,
    /// Copied out of `rt.cfg` once: step methods borrow this field
    /// directly so the hot loop never clones the config (the Vec-bearing
    /// `ModelConfig` clone per call would otherwise churn the allocator).
    mcfg: ModelConfig,
    kv_k: Vec<f32>,
    kv_v: Vec<f32>,
    pub stats: StepStats,
}

impl ModelRunner {
    pub fn new(rt: Rc<Runtime>) -> Result<Self> {
        let mcfg = rt.cfg.model.clone();
        let n = mcfg.kv_pool_elems();
        Ok(Self {
            rt,
            mcfg,
            kv_k: vec![0.0; n],
            kv_v: vec![0.0; n],
            stats: StepStats::default(),
        })
    }

    /// Owned config snapshot (cold paths / tests).
    fn m(&self) -> ModelConfig {
        self.mcfg.clone()
    }

    /// Zero both KV pools (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv_k.fill(0.0);
        self.kv_v.fill(0.0);
        Ok(())
    }

    /// Prefill the prompt chunk for newly-admitted slots.
    /// tokens: [S*P], plen/active: [S].  Returns last-token logits [S*V].
    pub fn prefill(&mut self, tokens: &[i32], plen: &[i32], active: &[i32]) -> Result<Vec<f32>> {
        let m = &self.mcfg;
        let (s_n, pad, v) = (m.slots, m.prompt_pad, m.vocab);
        debug_assert_eq!(tokens.len(), s_n * pad);
        let t0 = Instant::now();
        let mut logits = vec![0.0f32; s_n * v];
        for s in 0..s_n {
            if active[s] == 0 {
                continue;
            }
            let p = (plen[s].max(1) as usize).min(pad);
            for (j, &t) in tokens[s * pad..s * pad + p].iter().enumerate() {
                write_token(&mut self.kv_k, &mut self.kv_v, m, s, j, t);
            }
            let h = ctx_hash(&self.kv_k, m, s, p - 1);
            fill_logits(h, &mut logits[s * v..(s + 1) * v]);
        }
        self.stats.add("prefill", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(logits)
    }

    /// One sparse draft step (budget `w` must be a compiled variant).
    /// token/pos/active: [S]; idx: [S*L*Hkv*w] (-1 holes).
    pub fn draft(
        &mut self,
        w: usize,
        token: &[i32],
        pos: &[i32],
        idx: &[i32],
        active: &[i32],
    ) -> Result<DraftOut> {
        let m = &self.mcfg;
        let name = format!("draft_w{w}");
        validate_artifact(m, &name)?;
        let (s_n, v) = (m.slots, m.vocab);
        let per_slot = m.layers * m.kv_heads * w;
        debug_assert_eq!(idx.len(), s_n * per_slot);
        let t0 = Instant::now();
        let mut logits = vec![0.0f32; s_n * v];
        for s in 0..s_n {
            if active[s] == 0 {
                continue;
            }
            let p = pos[s].max(0) as usize;
            if p >= m.max_seq {
                continue;
            }
            write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, token[s]);
            let idx_row = &idx[s * per_slot..s * per_slot + w];
            let h = sparse_ctx_hash(&self.kv_k, m, s, p, idx_row);
            fill_logits(h, &mut logits[s * v..(s + 1) * v]);
        }
        self.stats.add(&name, 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(DraftOut { logits })
    }

    /// One dense verification step over q query tokens (compiled variant).
    /// tokens: [S*q]; pos/q_valid/active: [S].
    pub fn verify(
        &mut self,
        q: usize,
        tokens: &[i32],
        pos: &[i32],
        q_valid: &[i32],
        active: &[i32],
    ) -> Result<VerifyOut> {
        let m = &self.mcfg;
        let name = format!("verify_q{q}");
        validate_artifact(m, &name)?;
        let (s_n, v, t_dim) = (m.slots, m.vocab, m.max_seq);
        debug_assert_eq!(tokens.len(), s_n * q);
        let per_dump = m.layers * m.kv_heads * t_dim;
        let t0 = Instant::now();
        let mut logits = vec![0.0f32; s_n * q * v];
        let mut dump = vec![0.0f32; s_n * per_dump];
        for s in 0..s_n {
            if active[s] == 0 {
                continue;
            }
            let qv = (q_valid[s].max(1) as usize).min(q);
            let base = pos[s].max(0) as usize;
            for j in 0..qv {
                let p = base + j;
                if p >= t_dim {
                    break;
                }
                write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, tokens[s * q + j]);
                let h = ctx_hash(&self.kv_k, m, s, p);
                fill_logits(h, &mut logits[(s * q + j) * v..(s * q + j + 1) * v]);
            }
            let end = (base + qv).min(t_dim);
            for lh in 0..m.layers * m.kv_heads {
                let row = &mut dump[s * per_dump + lh * t_dim..s * per_dump + (lh + 1) * t_dim];
                for (t, x) in row.iter_mut().enumerate().take(end) {
                    *x = dump_mass(t, end);
                }
            }
        }
        self.stats.add(&name, 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(VerifyOut { logits, dump })
    }

    /// TriForce middle layer: verify q tokens under the sparse draft model.
    pub fn sparse_verify(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        q_valid: &[i32],
        idx: &[i32],
        active: &[i32],
    ) -> Result<Vec<f32>> {
        let m = &self.mcfg;
        let (s_n, v, w) = (m.slots, m.vocab, m.draft_budget);
        let q = m.spec_k + 1;
        let per_slot = m.layers * m.kv_heads * w;
        debug_assert_eq!(tokens.len(), s_n * q);
        debug_assert_eq!(idx.len(), s_n * per_slot);
        let t0 = Instant::now();
        let mut logits = vec![0.0f32; s_n * q * v];
        for s in 0..s_n {
            if active[s] == 0 {
                continue;
            }
            let qv = (q_valid[s].max(1) as usize).min(q);
            let base = pos[s].max(0) as usize;
            let idx_row = &idx[s * per_slot..s * per_slot + w];
            for j in 0..qv {
                let p = base + j;
                if p >= m.max_seq {
                    break;
                }
                write_token(&mut self.kv_k, &mut self.kv_v, m, s, p, tokens[s * q + j]);
                let h = sparse_ctx_hash(&self.kv_k, m, s, p, idx_row);
                fill_logits(h, &mut logits[(s * q + j) * v..(s * q + j + 1) * v]);
            }
        }
        self.stats
            .add("sparse_verify", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(logits)
    }

    /// EAGLE-like draft head: ctx [S*ECTX] -> logits [S*V].  The head sees
    /// only its short context window, so (as with an untrained head on the
    /// real path) its proposals are weaker than self-speculation.
    pub fn eagle(&mut self, ctx: &[i32]) -> Result<Vec<f32>> {
        let m = &self.mcfg;
        let ectx = self.rt.cfg.eagle.ctx;
        let (s_n, v) = (m.slots, m.vocab);
        debug_assert_eq!(ctx.len(), s_n * ectx);
        let t0 = Instant::now();
        let mut logits = vec![0.0f32; s_n * v];
        for s in 0..s_n {
            let mut h = 0xEA91_E000_0000_0001u64;
            for &t in &ctx[s * ectx..(s + 1) * ectx] {
                h = mix64(h ^ (t + 1) as u64);
            }
            fill_logits(h, &mut logits[s * v..(s + 1) * v]);
        }
        self.stats.add("eagle", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(logits)
    }

    /// Pull both KV pools to the host (offload path).
    /// Returns (k, v) each [L*S*T*Hkv*D].
    pub fn kv_dump(&mut self) -> Result<(Vec<f32>, Vec<f32>)> {
        let t0 = Instant::now();
        let out = (self.kv_k.clone(), self.kv_v.clone());
        self.stats
            .add("kv_dump", 0.0, 0.0, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    /// Write one slot's KV rows back into the device pools (onload path).
    /// rows_k/rows_v: [L*T*Hkv*D].
    pub fn kv_load(&mut self, slot: usize, rows_k: &[f32], rows_v: &[f32]) -> Result<()> {
        let m = &self.mcfg;
        debug_assert_eq!(rows_k.len(), m.kv_slot_elems());
        let t0 = Instant::now();
        let row = m.max_seq * m.kv_heads * m.head_dim;
        let per_l = m.slots * row;
        for l in 0..m.layers {
            let dst = l * per_l + slot * row;
            self.kv_k[dst..dst + row].copy_from_slice(&rows_k[l * row..(l + 1) * row]);
            self.kv_v[dst..dst + row].copy_from_slice(&rows_v[l * row..(l + 1) * row]);
        }
        self.stats
            .add("kv_load", 0.0, t0.elapsed().as_secs_f64(), 0.0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runner() -> ModelRunner {
        let rt = Rc::new(Runtime {
            cfg: SystemConfig::synthetic("artifacts"),
            compile_log: RefCell::new(Vec::new()),
        });
        ModelRunner::new(rt).unwrap()
    }

    #[test]
    fn logits_are_deterministic_and_in_range() {
        let mut row = vec![0.0f32; 64];
        fill_logits(1234, &mut row);
        let mut row2 = vec![0.0f32; 64];
        fill_logits(1234, &mut row2);
        assert_eq!(row, row2);
        assert!(row.iter().all(|&x| (0.0..8.0).contains(&x)));
        let mut row3 = vec![0.0f32; 64];
        fill_logits(1235, &mut row3);
        assert_ne!(row, row3);
    }

    #[test]
    fn prefill_then_verify_chain_is_causal() {
        let mut r = runner();
        let m = r.m();
        let mut tokens = vec![0i32; m.slots * m.prompt_pad];
        for j in 0..6 {
            tokens[j] = 16 + j as i32;
        }
        let mut plen = vec![1i32; m.slots];
        plen[0] = 6;
        let mut active = vec![0i32; m.slots];
        active[0] = 1;
        let l0 = r.prefill(&tokens, &plen, &active).unwrap();
        assert_eq!(l0.len(), m.slots * m.vocab);
        // one greedy verify step: writes position 6, logits differ from
        // the prefill row (context changed)
        let mut tok = vec![0i32; m.slots];
        tok[0] = 99;
        let mut pos = vec![0i32; m.slots];
        pos[0] = 6;
        let qv = vec![1i32; m.slots];
        let out = r.verify(1, &tok, &pos, &qv, &active).unwrap();
        assert_ne!(&out.logits[..m.vocab], &l0[..m.vocab]);
        // and the dump covers exactly [0, 7)
        assert!(out.dump[6] > 0.0);
        assert_eq!(out.dump[7], 0.0);
    }

    #[test]
    fn sparse_draft_matches_dense_when_window_covered() {
        let mut r = runner();
        let m = r.m();
        let mut tokens = vec![0i32; m.slots * m.prompt_pad];
        for j in 0..10 {
            tokens[j] = 20 + j as i32;
        }
        let mut plen = vec![1i32; m.slots];
        plen[0] = 10;
        let mut active = vec![0i32; m.slots];
        active[0] = 1;
        r.prefill(&tokens, &plen, &active).unwrap();

        // dense reference at position 10
        let mut tok = vec![0i32; m.slots];
        tok[0] = 7;
        let mut pos = vec![0i32; m.slots];
        pos[0] = 10;
        let qv = vec![1i32; m.slots];
        let dense = r.verify(1, &tok, &pos, &qv, &active).unwrap();

        // sparse with an index set covering every position <= 10
        let w = 16usize;
        let per_slot = m.layers * m.kv_heads * w;
        let mut idx = vec![-1i32; m.slots * per_slot];
        for lh in 0..m.layers * m.kv_heads {
            for j in 0..11 {
                idx[lh * w + j] = j as i32;
            }
        }
        let sparse = r.draft(w, &tok, &pos, &idx, &active).unwrap();
        assert_eq!(&sparse.logits[..m.vocab], &dense.logits[..m.vocab]);

        // drop position 10 (the fed token) from the set: logits diverge
        let mut idx2 = vec![-1i32; m.slots * per_slot];
        for lh in 0..m.layers * m.kv_heads {
            for j in 0..10 {
                idx2[lh * w + j] = j as i32;
            }
        }
        let sparse2 = r.draft(w, &tok, &pos, &idx2, &active).unwrap();
        assert_ne!(&sparse2.logits[..m.vocab], &dense.logits[..m.vocab]);
    }

    #[test]
    fn kv_roundtrip_preserves_tokens() {
        let mut r = runner();
        let m = r.m();
        write_token(&mut r.kv_k, &mut r.kv_v, &m, 3, 17, 123);
        let (k, v) = r.kv_dump().unwrap();
        // extract slot 3 rows the way the engine does
        let row = m.max_seq * m.kv_heads * m.head_dim;
        let per_l = m.slots * row;
        let mut rows_k = Vec::new();
        let mut rows_v = Vec::new();
        for l in 0..m.layers {
            let off = l * per_l + 3 * row;
            rows_k.extend_from_slice(&k[off..off + row]);
            rows_v.extend_from_slice(&v[off..off + row]);
        }
        r.reset_kv().unwrap();
        assert_eq!(read_token(&r.kv_k, &m, 3, 17), -1);
        r.kv_load(5, &rows_k, &rows_v).unwrap();
        assert_eq!(read_token(&r.kv_k, &m, 5, 17), 123);
    }

    #[test]
    fn artifact_validation() {
        let m = SystemConfig::synthetic("a").model;
        assert!(validate_artifact(&m, "prefill").is_ok());
        assert!(validate_artifact(&m, "verify_q9").is_ok());
        assert!(validate_artifact(&m, "verify_q7").is_err());
        assert!(validate_artifact(&m, "draft_w64").is_ok());
        assert!(validate_artifact(&m, "draft_w63").is_err());
        assert!(validate_artifact(&m, "bogus").is_err());
    }
}
