//! Per-slot request state.

use crate::spec::{DraftMode, NGramIndex, PillarState};
use crate::workload::Request;

/// Where a slot is inside its speculation round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Running sparse draft steps (self-spec) or collecting proposals.
    Drafting,
    /// Draft buffer full; waiting for the verification iteration.
    ReadyVerify,
    /// Verification launched; result consumed next iteration (§4.3).
    AwaitVerify,
}

impl Phase {
    /// Stable lowercase label for trace args and reports.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Drafting => "drafting",
            Phase::ReadyVerify => "ready_verify",
            Phase::AwaitVerify => "await_verify",
        }
    }
}

/// One resident request.
pub struct Slot {
    pub req: Request,
    /// KV frontier: positions [0, len) hold valid keys/values.
    pub len: usize,
    /// Accepted generated tokens so far (== output.len()).
    pub gen_count: usize,
    /// Next token to feed (sampled, KV not yet written).
    pub pending: i32,
    /// Anchor = round-start pending token (first token fed this round).
    pub anchor: i32,
    /// Anchor position == KV frontier at round start.
    pub round_start_len: usize,
    /// Drafted (provisional) tokens this round, in order.
    pub drafts: Vec<i32>,
    /// Draft distributions (k rows × vocab) for stochastic verification.
    pub draft_probs: Vec<f32>,
    /// How many drafts to take this round (shortened first round aligns
    /// the slot with its bucket — Fig. 8).
    pub draft_target: usize,
    pub phase: Phase,
    pub bucket: usize,
    /// Index into the engine's resolved drafter table (per-session
    /// drafter selection: every slot carries its own policy).
    pub drafter: usize,
    /// Cached `Drafter::mode()` of this slot's drafter (hot-loop gate).
    pub mode: DraftMode,
    /// Cached sparse budget W — selects the `draft_w{W}` artifact group
    /// this slot drafts in.
    pub draft_w: usize,
    /// Cached `Drafter::wants_dump_refresh()` — whether verification's
    /// score dump refreshes this slot's critical-token state.
    pub refresh_dump: bool,
    /// PillarAttn / window critical-token state.
    pub pillar: PillarState,
    /// N-gram history index (NGram + TriForce drafters).
    pub ngram: NGramIndex,
    /// Accepted output tokens.
    pub output: Vec<i32>,
    /// Wallclock admission time (for latency accounting).
    pub admitted_at: std::time::Instant,
    /// Simulated-clock admission time.
    pub sim_admitted_at: f64,
    /// Consecutive drafter faults (panic / malformed proposal) since the
    /// last clean round — reaching `fault::DEGRADE_FAULT_THRESHOLD`
    /// demotes the slot to vanilla decoding.
    pub faults: u32,
    /// Consecutive zero-accept speculation rounds (acceptance collapse).
    pub zero_accept_rounds: u32,
    /// Demoted to vanilla (k=1, non-speculative) decoding: the slot
    /// drafts nothing and takes one bonus token per verify round.
    pub degraded: bool,
    /// Remaining vanilla rounds before re-promotion back to speculation.
    pub probation: u32,
}

impl Slot {
    pub fn remaining(&self) -> usize {
        self.req.max_new.saturating_sub(self.gen_count)
    }

    pub fn done(&self) -> bool {
        self.gen_count >= self.req.max_new
    }

    /// The token sequence so far (prompt + accepted output).
    pub fn full_context(&self) -> Vec<i32> {
        let mut v = self.req.prompt.clone();
        v.extend_from_slice(&self.output);
        v
    }

    /// Record a drafter fault (panic / malformed proposal).  Returns true
    /// when the slot has crossed the demotion threshold.
    pub fn note_fault(&mut self) -> bool {
        self.faults += 1;
        !self.degraded && self.faults >= crate::fault::DEGRADE_FAULT_THRESHOLD
    }

    /// Record a finished speculation round's acceptance.  `speculated` is
    /// whether the round actually carried drafts (vanilla rounds don't
    /// count toward collapse).  Returns true when acceptance collapse
    /// says the slot should demote.
    pub fn note_round_accept(&mut self, accepted: usize, speculated: bool) -> bool {
        if !speculated || self.degraded {
            return false;
        }
        if accepted == 0 {
            self.zero_accept_rounds += 1;
        } else {
            self.zero_accept_rounds = 0;
            self.faults = 0; // a productive round clears fault pressure
        }
        self.zero_accept_rounds >= crate::fault::DEGRADE_ACCEPT_WINDOW
    }

    /// Demote to vanilla decoding for a probation window.
    pub fn demote(&mut self) {
        self.degraded = true;
        self.probation = crate::fault::PROBATION_ROUNDS;
        self.faults = 0;
        self.zero_accept_rounds = 0;
    }

    /// Tick the probation window at round start; returns true exactly
    /// when the slot re-promotes back to speculation.
    pub fn tick_probation(&mut self) -> bool {
        if !self.degraded {
            return false;
        }
        if self.probation <= 1 {
            self.degraded = false;
            self.probation = 0;
            true
        } else {
            self.probation -= 1;
            false
        }
    }

    /// Start a fresh speculation round.
    pub fn begin_round(&mut self, draft_target: usize) {
        self.anchor = self.pending;
        self.round_start_len = self.len;
        self.drafts.clear();
        self.draft_probs.clear();
        self.draft_target = draft_target;
        self.phase = if draft_target == 0 {
            Phase::ReadyVerify
        } else {
            Phase::Drafting
        };
    }
}
