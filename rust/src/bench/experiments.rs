//! Experiment implementations (one per paper artefact).

use super::BenchCtx;
use crate::engine::{Engine, EngineConfig, RunReport};
use crate::kv_cache::KvPolicy;
use crate::perfmodel::{DeviceModel, SpeedupModel};
use crate::scheduler::Schedule;
use crate::spec::DrafterKind;
use crate::workload::{Dataset, WorkloadGen};
use anyhow::Result;
use std::fmt::Write as _;

fn mk_requests(
    ctx: &mut BenchCtx,
    ds: Dataset,
    n: usize,
) -> Result<Vec<crate::workload::Request>> {
    let rt = ctx.rt()?;
    Ok(
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), ds, ctx.seed)
            .offline_batch(n),
    )
}

fn run_engine(ctx: &mut BenchCtx, cfg: EngineConfig, ds: Dataset, n: usize) -> Result<RunReport> {
    let reqs = mk_requests(ctx, ds, n)?;
    let rt = ctx.rt()?;
    let mut eng = Engine::new(rt, cfg)?;
    let r = eng.run(reqs)?;
    println!("  {}", r.summary());
    Ok(r)
}

// ---------------------------------------------------------------------
// Table 1 — dataset length statistics
// ---------------------------------------------------------------------
pub fn table1_dataset_stats(ctx: &mut BenchCtx) -> Result<()> {
    println!("Table 1: output-length statistics (scaled 1/50 vs paper; 2048 samples)");
    println!(
        "{:<16} {:>10} {:>16} {:>22}",
        "dataset", "avg input", "ours out (±std)", "paper out (±std)"
    );
    let mut csv = String::from("dataset,input_mean,out_mean,out_std,paper_mean,paper_std\n");
    for ds in [
        Dataset::Aime,
        Dataset::OlympiadBench,
        Dataset::LiveCodeBench,
        Dataset::NonReasoningAime,
    ] {
        let reqs = mk_requests(ctx, ds, 2048)?;
        let n = reqs.len() as f64;
        let im = reqs.iter().map(|r| r.prompt.len() as f64).sum::<f64>() / n;
        let om = reqs.iter().map(|r| r.max_new as f64).sum::<f64>() / n;
        let os = (reqs
            .iter()
            .map(|r| (r.max_new as f64 - om).powi(2))
            .sum::<f64>()
            / n)
            .sqrt();
        let (pm, ps) = ds.paper_profile();
        println!(
            "{:<16} {:>10.1} {:>9.1} ± {:<6.1} {:>13.0} ± {:<6.0}",
            ds.name(),
            im,
            om,
            os,
            pm,
            ps
        );
        let _ = writeln!(csv, "{},{im:.1},{om:.1},{os:.1},{pm},{ps}", ds.name());
    }
    ctx.save("table1.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 2 — compute / bandwidth utilisation of vanilla batch inference
// ---------------------------------------------------------------------
pub fn fig2_utilization(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 2: per-iteration utilisation of vanilla decoding (AIME profile)");
    let r = run_engine(
        ctx,
        EngineConfig::new(DrafterKind::Vanilla),
        Dataset::Aime,
        ctx.n_requests,
    )?;
    let dev = DeviceModel::default();
    // H100-scale flops per token row for a Qwen3-8B-ish model: 2*8e9.
    let flops_per_row = 2.0 * 8.0e9;
    let mut csv = String::from("iter,attn_frac,gemm_frac,bw_util,compute_util\n");
    let mut attn_sum = 0.0;
    let mut bw_sum = 0.0;
    let mut cu_sum = 0.0;
    // Scale the engine's real schedule to the paper's operating point.
    let rt = ctx.rt()?;
    let m = &rt.cfg.model;
    let sc = crate::perfmodel::SimScale::paper_scale(m.slots, m.kv_bytes_per_token());
    for (i, c) in r.trace.iters.iter().enumerate() {
        if c.gemm_rows == 0 {
            continue;
        }
        let u = dev.util_split(
            c.gemm_rows as f64 * sc.gemm_rows,
            c.attn_bytes as f64 * sc.kv_bytes,
            c.gemm_rows as f64 * sc.gemm_rows * flops_per_row,
            989e12,
        );
        attn_sum += u.attn_frac;
        bw_sum += u.bw_util;
        cu_sum += u.compute_util;
        let _ = writeln!(
            csv,
            "{i},{:.4},{:.4},{:.4},{:.4}",
            u.attn_frac, u.gemm_frac, u.bw_util, u.compute_util
        );
    }
    let n = r.trace.iters.iter().filter(|c| c.gemm_rows > 0).count() as f64;
    println!(
        "  mean attention share of iteration: {:.1}% (paper: >77%)",
        100.0 * attn_sum / n
    );
    println!(
        "  mean bandwidth util: {:.1}%  mean compute util: {:.1}% (paper: BW-bound, compute <50%)",
        100.0 * bw_sum / n,
        100.0 * cu_sum / n
    );
    ctx.save("fig2.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 3 — theoretical vs achieved speedup (window vs oracle top-k)
// ---------------------------------------------------------------------
pub fn fig3_theory_vs_achieved(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 3: theoretical & achieved speedup over vanilla (k=8, s=0.5)");
    let n = ctx.n_requests;
    let base = run_engine(ctx, EngineConfig::new(DrafterKind::Vanilla), Dataset::Aime, n)?;
    let rt = ctx.rt()?;
    let m = &rt.cfg.model;
    // s = 0.5 of the *mean resident context* (~260 tokens on the AIME
    // profile), matching the paper's definition of the sparsity ratio.
    let w_half = 128;
    let win = run_engine(
        ctx,
        EngineConfig::new(DrafterKind::Window { w: w_half }).with_k(8),
        Dataset::Aime,
        n,
    )?;
    let ora = run_engine(
        ctx,
        EngineConfig::new(DrafterKind::OracleTopK { w: w_half }).with_k(8),
        Dataset::Aime,
        n,
    )?;
    let sc = crate::perfmodel::SimScale::paper_scale(m.slots, m.kv_bytes_per_token());
    let kv_bytes = (ctx.n_requests * 300 * m.kv_bytes_per_token()) as f64 * sc.kv_bytes;
    let model = SpeedupModel {
        device: DeviceModel::default(),
        batch: 128.0,
        kv_bytes,
    };
    let s = 0.5;
    let theory_win = model.speedup(8.0, win.accept.alpha(), s);
    let theory_ora = model.speedup(8.0, ora.accept.alpha(), s);
    let ach_win = base.sim_s / win.sim_s;
    let ach_ora = base.sim_s / ora.sim_s;
    println!(
        "  window(MagicDec): alpha={:.2} theory={:.2}x achieved(sim)={:.2}x",
        win.accept.alpha(),
        theory_win,
        ach_win
    );
    println!(
        "  oracle top-k:     alpha={:.2} theory={:.2}x achieved(sim)={:.2}x",
        ora.accept.alpha(),
        theory_ora,
        ach_ora
    );
    println!("  (paper shape: oracle >> window in alpha; achieved < theory)");
    let csv = format!(
        "drafter,alpha,theory,achieved_sim\nwindow,{:.4},{:.4},{:.4}\noracle,{:.4},{:.4},{:.4}\n",
        win.accept.alpha(),
        theory_win,
        ach_win,
        ora.accept.alpha(),
        theory_ora,
        ach_ora
    );
    ctx.save("fig3.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 4 — attention-score dynamics over generation
// ---------------------------------------------------------------------
pub fn fig4_attention_dynamics(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 4: attention-score dynamics (verify dumps across decode steps)");
    use crate::runtime::ModelRunner;
    let rt = ctx.rt()?;
    let m = rt.cfg.model.clone();
    let mut runner = ModelRunner::new(rt.clone())?;
    let g = rt.cfg.grammar.clone();
    let prompt = crate::workload::TraceGen::prompt(ctx.seed, g);
    let s = m.slots;
    let p = m.prompt_pad;
    let mut tokens = vec![0i32; s * p];
    for (j, &t) in prompt.iter().enumerate() {
        tokens[j] = t;
    }
    let mut plen = vec![1i32; s];
    plen[0] = prompt.len() as i32;
    let mut active = vec![0i32; s];
    active[0] = 1;
    runner.prefill(&tokens, &plen, &active)?;
    let mut pending = crate::sampling::argmax(&runner.logits()[0..m.vocab]) as i32;
    let mut len = prompt.len();

    let steps = 256usize;
    let probe_every = 16usize;
    let mut csv = String::from("step,position,score\n");
    let mut snapshots = 0;
    let mut drift_pairs: Vec<Vec<usize>> = Vec::new();
    for step in 0..steps {
        let mut tok = vec![0i32; s];
        tok[0] = pending;
        let mut pos = vec![0i32; s];
        pos[0] = len as i32;
        let qv = vec![1i32; s];
        runner.verify(1, &tok, &pos, &qv, &active)?;
        len += 1;
        pending = crate::sampling::argmax(&runner.logits()[0..m.vocab]) as i32;
        if step % probe_every == 0 {
            // aggregate dump over layers+heads for slot 0
            let t = m.max_seq;
            let per = m.layers * m.kv_heads * t;
            let d = &runner.dump()[0..per];
            let mut agg = vec![0.0f32; t];
            for lh in 0..(m.layers * m.kv_heads) {
                for x in 0..t {
                    agg[x] += d[lh * t + x];
                }
            }
            for (x, &v) in agg.iter().enumerate().take(len) {
                let _ = writeln!(csv, "{step},{x},{:.5}", v);
            }
            // top-16 critical positions at this snapshot
            let mut order: Vec<usize> = (0..len).collect();
            order.sort_by(|&a, &b| agg[b].partial_cmp(&agg[a]).unwrap());
            drift_pairs.push(order.into_iter().take(16).collect());
            snapshots += 1;
        }
    }
    // Context-dynamics measure: Jaccard similarity of consecutive top-16 sets.
    let mut jac = Vec::new();
    for w in drift_pairs.windows(2) {
        let a: std::collections::HashSet<_> = w[0].iter().collect();
        let b: std::collections::HashSet<_> = w[1].iter().collect();
        let inter = a.intersection(&b).count() as f64;
        jac.push(inter / (a.len() + b.len()) as f64 * 2.0 / (2.0 - inter / (a.len().max(1)) as f64 * 0.0));
    }
    let mean_j: f64 = jac.iter().sum::<f64>() / jac.len().max(1) as f64;
    println!(
        "  {snapshots} snapshots; mean Jaccard overlap of consecutive top-16 critical sets: {:.2}",
        mean_j
    );
    println!("  (<1.0 means the critical set drifts over generation — the paper's context dynamics)");
    ctx.save("fig4.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 5 — memory utilisation & recomputation under the three policies
// ---------------------------------------------------------------------
pub fn fig5_memory_policies(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 5: KV utilisation & recomputation (device budget = 25% of pool)");
    let rt = ctx.rt()?;
    let budget = rt.cfg.model.slots * rt.cfg.model.max_seq / 4;
    let n = ctx.n_requests * 3; // oversubscribe to create pressure
    let mut csv = String::from("policy,iter,utilization\n");
    let mut summary = String::from("policy,mean_util,peak_util,recomputed_tokens,offload_events,stall_s\n");
    for (policy, name) in [
        (KvPolicy::Conservative, "conservative"),
        (KvPolicy::Preempt, "preempt"),
        (KvPolicy::Dynamic, "dynamic"),
    ] {
        let cfg = EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_kv(policy, budget);
        let r = run_engine(ctx, cfg, Dataset::Aime, n)?;
        let trace_util: Vec<f64> = r
            .trace
            .iters
            .iter()
            .enumerate()
            .map(|(i, _)| {
                let _ = i;
                0.0
            })
            .collect();
        let _ = trace_util;
        let _ = writeln!(
            summary,
            "{name},{:.3},{:.3},{},{},{:.4}",
            r.mean_kv_util,
            r.kv.peak_used_tokens as f64 / budget as f64,
            r.kv.recomputed_tokens,
            r.kv.offload_events,
            r.offload.stall_s
        );
        let _ = writeln!(csv, "{name},end,{:.3}", r.mean_kv_util);
        println!(
            "  {name:<13} mean_util={:.2} peak={:.2} recomputed={} offloads={} offload_stall={:.1}ms",
            r.mean_kv_util,
            r.kv.peak_used_tokens as f64 / budget as f64,
            r.kv.recomputed_tokens,
            r.kv.offload_events,
            r.offload.stall_s * 1e3,
        );
    }
    println!("  (paper shape: conservative underutilises; preempt recomputes; dynamic ~full util, 0 recompute)");
    ctx.save("fig5_summary.csv", &summary)?;
    ctx.save("fig5.csv", &csv)
}

// ---------------------------------------------------------------------
// Table 2 — execution-time breakdown
// ---------------------------------------------------------------------
pub fn table2_breakdown(ctx: &mut BenchCtx) -> Result<()> {
    println!("Table 2: per-iteration execution breakdown (simulated H100 ms, AIME-long)");
    let dev = DeviceModel::default();
    let mut csv = String::from("system,cpu_ms,attn_ms,gemm_ms,total_ms\n");
    for (name, cfg) in [
        ("vanilla(vLLM)", EngineConfig::new(DrafterKind::Vanilla)),
        (
            "SparseSpec",
            EngineConfig::new(DrafterKind::Pillar { w: 64 })
                .with_k(8)
                .with_schedule(Schedule::Unified, true),
        ),
    ] {
        let r = run_engine(ctx, cfg, Dataset::AimeLong, ctx.n_requests)?;
        let iters = r.trace.iters.len().max(1) as f64;
        let rt = ctx.rt()?;
        let m = &rt.cfg.model;
        let sc = crate::perfmodel::SimScale::paper_scale(m.slots, m.kv_bytes_per_token());
        let attn: f64 = r
            .trace
            .iters
            .iter()
            .map(|c| dev.t_attn(c.attn_bytes as f64 * sc.kv_bytes))
            .sum::<f64>()
            / iters;
        let gemm: f64 = r
            .trace
            .iters
            .iter()
            .map(|c| dev.t_gemm(c.gemm_rows as f64 * sc.gemm_rows))
            .sum::<f64>()
            / iters;
        // CPU: measured host bookkeeping per iteration (paper's CPU column).
        let cpu = if r.sim_cpu_s > 0.0 {
            r.sim_cpu_s / iters
        } else {
            0.0002
        };
        // Normalise per *generated token* so vanilla/spec are comparable:
        let per_tok = (attn + gemm + cpu) * iters / r.tokens_generated as f64;
        println!(
            "  {name:<14} cpu={:>6.2}ms attn={:>6.2}ms gemm={:>6.2}ms | per-iter {:.2}ms, per-token {:.2}ms",
            cpu * 1e3,
            attn * 1e3,
            gemm * 1e3,
            (attn + gemm + cpu) * 1e3,
            per_tok * 1e3,
        );
        let _ = writeln!(
            csv,
            "{name},{:.3},{:.3},{:.3},{:.3}",
            cpu * 1e3,
            attn * 1e3,
            gemm * 1e3,
            (cpu + attn + gemm) * 1e3
        );
    }
    println!("  (paper shape: attention cut ~3x, GEMM up ~25%, CPU <1ms with delayed verification)");
    ctx.save("table2.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 10 — end-to-end throughput vs training-free baselines
// ---------------------------------------------------------------------
pub fn fig10_training_free(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 10: e2e throughput, training-free systems (wall + simulated-H100)");
    // Sparse budgets sit at the acceptance-saturation knee of the
    // sensitivity sweep (fig12_sens), exactly how the paper picked its
    // s=0.05; same budget for every sparse baseline for fairness.
    let systems: Vec<(&str, DrafterKind)> = vec![
        ("vllm", DrafterKind::Vanilla),
        ("vllm-ngram", DrafterKind::NGram { n: 3 }),
        ("magicdec", DrafterKind::Window { w: 128 }),
        ("triforce", DrafterKind::TriForce { w: 64 }), // sparse_verify artifact is W=64
        ("sparsespec", DrafterKind::Pillar { w: 128 }),
    ];
    let mut csv = String::from("dataset,system,wall_tok_s,sim_tok_s,alpha,mean_accepted\n");
    for ds in [
        Dataset::Aime,
        Dataset::OlympiadBench,
        Dataset::LiveCodeBench,
        Dataset::AimeLong,
    ] {
        println!("  --- {} ---", ds.name());
        let mut base_sim = 0.0;
        for (name, d) in &systems {
            let r = run_engine(ctx, EngineConfig::new(*d).with_k(8), ds, ctx.n_requests)?;
            if *name == "vllm" {
                base_sim = r.sim_tok_s();
            }
            let _ = writeln!(
                csv,
                "{},{},{:.2},{:.2},{:.4},{:.3}",
                ds.name(),
                name,
                r.wall_tok_s(),
                r.sim_tok_s(),
                r.accept.alpha(),
                r.accept.mean_accepted()
            );
            if *name != "vllm" && base_sim > 0.0 {
                println!(
                    "      -> sim speedup vs vLLM: {:.2}x",
                    r.sim_tok_s() / base_sim
                );
            }
        }
    }
    println!("  (paper shape: sparsespec > magicdec > triforce > ngram ≈/> vllm)");
    ctx.save("fig10.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 11 — vs draft-model-based speculation (EAGLE-like)
// ---------------------------------------------------------------------
pub fn fig11_draft_model(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 11: SparseSpec vs trained-draft-head (EAGLE-like, k=3 per paper)");
    let mut csv = String::from("dataset,system,wall_tok_s,sim_tok_s,alpha\n");
    for ds in Dataset::all() {
        println!("  --- {} ---", ds.name());
        for (name, cfg) in [
            ("vllm", EngineConfig::new(DrafterKind::Vanilla)),
            // k=4 (nearest compiled variant to the paper's EAGLE k=3)
            ("eagle", EngineConfig::new(DrafterKind::Eagle).with_k(4)),
            (
                "sparsespec",
                EngineConfig::new(DrafterKind::Pillar { w: 128 }).with_k(8),
            ),
        ] {
            let r = run_engine(ctx, cfg, ds, ctx.n_requests)?;
            let _ = writeln!(
                csv,
                "{},{},{:.2},{:.2},{:.4}",
                ds.name(),
                name,
                r.wall_tok_s(),
                r.sim_tok_s(),
                r.accept.alpha()
            );
        }
    }
    println!("  (paper shape: sparsespec >= eagle without any training)");
    ctx.save("fig11.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 12 left — accepted tokens per drafter
// ---------------------------------------------------------------------
pub fn fig12_acceptance(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 12 (left): accepted tokens out of k=8 drafts (bonus not counted)");
    let mut csv = String::from("drafter,dataset,mean_accepted,alpha\n");
    for (name, d) in [
        ("eagle3", DrafterKind::Eagle),
        ("ngram", DrafterKind::NGram { n: 3 }),
        ("streaming", DrafterKind::Window { w: 64 }),
        ("sparsespec", DrafterKind::Pillar { w: 64 }),
    ] {
        let mut accs = Vec::new();
        for ds in Dataset::all() {
            let r = run_engine(ctx, EngineConfig::new(d).with_k(8), ds, ctx.n_requests / 2)?;
            accs.push(r.accept.mean_accepted());
            let _ = writeln!(
                csv,
                "{name},{},{:.3},{:.4}",
                ds.name(),
                r.accept.mean_accepted(),
                r.accept.alpha()
            );
        }
        let mean: f64 = accs.iter().sum::<f64>() / accs.len() as f64;
        println!("  {name:<12} mean accepted = {mean:.2} / 8");
    }
    println!("  (paper: sparsespec 6.16/8; ngram & eagle <2 on reasoning workloads)");
    ctx.save("fig12_accept.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 12 right — sensitivity to sparsity budget and stride k
// ---------------------------------------------------------------------
pub fn fig12_sensitivity(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 12 (right): PillarAttn acceptance sensitivity");
    let mut csv = String::from("axis,value,alpha,mean_accepted\n");
    let rt = ctx.rt()?;
    println!("  budget sweep (k=8):");
    for w in rt.cfg.model.draft_w_variants.clone() {
        let r = run_engine(
            ctx,
            EngineConfig::new(DrafterKind::Pillar { w }).with_k(8),
            Dataset::Aime,
            ctx.n_requests / 2,
        )?;
        println!(
            "    W={w:<4} (s={:.3}) alpha={:.2} accepted={:.2}",
            w as f64 / rt.cfg.model.max_seq as f64,
            r.accept.alpha(),
            r.accept.mean_accepted()
        );
        let _ = writeln!(csv, "budget,{w},{:.4},{:.3}", r.accept.alpha(), r.accept.mean_accepted());
    }
    println!("  stride sweep (W=64):");
    for q in rt.cfg.model.verify_q_variants.clone() {
        let k = q - 1;
        if k == 0 {
            continue;
        }
        let r = run_engine(
            ctx,
            EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(k),
            Dataset::Aime,
            ctx.n_requests / 2,
        )?;
        println!(
            "    k={k:<3} alpha={:.2} accepted={:.2}",
            r.accept.alpha(),
            r.accept.mean_accepted()
        );
        let _ = writeln!(csv, "stride,{k},{:.4},{:.3}", r.accept.alpha(), r.accept.mean_accepted());
    }
    println!("  (paper shape: alpha saturates with budget; degrades slowly with k)");
    ctx.save("fig12_sens.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 13 — ablation: naive -> +unified -> +kv manager -> +delayed
// ---------------------------------------------------------------------
pub fn fig13_ablation(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 13: ablation (simulated-H100 throughput, AIME)");
    let rt = ctx.rt()?;
    let budget = rt.cfg.model.slots * rt.cfg.model.max_seq / 4;
    let n = ctx.n_requests * 2;
    let steps: Vec<(&str, EngineConfig)> = vec![
        (
            "naive",
            EngineConfig::new(DrafterKind::Pillar { w: 64 })
                .with_k(8)
                .with_schedule(Schedule::Lockstep, false)
                .with_kv(KvPolicy::Preempt, budget),
        ),
        (
            "+unified",
            EngineConfig::new(DrafterKind::Pillar { w: 64 })
                .with_k(8)
                .with_schedule(Schedule::Unified, false)
                .with_kv(KvPolicy::Preempt, budget),
        ),
        (
            "+kv-manager",
            EngineConfig::new(DrafterKind::Pillar { w: 64 })
                .with_k(8)
                .with_schedule(Schedule::Unified, false)
                .with_kv(KvPolicy::Dynamic, budget),
        ),
        (
            "+delayed-verify",
            EngineConfig::new(DrafterKind::Pillar { w: 64 })
                .with_k(8)
                .with_schedule(Schedule::Unified, true)
                .with_kv(KvPolicy::Dynamic, budget),
        ),
    ];
    let mut csv = String::from("config,sim_tok_s,wall_tok_s,cum_speedup\n");
    let mut first = 0.0;
    for (name, cfg) in steps {
        let r = run_engine(ctx, cfg, Dataset::AimeLong, n)?;
        if first == 0.0 {
            first = r.sim_tok_s();
        }
        println!(
            "  {name:<16} sim {:.1} tok/s  (cumulative {:.2}x)",
            r.sim_tok_s(),
            r.sim_tok_s() / first
        );
        let _ = writeln!(
            csv,
            "{name},{:.2},{:.2},{:.3}",
            r.sim_tok_s(),
            r.wall_tok_s(),
            r.sim_tok_s() / first
        );
    }
    println!("  (paper: 1.23x, 1.61x, 1.12x component gains, ~2.2x aggregate)");
    ctx.save("fig13.csv", &csv)
}

// ---------------------------------------------------------------------
// Fig. 14 — GEMM batch-size trace: naive vs unified scheduling
// ---------------------------------------------------------------------
pub fn fig14_schedule_trace(ctx: &mut BenchCtx) -> Result<()> {
    println!("Fig 14: GEMM input rows per iteration (lockstep vs unified)");
    let mut out = String::new();
    for (name, sched) in [("naive", Schedule::Lockstep), ("unified", Schedule::Unified)] {
        let cfg = EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_schedule(sched, false);
        let r = run_engine(ctx, cfg, Dataset::Aime, ctx.n_requests)?;
        let sd = r.trace.gemm_rows_stddev();
        let mean: f64 = r.trace.iters.iter().map(|c| c.gemm_rows as f64).sum::<f64>()
            / r.trace.iters.len().max(1) as f64;
        println!("  {name:<8} gemm rows: mean={mean:.1} stddev={sd:.1}");
        out.push_str(&format!("# {name}\n"));
        out.push_str(&r.trace.csv());
        ctx.save(&format!("fig14_{name}.csv"), &r.trace.csv())?;
    }
    println!("  (paper shape: unified keeps rows flat; naive alternates draft/verify spikes)");
    Ok(())
}
